"""AOT lowering checks: HLO text is produced, parseable-looking, and the
manifest is consistent.  (The authoritative load check lives on the Rust
side — rust/tests/runtime_artifacts.rs — which compiles the text through
the real PJRT client.)"""

import os
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def lowered_dir():
    with tempfile.TemporaryDirectory() as d:
        lines = aot.lower_preset("mlp_s", aot.PRESETS["mlp_s"], d)
        with open(os.path.join(d, "manifest.txt"), "w") as f:
            f.write("\n".join(lines) + "\n")
        yield d


def test_all_artifacts_written(lowered_dir):
    for art in ["init", "step", "step_k", "eval", "qavg"]:
        path = os.path.join(lowered_dir, f"mlp_s_{art}.hlo.txt")
        assert os.path.exists(path), art
        text = open(path).read()
        assert text.startswith("HloModule"), f"{art}: not HLO text"
        assert "ENTRY" in text


def test_hlo_has_tuple_root(lowered_dir):
    """return_tuple=True — the Rust side unwraps with to_tuple*()."""
    text = open(os.path.join(lowered_dir, "mlp_s_step.hlo.txt")).read()
    assert "ROOT" in text
    root_line = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
    assert root_line, "expected a tuple-shaped ROOT"


def test_manifest_fields(lowered_dir):
    text = open(os.path.join(lowered_dir, "manifest.txt")).read()
    assert "[mlp_s]" in text
    for key in ["param_count", "batch", "k", "step", "step_k", "eval", "init", "qavg"]:
        assert f"{key} = " in text


def test_no_serialized_protos(lowered_dir):
    """Guard: we must never emit binary protos (xla_extension 0.5.1 rejects
    jax>=0.5 64-bit ids) — everything is text."""
    for f in os.listdir(lowered_dir):
        if f.endswith(".hlo.txt"):
            head = open(os.path.join(lowered_dir, f), "rb").read(64)
            head.decode("utf-8")  # must be valid text


def test_presets_cover_models():
    models = {p["model"] for p in aot.PRESETS.values()}
    assert models == {"mlp", "cnn", "transformer"}
