"""L2 model checks: shapes, packing round-trips, loss decrease, artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import build
from compile.models import REGISTRY
from compile.packing import ParamSpec

jax.config.update("jax_platform_name", "cpu")

SMALL_CFGS = {
    "mlp": dict(in_dim=16, hidden=32, depth=2, classes=5, batch=8),
    "cnn": dict(image=8, chan_in=3, width=8, depth=2, classes=5, batch=4),
    "transformer": dict(vocab=32, d_model=32, heads=2, layers=1, seq=8, batch=4),
}


def _batch(name, cfg, key=0):
    r = np.random.default_rng(key)
    if name == "mlp":
        x = jnp.array(r.normal(size=(cfg["batch"], cfg["in_dim"])), jnp.float32)
        y = jnp.array(r.integers(0, cfg["classes"], cfg["batch"]), jnp.int32)
    elif name == "cnn":
        x = jnp.array(
            r.normal(size=(cfg["batch"], cfg["image"], cfg["image"], cfg["chan_in"])),
            jnp.float32,
        )
        y = jnp.array(r.integers(0, cfg["classes"], cfg["batch"]), jnp.int32)
    else:
        x = jnp.array(
            r.integers(0, cfg["vocab"], (cfg["batch"], cfg["seq"])), jnp.int32
        )
        y = jnp.array(
            r.integers(0, cfg["vocab"], (cfg["batch"], cfg["seq"])), jnp.int32
        )
    return x, y


class TestPacking:
    def test_roundtrip(self):
        s = ParamSpec()
        s.add("a", (3, 4)).add("b", (5,)).add("c_b", (7,))
        flat = s.init_flat(jax.random.PRNGKey(0))
        assert flat.shape == (3 * 4 + 5 + 7,)
        parts = s.unpack(flat)
        assert parts["a"].shape == (3, 4)
        assert parts["b"].shape == (5,)
        # biases init to zero
        np.testing.assert_array_equal(parts["c_b"], np.zeros(7))
        # re-concatenation reproduces the flat vector
        recon = jnp.concatenate([parts[n].reshape(-1) for n, _, _ in s.entries])
        np.testing.assert_array_equal(recon, flat)

    def test_offsets_disjoint_and_total(self):
        s = ParamSpec()
        s.add("x", (10, 10)).add("y", (100,)).add("z", (2, 3, 4))
        offs = s.offsets()
        spans = sorted((o, o + int(np.prod(sh))) for o, sh in offs.values())
        for (a0, a1), (b0, _) in zip(spans, spans[1:]):
            assert a1 == b0
        assert spans[-1][1] == s.size

    def test_ln_scale_init_ones(self):
        s = ParamSpec()
        s.add("l_ln_s", (4,)).add("l_ln_b", (4,))
        flat = s.init_flat(jax.random.PRNGKey(1))
        p = s.unpack(flat)
        np.testing.assert_array_equal(p["l_ln_s"], np.ones(4))
        np.testing.assert_array_equal(p["l_ln_b"], np.zeros(4))


@pytest.mark.parametrize("name", list(REGISTRY))
class TestModels:
    def test_param_count_positive(self, name):
        fns = build(name, SMALL_CFGS[name])
        assert fns["param_count"] > 0

    def test_init_shapes(self, name):
        fns = build(name, SMALL_CFGS[name])
        p, m = fns["init"](jnp.int32(0))
        assert p.shape == (fns["param_count"],)
        assert m.shape == p.shape
        assert float(jnp.abs(m).max()) == 0.0
        assert bool(jnp.all(jnp.isfinite(p)))

    def test_init_seed_sensitivity(self, name):
        fns = build(name, SMALL_CFGS[name])
        p0, _ = fns["init"](jnp.int32(0))
        p1, _ = fns["init"](jnp.int32(1))
        assert not np.allclose(np.asarray(p0), np.asarray(p1))

    def test_loss_decreases(self, name):
        cfg = SMALL_CFGS[name]
        fns = build(name, cfg)
        p, m = fns["init"](jnp.int32(0))
        x, y = _batch(name, cfg)
        step = jax.jit(fns["train_step"])
        first = None
        for _ in range(15):
            p, m, l = step(p, m, x, y, jnp.float32(0.05))
            first = first if first is not None else float(l)
        assert float(l) < first, f"{name}: {first} -> {float(l)}"
        assert np.isfinite(float(l))

    def test_step_k_equals_k_steps(self, name):
        """The scan'd fast path must equal k sequential single steps."""
        cfg = SMALL_CFGS[name]
        fns = build(name, cfg)
        p0, m0 = fns["init"](jnp.int32(3))
        k = 3
        xs, ys = zip(*[_batch(name, cfg, key=i) for i in range(k)])
        xs = jnp.stack(xs)
        ys = jnp.stack(ys)
        lr = jnp.float32(0.02)
        pk, mk, lk = jax.jit(fns["train_step_k"])(p0, m0, xs, ys, lr)
        p, m = p0, m0
        ls = []
        for i in range(k):
            p, m, l = jax.jit(fns["train_step"])(p, m, xs[i], ys[i], lr)
            ls.append(float(l))
        np.testing.assert_allclose(np.asarray(pk), np.asarray(p), rtol=2e-4, atol=2e-6)
        np.testing.assert_allclose(float(lk), np.mean(ls), rtol=1e-5)

    def test_eval_metrics(self, name):
        cfg = SMALL_CFGS[name]
        fns = build(name, cfg)
        p, _ = fns["init"](jnp.int32(0))
        x, y = _batch(name, cfg)
        loss, correct = fns["eval_step"](p, x, y)
        n_pred = y.size
        assert 0.0 <= float(correct) <= n_pred
        assert np.isfinite(float(loss))

    def test_qavg_step_midpoint(self, name):
        cfg = SMALL_CFGS[name]
        fns = build(name, cfg)
        p0, _ = fns["init"](jnp.int32(0))
        p1, _ = fns["init"](jnp.int32(1))
        avg = fns["qavg_step"](p0, p1, jnp.uint32(9))
        mid = (np.asarray(p0) + np.asarray(p1)) / 2
        # quantized average is within eps/2 of the true midpoint per coord
        assert np.abs(np.asarray(avg) - mid).max() <= 1e-3


class TestTransformerSpecifics:
    def test_causality(self):
        """Future tokens must not influence earlier logits."""
        cfg = SMALL_CFGS["transformer"]
        from compile.models import transformer as tr

        spec_ = tr.spec(cfg)
        flat = spec_.init_flat(jax.random.PRNGKey(0))
        r = np.random.default_rng(0)
        x1 = jnp.array(r.integers(0, cfg["vocab"], (1, cfg["seq"])), jnp.int32)
        x2 = np.asarray(x1).copy()
        x2[0, -1] = (x2[0, -1] + 1) % cfg["vocab"]  # change ONLY the last token
        x2 = jnp.array(x2)
        l1 = tr.forward(spec_, cfg, flat, x1)
        l2 = tr.forward(spec_, cfg, flat, x2)
        np.testing.assert_allclose(
            np.asarray(l1)[0, :-1], np.asarray(l2)[0, :-1], atol=1e-5
        )
        assert not np.allclose(np.asarray(l1)[0, -1], np.asarray(l2)[0, -1])

    def test_loss_at_init_near_uniform(self):
        cfg = SMALL_CFGS["transformer"]
        fns = build("transformer", cfg)
        p, _ = fns["init"](jnp.int32(0))
        x, y = _batch("transformer", cfg)
        loss, _ = fns["eval_step"](p, x, y)
        assert abs(float(loss) - np.log(cfg["vocab"])) < 0.5
