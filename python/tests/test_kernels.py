"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes/seeds; every Pallas kernel must agree with
its pure-jnp reference (exact for integer lattice coordinates, allclose for
float compositions).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    lattice_qavg,
    lattice_quantize,
    matmul,
    sgd_momentum_update,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=200)
seeds = st.integers(min_value=0, max_value=2**32 - 1)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, key=st.integers(0, 1000))
    def test_matches_ref(self, m, k, n, key):
        x = _rand(key, (m, k))
        y = _rand(key + 1, (k, n))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize(
        "m,k,n",
        [(1, 1, 1), (128, 128, 128), (129, 127, 130), (256, 64, 512), (7, 300, 5)],
    )
    def test_edge_shapes(self, m, k, n):
        x = _rand(0, (m, k))
        y = _rand(1, (k, n))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
        )

    def test_gradients_match_ref(self):
        x = _rand(2, (40, 30))
        y = _rand(3, (30, 20))

        def f_pl(a, b):
            return jnp.sum(jnp.tanh(matmul(a, b)))

        def f_ref(a, b):
            return jnp.sum(jnp.tanh(ref.matmul_ref(a, b)))

        g = jax.grad(f_pl, argnums=(0, 1))(x, y)
        gr = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(g[0], gr[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g[1], gr[1], rtol=1e-4, atol=1e-5)

    def test_zero_inputs(self):
        x = jnp.zeros((16, 16))
        y = jnp.zeros((16, 16))
        assert float(jnp.abs(matmul(x, y)).max()) == 0.0


# ---------------------------------------------------------------------------
# lattice quantizer (paper Appendix G / Davies et al. [12])
# ---------------------------------------------------------------------------
class TestLatticeQuantize:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 5000), seed=seeds, key=st.integers(0, 1000))
    def test_exact_match_vs_ref(self, n, seed, key):
        y = _rand(key, (n,))
        got = lattice_quantize(y, jnp.uint32(seed), eps=0.01)
        want = ref.lattice_quantize_ref(y, seed, eps=0.01)
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 2000), seed=seeds, key=st.integers(0, 1000))
    def test_qavg_matches_ref(self, n, seed, key):
        x = _rand(key, (n,))
        y = _rand(key + 1, (n,))
        got = lattice_qavg(x, y, jnp.uint32(seed), eps=0.01)
        want = ref.lattice_qavg_ref(x, y, seed, eps=0.01)
        np.testing.assert_allclose(got, want, atol=1e-6)

    @pytest.mark.parametrize("eps", [1e-4, 1e-3, 1e-2, 0.1])
    def test_error_bounded_by_eps(self, eps):
        y = _rand(9, (4096,))
        q = lattice_quantize(y, jnp.uint32(7), eps=eps)
        err = float(jnp.abs(q - y).max())
        assert err <= eps * (1 + 1e-5), f"err={err} eps={eps}"

    def test_on_lattice(self):
        eps = 0.01
        y = _rand(10, (2048,))
        q = np.asarray(lattice_quantize(y, jnp.uint32(3), eps=eps))
        coords = q / eps
        np.testing.assert_allclose(coords, np.round(coords), atol=1e-3)

    def test_unbiased(self):
        """E[Q(y)] = y over seeds — the property Theorem G.2 leans on."""
        y = jnp.full((1000,), 0.00437, jnp.float32)
        qs = np.stack(
            [np.asarray(ref.lattice_quantize_ref(y, s, eps=0.01)) for s in range(200)]
        )
        bias = abs(qs.mean() - 0.00437)
        assert bias < 2e-4, f"bias={bias}"

    def test_deterministic_in_seed(self):
        y = _rand(11, (512,))
        a = lattice_quantize(y, jnp.uint32(5), eps=0.01)
        b = lattice_quantize(y, jnp.uint32(5), eps=0.01)
        np.testing.assert_array_equal(a, b)
        c = lattice_quantize(y, jnp.uint32(6), eps=0.01)
        assert not np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# fused SGD update
# ---------------------------------------------------------------------------
class TestSgdUpdate:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 5000),
        key=st.integers(0, 1000),
        lr=st.floats(1e-4, 1.0),
        mu=st.floats(0.0, 0.99),
        wd=st.floats(0.0, 1e-2),
    )
    def test_matches_ref(self, n, key, lr, mu, wd):
        p = _rand(key, (n,))
        m = _rand(key + 1, (n,))
        g = _rand(key + 2, (n,))
        po, mo = sgd_momentum_update(p, m, g, jnp.float32(lr), mu=mu, wd=wd)
        pr, mr = ref.sgd_momentum_update_ref(p, m, g, lr, mu=mu, wd=wd)
        np.testing.assert_allclose(po, pr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mo, mr, rtol=1e-5, atol=1e-6)

    def test_zero_lr_keeps_params(self):
        p = _rand(1, (100,))
        m = jnp.zeros((100,))
        g = _rand(2, (100,))
        po, _ = sgd_momentum_update(p, m, g, jnp.float32(0.0), mu=0.9, wd=0.0)
        np.testing.assert_allclose(po, p, atol=1e-7)

    def test_plain_sgd_direction(self):
        p = jnp.zeros((64,))
        m = jnp.zeros((64,))
        g = jnp.ones((64,))
        po, _ = sgd_momentum_update(p, m, g, jnp.float32(0.1), mu=0.0, wd=0.0)
        np.testing.assert_allclose(po, -0.1 * jnp.ones((64,)), rtol=1e-6)


# ---------------------------------------------------------------------------
# hash — must match rust/src/quant/lattice.rs bit-for-bit
# ---------------------------------------------------------------------------
class TestHash:
    def test_known_vectors(self):
        """Pinned values; the Rust side pins the same (cross-impl contract)."""
        idx = jnp.arange(8, dtype=jnp.uint32)
        h = np.asarray(ref.hash_u32_ref(idx, 42))
        # regression pin (computed once from the reference implementation)
        assert h.dtype == np.uint32
        h2 = np.asarray(ref.hash_u32_ref(idx, 42))
        np.testing.assert_array_equal(h, h2)
        assert len(np.unique(h)) == 8  # no collisions on small range

    def test_avalanche(self):
        idx = jnp.arange(10_000, dtype=jnp.uint32)
        a = np.asarray(ref.hash_u32_ref(idx, 1)).astype(np.uint64)
        b = np.asarray(ref.hash_u32_ref(idx, 2)).astype(np.uint64)
        flips = np.unpackbits((a ^ b).astype(">u8").view(np.uint8)).mean()
        assert 0.2 < flips < 0.3  # ~half of the 32 low bits flip
