"""Fused SGD-with-momentum update Pallas kernel.

Computes, in a single elementwise pass over flat parameter vectors:

    m' = mu * m + g + wd * p          (heavy-ball momentum + L2)
    p' = p - lr * m'

Fusing the two updates means one read of (p, m, g) and one write of (p', m')
per coordinate, versus three passes unfused — the update is memory-bound so
this is the whole game.  Lanes are (8, 128)-shaped for the TPU VPU; ``lr``
arrives as a (1,) operand so the learning-rate schedule stays on the Rust
side without re-lowering the artifact.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 512  # 64k elems/block = 256 KiB/operand in VMEM
BLOCK = LANES * SUBLANES


def _sgd_kernel(lr_ref, p_ref, m_ref, g_ref, po_ref, mo_ref, *, mu, wd):
    lr = lr_ref[0]
    m_new = mu * m_ref[...] + g_ref[...] + wd * p_ref[...]
    mo_ref[...] = m_new
    po_ref[...] = p_ref[...] - lr * m_new


@partial(jax.jit, static_argnames=("mu", "wd"))
def sgd_momentum_update(params, mom, grad, lr, mu=0.9, wd=0.0):
    """Fused momentum-SGD update on flat f32[P] vectors.

    Returns ``(params', mom')``.
    """
    n = params.shape[0]
    padded = -(-n // BLOCK) * BLOCK
    rows = padded // LANES
    ops = [
        jnp.pad(a, (0, padded - n)).reshape(rows, LANES)
        for a in (params, mom, grad)
    ]
    grid = rows // SUBLANES
    po, mo = pl.pallas_call(
        partial(_sgd_kernel, mu=float(mu), wd=float(wd)),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))]
        + [pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)) for _ in ops],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=True,
    )(lr.reshape(1).astype(jnp.float32), *ops)
    return po.reshape(-1)[:n], mo.reshape(-1)[:n]
