"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package has an exact reference here.  ``pytest`` sweeps
shapes/dtypes (hypothesis) and asserts ``allclose`` (matmul/sgd) or exact
equality (qavg — the stochastic rounding hash is deterministic and
re-implemented bit-for-bit, both here and in the Rust codec
``rust/src/quant/lattice.rs``).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def hash_u32_ref(idx, seed):
    """lowbias32 avalanche hash — must match qavg.py and quant/lattice.rs."""
    x = idx.astype(jnp.uint32) * jnp.uint32(2654435761) + jnp.uint32(seed)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def uniform01_ref(idx, seed):
    return hash_u32_ref(idx, seed).astype(jnp.float32) * jnp.float32(2.0**-32)


def lattice_quantize_ref(y, seed, eps=1e-3):
    idx = jnp.arange(y.shape[0], dtype=jnp.uint32)
    u = uniform01_ref(idx, seed)
    return jnp.floor(y / jnp.float32(eps) + u) * jnp.float32(eps)


def lattice_qavg_ref(x, y, seed, eps=1e-3):
    return (x + lattice_quantize_ref(y, seed, eps)) * jnp.float32(0.5)


def sgd_momentum_update_ref(params, mom, grad, lr, mu=0.9, wd=0.0):
    m_new = jnp.float32(mu) * mom + grad + jnp.float32(wd) * params
    return params - jnp.float32(lr).reshape(()) * m_new, m_new
