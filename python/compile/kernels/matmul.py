"""Tiled matmul Pallas kernel — the model's MXU hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): a GPU implementation
would tile with threadblocks over shared memory; on TPU we tile with
``BlockSpec`` over VMEM.  Default tiles are 128x128x128:

    VMEM footprint / grid step = (128*128 + 128*128 + 128*128) * 4 B = 192 KiB

which leaves ample double-buffering headroom in ~16 MiB of VMEM and feeds the
128x128 MXU systolic array with full-width operands.  The K dimension is the
innermost grid axis and the output block index map ignores it, so the output
tile is revisited and accumulated in place — the canonical Pallas reduction
pattern (equivalent to a K-loop inside one threadblock on GPU).

``matmul`` is wrapped in ``jax.custom_vjp`` so the L2 model can differentiate
through it: both backward matmuls reuse the same Pallas kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile. 512^3 keeps the VMEM footprint at 3 * 512*512*4 B = 3 MiB
# (well inside ~16 MiB), remains MXU-aligned (512 = 4*128 lanes), and cuts
# the interpret-mode grid iteration count 64x vs 128^3 — the dominant cost
# when the kernel runs as lowered HLO loops on CPU (see EXPERIMENTS.md
# §Perf). On real TPU hardware either size feeds the systolic array at full
# width; 128^3 would be preferred only under multi-buffer pressure.
BLOCK_M = 512
BLOCK_N = 512
BLOCK_K = 512


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; grid axis 2 walks K and accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation on the MXU (preferred_element_type pins the accumulator
    # dtype even if inputs are later switched to bf16).
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _block(dim: int, block: int) -> int:
    """Pick a tile size: full tile if the dim is large, else the padded dim."""
    if dim >= block:
        return block
    # round small dims up to a multiple of 8 (sublane) for TPU friendliness
    return max(8, -(-dim // 8) * 8)


def _pad_to(a, rows, cols):
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


@partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_raw(x, y, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {y.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, q: (i, q)),
            pl.BlockSpec((bk, bn), lambda i, j, q: (q, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, q: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """``x @ y`` through the Pallas tiled kernel, differentiable."""
    return _matmul_raw(x, y)


def _matmul_fwd(x, y):
    return _matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Y^T ; dY = X^T @ g — same kernel, transposed operands.
    return _matmul_raw(g, y.T), _matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
