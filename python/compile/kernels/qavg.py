"""Fused lattice-quantize + average Pallas kernel (paper Appendix G).

The quantized averaging step of SwarmSGD replaces ``(x + y) / 2`` with
``(x + Q(y)) / 2`` where ``Q`` is the cubic-lattice quantizer of Davies et
al. [12]: stochastically round ``y`` to the lattice ``eps * Z^d``.  The
rounding is *unbiased* (``E[Q(y)] = y``) and its error is bounded by ``eps``
per coordinate — i.e. by a resolution we control, not by ``||y||`` — which is
exactly the property the paper's potential argument needs (the modulo wire
encoding that achieves the O(d + log T) bit cost lives in the Rust codec,
``rust/src/quant``; values are unchanged by it whenever the distance
criterion holds, so this kernel computes the same result the decoded wire
format produces).

Kernel structure: single fused elementwise pass (one read of x, one read of
y, one write) over (8, 128)-shaped VPU lanes.  Stochastic rounding uses a
counter-based xorshift hash of (global element index, seed) so the kernel is
deterministic given the seed — the pure-jnp oracle in ``ref.py`` and the
Rust codec implement the *same* hash, giving exact cross-layer agreement.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 512  # 64k elems/block = 256 KiB/operand in VMEM
BLOCK = LANES * SUBLANES  # elements per grid step


def _hash_u32(idx, seed):
    """lowbias32-style avalanche hash of a u32 counter, keyed by seed."""
    x = idx.astype(jnp.uint32) * jnp.uint32(2654435761) + seed.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _uniform01(idx, seed):
    """u32 hash -> f32 uniform in [0, 1)."""
    return _hash_u32(idx, seed).astype(jnp.float32) * jnp.float32(2.0**-32)


def _qavg_kernel(seed_ref, x_ref, y_ref, o_ref, *, eps):
    pid = pl.program_id(0)
    shape = y_ref.shape
    base = pid * BLOCK
    lin = jax.lax.broadcasted_iota(jnp.uint32, shape, 0) * shape[1]
    lin = lin + jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    gidx = lin + jnp.uint32(base)
    u = _uniform01(gidx, seed_ref[0])
    y = y_ref[...]
    q = jnp.floor(y / eps + u) * eps  # stochastic rounding to eps*Z
    o_ref[...] = (x_ref[...] + q) * jnp.float32(0.5)


def _quant_kernel(seed_ref, y_ref, o_ref, *, eps):
    pid = pl.program_id(0)
    shape = y_ref.shape
    base = pid * BLOCK
    lin = jax.lax.broadcasted_iota(jnp.uint32, shape, 0) * shape[1]
    lin = lin + jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    gidx = lin + jnp.uint32(base)
    u = _uniform01(gidx, seed_ref[0])
    o_ref[...] = jnp.floor(y_ref[...] / eps + u) * eps


def _run_elementwise(kernel, seed, arrays, eps):
    """Pad 1-D operands to a (rows, 128) layout and launch a 1-D grid."""
    n = arrays[0].shape[0]
    padded = -(-n // BLOCK) * BLOCK
    rows = padded // LANES
    ops = [jnp.pad(a, (0, padded - n)).reshape(rows, LANES) for a in arrays]
    grid = rows // SUBLANES
    out = pl.pallas_call(
        partial(kernel, eps=float(eps)),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))]
        + [pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)) for _ in ops],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(seed.reshape(1).astype(jnp.uint32), *ops)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("eps",))
def lattice_qavg(x, y, seed, eps=1e-3):
    """``(x + Q_eps(y)) / 2`` — the quantized SwarmSGD averaging step.

    Args:
      x: local model, f32[P].
      y: remote model, f32[P] (this is the side that crossed the wire).
      seed: u32 scalar shared by encoder/decoder.
      eps: lattice resolution (static).
    """
    return _run_elementwise(_qavg_kernel, seed, [x, y], eps)


@partial(jax.jit, static_argnames=("eps",))
def lattice_quantize(y, seed, eps=1e-3):
    """Unbiased stochastic rounding of ``y`` to the lattice ``eps * Z^d``."""
    return _run_elementwise(_quant_kernel, seed, [y], eps)
