"""L1 — Pallas kernels for SwarmSGD (build-time only).

All kernels run under ``interpret=True`` (CPU lowers them to plain HLO ops);
the block structure is written for TPU: 128-lane minor dimension, MXU-shaped
matmul tiles, fused single-pass elementwise kernels.  See DESIGN.md
§Hardware-Adaptation.
"""

from .matmul import matmul
from .qavg import lattice_qavg, lattice_quantize
from .sgd import sgd_momentum_update

__all__ = ["matmul", "lattice_qavg", "lattice_quantize", "sgd_momentum_update"]
