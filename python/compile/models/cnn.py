"""Small convnet — stands in for the paper's ResNet18/50 ImageNet workloads
(DESIGN.md §2: synthetic Gaussian-mixture images replace ImageNet; the
*algorithmic* path — non-convex vision-model SGD + decentralized averaging —
is identical).

Structure: ``depth`` conv blocks (3x3 conv, bias, ReLU, 2x2 avg-pool with a
residual bypass when channels match), then a Pallas-matmul classifier head.
Convolutions lower to XLA's native conv HLO; the dense head exercises the L1
matmul kernel inside the same artifact.
"""

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import matmul
from ..packing import ParamSpec

DEFAULTS = dict(image=16, chan_in=3, width=16, depth=2, classes=10, batch=32)


def _out_hw(cfg):
    hw = cfg["image"]
    for _ in range(cfg["depth"]):
        hw //= 2
    return hw


def spec(cfg) -> ParamSpec:
    s = ParamSpec()
    cin = cfg["chan_in"]
    for i in range(cfg["depth"]):
        cout = cfg["width"] * (2**i)
        s.add(f"conv{i}", (3, 3, cin, cout))
        s.add(f"conv{i}_b", (cout,))
        cin = cout
    feat = _out_hw(cfg) ** 2 * cin
    s.add("head", (feat, cfg["classes"]))
    s.add("head_b", (cfg["classes"],))
    return s


def forward(spec_, cfg, flat, x):
    p = spec_.unpack(flat)
    h = x  # NHWC
    for i in range(cfg["depth"]):
        w = p[f"conv{i}"]
        z = lax.conv_general_dilated(
            h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + p[f"conv{i}_b"]
        z = jax.nn.relu(z)
        if z.shape == h.shape:  # residual bypass when shapes allow
            z = z + h
        h = lax.reduce_window(
            z, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        ) * jnp.float32(0.25)
    h = h.reshape(h.shape[0], -1)
    return matmul(h, p["head"]) + p["head_b"]


def loss_fn(spec_, cfg, flat, x, y):
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def metrics_fn(spec_, cfg, flat, x, y):
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


def example_batch(cfg):
    b = cfg["batch"]
    return (
        jax.ShapeDtypeStruct(
            (b, cfg["image"], cfg["image"], cfg["chan_in"]), jnp.float32
        ),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )


def manifest_fields(cfg):
    return {
        "kind": "image",
        "image": cfg["image"],
        "chan_in": cfg["chan_in"],
        "classes": cfg["classes"],
    }
