"""MLP classifier — the quickstart model (paper's ResNet20/CIFAR-10 slot in
spirit: small non-convex classification workload).

Dense layers run through the Pallas tiled matmul (L1); loss is softmax
cross-entropy over integer labels.
"""

import jax
import jax.numpy as jnp

from ..kernels import matmul
from ..packing import ParamSpec

DEFAULTS = dict(in_dim=64, hidden=128, depth=2, classes=10, batch=32)


def spec(cfg) -> ParamSpec:
    s = ParamSpec()
    dims = [cfg["in_dim"]] + [cfg["hidden"]] * cfg["depth"]
    for i in range(cfg["depth"]):
        s.add(f"w{i}", (dims[i], dims[i + 1]))
        s.add(f"w{i}_b", (dims[i + 1],))
    s.add("head", (dims[-1], cfg["classes"]))
    s.add("head_b", (cfg["classes"],))
    return s


def forward(spec_, cfg, flat, x):
    p = spec_.unpack(flat)
    h = x
    for i in range(cfg["depth"]):
        h = matmul(h, p[f"w{i}"]) + p[f"w{i}_b"]
        h = jax.nn.relu(h)
    return matmul(h, p["head"]) + p["head_b"]


def loss_fn(spec_, cfg, flat, x, y):
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def metrics_fn(spec_, cfg, flat, x, y):
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


def example_batch(cfg):
    """ShapeDtypeStructs for (x, y) used at lowering time."""
    b = cfg["batch"]
    return (
        jax.ShapeDtypeStruct((b, cfg["in_dim"]), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )


def manifest_fields(cfg):
    return {
        "kind": "vector",
        "in_dim": cfg["in_dim"],
        "classes": cfg["classes"],
    }
