"""Causal transformer LM — stands in for the paper's Transformer-XL/WMT17
workload (DESIGN.md §2: a synthetic Markov token corpus replaces WMT17; the
over-parameterized autoregressive-LM regime where SwarmSGD matches baseline
epochs is what matters, not BLEU).

Pre-LN decoder blocks; all dense projections (QKV, attention out, MLP in/out,
LM head) run through the Pallas tiled matmul (L1) — these carry ~100% of the
model FLOPs, which is exactly the MXU hot-spot the kernel exists for.
Attention score/mix einsums stay in jnp (batched 4-D contractions).
"""

import math

import jax
import jax.numpy as jnp

from ..kernels import matmul
from ..packing import ParamSpec

DEFAULTS = dict(vocab=256, d_model=128, heads=4, layers=2, seq=64, batch=16)


def spec(cfg) -> ParamSpec:
    d = cfg["d_model"]
    s = ParamSpec()
    s.add("embed", (cfg["vocab"], d), scale=0.02)
    s.add("pos", (cfg["seq"], d), scale=0.02)
    for i in range(cfg["layers"]):
        s.add(f"l{i}_attn_ln_s", (d,))
        s.add(f"l{i}_attn_ln_b", (d,))
        s.add(f"l{i}_qkv", (d, 3 * d))
        s.add(f"l{i}_qkv_b", (3 * d,))
        s.add(f"l{i}_proj", (d, d), scale=0.02 / math.sqrt(2 * cfg["layers"]))
        s.add(f"l{i}_proj_b", (d,))
        s.add(f"l{i}_mlp_ln_s", (d,))
        s.add(f"l{i}_mlp_ln_b", (d,))
        s.add(f"l{i}_fc", (d, 4 * d))
        s.add(f"l{i}_fc_b", (4 * d,))
        s.add(f"l{i}_out", (4 * d, d), scale=0.02 / math.sqrt(2 * cfg["layers"]))
        s.add(f"l{i}_out_b", (d,))
    s.add("final_ln_s", (d,))
    s.add("final_ln_b", (d,))
    s.add("head", (d, cfg["vocab"]), scale=0.02)
    return s


def _ln(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _dense(x, w, b):
    """(B, L, Din) @ (Din, Dout) through the Pallas matmul."""
    bsz, seq, din = x.shape
    y = matmul(x.reshape(bsz * seq, din), w) + b
    return y.reshape(bsz, seq, -1)


def forward(spec_, cfg, flat, tokens):
    p = spec_.unpack(flat)
    d, nh = cfg["d_model"], cfg["heads"]
    hd = d // nh
    bsz, seq = tokens.shape
    h = p["embed"][tokens] + p["pos"][None, :seq, :]
    causal = jnp.tril(jnp.ones((seq, seq), jnp.bool_))
    for i in range(cfg["layers"]):
        # --- attention ---
        a = _ln(h, p[f"l{i}_attn_ln_s"], p[f"l{i}_attn_ln_b"])
        qkv = _dense(a, p[f"l{i}_qkv"], p[f"l{i}_qkv_b"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
        scores = jnp.where(causal[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        mix = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
        mix = mix.transpose(0, 2, 1, 3).reshape(bsz, seq, d)
        h = h + _dense(mix, p[f"l{i}_proj"], p[f"l{i}_proj_b"])
        # --- MLP ---
        m = _ln(h, p[f"l{i}_mlp_ln_s"], p[f"l{i}_mlp_ln_b"])
        m = jax.nn.gelu(_dense(m, p[f"l{i}_fc"], p[f"l{i}_fc_b"]))
        h = h + _dense(m, p[f"l{i}_out"], p[f"l{i}_out_b"])
    h = _ln(h, p["final_ln_s"], p["final_ln_b"])
    logits = matmul(h.reshape(bsz * seq, d), p["head"])
    return logits.reshape(bsz, seq, cfg["vocab"])


def loss_fn(spec_, cfg, flat, x, y):
    """x: int32[B, L] inputs; y: int32[B, L] next-token targets."""
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    return jnp.mean(nll)


def metrics_fn(spec_, cfg, flat, x, y):
    logits = forward(spec_, cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = jnp.mean(-jnp.take_along_axis(logp, y[..., None], axis=-1))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


def example_batch(cfg):
    b, l = cfg["batch"], cfg["seq"]
    return (
        jax.ShapeDtypeStruct((b, l), jnp.int32),
        jax.ShapeDtypeStruct((b, l), jnp.int32),
    )


def manifest_fields(cfg):
    return {
        "kind": "tokens",
        "vocab": cfg["vocab"],
        "seq": cfg["seq"],
        "d_model": cfg["d_model"],
        "layers": cfg["layers"],
    }
