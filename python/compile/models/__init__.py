"""L2 model zoo.

Each model module exposes:

  * ``spec(cfg) -> ParamSpec``       — flat parameter layout
  * ``loss_fn(spec, cfg, flat, x, y) -> scalar loss``  (mean over batch)
  * ``metrics_fn(spec, cfg, flat, x, y) -> (loss, correct_count)``

``cfg`` is a plain dict of ints; all shapes are static at lowering time.
"""

from . import cnn, mlp, transformer

REGISTRY = {
    "mlp": mlp,
    "cnn": cnn,
    "transformer": transformer,
}
