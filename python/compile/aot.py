"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts + manifest.

HLO *text* is the interchange format, NOT ``lowered.compile().serialize()``:
the ``xla`` crate links xla_extension 0.5.1, which rejects jax>=0.5 protos
(64-bit instruction ids; ``proto.id() <= INT_MAX``).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``
Presets can be restricted: ``--preset mlp_s --preset transformer_s``.

Python runs ONCE, at build time; the Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import hashlib
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import build

# ---------------------------------------------------------------------------
# Presets: every (model, size) the coordinator/figures need.  K is the scan
# length of the fixed-H fast-path artifact.
# ---------------------------------------------------------------------------
PRESETS = {
    # quickstart + unit tests
    "mlp_s": dict(model="mlp", k=4, wd=0.0,
                  cfg=dict(in_dim=64, hidden=128, depth=2, classes=10, batch=32)),
    # CIFAR-10/ResNet20 slot (table1, fig6, fig8)
    "cnn_s": dict(model="cnn", k=4, wd=5e-4,
                  cfg=dict(image=16, chan_in=3, width=16, depth=2, classes=10, batch=32)),
    # ImageNet/ResNet18 slot (table1, fig2a, fig5)
    "cnn_m": dict(model="cnn", k=4, wd=5e-4,
                  cfg=dict(image=32, chan_in=3, width=24, depth=3, classes=100, batch=32)),
    # WMT17/Transformer slots — xs for figure sweeps (CPU-tractable),
    # small for tests/e2e-small, medium for the e2e driver
    "transformer_xs": dict(model="transformer", k=2, wd=0.0,
                           cfg=dict(vocab=128, d_model=64, heads=4, layers=2, seq=32, batch=8)),
    "transformer_s": dict(model="transformer", k=2, wd=0.0,
                          cfg=dict(vocab=256, d_model=128, heads=4, layers=2, seq=64, batch=16)),
    "transformer_m": dict(model="transformer", k=2, wd=0.0,
                          cfg=dict(vocab=512, d_model=256, heads=8, layers=4, seq=64, batch=16)),
}

QAVG_EPS = 1e-3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_preset(name, preset, out_dir):
    cfg, k = preset["cfg"], preset["k"]
    fns = build(preset["model"], cfg, wd=preset["wd"], qavg_eps=QAVG_EPS)
    p = fns["param_count"]
    fvec = jax.ShapeDtypeStruct((p,), jnp.float32)
    scal_i = jax.ShapeDtypeStruct((), jnp.int32)
    scal_f = jax.ShapeDtypeStruct((), jnp.float32)
    scal_u = jax.ShapeDtypeStruct((), jnp.uint32)
    x, y = fns["example_batch"]()
    xs = jax.ShapeDtypeStruct((k,) + x.shape, x.dtype)
    ys = jax.ShapeDtypeStruct((k,) + y.shape, y.dtype)

    artifacts = {
        "init": (fns["init"], (scal_i,)),
        "step": (fns["train_step"], (fvec, fvec, x, y, scal_f)),
        "step_k": (fns["train_step_k"], (fvec, fvec, xs, ys, scal_f)),
        "eval": (fns["eval_step"], (fvec, x, y)),
        "qavg": (fns["qavg_step"], (fvec, fvec, scal_u)),
    }
    lines = [f"[{name}]"]
    lines.append(f"model = {preset['model']}")
    lines.append(f"param_count = {p}")
    lines.append(f"batch = {cfg['batch']}")
    lines.append(f"k = {k}")
    lines.append(f"qavg_eps = {QAVG_EPS}")
    for key, val in fns["manifest_fields"]().items():
        lines.append(f"{key} = {val}")
    for art, (fn, args) in artifacts.items():
        fname = f"{name}_{art}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = to_hlo_text(jax.jit(fn).lower(*args))
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        print(f"  {fname:36s} {len(text) / 1e6:7.2f} MB  sha={digest}")
        lines.append(f"{art} = {fname}")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", default=None,
                    help="restrict to specific presets (repeatable)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = args.preset or list(PRESETS)
    manifest = []
    for name in names:
        print(f"[aot] lowering preset {name}")
        manifest.extend(lower_preset(name, PRESETS[name], args.out_dir))
        manifest.append("")
    mpath = os.path.join(args.out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
