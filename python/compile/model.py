"""L2 — assemble per-model train/eval/init functions for AOT lowering.

Every artifact signature is flat-vector based so the Rust coordinator stays
shape-agnostic (DESIGN.md §7.2):

  init(seed i32[])                     -> (params f32[P], mom f32[P])
  train_step(params, mom, x, y, lr[])  -> (params', mom', loss[])
  train_step_k(params, mom, xs, ys, lr[]) -> (params', mom', mean_loss[])
      where xs/ys stack K batches; lax.scan over the fused single step —
      the fixed-H fast path that amortizes PJRT dispatch.
  eval_step(params, x, y)              -> (loss[], correct[])
  qavg_step(x f32[P], y f32[P], seed u32[]) -> avg f32[P]
      the quantized averaging step (Pallas lattice kernel), lowered once per
      model size so L3 can do averaging inside XLA when configured.

The SGD update (momentum 0.9 + optional weight decay, both static) runs
through the fused Pallas axpy kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import lattice_qavg, sgd_momentum_update
from .models import REGISTRY

MOMENTUM = 0.9


def get_model(name):
    return REGISTRY[name]


def build(name, cfg, wd=0.0, qavg_eps=1e-3):
    """Return dict of jittable fns + the ParamSpec for model ``name``."""
    mod = get_model(name)
    spec_ = mod.spec(cfg)
    psize = spec_.size

    def loss(flat, x, y):
        return mod.loss_fn(spec_, cfg, flat, x, y)

    def init(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        flat = spec_.init_flat(key)
        return flat, jnp.zeros((psize,), jnp.float32)

    def train_step(flat, mom, x, y, lr):
        l, g = jax.value_and_grad(loss)(flat, x, y)
        flat2, mom2 = sgd_momentum_update(flat, mom, g, lr, mu=MOMENTUM, wd=wd)
        return flat2, mom2, l

    def train_step_k(flat, mom, xs, ys, lr):
        def body(carry, xy):
            f, m = carry
            x, y = xy
            f2, m2, l = train_step(f, m, x, y, lr)
            return (f2, m2), l

        (flat2, mom2), ls = jax.lax.scan(body, (flat, mom), (xs, ys))
        return flat2, mom2, jnp.mean(ls)

    def eval_step(flat, x, y):
        return mod.metrics_fn(spec_, cfg, flat, x, y)

    def qavg_step(x, y, seed):
        return lattice_qavg(x, y, seed, eps=qavg_eps)

    return dict(
        spec=spec_,
        param_count=psize,
        init=init,
        train_step=train_step,
        train_step_k=train_step_k,
        eval_step=eval_step,
        qavg_step=qavg_step,
        example_batch=partial(mod.example_batch, cfg),
        manifest_fields=partial(mod.manifest_fields, cfg),
    )
