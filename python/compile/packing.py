"""Flat parameter packing — the model-space view the coordinator operates on.

SwarmSGD's averaging/quantization acts on whole models as vectors in R^d
(paper §2).  We therefore pack every model's parameter pytree into a single
``f32[P]`` vector at the AOT boundary: the Rust coordinator averages,
quantizes, and ships flat vectors without knowing layer shapes, and the L2
forward pass unpacks them with static slices (free at HLO level — XLA folds
``dynamic_slice`` with constant offsets into bitcasts/views).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@dataclass
class ParamSpec:
    """Ordered list of named tensors plus their init scales."""

    entries: list[tuple[str, tuple[int, ...], float]] = field(default_factory=list)

    def add(self, name: str, shape: tuple[int, ...], scale: float | None = None):
        """Register a tensor. ``scale=None`` -> He/Glorot-ish fan-in init."""
        if scale is None:
            fan_in = shape[0] if len(shape) >= 2 else max(1, shape[-1])
            if len(shape) == 4:  # HWIO conv kernel
                fan_in = shape[0] * shape[1] * shape[2]
            scale = 1.0 / math.sqrt(fan_in)
        self.entries.append((name, tuple(shape), float(scale)))
        return self

    @property
    def size(self) -> int:
        return sum(math.prod(s) for _, s, _ in self.entries)

    def offsets(self) -> dict[str, tuple[int, tuple[int, ...]]]:
        out, off = {}, 0
        for name, shape, _ in self.entries:
            out[name] = (off, shape)
            off += math.prod(shape)
        return out

    def unpack(self, flat: jax.Array) -> dict[str, jax.Array]:
        """Slice the flat vector into named, shaped tensors (static offsets)."""
        params = {}
        for name, (off, shape) in self.offsets().items():
            n = math.prod(shape)
            params[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        return params

    def init_flat(self, key: jax.Array) -> jax.Array:
        """Initialize the packed vector: scaled normals (zeros for biases/LN-b)."""
        chunks = []
        for i, (name, shape, scale) in enumerate(self.entries):
            sub = jax.random.fold_in(key, i)
            n = math.prod(shape)
            if name.endswith("_b"):  # biases start at zero
                chunks.append(jnp.zeros((n,), jnp.float32))
            elif name.endswith("_ln_s"):  # LayerNorm scales start at one
                chunks.append(jnp.ones((n,), jnp.float32))
            else:
                chunks.append(
                    jax.random.normal(sub, (n,), jnp.float32) * jnp.float32(scale)
                )
        return jnp.concatenate(chunks)
