//! Quickstart: SwarmSGD on the full three-layer stack in ~30 lines of API.
//!
//! 8 agents on a complete graph train the MLP preset (JAX+Pallas lowered to
//! HLO, executed through PJRT) on a synthetic Gaussian-mixture task; the
//! agents gossip non-blockingly with 2 local steps between interactions.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::Path;
use swarm_sgd::config::ShardMode;
use swarm_sgd::coordinator::{
    run_serial, AveragingMode, LocalSteps, LrSchedule, RunSpec, SwarmSgd,
};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::runtime::{XlaBackend, XlaBackendConfig};
use swarm_sgd::topology::{Graph, Topology};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 8;
    // 1. backend: AOT-compiled MLP + per-agent data shards
    let backend = XlaBackend::load(
        Path::new("artifacts"),
        "mlp_s",
        XlaBackendConfig {
            agents: n,
            data_per_agent: 512,
            shard: ShardMode::Iid,
            ..Default::default()
        },
    )?;

    // 2. topology + communication cost model
    let mut rng = Pcg64::seed(42);
    let graph = Graph::build(Topology::Complete, n, &mut rng);
    let cost = CostModel::default(); // Piz-Daint-ish: 0.4 s/batch, Aries-class net

    // 3. run SwarmSGD (swap in any other Algorithm — adpsgd, sgp, … — or
    // run_parallel for real worker threads; metrics are bit-identical)
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let spec = RunSpec {
        n,
        events: 400,
        lr: LrSchedule::Constant(0.05),
        seed: 1,
        name: "quickstart".into(),
        eval_every: 40,
        track_gamma: true,
    };
    let metrics = run_serial(&algo, &backend, &spec, &graph, &cost);

    println!("t      eval-loss  accuracy  gamma");
    for p in &metrics.curve {
        println!(
            "{:<6} {:<10.4} {:<9.3} {:.5}",
            p.t, p.eval_loss, p.eval_acc, p.gamma
        );
    }
    println!(
        "\nfinal: loss={:.4} acc={:.3} after {} interactions \
         ({} local steps, {:.1} simulated seconds)",
        metrics.final_eval_loss,
        metrics.final_eval_acc,
        metrics.interactions,
        metrics.local_steps,
        metrics.sim_time
    );
    assert!(metrics.final_eval_acc > 0.8, "quickstart should reach >80% acc");
    Ok(())
}
