//! END-TO-END DRIVER (DESIGN.md §validation): decentralized training of a
//! multi-million-parameter causal transformer LM on a synthetic Markov
//! corpus, through the complete stack —
//!
//!   Pallas matmul kernels (L1) → JAX fwd/bwd, lax.scan'd SGD (L2)
//!     → HLO text → PJRT executables → Rust SwarmSGD coordinator (L3),
//!
//! 8 agents, non-blocking gossip, 2 local steps; logs the loss curve and
//! writes it to results/e2e_transformer.csv.  The run is recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example train_transformer`
//! Flags: `-- small` uses the transformer_s preset (CI-speed); default is
//! transformer_m (~3.6M params).

use std::path::Path;
use swarm_sgd::config::ShardMode;
use swarm_sgd::coordinator::{
    run_serial, AveragingMode, LocalSteps, LrSchedule, RunSpec, SwarmSgd,
};
use swarm_sgd::figures::write_curves;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::runtime::{XlaBackend, XlaBackendConfig};
use swarm_sgd::topology::{Graph, Topology};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let small = std::env::args().any(|a| a == "small");
    let preset = if small { "transformer_xs" } else { "transformer_s" };
    let n = 8;
    let interactions: u64 = if small { 150 } else { 220 };

    println!("== SwarmSGD end-to-end transformer training ==");
    println!("preset={preset} agents={n} interactions={interactions}");

    let backend = XlaBackend::load(
        Path::new("artifacts"),
        preset,
        XlaBackendConfig {
            agents: n,
            data_per_agent: 8192, // tokens per agent shard
            shard: ShardMode::Iid,
            seed: 7,
            eval_batches: 2,
            ..Default::default()
        },
    )?;
    println!(
        "model: {} params={} vocab={} seq={}",
        preset,
        backend.manifest().param_count,
        backend.manifest().field_usize("vocab").unwrap_or(0),
        backend.manifest().field_usize("seq").unwrap_or(0),
    );

    let backend_vocab = backend.manifest().field_usize("vocab").unwrap_or(2);
    let mut rng = Pcg64::seed(3);
    let graph = Graph::build(Topology::Complete, n, &mut rng);
    let cost = CostModel::default();
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let spec = RunSpec {
        n,
        events: interactions,
        lr: LrSchedule::StepDecay { base: 0.3, total: interactions },
        seed: 11,
        name: "e2e-transformer".into(),
        eval_every: (interactions / 12).max(1),
        track_gamma: true,
    };
    let started = std::time::Instant::now();
    let metrics = run_serial(&algo, &backend, &spec, &graph, &cost);
    let wall = started.elapsed();

    println!("\nt      sim-time  train-loss  eval-loss  tok-acc  gamma");
    for p in &metrics.curve {
        println!(
            "{:<6} {:<9.1} {:<11.4} {:<10.4} {:<8.3} {:.4}",
            p.t, p.sim_time, p.train_loss, p.eval_loss, p.eval_acc, p.gamma
        );
    }
    let first = metrics.curve.first().map(|p| p.eval_loss).unwrap_or(f64::NAN);
    println!(
        "\nloss {first:.3} -> {:.3}  (token acc {:.3}); {} local steps; \
         wall {:.0}s; simulated cluster time {:.0}s",
        metrics.final_eval_loss,
        metrics.final_eval_acc,
        metrics.local_steps,
        wall.as_secs_f64(),
        metrics.sim_time
    );
    std::fs::create_dir_all("results")?;
    write_curves(Path::new("results/e2e_transformer.csv"), &[metrics.clone()])?;
    println!("curve -> results/e2e_transformer.csv");
    // checkpoint the deployable (mean) model as .npy for numpy/JAX analysis
    swarm_sgd::output::save_npy(
        Path::new("results/e2e_transformer_model.npy"),
        &metrics.final_model,
    )?;
    println!("model -> results/e2e_transformer_model.npy");
    let vocab = backend_vocab as f64;
    let _ = first;
    assert!(
        metrics.final_eval_loss < 0.85 * vocab.ln(),
        "e2e training must push the LM loss well below the uniform baseline ln(V)={:.2}",
        vocab.ln()
    );
    Ok(())
}
