//! Paper-scale free-running preset: n=256 nodes on the non-blocking
//! sharded executor, with the cost model simulating ResNet18's 45 MB wire
//! size (`model_bytes=45e6`) on Aries-class p2p parameters — the regime
//! the paper's CSCS experiments run in, where n is in the hundreds and
//! pairwise exchange cost is independent of n.
//!
//! The compute backend stays a small quadratic oracle (this example is
//! about the *runtime*: sharded ownership with n >> cores, seqlock slot
//! traffic, staleness, and the simulated wire accounting under a 45 MB
//! model), so it runs in seconds on a laptop while exercising exactly the
//! code path `--executor freerun` uses at paper scale.
//!
//! Run: `cargo run --release --example freerun_paper_scale`
//!
//! CLI equivalent (same executor, same cost model):
//! ```text
//! swarm train --algorithm swarm --executor freerun --threads 4 --shards 32 \
//!     --set preset=oracle:quadratic,n=256,interactions=40000,\
//!          model_bytes=45000000,latency=1e-4,batch_time=1e-4,jitter=0
//! ```
//! Add `--wire lattice` to send the slot payloads through the lattice
//! quantizer instead of full-precision f32.

use swarm_sgd::coordinator::{
    make_algorithm, run_freerun, AlgoOptions, LrSchedule, RunSpec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn main() {
    // paper scale: hundreds of nodes, a handful of cores — the sharded
    // workers own 256/32 = 8-node shards each
    let n = 256;
    let (threads, shards) = (4, 32);
    let interactions = 40_000u64;

    // small quadratic stand-in for compute; the WIRE is ResNet18-sized
    let backend = QuadraticOracle::new(256, n, 1.0, 0.5, 2.0, 0.1, 7);
    let graph = {
        let mut rng = Pcg64::seed(5);
        Graph::build(Topology::Complete, n, &mut rng)
    };
    // 45 MB model on the simulated wire, Aries-ish latency, 10 GB/s flows
    let cost = CostModel {
        batch_time: 1e-4,
        jitter: 0.0,
        straggler_prob: 0.0,
        straggle_factor: 1.0,
        latency: 1e-4,
        bandwidth: 10.0e9,
        model_bytes_override: Some(45_000_000),
    };
    let spec = RunSpec {
        n,
        events: interactions,
        lr: LrSchedule::Constant(0.02),
        seed: 1,
        name: "freerun-paper-scale".into(),
        eval_every: 10_000,
        track_gamma: false,
    };

    let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
    let m = run_freerun(algo.as_ref(), &backend, &spec, &graph, &cost, threads, shards);

    let fr = m.freerun.as_ref().expect("freerun telemetry");
    println!(
        "n={n} over {threads} workers x {shards} shards ({} codec): \
         {:.0} interactions/s real throughput",
        fr.codec, fr.interactions_per_sec
    );
    println!(
        "staleness p50={} p99={} max={}  |  {} read retries, {} dropped cross-writes",
        fr.staleness.p50(),
        fr.staleness.p99(),
        fr.staleness.max_observed(),
        fr.slot_read_retries,
        fr.slot_push_conflicts,
    );
    println!(
        "simulated: {:.1} GB on the wire ({} fallbacks), {:.1} s sim time, \
         final eval loss {:.5}",
        m.total_bits as f64 / 8e9,
        m.quant_fallbacks,
        m.sim_time,
        m.final_eval_loss,
    );
}
