//! Quantized gossip demo (paper Appendix G): the lattice/modulo codec on
//! real models — wire-size accounting, decode-failure fallbacks, and the
//! accuracy cost of 8/6/4-bit averaging, vs full precision.
//!
//! Run: `make artifacts && cargo run --release --example quantized_gossip`

use swarm_sgd::coordinator::{AveragingMode, LocalSteps, LrSchedule};
use swarm_sgd::figures::{paper_cost, run_arm, Arm, BackendSpec};
use swarm_sgd::output::Table;
use swarm_sgd::quant::{decode, encode};
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::Topology;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- codec micro-demo -------------------------------------------------
    println!("== lattice codec on a 100k-dim model pair ==");
    let d = 100_000;
    let mut rng = Pcg64::seed(1);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = x.iter().map(|v| v + 0.02 * rng.normal() as f32).collect();
    for bits in [4u32, 6, 8, 10] {
        let msg = encode(&x, 1e-3, bits, 7);
        let ok = decode(&msg, &y).is_ok();
        println!(
            "  {bits:>2}-bit: {:>9} wire bits ({:>5.2}x smaller than fp32)  decode_ok={ok}",
            msg.wire_bits(),
            (32 * d) as f64 / msg.wire_bits() as f64,
        );
    }

    // --- end-to-end: quantized swarm on the MLP preset --------------------
    println!("\n== quantized SwarmSGD (mlp_s, n=8) ==");
    let n = 8;
    let t = 300u64;
    let lr = 0.05;
    let cost = paper_cost("wideresnet28");
    let spec = BackendSpec::xla("mlp_s", n, 512, 3);
    let mut table = Table::new(&[
        "variant", "acc", "loss", "GB on wire", "sim time (s)", "fallbacks",
    ]);
    for (name, mode) in [
        ("fp32", AveragingMode::NonBlocking),
        ("8-bit", AveragingMode::Quantized { bits: 8, eps: 2e-3 }),
        ("6-bit", AveragingMode::Quantized { bits: 6, eps: 2e-3 }),
        ("4-bit", AveragingMode::Quantized { bits: 4, eps: 2e-3 }),
    ] {
        let arm = Arm {
            name: name.into(),
            algo: "swarm".into(),
            mode,
            local_steps: LocalSteps::Fixed(2),
            t,
            lr: LrSchedule::Constant(lr),
            h_localsgd: 5,
        };
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 27, 0, false)?;
        table.row(&[
            name.to_string(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.4}", m.total_bits as f64 / 8e9),
            format!("{:.1}", m.sim_time),
            m.quant_fallbacks.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nexpected shape: 8-bit matches fp32 accuracy (paper: <0.3% drop) \
         at ~4x fewer bytes; aggressive 4-bit trips the distance criterion \
         more often (fallbacks) and can cost accuracy."
    );
    Ok(())
}
