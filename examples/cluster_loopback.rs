//! Loopback cluster demo: one coordinator + two workers as real OS
//! processes on 127.0.0.1, gossiping lattice-quantized model payloads over
//! TCP — the smallest end-to-end run of `--executor cluster`.
//!
//! The example re-execs itself for the child roles, so a single
//! `cargo run --release --example cluster_loopback` is the whole cluster:
//!
//! * parent: spawns the coordinator, parses its stdout for the ephemeral
//!   port, spawns two workers pointed at it, relays output, and appends an
//!   interactions/sec row to `BENCH_cluster.json` (merged into the
//!   committed perf trajectory by the CI cluster-smoke job);
//! * `coordinator` arg: runs [`swarm_sgd::cluster::run_coordinator`];
//! * `worker ADDR` arg: runs [`swarm_sgd::cluster::run_worker`].

use std::io::{BufRead, BufReader, Write};
use std::process::{Command, Stdio};

use swarm_sgd::cluster;
use swarm_sgd::config::RunConfig;

const WORKERS: usize = 2;
const N: usize = 16;
const INTERACTIONS: u64 = 1500;

fn run_config() -> RunConfig {
    let mut cfg = RunConfig::default();
    for (k, v) in [
        ("algo", "swarm"),
        ("preset", "oracle:quadratic"),
        ("executor", "cluster"),
        ("n", "16"),
        ("interactions", "1500"),
        ("wire", "lattice"),
        ("workers", "2"),
        ("heartbeat_timeout", "10"),
        ("eval_every", "0"),
    ] {
        cfg.set(k, v).expect("static config");
    }
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(|s| s.as_str()) {
        Some("coordinator") => child_coordinator(),
        Some("worker") => {
            let addr = args.get(1).expect("usage: cluster_loopback worker ADDR");
            cluster::run_worker(addr, 0)
        }
        _ => parent(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn child_coordinator() -> Result<(), String> {
    let cfg = run_config();
    let dir = std::env::temp_dir().join("swarm_cluster_loopback");
    std::fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    cluster::run_coordinator(&cfg, "127.0.0.1:0", &dir).map(|_| ())
}

fn parent() -> Result<(), String> {
    let me = std::env::current_exe().map_err(|e| e.to_string())?;
    println!(
        "cluster loopback: 1 coordinator + {WORKERS} workers on 127.0.0.1 \
         (swarm, n={N}, {INTERACTIONS} interactions, lattice wire)\n"
    );
    let mut coord = Command::new(&me)
        .arg("coordinator")
        .stdout(Stdio::piped())
        .spawn()
        .map_err(|e| format!("spawn coordinator: {e}"))?;
    let stdout = coord.stdout.take().expect("piped stdout");
    let mut lines = BufReader::new(stdout).lines();

    // the coordinator prints "cluster coordinator listening on ADDR (...)"
    let mut addr = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| e.to_string())?;
        println!("[coord] {line}");
        if let Some(rest) = line.strip_prefix("cluster coordinator listening on ") {
            addr = rest.split_whitespace().next().map(str::to_string);
            break;
        }
    }
    let addr = addr.ok_or("coordinator exited before printing its address")?;

    let workers: Vec<_> = (0..WORKERS)
        .map(|_| Command::new(&me).args(["worker", &addr]).spawn())
        .collect::<Result<_, _>>()
        .map_err(|e| format!("spawn worker: {e}"))?;

    // relay the rest of the coordinator's report, harvesting the numbers
    let mut throughput = 0.0f64;
    let mut final_line = String::new();
    for line in lines {
        let line = line.map_err(|e| e.to_string())?;
        println!("[coord] {line}");
        if let Some(rest) = line.trim().strip_prefix("real throughput") {
            if let Some(v) = rest.trim_start_matches([':', ' ']).split_whitespace().next() {
                throughput = v.parse().unwrap_or(0.0);
            }
        }
        if line.starts_with("cluster: final ") {
            final_line = line;
        }
    }
    let status = coord.wait().map_err(|e| e.to_string())?;
    for mut w in workers {
        let ws = w.wait().map_err(|e| e.to_string())?;
        if !ws.success() {
            return Err(format!("worker exited with {ws}"));
        }
    }
    if !status.success() {
        return Err(format!("coordinator exited with {status}"));
    }
    if final_line.is_empty() {
        return Err("coordinator never printed its final report".into());
    }

    let json = format!(
        "{{\n  \"bench\": \"cluster_loopback\",\n  \"workload\": \
         {{\"n\": {N}, \"workers\": {WORKERS}, \"interactions\": {INTERACTIONS}, \
         \"backend\": \"quadratic\", \"wire\": \"lattice\"}},\n  \"results\": [\n    \
         {{\"label\": \"loopback-tcp\", \"interactions_per_sec\": {throughput:.1}, \
         \"report\": \"{final_line}\"}}\n  ]\n}}\n",
    );
    let written = std::fs::File::create("BENCH_cluster.json")
        .and_then(|mut f| f.write_all(json.as_bytes()));
    match written {
        Ok(()) => println!("\nwrote BENCH_cluster.json"),
        Err(e) => eprintln!("could not write BENCH_cluster.json: {e}"),
    }
    Ok(())
}
