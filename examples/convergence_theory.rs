//! Theory validation on a quadratic with known constants: Γ_t boundedness
//! (Lemma F.3), the H²-scaling of the potential, topology effects, and the
//! Theorem 4.1 bound vs measured average gradient norm.
//!
//! Pure-Rust oracle — runs in seconds, no artifacts needed.
//!
//! Run: `cargo run --release --example convergence_theory`

use swarm_sgd::analysis::{lemma_f3_bound, theorem41_bound, BoundParams};
use swarm_sgd::backend::Backend;
use swarm_sgd::coordinator::LrSchedule;
use swarm_sgd::figures::{run_arm, Arm, BackendSpec};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::output::Table;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 16;
    let dim = 16;
    let sigma = 0.5;
    let t = 20_000u64;
    let eta = 0.02f32;
    let cost = CostModel::deterministic(1.0);

    // oracle constants
    let oracle = QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 41);
    let l = oracle.smoothness();
    let m_sq = {
        let g = oracle.true_grad(&vec![0.0; dim]);
        g.iter().map(|v| v * v).sum::<f64>() + sigma * sigma * dim as f64
    };
    let f_gap = {
        let o = QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 41);
        let (p, _) = o.init();
        o.full_loss(&p) - o.f_star()
    };
    println!("quadratic oracle: n={n} d={dim} L={l:.2} M^2={m_sq:.2} f-gap={f_gap:.3}\n");

    let mut table = Table::new(&[
        "topology", "H", "steady Gamma", "F.3 bound", "final loss-f*", "Thm4.1 bound",
    ]);
    for topo in [Topology::Complete, Topology::Ring] {
        let (l2, r) = {
            let mut rng = Pcg64::seed(2);
            let g = Graph::build(topo, n, &mut rng);
            (g.lambda2(), g.regular_degree().unwrap() as f64)
        };
        for h in [1u64, 2, 4] {
            let spec = BackendSpec::Quadratic { dim, spread: 1.0, sigma, seed: 41 };
            let arm = Arm {
                lr: LrSchedule::Constant(eta),
                ..Arm::swarm(&format!("H{h}"), h, t, eta)
            };
            let m = run_arm(&arm, &spec, n, topo, &cost, 3, t / 32, true)?;
            let gs: Vec<f64> = m.curve.iter().map(|p| p.gamma).collect();
            let steady =
                gs[gs.len() / 2..].iter().sum::<f64>() / (gs.len() - gs.len() / 2) as f64;
            let f3 = lemma_f3_bound(r, l2, n, eta as f64, h as f64, m_sq);
            let bp = BoundParams { n, r, lambda2: l2, h: h as f64, l, t, f_gap };
            let b41 = theorem41_bound(&bp, m_sq);
            let f_star = QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 41).f_star();
            table.row(&[
                format!("{topo:?}"),
                h.to_string(),
                format!("{steady:.4}"),
                format!("{f3:.2}"),
                format!("{:.4}", (m.final_eval_loss - f_star).max(0.0)),
                format!("{b41:.1}"),
            ]);
            assert!(steady <= f3, "Lemma F.3 bound violated: {steady} > {f3}");
        }
    }
    table.print();
    println!(
        "\nall steady-state Γ values sit below the Lemma F.3 bound; Γ grows \
         ~H² and degrades on the ring (λ₂ small), exactly as the analysis \
         predicts. The Thm 4.1 bound is loose but finite and O(1/sqrt(T))."
    );
    Ok(())
}
