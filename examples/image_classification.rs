//! Image-classification scenario (the paper's ResNet/CIFAR workload slot):
//! SwarmSGD vs AD-PSGD vs large-batch SGD on the CNN preset over synthetic
//! Gaussian-mixture images — reports accuracy, epochs, and simulated time.
//!
//! Run: `make artifacts && cargo run --release --example image_classification`

use swarm_sgd::coordinator::LrSchedule;
use swarm_sgd::figures::{interactions_for_epochs, paper_cost, run_arm, Arm, BackendSpec};
use swarm_sgd::output::Table;
use swarm_sgd::topology::Topology;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 8;
    let data_per_agent = 512;
    let batch = 32;
    let epochs = 10.0;
    let lr = 0.05;
    let cost = paper_cost("resnet18");
    let spec = BackendSpec::xla("cnn_s", n, data_per_agent, 33);

    let h = 3u64;
    let t_swarm = interactions_for_epochs(epochs * 1.5, n, h as f64, data_per_agent, batch);
    let rounds_lb = (epochs * data_per_agent as f64 / batch as f64) as u64;
    let arms = vec![
        Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t_swarm },
            ..Arm::swarm("SwarmSGD H=3 x1.5", h, t_swarm, lr)
        },
        Arm {
            lr: LrSchedule::StepDecay { base: lr, total: rounds_lb },
            ..Arm::baseline("AD-PSGD", "adpsgd", t_swarm * h, lr)
        },
        Arm {
            lr: LrSchedule::StepDecay { base: lr, total: rounds_lb },
            ..Arm::baseline("LB-SGD", "allreduce", rounds_lb, lr)
        },
    ];

    let mut table = Table::new(&[
        "method", "top-1 acc", "eval loss", "epochs/agent", "sim time (s)", "GB on wire",
    ]);
    for arm in arms {
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 99, 0, false)?;
        table.row(&[
            arm.name.clone(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.2}", m.epochs),
            format!("{:.0}", m.sim_time),
            format!("{:.2}", m.total_bits as f64 / 8e9),
        ]);
    }
    println!("\nimage classification (cnn_s, n={n}, synthetic CIFAR-like):");
    table.print();
    println!(
        "\nexpected shape (paper Table 1 / Fig 2b): all methods recover \
         accuracy; Swarm ships far fewer bytes and its per-step time is \
         independent of n."
    );
    Ok(())
}
