//! Scenario-matrix smoke bench: convergence vs staleness vs spectral gap
//! across topology families, on the free-running executor.
//!
//! The paper's convergence bound degrades with the gossip matrix's
//! spectral gap; this bench makes that trade-off *observable* in one
//! table — for each topology × algorithm cell it records the graph's
//! `spectral_gap`, the freerun staleness quantiles that topology induces,
//! and the normalized loss gap actually reached. Two heterogeneity rows
//! (bimodal speed classes on the sparse graphs) track how structural
//! stragglers stretch the staleness tail.
//!
//! Like `bench_freerun`, rows are runner-dependent and non-replayable —
//! CI records `BENCH_scenario.json` in a non-blocking job, it never gates
//! on the numbers. `-- --test` runs the reduced smoke configuration.

use std::io::Write;
use swarm_sgd::backend::Backend;
use swarm_sgd::config::RunConfig;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun_scenario, AlgoOptions, LrSchedule, RunSpec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::obs::ObsOptions;
use swarm_sgd::scenario::Scenario;
use swarm_sgd::topology::spectral_gap;

const N: usize = 64;

fn scenario(topology: &str, speeds: &str) -> Scenario {
    let mut cfg = RunConfig::default();
    cfg.set("topology", topology).expect("valid topology");
    cfg.set("n", &N.to_string()).expect("valid n");
    cfg.set("seed", "7").expect("valid seed");
    cfg.set("speeds", speeds).expect("valid speeds");
    Scenario::from_config(&cfg).expect("feasible scenario")
}

fn row_json(
    topology: &str,
    speeds: &str,
    algorithm: &str,
    gap: f64,
    norm_gap: f64,
    fr: &swarm_sgd::coordinator::FreerunStats,
) -> String {
    format!(
        "    {{\"topology\": \"{topology}\", \"speeds\": \"{speeds}\", \
         \"algorithm\": \"{algorithm}\", \"n\": {N}, \
         \"spectral_gap\": {gap:.6}, \"norm_loss_gap\": {norm_gap:.4}, \
         \"staleness_p50\": {}, \"staleness_p99\": {}, \
         \"staleness_mean\": {:.2}, \"interactions_per_sec\": {:.1}}}",
        fr.staleness.p50(),
        fr.staleness.p99(),
        fr.staleness.mean(),
        fr.interactions_per_sec,
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let (dim, t) = if smoke { (64, 6_000u64) } else { (512, 40_000) };
    println!("== scenario matrix (n={N}, d={dim}, T={t}, quadratic oracle) ==");

    let backend = QuadraticOracle::new(dim, N, 1.0, 0.5, 2.0, 0.1, 3);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let cost = CostModel::deterministic(0.4);
    let spec = RunSpec {
        n: N,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed: 1,
        name: "bench-scenario".into(),
        eval_every: 0,
        track_gamma: false,
    };

    // the matrix: dense baseline + the three sparse families the paper's
    // spectral-gap factor actually bites on, × the two gossip algorithms
    // with distinct mixing (pairwise averaging vs directed-capable
    // push-sum), + bimodal straggler rows on the sparse graphs
    let mut cells: Vec<(&str, &str, &str)> = Vec::new();
    for topo in ["complete", "ring", "torus", "regular4"] {
        for algo in ["swarm", "sgp"] {
            cells.push((topo, "uniform", algo));
        }
    }
    cells.push(("ring", "bimodal:0.25:4", "swarm"));
    cells.push(("torus", "bimodal:0.25:4", "swarm"));

    let mut rows: Vec<String> = Vec::new();
    for (topo, speeds, name) in cells {
        let scn = scenario(topo, speeds);
        let gap = spectral_gap(scn.graph0());
        let algo = make_algorithm(name, &AlgoOptions::default()).expect("known algorithm");
        let m = run_freerun_scenario(
            algo.as_ref(),
            &backend,
            &spec,
            &scn,
            &cost,
            4,
            8,
            &ObsOptions::default(),
        );
        let fr = m.freerun.as_ref().expect("freerun telemetry");
        let norm_gap = (m.final_eval_loss - f_star) / gap0;
        println!(
            "{topo:<9} {name:<6} {speeds:<15} spectral_gap={gap:.4}  \
             norm_loss_gap={norm_gap:.4}  staleness p50={} p99={}  {:>9.0} int/s",
            fr.staleness.p50(),
            fr.staleness.p99(),
            fr.interactions_per_sec,
        );
        rows.push(row_json(topo, speeds, name, gap, norm_gap, fr));
    }

    let json = format!(
        "{{\n  \"bench\": \"bench_scenario\",\n  \"workload\": \
         {{\"n\": {N}, \"dim\": {dim}, \"interactions\": {t}, \
         \"backend\": \"quadratic\", \"smoke\": {smoke}}},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_scenario.json")
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_scenario.json"),
        Err(e) => eprintln!("could not write BENCH_scenario.json: {e}"),
    }
}
