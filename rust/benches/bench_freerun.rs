//! Free-running executor smoke bench: *real* interactions/second and
//! staleness quantiles vs worker-thread count, for the two gossip
//! algorithms the paper races (SwarmSGD and AD-PSGD) plus SGP over the
//! weighted push-sum slots the `MixPolicy` redesign admitted, on an
//! `n ≫ threads` sharded quadratic workload — and one **paper-scale** row
//! (n=256 nodes, `model_bytes=45e6` ResNet18 wire simulation, matching
//! `examples/freerun_paper_scale.rs`).
//!
//! Unlike `bench_parallel` this does not wrap runs in the timing harness:
//! the free-running executor measures its own wall-clock throughput
//! (`RunMetrics::freerun`), and its numbers are non-replayable and
//! runner-dependent by contract — CI records them (`BENCH_freerun.json`),
//! it never gates on them. `-- --test` runs the reduced smoke
//! configuration.

use std::io::Write;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun, run_freerun_with_obs, AlgoOptions, AveragingMode, LocalSteps,
    LrSchedule, RunSpec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::obs::ObsOptions;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

const N: usize = 64;

fn complete_graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn row_json(
    name: &str,
    threads: usize,
    shards: usize,
    n: usize,
    fr: &swarm_sgd::coordinator::FreerunStats,
) -> String {
    format!(
        "    {{\"algorithm\": \"{name}\", \"threads\": {threads}, \
         \"shards\": {shards}, \"n\": {n}, \"codec\": \"{}\", \
         \"interactions_per_sec\": {:.1}, \
         \"staleness_p50\": {}, \"staleness_p99\": {}, \
         \"staleness_mean\": {:.2}, \"slot_read_retries\": {}, \
         \"slot_publish_retries\": {}, \"slot_push_conflicts\": {}}}",
        fr.codec,
        fr.interactions_per_sec,
        fr.staleness.p50(),
        fr.staleness.p99(),
        fr.staleness.mean(),
        fr.slot_read_retries,
        fr.slot_publish_retries,
        fr.slot_push_conflicts,
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let (dim, t) = if smoke { (256, 4_000u64) } else { (2048, 40_000) };
    println!("== freerun executor (n={N}, d={dim}, T={t}, quadratic oracle) ==");

    // σ=0: draw-free oracle, so the numbers measure runtime + slot traffic
    let backend = QuadraticOracle::new(dim, N, 1.0, 0.5, 2.0, 0.0, 3);
    let graph = complete_graph(N);
    let cost = CostModel::deterministic(0.4);
    let spec = RunSpec {
        n: N,
        events: t,
        lr: LrSchedule::Constant(0.02),
        seed: 1,
        name: "bench-freerun".into(),
        eval_every: 0,
        track_gamma: false,
    };

    let mut rows: Vec<String> = Vec::new();
    for (name, opts) in [
        (
            "swarm",
            AlgoOptions {
                local_steps: LocalSteps::Fixed(4),
                mode: AveragingMode::NonBlocking,
                ..AlgoOptions::default()
            },
        ),
        ("adpsgd", AlgoOptions::default()),
        // the MixPolicy redesign's payoff: SGP freeruns over weighted
        // (x, w) push-sum slots
        ("sgp", AlgoOptions::default()),
    ] {
        let algo = make_algorithm(name, &opts).expect("known algorithm");
        for threads in [1usize, 2, 4] {
            let shards = 2 * threads; // exercise multi-shard ownership
            let m = run_freerun(algo.as_ref(), &backend, &spec, &graph, &cost, threads, shards);
            let fr = m.freerun.as_ref().expect("freerun telemetry");
            println!(
                "{name:<7} x{threads} ({shards} shards): {:>9.0} interactions/s  \
                 staleness p50={} p99={} max={}  read-retries={} cross-write drops={}",
                fr.interactions_per_sec,
                fr.staleness.p50(),
                fr.staleness.p99(),
                fr.staleness.max_observed(),
                fr.slot_read_retries,
                fr.slot_push_conflicts,
            );
            rows.push(row_json(name, threads, shards, N, fr));
        }
    }

    // paper-scale freerun row: n=256 nodes sharded over 4 workers, with
    // the cost model simulating ResNet18's 45 MB wire size on CSCS-like
    // p2p parameters (the examples/freerun_paper_scale.rs preset). The
    // compute stays a small quadratic stand-in; the *wire accounting*
    // and sharding pressure are what this row tracks.
    {
        let n_paper = 256;
        let (dim_p, t_p) = if smoke { (64, 4_000u64) } else { (256, 40_000) };
        let backend = QuadraticOracle::new(dim_p, n_paper, 1.0, 0.5, 2.0, 0.0, 3);
        let graph = complete_graph(n_paper);
        let cost = CostModel {
            batch_time: 1e-4,
            jitter: 0.0,
            straggler_prob: 0.0,
            straggle_factor: 1.0,
            latency: 1e-4,
            bandwidth: 10.0e9,
            model_bytes_override: Some(45_000_000),
        };
        let spec = RunSpec {
            n: n_paper,
            events: t_p,
            lr: LrSchedule::Constant(0.02),
            seed: 1,
            name: "bench-freerun-paper".into(),
            eval_every: 0,
            track_gamma: false,
        };
        let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
        let (threads, shards) = (4usize, 32usize);
        let m = run_freerun(algo.as_ref(), &backend, &spec, &graph, &cost, threads, shards);
        let fr = m.freerun.as_ref().expect("freerun telemetry");
        println!(
            "paper-scale swarm x{threads} ({shards} shards, n={n_paper}, 45 MB wire): \
             {:>9.0} interactions/s  staleness p50={} p99={}  simulated wire={:.1} GB",
            fr.interactions_per_sec,
            fr.staleness.p50(),
            fr.staleness.p99(),
            m.total_bits as f64 / 8e9,
        );
        rows.push(row_json("swarm-paper-scale", threads, shards, n_paper, fr));
    }

    // tracing on vs off: the same swarm ×4 workload twice through the obs
    // entry point — the obs acceptance bar is that full-sampling tracing
    // stays within a few percent of the untraced run
    let overhead_pct = {
        let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
        let (threads, shards) = (4usize, 8usize);
        let configs = [
            ("swarm-trace-off", ObsOptions::default()),
            (
                "swarm-trace-on",
                ObsOptions {
                    trace_capacity: swarm_sgd::obs::DEFAULT_TRACE_CAPACITY,
                    trace_sample: 1.0,
                    metrics_out: None,
                },
            ),
        ];
        let mut ips = [0.0f64; 2];
        for (i, (tag, obs)) in configs.iter().enumerate() {
            let m = run_freerun_with_obs(
                algo.as_ref(),
                &backend,
                &spec,
                &graph,
                &cost,
                threads,
                shards,
                obs,
            );
            let fr = m.freerun.as_ref().expect("freerun telemetry");
            ips[i] = fr.interactions_per_sec;
            println!(
                "{tag:<15} x{threads} ({shards} shards): {:>9.0} interactions/s",
                fr.interactions_per_sec
            );
            rows.push(row_json(tag, threads, shards, N, fr));
            if i == 1 {
                let tr = m.trace.as_ref().expect("tracing-on run drains a trace");
                println!("  traced {} event(s), {} dropped", tr.events.len(), tr.dropped);
            }
        }
        100.0 * (ips[0] - ips[1]) / ips[0].max(1e-9)
    };
    println!("tracing overhead: {overhead_pct:.1}% (positive = tracing-on slower)");

    let json = format!(
        "{{\n  \"bench\": \"bench_freerun\",\n  \"workload\": \
         {{\"n\": {N}, \"dim\": {dim}, \"interactions\": {t}, \
         \"backend\": \"quadratic\", \"smoke\": {smoke}}},\n  \
         \"tracing_overhead_pct\": {overhead_pct:.1},\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_freerun.json")
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_freerun.json"),
        Err(e) => eprintln!("could not write BENCH_freerun.json: {e}"),
    }
}
