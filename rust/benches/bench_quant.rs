//! Quantizer hot-path benches: encode / decode / stochastic rounding /
//! bit packing throughput. §Perf target: ≥ 1 GB/s/core end-to-end codec.

use swarm_sgd::bench::Bench;
use swarm_sgd::quant::{decode, encode, pack_bits, quantize_unbiased, unpack_bits};
use swarm_sgd::rngx::Pcg64;

fn main() {
    let mut b = Bench::default();
    let d = 1 << 20; // 1M coords = 4 MB model
    let bytes = (d * 4) as u64;
    let mut rng = Pcg64::seed(1);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = x.iter().map(|v| v + 0.01).collect();

    println!("== quant codec (d = 1M coords, 4 MB model) ==");
    b.run_elems("quantize_unbiased 1M", bytes, || {
        quantize_unbiased(&x, 1e-3, 7)
    });
    b.run_elems("encode 8-bit 1M", bytes, || encode(&x, 1e-3, 8, 7));
    let msg = encode(&x, 1e-3, 8, 7);
    b.run_elems("decode 8-bit 1M", bytes, || decode(&msg, &y).unwrap());
    b.run_elems("roundtrip 8-bit 1M", bytes, || {
        let m = encode(&x, 1e-3, 8, 7);
        decode(&m, &y).unwrap()
    });

    let coords: Vec<u32> = (0..d as u32).map(|i| i & 0xFF).collect();
    b.run_elems("pack_bits 8 1M", bytes, || pack_bits(&coords, 8));
    let packed = pack_bits(&coords, 8);
    b.run_elems("unpack_bits 8 1M", bytes, || unpack_bits(&packed, 8, d));
    b.run_elems("pack_bits 4 1M", bytes, || pack_bits(&coords, 4));

    // averaging primitive (memory-bound baseline for comparison)
    let mut a2 = x.clone();
    let mut b2 = y.clone();
    b.run_elems("average_into_both 1M", bytes * 2, || {
        swarm_sgd::coordinator::average_into_both(&mut a2, &mut b2)
    });

    b.write_csv("results/bench_quant.csv").ok();
}
