//! PJRT runtime benches (artifact-gated): per-step latency of the compiled
//! train steps, the fused step_k amortization, and eval. §Perf target:
//! dispatch overhead ≤ 5% of step compute at transformer size; step_k
//! should clearly beat k separate dispatches at MLP size.
//!
//! Requires `--features pjrt`; the default build prints a skip notice so
//! `cargo bench` stays green in hermetic environments.

#[cfg(feature = "pjrt")]
mod real {
    use std::path::Path;
    use swarm_sgd::backend::Backend;
    use swarm_sgd::bench::Bench;
    use swarm_sgd::config::ShardMode;
    use swarm_sgd::rngx::Pcg64;
    use swarm_sgd::runtime::{XlaBackend, XlaBackendConfig};

    fn load(preset: &str) -> Option<XlaBackend> {
        if !Path::new("artifacts/manifest.txt").exists() {
            eprintln!("SKIP bench_runtime: run `make artifacts` first");
            return None;
        }
        XlaBackend::load(
            Path::new("artifacts"),
            preset,
            XlaBackendConfig {
                agents: 1,
                data_per_agent: 2048,
                shard: ShardMode::Iid,
                separation: 3.0,
                seed: 5,
                eval_batches: 2,
            },
        )
        .ok()
    }

    pub fn main() {
        let mut b = Bench::quick();
        println!("== PJRT runtime (per-step latency) ==");
        for preset in ["mlp_s", "cnn_s", "transformer_s"] {
            let Some(be) = load(preset) else { return };
            let (mut p, mut m) = be.init();
            let mut rng = Pcg64::seed(7);
            b.run(&format!("{preset} step x1"), || {
                be.step(0, &mut p, &mut m, 0.01, &mut rng)
            });
            let k = be.manifest().k as u64;
            b.run_elems(&format!("{preset} step_k (k={k}) per-call"), k, || {
                be.step_burst(0, &mut p, &mut m, 0.01, k, &mut rng)
            });
            b.run(&format!("{preset} eval"), || be.eval(&p));
            if preset == "mlp_s" {
                let d = be.dim();
                let x: Vec<f32> = vec![0.1; d];
                let y: Vec<f32> = vec![0.2; d];
                b.run_elems(&format!("{preset} qavg artifact (d={d})"), (d * 4) as u64, || {
                    be.qavg(&x, &y, 3).unwrap()
                });
            }
        }
        b.write_csv("results/bench_runtime.csv").ok();
    }
}

#[cfg(feature = "pjrt")]
fn main() {
    real::main();
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("SKIP bench_runtime: built without the `pjrt` feature");
}
