//! Coordinator engine throughput: full SwarmSGD interactions/second on the
//! quadratic oracle (gradient cost ~ O(d), so this measures the L3 overhead:
//! averaging, scratch copies, clock accounting, RNG, metrics).
//! §Perf target: the engine must never bottleneck simulated 0.4 s batches —
//! i.e. ≥ 10^5 interactions/s at d=1k.

use swarm_sgd::bench::Bench;
use swarm_sgd::coordinator::{
    run_serial, AveragingMode, LocalSteps, LrSchedule, RunSpec, SwarmSgd,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn run_swarm(dim: usize, n: usize, t: u64, mode: AveragingMode) -> f64 {
    let backend = QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, 0.1, 3);
    let mut rng = Pcg64::seed(5);
    let graph = Graph::build(Topology::Complete, n, &mut rng);
    let cost = CostModel::deterministic(0.4);
    let algo = SwarmSgd { local_steps: LocalSteps::Fixed(2), mode };
    let spec = RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.02),
        seed: 1,
        name: "bench".into(),
        eval_every: 0,
        track_gamma: false,
    };
    run_serial(&algo, &backend, &spec, &graph, &cost).final_eval_loss
}

fn main() {
    // `cargo bench --bench bench_engine -- --test` = CI smoke mode: tiny
    // budgets, no stats — just proves the bench paths run
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut b = if smoke { Bench::quick() } else { Bench::default() };
    println!("== coordinator engine (interactions/s, oracle backend) ==");
    let sizes: &[(usize, u64)] =
        if smoke { &[(64, 2_000)] } else { &[(64, 20_000), (1024, 5_000)] };
    for &(dim, t) in sizes {
        b.run_elems(&format!("swarm nonblocking d={dim} T={t}"), t, || {
            run_swarm(dim, 16, t, AveragingMode::NonBlocking)
        });
        b.run_elems(&format!("swarm blocking    d={dim} T={t}"), t, || {
            run_swarm(dim, 16, t, AveragingMode::Blocking)
        });
        b.run_elems(&format!("swarm quantized8  d={dim} T={t}"), t, || {
            run_swarm(dim, 16, t, AveragingMode::Quantized { bits: 8, eps: 1e-2 })
        });
    }
    b.write_csv("results/bench_engine.csv").ok();
}
