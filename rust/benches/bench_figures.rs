//! End-to-end bench rows, one per paper table/figure family (reduced sizes
//! so `cargo bench` stays tractable; the full regenerations live behind
//! `swarm figure --id <id>`). These time the complete pipeline each figure
//! exercises: backend + coordinator + metrics + CSV.

use swarm_sgd::bench::Bench;
use swarm_sgd::coordinator::LrSchedule;
use swarm_sgd::figures::{run_arm, Arm, BackendSpec};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::topology::Topology;

fn main() {
    let mut b = Bench::quick();
    let cost = CostModel::deterministic(0.4);
    println!("== figure-harness end-to-end rows (oracle-reduced) ==");

    // table1 family: accuracy-recovery arms
    let spec = BackendSpec::Softmax { n_train: 2048, dim: 32, classes: 10, batch: 32, seed: 5 };
    b.run("table1 row: swarm H=2 softmax n=8 T=256", || {
        run_arm(
            &Arm::swarm("s", 2, 256, 0.1),
            &spec,
            8,
            Topology::Complete,
            &cost,
            7,
            0,
            false,
        )
        .unwrap()
    });
    b.run("table1 row: allreduce softmax n=8 T=64", || {
        run_arm(
            &Arm::baseline("a", "allreduce", 64, 0.1),
            &spec,
            8,
            Topology::Complete,
            &cost,
            7,
            0,
            false,
        )
        .unwrap()
    });

    // table2/gamma family: theory runs on quadratic
    let qspec = BackendSpec::Quadratic { dim: 16, spread: 1.0, sigma: 0.2, seed: 31 };
    b.run("table2 row: swarm theory-lr n=8 T=4096", || {
        run_arm(
            &Arm {
                lr: LrSchedule::Theory { n: 8, t: 4096 },
                ..Arm::swarm("s", 2, 4096, 0.0)
            },
            &qspec,
            8,
            Topology::Complete,
            &cost,
            7,
            512,
            true,
        )
        .unwrap()
    });

    // fig2b/fig4 family: time-per-batch measurement arms
    for algo in ["adpsgd", "dpsgd", "sgp", "localsgd"] {
        b.run(&format!("fig2b row: {algo} n=16 T=64"), || {
            run_arm(
                &Arm::baseline(algo, algo, 64, 0.05),
                &qspec,
                16,
                Topology::Complete,
                &cost,
                7,
                0,
                false,
            )
            .unwrap()
        });
    }

    // fig6a family: 64-agent scaling row
    b.run("fig6a row: swarm softmax n=64 T=512", || {
        run_arm(
            &Arm::swarm("s", 2, 512, 0.1),
            &BackendSpec::Softmax { n_train: 8192, dim: 32, classes: 10, batch: 32, seed: 5 },
            64,
            Topology::Complete,
            &cost,
            7,
            0,
            false,
        )
        .unwrap()
    });

    b.write_csv("results/bench_figures.csv").ok();
}
