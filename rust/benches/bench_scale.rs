//! Scale-engine bench: interactions/second and resident bytes/node versus
//! n on the membership subsystem's compact-store executor — the headline
//! numbers of the scale regime (n ∈ {10k, 100k, 1M} on one box).
//!
//! Every row runs SwarmSGD over the procedural expander overlay with the
//! table-free `ProcQuadraticOracle` backend, so nothing anywhere is
//! O(n·dim) resident except the `NodeStore` arena itself — which is
//! exactly what `bytes_per_node` (enforced via `node_budget`) pins. One
//! additional row turns churn on (`join:0.2, leave:0.4` → stationary live
//! count n/2) to record what a live roster costs in throughput and how
//! many partner draws/cross-writes churn collisions drop.
//!
//! Like `bench_freerun`, rows are measured wall-clock, non-replayable, and
//! runner-dependent by contract: CI records them (`BENCH_scale.json`
//! merged into the committed trajectory), it never gates on them.
//! `-- --test` runs the reduced smoke configuration (n up to 100k); the
//! full run adds the n=1M row.

use std::io::Write;
use swarm_sgd::coordinator::{
    make_algorithm, AlgoOptions, LrSchedule, MembershipStats, RunSpec,
};
use swarm_sgd::grad::ProcQuadraticOracle;
use swarm_sgd::membership::{run_scale, ChurnSpec, ScaleOptions};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::topology::Topology;

const DIM: usize = 64;
const THREADS: usize = 4;
/// Generous ceiling over the d=64 compact record (~212 bytes with the
/// roster/rate overhead) — every row runs with the budget gate ARMED so a
/// layout regression fails the bench instead of silently growing.
const NODE_BUDGET: u64 = 512;

fn row_json(name: &str, n: usize, events: u64, ips: f64, ms: &MembershipStats) -> String {
    format!(
        "    {{\"workload\": \"{name}\", \"n\": {n}, \"threads\": {THREADS}, \
         \"interactions\": {events}, \"interactions_per_sec\": {ips:.1}, \
         \"bytes_per_node\": {}, \"node_budget\": {}, \
         \"live_start\": {}, \"live_end\": {}, \"joins\": {}, \"leaves\": {}, \
         \"rejected_joins\": {}, \"churn_misses\": {}, \"skipped_events\": {}, \
         \"raw_nodes\": {}, \"decode_failures\": {}}}",
        ms.bytes_per_node,
        ms.node_budget,
        ms.live_start,
        ms.live_end,
        ms.joins,
        ms.leaves,
        ms.rejected_joins,
        ms.churn_misses,
        ms.skipped_events,
        ms.raw_nodes,
        ms.decode_failures,
    )
}

fn run_row(n: usize, events: u64, churn: ChurnSpec) -> (f64, MembershipStats) {
    let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
    // table-free backend: the bench's resident set is the store arena
    let backend = ProcQuadraticOracle::new(DIM, n, 1.0, 0.5, 2.0, 0.0, 3);
    let cost = CostModel::deterministic(0.4);
    let spec = RunSpec {
        n,
        events,
        lr: LrSchedule::Constant(0.02),
        seed: 1,
        name: format!("bench-scale-{n}"),
        eval_every: 0,
        track_gamma: false,
    };
    let opts = ScaleOptions {
        threads: THREADS,
        topology: Topology::Expander(8),
        churn,
        node_budget: NODE_BUDGET,
        ..ScaleOptions::default()
    };
    let m = run_scale(algo.as_ref(), &backend, &spec, &cost, &opts).expect("scale run");
    let fr = m.freerun.expect("scale telemetry");
    let ms = fr.membership.expect("membership telemetry");
    (fr.interactions_per_sec, ms)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    println!(
        "== scale engine (swarm, expander8, d={DIM}, proc-quadratic, \
         {THREADS} threads, budget {NODE_BUDGET} B/node) =="
    );

    let mut rows: Vec<String> = Vec::new();
    let sizes: &[(usize, u64)] = if smoke {
        &[(10_000, 40_000), (100_000, 100_000)]
    } else {
        &[(10_000, 100_000), (100_000, 400_000), (1_000_000, 1_000_000)]
    };
    for &(n, events) in sizes {
        let (ips, ms) = run_row(n, events, ChurnSpec::none());
        println!(
            "n={n:<9} fixed roster : {ips:>9.0} interactions/s  \
             {} bytes/node resident  raw={} decode_failures={}",
            ms.bytes_per_node, ms.raw_nodes, ms.decode_failures,
        );
        assert_eq!(ms.live_end, n as u64, "fixed roster must stay full");
        rows.push(row_json("fixed", n, events, ips, &ms));
    }

    // the churn row: join 0.2 / leave 0.4 mean-reverts the live count to
    // n/2 — records roster-flux throughput cost and collision drops
    {
        let (n, events) = if smoke { (10_000, 60_000u64) } else { (100_000, 400_000) };
        let churn = ChurnSpec { join: 0.2, leave: 0.4 };
        let (ips, ms) = run_row(n, events, churn);
        println!(
            "n={n:<9} churn {churn} : {ips:>9.0} interactions/s  \
             live {} -> {} ({} joins, {} leaves, {} collision drops)",
            ms.live_start, ms.live_end, ms.joins, ms.leaves, ms.churn_misses,
        );
        assert!(ms.joins > 0 && ms.leaves > 0, "churn row must actually churn");
        rows.push(row_json("churn", n, events, ips, &ms));
    }

    let json = format!(
        "{{\n  \"bench\": \"bench_scale\",\n  \"workload\": \
         {{\"dim\": {DIM}, \"threads\": {THREADS}, \"topology\": \"expander8\", \
         \"backend\": \"quadratic-proc\", \"node_budget\": {NODE_BUDGET}, \
         \"smoke\": {smoke}}},\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_scale.json")
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_scale.json"),
        Err(e) => eprintln!("could not write BENCH_scale.json: {e}"),
    }
}
