//! Topology/spectral benches: graph construction, λ₂ eigensolve (O(n³)
//! Jacobi — fine for experiment sizes), edge sampling (the per-interaction
//! hot path).

use swarm_sgd::bench::Bench;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn main() {
    let mut b = Bench::default();
    println!("== topology ==");
    for n in [16usize, 64, 128] {
        b.run(&format!("lambda2 complete n={n}"), || {
            Graph::complete(n).lambda2()
        });
    }
    for n in [64usize, 256] {
        let mut rng = Pcg64::seed(3);
        b.run(&format!("build random_regular(6) n={n}"), || {
            Graph::random_regular(n, 6, &mut rng)
        });
    }
    let g = Graph::complete(64);
    let mut rng = Pcg64::seed(5);
    b.run_elems("sample_edge x1000 (K64)", 1000, || {
        let mut acc = 0usize;
        for _ in 0..1000 {
            acc ^= g.sample_edge(&mut rng).0;
        }
        acc
    });
    b.run("random_matching (K64)", || g.random_matching(&mut rng));
    b.write_csv("results/bench_topology.csv").ok();
}
