//! Fused quantize-average microbench: per-element cost of the merge inner
//! loop, two-pass reference vs the fused kernels (`--kernel scalar|simd`),
//! on both wire paths.
//!
//! * **f32**: copy the partner snapshot + separate midpoint sweep (two
//!   traversals, the pre-fusion shape) vs `kernels::avg_into` (one).
//! * **lattice**: `quantized_transfer` (encode → pack → unpack → decode,
//!   allocating the decoded vector) + separate midpoint sweep vs
//!   `kernels::lattice_qavg_into` (decode + average in one traversal into
//!   a caller buffer, zero allocation).
//!
//! All variants produce bit-identical outputs (pinned by
//! `tests/fused_kernels.rs`), so the rows compare cost only. Rows are
//! kernel-tagged and appended to `BENCH_qavg.json`; CI compiles this bench
//! as a blocking gate and records the JSON non-blockingly. `-- --test`
//! runs the reduced smoke configuration.

use std::io::Write;
use swarm_sgd::bench::Bench;
use swarm_sgd::coordinator::quantized_transfer;
use swarm_sgd::kernels::{avg_into, lattice_qavg_into, Kernel};
use swarm_sgd::rngx::Pcg64;

fn row_json(path: &str, implname: &str, kernel: &str, dim: usize, median_ns: u128) -> String {
    let per_elem = median_ns as f64 / dim as f64;
    format!(
        "    {{\"path\": \"{path}\", \"impl\": \"{implname}\", \"kernel\": \"{kernel}\", \
         \"dim\": {dim}, \"median_ns\": {median_ns}, \"ns_per_elem\": {per_elem:.4}}}"
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut b = if smoke { Bench::quick() } else { Bench::default() };
    let d: usize = if smoke { 1 << 14 } else { 1 << 20 };
    let (eps, bits, seed) = (1e-3f32, 8u32, 7u32);

    // close pair: the checksum criterion holds, so no run falls back and
    // every variant times the quantized fast path
    let mut rng = Pcg64::seed(1);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<f32> = x.iter().map(|v| v + 0.001).collect();
    let mut out = vec![0.0f32; d];

    println!("== fused quantize-average (d = {d} coords, 8-bit lattice) ==");

    let mut rows: Vec<String> = Vec::new();

    // ---- f32 path -------------------------------------------------------
    let r = b
        .run_elems("f32 two-pass (copy + midpoint)", d as u64, || {
            out.copy_from_slice(&x);
            for (o, &l) in out.iter_mut().zip(&y) {
                *o = 0.5 * (l + *o);
            }
            out[0]
        })
        .median
        .as_nanos();
    rows.push(row_json("f32", "two-pass", "-", d, r));
    for kern in [Kernel::Scalar, Kernel::Simd] {
        let r = b
            .run_elems(&format!("f32 fused avg_into [{}]", kern.name()), d as u64, || {
                avg_into(kern, &x, &y, &mut out);
                out[0]
            })
            .median
            .as_nanos();
        rows.push(row_json("f32", "fused", kern.name(), d, r));
    }

    // ---- lattice path ---------------------------------------------------
    let tr = quantized_transfer(&x, &y, eps, bits, seed);
    assert!(!tr.fell_back, "bench workload must stay on the quantized path");
    let r = b
        .run_elems("lattice two-pass (transfer + midpoint)", d as u64, || {
            let tr = quantized_transfer(&x, &y, eps, bits, seed);
            for (o, (&l, &dec)) in out.iter_mut().zip(y.iter().zip(&tr.decoded)) {
                *o = 0.5 * (l + dec);
            }
            out[0]
        })
        .median
        .as_nanos();
    rows.push(row_json("lattice", "two-pass", "-", d, r));
    for kern in [Kernel::Scalar, Kernel::Simd] {
        let r = b
            .run_elems(
                &format!("lattice fused qavg_into [{}]", kern.name()),
                d as u64,
                || {
                    let (bits, fb) = lattice_qavg_into(kern, &x, &y, eps, bits, seed, &mut out);
                    assert!(!fb);
                    bits
                },
            )
            .median
            .as_nanos();
        rows.push(row_json("lattice", "fused", kern.name(), d, r));
    }

    b.write_csv("results/bench_qavg.csv").ok();

    let json = format!(
        "{{\n  \"bench\": \"bench_qavg\",\n  \"workload\": \
         {{\"dim\": {d}, \"bits\": {bits}, \"eps\": {eps}, \"smoke\": {smoke}}},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_qavg.json").and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_qavg.json"),
        Err(e) => eprintln!("could not write BENCH_qavg.json: {e}"),
    }
}
