//! Parallel-executor throughput: SwarmSGD interactions/second vs worker
//! thread count on an n=32 synthetic-quadratic workload, against the serial
//! discrete-event runner as baseline. §Perf target (CI-recorded): ≥ 2x
//! interactions/s at 4 threads vs serial.
//!
//! Writes `BENCH_parallel.json` (crate root) so CI can archive the perf
//! trajectory per PR. `-- --test` runs the reduced smoke configuration.

use std::io::Write;
use swarm_sgd::bench::{Bench, BenchResult};
use swarm_sgd::coordinator::{
    run_parallel, AveragingMode, LocalSteps, LrSchedule, RunContext, SwarmConfig, SwarmRunner,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

const N: usize = 32;

/// σ=0 so the oracle is draw-free and the bench measures executor overhead
/// + gradient math, not Box–Muller throughput.
fn oracle(dim: usize) -> QuadraticOracle {
    QuadraticOracle::new(dim, N, 1.0, 0.5, 2.0, 0.0, 3)
}

fn cfg(t: u64, mode: AveragingMode) -> SwarmConfig {
    SwarmConfig {
        n: N,
        local_steps: LocalSteps::Fixed(4),
        mode,
        lr: LrSchedule::Constant(0.02),
        interactions: t,
        seed: 1,
        name: "bench-par".into(),
    }
}

fn graph() -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, N, &mut rng)
}

fn run_serial(dim: usize, t: u64, mode: AveragingMode) -> f64 {
    let mut backend = oracle(dim);
    let mut rng = Pcg64::seed(5);
    let g = graph();
    let cost = CostModel::deterministic(0.4);
    let mut ctx = RunContext {
        backend: &mut backend,
        graph: &g,
        cost: &cost,
        rng: &mut rng,
        eval_every: 0,
        track_gamma: false,
    };
    SwarmRunner::new(cfg(t, mode), &mut ctx).run(&mut ctx).final_eval_loss
}

fn run_par(dim: usize, t: u64, threads: usize, mode: AveragingMode) -> f64 {
    let backend = oracle(dim);
    let g = graph();
    let cost = CostModel::deterministic(0.4);
    run_parallel(&cfg(t, mode), threads, &g, &cost, &backend, 0, false).final_eval_loss
}

fn json_row(r: &BenchResult, threads: usize) -> String {
    format!(
        "    {{\"name\": \"{}\", \"threads\": {}, \"interactions_per_sec\": {:.1}, \
         \"median_ns\": {}}}",
        r.name,
        threads,
        r.throughput().unwrap_or(f64::NAN),
        r.median.as_nanos()
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let (dim, t) = if smoke { (512, 2_000u64) } else { (2048, 10_000) };
    let mut b = if smoke { Bench::quick() } else { Bench::default() };
    println!("== parallel executor (n={N}, d={dim}, T={t}, H=4, quadratic oracle) ==");

    let mode = AveragingMode::NonBlocking;
    let mut rows: Vec<String> = Vec::new();

    let serial = b
        .run_elems(&format!("serial runner      d={dim} T={t}"), t, || {
            run_serial(dim, t, mode)
        })
        .clone();
    rows.push(json_row(&serial, 1));

    let mut par4_tp = f64::NAN;
    for threads in [1usize, 2, 4] {
        let r = b
            .run_elems(&format!("parallel x{threads}        d={dim} T={t}"), t, || {
                run_par(dim, t, threads, mode)
            })
            .clone();
        if threads == 4 {
            par4_tp = r.throughput().unwrap_or(f64::NAN);
        }
        rows.push(json_row(&r, threads));
    }

    // quantized non-blocking at 4 threads (the Appendix-G hot path)
    let rq = b
        .run_elems(&format!("parallel x4 quant8 d={dim} T={t}"), t, || {
            run_par(dim, t, 4, AveragingMode::Quantized { bits: 8, eps: 1e-2 })
        })
        .clone();
    rows.push(json_row(&rq, 4));

    let serial_tp = serial.throughput().unwrap_or(f64::NAN);
    let speedup = par4_tp / serial_tp;
    println!(
        "speedup @4 threads vs serial runner: {speedup:.2}x \
         ({par4_tp:.0} vs {serial_tp:.0} interactions/s)"
    );

    let json = format!(
        "{{\n  \"bench\": \"bench_parallel\",\n  \"workload\": \
         {{\"n\": {N}, \"dim\": {dim}, \"interactions\": {t}, \"h\": 4, \
         \"backend\": \"quadratic\", \"smoke\": {smoke}}},\n  \"results\": [\n{}\n  ],\n  \
         \"speedup_4threads_vs_serial\": {speedup:.3}\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_parallel.json")
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_parallel.json"),
        Err(e) => eprintln!("could not write BENCH_parallel.json: {e}"),
    }
    b.write_csv("results/bench_parallel.csv").ok();
}
