//! Parallel-executor throughput: interactions/second vs worker thread count
//! on an n=32 synthetic-quadratic workload — the gossip algorithms
//! (SwarmSGD and AD-PSGD) plus the round-based baselines that parallelize
//! since the phased-event redesign (D-PSGD and allreduce: n per-node
//! compute events + mix barrier per round), against the serial executor as
//! baseline. §Perf target (CI-recorded): ≥ 2x interactions/s at 4 threads
//! vs serial for SwarmSGD non-blocking. Round-based rows count rounds/s
//! (one round = n compute events + mixing).
//!
//! Writes `BENCH_parallel.json` (crate root) with algorithm-tagged entries
//! so CI can archive the perf trajectory per PR. `-- --test` runs the
//! reduced smoke configuration.

use std::io::Write;
use swarm_sgd::bench::{Bench, BenchResult};
use swarm_sgd::coordinator::{
    make_algorithm, run_parallel, run_serial, AlgoOptions, AveragingMode, LocalSteps,
    LrSchedule, RunSpec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

const N: usize = 32;

/// σ=0 so the oracle is draw-free and the bench measures executor overhead
/// + gradient math, not Box–Muller throughput.
fn oracle(dim: usize) -> QuadraticOracle {
    QuadraticOracle::new(dim, N, 1.0, 0.5, 2.0, 0.0, 3)
}

fn spec(t: u64) -> RunSpec {
    RunSpec {
        n: N,
        events: t,
        lr: LrSchedule::Constant(0.02),
        seed: 1,
        name: "bench-par".into(),
        eval_every: 0,
        track_gamma: false,
    }
}

fn graph() -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, N, &mut rng)
}

fn opts(h: u64, mode: AveragingMode) -> AlgoOptions {
    AlgoOptions { local_steps: LocalSteps::Fixed(h), mode, h_localsgd: 5, ..Default::default() }
}

fn run_algo(name: &str, dim: usize, t: u64, threads: usize, o: &AlgoOptions) -> f64 {
    let algo = make_algorithm(name, o).expect("known algorithm");
    let backend = oracle(dim);
    let g = graph();
    let cost = CostModel::deterministic(0.4);
    let s = spec(t);
    if threads <= 1 {
        run_serial(algo.as_ref(), &backend, &s, &g, &cost).final_eval_loss
    } else {
        run_parallel(algo.as_ref(), &backend, &s, &g, &cost, threads).final_eval_loss
    }
}

fn json_row(r: &BenchResult, algorithm: &str, threads: usize, h: u64) -> String {
    format!(
        "    {{\"name\": \"{}\", \"algorithm\": \"{}\", \"threads\": {}, \"h\": {}, \
         \"interactions_per_sec\": {:.1}, \"median_ns\": {}}}",
        r.name,
        algorithm,
        threads,
        h,
        r.throughput().unwrap_or(f64::NAN),
        r.median.as_nanos()
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let (dim, t) = if smoke { (512, 2_000u64) } else { (2048, 10_000) };
    let mut b = if smoke { Bench::quick() } else { Bench::default() };
    println!("== parallel executor (n={N}, d={dim}, T={t}, quadratic oracle) ==");

    let swarm = opts(4, AveragingMode::NonBlocking);
    let mut rows: Vec<String> = Vec::new();

    let serial = b
        .run_elems(&format!("swarm serial       d={dim} T={t}"), t, || {
            run_algo("swarm", dim, t, 1, &swarm)
        })
        .clone();
    rows.push(json_row(&serial, "swarm", 1, 4));

    let mut par4_tp = f64::NAN;
    for threads in [2usize, 4] {
        let r = b
            .run_elems(&format!("swarm parallel x{threads}  d={dim} T={t}"), t, || {
                run_algo("swarm", dim, t, threads, &swarm)
            })
            .clone();
        if threads == 4 {
            par4_tp = r.throughput().unwrap_or(f64::NAN);
        }
        rows.push(json_row(&r, "swarm", threads, 4));
    }

    // quantized non-blocking at 4 threads (the Appendix-G hot path)
    let rq = b
        .run_elems(&format!("swarm x4 quant8    d={dim} T={t}"), t, || {
            run_algo("swarm", dim, t, 4, &opts(4, AveragingMode::Quantized { bits: 8, eps: 1e-2 }))
        })
        .clone();
    rows.push(json_row(&rq, "swarm-quant8", 4, 4));

    // AD-PSGD: the asynchronous baseline on the same executor (satellite:
    // algorithm-tagged throughput rows in BENCH_parallel.json)
    let adpsgd = opts(1, AveragingMode::NonBlocking);
    let ra1 = b
        .run_elems(&format!("adpsgd serial      d={dim} T={t}"), t, || {
            run_algo("adpsgd", dim, t, 1, &adpsgd)
        })
        .clone();
    rows.push(json_row(&ra1, "adpsgd", 1, 1));
    let ra4 = b
        .run_elems(&format!("adpsgd parallel x4 d={dim} T={t}"), t, || {
            run_algo("adpsgd", dim, t, 4, &adpsgd)
        })
        .clone();
    rows.push(json_row(&ra4, "adpsgd", 4, 1));

    // the newly-parallel round-based baselines (phased events): one round
    // is n compute events + mixing, so fewer rounds match the step budget
    let t_rounds = (t / 8).max(1);
    let round_opts = opts(1, AveragingMode::NonBlocking);
    let rd1 = b
        .run_elems(&format!("dpsgd serial       d={dim} R={t_rounds}"), t_rounds, || {
            run_algo("dpsgd", dim, t_rounds, 1, &round_opts)
        })
        .clone();
    rows.push(json_row(&rd1, "dpsgd", 1, 1));
    let rd4 = b
        .run_elems(&format!("dpsgd parallel x4  d={dim} R={t_rounds}"), t_rounds, || {
            run_algo("dpsgd", dim, t_rounds, 4, &round_opts)
        })
        .clone();
    rows.push(json_row(&rd4, "dpsgd", 4, 1));
    let rr1 = b
        .run_elems(&format!("allreduce serial   d={dim} R={t_rounds}"), t_rounds, || {
            run_algo("allreduce", dim, t_rounds, 1, &round_opts)
        })
        .clone();
    rows.push(json_row(&rr1, "allreduce", 1, 1));
    let rr4 = b
        .run_elems(&format!("allreduce parallel x4 d={dim} R={t_rounds}"), t_rounds, || {
            run_algo("allreduce", dim, t_rounds, 4, &round_opts)
        })
        .clone();
    rows.push(json_row(&rr4, "allreduce", 4, 1));

    let serial_tp = serial.throughput().unwrap_or(f64::NAN);
    let speedup = par4_tp / serial_tp;
    println!(
        "swarm speedup @4 threads vs serial: {speedup:.2}x \
         ({par4_tp:.0} vs {serial_tp:.0} interactions/s)"
    );
    let adpsgd_speedup =
        ra4.throughput().unwrap_or(f64::NAN) / ra1.throughput().unwrap_or(f64::NAN);
    println!("adpsgd speedup @4 threads vs serial: {adpsgd_speedup:.2}x");
    let dpsgd_speedup =
        rd4.throughput().unwrap_or(f64::NAN) / rd1.throughput().unwrap_or(f64::NAN);
    println!("dpsgd speedup @4 threads vs serial: {dpsgd_speedup:.2}x (phased rounds)");
    let allreduce_speedup =
        rr4.throughput().unwrap_or(f64::NAN) / rr1.throughput().unwrap_or(f64::NAN);
    println!("allreduce speedup @4 threads vs serial: {allreduce_speedup:.2}x (phased rounds)");

    // h is per-algorithm (swarm rows run H=4, adpsgd is defined with H=1),
    // so the shared workload stanza carries only algorithm-independent keys
    let json = format!(
        "{{\n  \"bench\": \"bench_parallel\",\n  \"workload\": \
         {{\"n\": {N}, \"dim\": {dim}, \"interactions\": {t}, \
         \"rounds\": {t_rounds}, \
         \"backend\": \"quadratic\", \"smoke\": {smoke}}},\n  \"results\": [\n{}\n  ],\n  \
         \"speedup_4threads_vs_serial\": {speedup:.3},\n  \
         \"adpsgd_speedup_4threads_vs_serial\": {adpsgd_speedup:.3},\n  \
         \"dpsgd_speedup_4threads_vs_serial\": {dpsgd_speedup:.3},\n  \
         \"allreduce_speedup_4threads_vs_serial\": {allreduce_speedup:.3}\n}}\n",
        rows.join(",\n")
    );
    match std::fs::File::create("BENCH_parallel.json")
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("wrote BENCH_parallel.json"),
        Err(e) => eprintln!("could not write BENCH_parallel.json: {e}"),
    }
    b.write_csv("results/bench_parallel.csv").ok();
}
