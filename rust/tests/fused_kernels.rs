//! Property tests for the fused quantize-average kernels (`--kernel`):
//!
//! 1. **Fused ≡ two-pass** (the crate's core contract): the one-traversal
//!    scalar kernel is bit-identical to the legacy `encode → pack → unpack
//!    → decode → merge` path, on the f32 and lattice paths, across the
//!    codec's full bit-width range. (Bit width 1 is outside the lattice
//!    codec's domain — the encoder has always asserted `2..=16` — so the
//!    fused kernels pin the same rejection rather than inventing a wider
//!    domain.)
//! 2. **SIMD ≡ scalar**: the chunk-of-8 lane path is bit-exact with the
//!    scalar reference (elementwise math, checksums folded in element
//!    order), across lengths that do and don't divide the lane width.
//! 3. **Run-level**: switching `--kernel` must not change a single metric
//!    bit on the replay executors — serial and parallel, every
//!    freerun-eligible algorithm, lattice and f32 wires. This is what lets
//!    the replay-determinism contract hold with the kernel axis open.
//! 4. **Tagging**: the selected kernel is surfaced through
//!    `RunMetrics::kernel` (and `FreerunStats::kernel`) so bench rows are
//!    kernel-tagged.

use swarm_sgd::coordinator::{
    make_algorithm, quantized_transfer, run_freerun, run_parallel, run_serial, AlgoOptions,
    Kernel, LrSchedule, RunMetrics, RunSpec, WireCodec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::kernels::{
    avg_into, avg_into_both, half_into, lattice_qavg_into, lattice_take_half_into,
};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn close_pair(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seed(seed);
    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<f32> = x.iter().map(|v| v + 0.01 * rng.normal() as f32).collect();
    (x, y)
}

#[test]
fn fused_scalar_equals_two_pass_across_bit_widths() {
    // qavg and take-half vs the two-pass reference (quantized_transfer +
    // a separate merge sweep), bit for bit, at every valid lattice width
    for bits in 2..=16u32 {
        for (dim, seed) in [(97usize, 3u32), (256, 9), (1021, 31)] {
            let (x, y) = close_pair(dim, bits as u64 * 1000 + dim as u64);
            let eps = 2e-3f32;
            let tr = quantized_transfer(&x, &y, eps, bits, seed);

            let want_avg: Vec<f32> =
                y.iter().zip(&tr.decoded).map(|(a, d)| 0.5 * (a + d)).collect();
            let mut avg = vec![0.0f32; dim];
            let (b, fb) = lattice_qavg_into(Kernel::Scalar, &x, &y, eps, bits, seed, &mut avg);
            assert_eq!(avg, want_avg, "qavg bits={bits} dim={dim}");
            assert_eq!((b, fb), (tr.bits, tr.fell_back), "qavg bits={bits} dim={dim}");

            let want_half: Vec<f32> = tr.decoded.iter().map(|d| 0.5 * d).collect();
            let mut half = vec![0.0f32; dim];
            let (b, fb) =
                lattice_take_half_into(Kernel::Scalar, &x, &y, eps, bits, seed, &mut half);
            assert_eq!(half, want_half, "half bits={bits} dim={dim}");
            assert_eq!((b, fb), (tr.bits, tr.fell_back), "half bits={bits} dim={dim}");
        }
    }
}

#[test]
fn fused_scalar_equals_two_pass_on_f32_path() {
    // the full-precision path: fused avg == copy + separate midpoint sweep
    let (x, y) = close_pair(513, 7);
    let want: Vec<f32> = x.iter().zip(&y).map(|(a, b)| 0.5 * (a + b)).collect();
    let mut out = vec![0.0f32; x.len()];
    avg_into(Kernel::Scalar, &x, &y, &mut out);
    assert_eq!(out, want);
    half_into(Kernel::Scalar, &y, &mut out);
    let want_half: Vec<f32> = y.iter().map(|v| 0.5 * v).collect();
    assert_eq!(out, want_half);
}

#[test]
#[should_panic(expected = "bits must be in 2..=16")]
fn fused_kernel_pins_the_codec_bit_width_domain() {
    // bit width 1 has never been in the lattice codec's domain; the fused
    // kernel rejects it with the same assertion instead of widening it
    let (x, y) = close_pair(8, 1);
    let mut out = vec![0.0f32; 8];
    lattice_qavg_into(Kernel::Scalar, &x, &y, 1e-3, 1, 0, &mut out);
}

#[test]
fn simd_equals_scalar_across_lengths_and_widths() {
    // bit-exactness of the lane path, including lengths below, at, and off
    // multiples of the 8-wide chunk
    for dim in [1usize, 7, 8, 9, 16, 63, 64, 65, 300, 1021] {
        for bits in [2u32, 5, 8, 12, 16] {
            let (x, y) = close_pair(dim, dim as u64 * 77 + bits as u64);
            let mut a = vec![0.0f32; dim];
            let mut b = vec![0.0f32; dim];
            let ra = lattice_qavg_into(Kernel::Scalar, &x, &y, 1e-3, bits, 5, &mut a);
            let rb = lattice_qavg_into(Kernel::Simd, &x, &y, 1e-3, bits, 5, &mut b);
            assert_eq!(a, b, "qavg dim={dim} bits={bits}");
            assert_eq!(ra, rb, "qavg dim={dim} bits={bits}");
            let ra = lattice_take_half_into(Kernel::Scalar, &x, &y, 1e-3, bits, 5, &mut a);
            let rb = lattice_take_half_into(Kernel::Simd, &x, &y, 1e-3, bits, 5, &mut b);
            assert_eq!(a, b, "half dim={dim} bits={bits}");
            assert_eq!(ra, rb, "half dim={dim} bits={bits}");
        }
        let (x, y) = close_pair(dim, dim as u64);
        let mut a = vec![0.0f32; dim];
        let mut b = vec![0.0f32; dim];
        avg_into(Kernel::Scalar, &x, &y, &mut a);
        avg_into(Kernel::Simd, &x, &y, &mut b);
        assert_eq!(a, b, "avg dim={dim}");
        let (mut xa, mut ya) = (x.clone(), y.clone());
        let (mut xb, mut yb) = (x.clone(), y.clone());
        avg_into_both(Kernel::Scalar, &mut xa, &mut ya);
        avg_into_both(Kernel::Simd, &mut xb, &mut yb);
        assert_eq!(xa, xb, "both dim={dim}");
        assert_eq!(ya, yb, "both dim={dim}");
    }
}

fn quad(n: usize, dim: usize, seed: u64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, 0.2, seed)
}

fn graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn spec(n: usize, t: u64, seed: u64) -> RunSpec {
    RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed,
        name: "fused-it".into(),
        eval_every: t / 4,
        track_gamma: true,
    }
}

/// Every externally observable metric must agree to the bit.
fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, tag: &str) {
    assert_eq!(a.curve.len(), b.curve.len(), "{tag}");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.t, q.t, "{tag}");
        assert_eq!(p.eval_loss.to_bits(), q.eval_loss.to_bits(), "{tag} eval t={}", p.t);
        assert_eq!(p.train_loss.to_bits(), q.train_loss.to_bits(), "{tag} train t={}", p.t);
        assert_eq!(p.gamma.to_bits(), q.gamma.to_bits(), "{tag} gamma t={}", p.t);
        assert_eq!(p.sim_time.to_bits(), q.sim_time.to_bits(), "{tag} time t={}", p.t);
        assert_eq!(p.bits, q.bits, "{tag} bits t={}", p.t);
    }
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits(), "{tag}");
    assert_eq!(a.total_bits, b.total_bits, "{tag}");
    assert_eq!(a.quant_fallbacks, b.quant_fallbacks, "{tag}");
    assert_eq!(a.local_steps, b.local_steps, "{tag}");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{tag}");
    assert_eq!(a.final_model, b.final_model, "{tag}");
}

#[test]
fn kernel_axis_is_bit_invariant_on_replay_executors() {
    // --kernel simd must not move a single bit on serial OR parallel, for
    // every freerun-eligible algorithm on both wires (the quantized merge
    // is where the fused lattice kernel actually runs), under a jittery
    // cost model so time accounting is pinned too
    let n = 8;
    let g = graph(n);
    let backend = quad(n, 37, 17); // dim off the 8-lane multiple on purpose
    let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
    for wire in [WireCodec::F32, WireCodec::Lattice { bits: 8, eps: 1e-2 }] {
        for name in ["swarm", "poisson", "adpsgd", "dpsgd", "sgp"] {
            let s = spec(n, 240, 0xF15E);
            let scalar = make_algorithm(
                name,
                &AlgoOptions { wire, kernel: Kernel::Scalar, ..AlgoOptions::default() },
            )
            .unwrap();
            let simd = make_algorithm(
                name,
                &AlgoOptions { wire, kernel: Kernel::Simd, ..AlgoOptions::default() },
            )
            .unwrap();
            let tag = format!("{name}/{}", wire.name());
            let base = run_serial(scalar.as_ref(), &backend, &s, &g, &cost);
            let serial_simd = run_serial(simd.as_ref(), &backend, &s, &g, &cost);
            assert_bit_identical(&base, &serial_simd, &tag);
            for threads in [2, 4] {
                let par = run_parallel(simd.as_ref(), &backend, &s, &g, &cost, threads);
                assert_bit_identical(&base, &par, &format!("{tag}/threads={threads}"));
            }
        }
    }
}

#[test]
fn kernel_tag_is_surfaced_in_run_metrics() {
    let n = 8;
    let g = graph(n);
    let backend = quad(n, 16, 3);
    let cost = CostModel::deterministic(0.2);
    let s = spec(n, 80, 0x7A6);
    let scalar = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
    let simd = make_algorithm(
        "swarm",
        &AlgoOptions { kernel: Kernel::Simd, ..AlgoOptions::default() },
    )
    .unwrap();
    assert_eq!(run_serial(scalar.as_ref(), &backend, &s, &g, &cost).kernel, "scalar");
    assert_eq!(run_serial(simd.as_ref(), &backend, &s, &g, &cost).kernel, "simd");
    assert_eq!(run_parallel(simd.as_ref(), &backend, &s, &g, &cost, 2).kernel, "simd");
}

#[test]
fn freerun_runs_on_the_simd_kernel_and_tags_its_stats() {
    // freerun is non-replayable, so assert liveness + tagging, not bits
    let n = 16;
    let g = graph(n);
    let backend = quad(n, 32, 11);
    let cost = CostModel::deterministic(0.1);
    let s = spec(n, 2000, 0xFEE);
    let algo = make_algorithm(
        "sgp",
        &AlgoOptions {
            wire: WireCodec::Lattice { bits: 8, eps: 1e-2 },
            kernel: Kernel::Simd,
            ..AlgoOptions::default()
        },
    )
    .unwrap();
    let m = run_freerun(algo.as_ref(), &backend, &s, &g, &cost, 2, 4);
    assert_eq!(m.kernel, "simd");
    let fr = m.freerun.expect("freerun stats");
    assert_eq!(fr.kernel, "simd");
    assert!(m.final_eval_loss.is_finite());
    assert!(m.interactions > 0);
}
