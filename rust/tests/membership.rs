//! Membership subsystem integration tests: the churn process holds its
//! statistical band end-to-end through `run_scale`, churned runs still
//! converge, and recycled roster slots never alias a live generation.

use swarm_sgd::coordinator::{make_algorithm, AlgoOptions, LrSchedule, RunSpec};
use swarm_sgd::grad::ProcQuadraticOracle;
use swarm_sgd::membership::{run_scale, ChurnSpec, Roster, ScaleOptions};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::topology::Topology;

fn scale_run(
    n: usize,
    events: u64,
    churn: ChurnSpec,
    topology: Topology,
) -> swarm_sgd::coordinator::RunMetrics {
    let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
    let backend = ProcQuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.2, 9);
    let cost = CostModel::deterministic(0.1);
    let spec = RunSpec {
        n,
        events,
        lr: LrSchedule::Constant(0.05),
        seed: 7,
        name: "membership-it".into(),
        eval_every: 0,
        track_gamma: false,
    };
    let opts = ScaleOptions {
        threads: 2,
        topology,
        churn,
        ..ScaleOptions::default()
    };
    run_scale(algo.as_ref(), &backend, &spec, &cost, &opts).expect("scale run")
}

/// The birth–death competition mean-reverts the live count to
/// `n · min(1, join/leave)`: with join 0.3 / leave 0.6 the stationary
/// fraction is 1/2, and after many events the run must sit inside a wide
/// band around it — while the flux counters stay consistent with the
/// final census.
#[test]
fn churn_holds_the_stationary_band_through_the_public_api() {
    let n = 512;
    let m = scale_run(n, 25_000, ChurnSpec { join: 0.3, leave: 0.6 }, Topology::Complete);
    let fr = m.freerun.expect("scale telemetry");
    let ms = fr.membership.expect("membership telemetry");
    assert_eq!(ms.capacity, n);
    assert_eq!(ms.live_start, n as u64);
    assert!(ms.joins > 0 && ms.leaves > 0, "churn never fired: {ms:?}");
    let frac = ms.live_end as f64 / n as f64;
    assert!(
        (0.3..=0.7).contains(&frac),
        "live fraction {frac:.3} outside the [0.3, 0.7] band around the \
         n/2 equilibrium: {ms:?}"
    );
    // census identity: every join/leave is one slot transition
    assert_eq!(
        ms.live_end,
        ms.live_start + ms.joins - ms.leaves,
        "flux counters disagree with the final census: {ms:?}"
    );
}

/// A churned run still trains: joiners bootstrap from a live neighbor's
/// snapshot, so the population loss keeps descending from x0 even while
/// half the roster turns over.
#[test]
fn churned_run_converges_on_the_procedural_quadratic() {
    let n = 256;
    let backend = ProcQuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.2, 9);
    let x0_loss = {
        use swarm_sgd::backend::Backend;
        let (p0, _) = backend.init();
        backend.full_loss(&p0)
    };
    let m = scale_run(n, 20_000, ChurnSpec { join: 0.2, leave: 0.2 }, Topology::Expander(8));
    assert!(
        m.final_eval_loss < 0.6 * x0_loss,
        "churned run did not converge: final {} vs x0 {x0_loss}",
        m.final_eval_loss
    );
    let ms = m.freerun.expect("telemetry").membership.expect("membership");
    assert!(ms.joins > 0 && ms.leaves > 0);
    assert_eq!(ms.decode_failures, 0, "store roundtrips must be clean");
}

/// The aliasing guarantee behind safe slot recycling: across arbitrary
/// retire/admit cycles, `(slot, generation)` pairs are unique, live
/// generations are exactly the odd ones, and no recycled incarnation ever
/// reuses a prior generation — so a stale cross-write tagged with a dead
/// generation can always be recognized and dropped.
#[test]
fn recycled_slots_never_alias_live_generations() {
    let r = Roster::new(8, 8);
    let mut seen: std::collections::HashSet<(usize, u32)> = std::collections::HashSet::new();
    for slot in 0..8 {
        assert!(r.is_live(slot));
        assert!(seen.insert((slot, r.generation(slot))));
    }
    // cycle each slot a different number of times; every observed live
    // generation must be fresh and odd
    for slot in 0..8 {
        for _ in 0..=slot {
            let dead = r.retire(slot);
            assert_eq!(dead & 1, 0, "retired generation must be even");
            assert!(!r.is_live(slot));
            let live = r.admit(slot);
            assert_eq!(live & 1, 1, "admitted generation must be odd");
            assert!(
                seen.insert((slot, live)),
                "slot {slot} recycled into a previously-live generation {live}"
            );
        }
    }
    assert_eq!(r.live_count(), 8);
    assert_eq!(r.joins(), 8 * 9 / 2);
    assert_eq!(r.leaves(), 8 * 9 / 2);
}
