//! Zero-allocation smoke test for the scratch-threaded merge path.
//!
//! The API-redesign contract: once a worker owns a warmed-up
//! [`MergeScratch`], running merges through it allocates **nothing** per
//! interaction — the fused kernels write into the scratch buffers and the
//! policies never materialize a `Vec`. Pinned with a counting global
//! allocator; this file holds exactly one test so no concurrent test body
//! can pollute the counter inside the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use swarm_sgd::coordinator::{
    LocalSteps, MergeScratch, MixPolicy, NodeState, PairMerge, PairwisePolicy, PushSumPolicy,
    PushSumWeighted, SlotPayload, StepCtx, WireCodec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
fn merges_through_a_warm_scratch_do_not_allocate() {
    let n = 4;
    let dim = 64;
    let backend = QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, 0.2, 7);
    let mut grng = Pcg64::seed(5);
    let graph = Graph::build(Topology::Complete, n, &mut grng);
    let cost = CostModel::deterministic(0.1);
    let ctx = StepCtx { backend: &backend, cost: &cost, graph: &graph, lr: 0.05, dim, n };
    let mut rng = Pcg64::seed(11);

    // a pairwise (plain-model) policy on the lattice wire — the fused
    // qavg kernel — and the push-sum take-half policy on dim+1 lanes
    let pairwise = PairwisePolicy {
        steps: LocalSteps::Fixed(2),
        merge: PairMerge::NonBlocking,
        wire: WireCodec::Lattice { bits: 8, eps: 1e-2 },
    };
    let pushsum = PushSumPolicy {
        steps: LocalSteps::Fixed(2),
        wire: WireCodec::Lattice { bits: 8, eps: 1e-2 },
    };

    let mut st = NodeState::new(vec![0.1; dim], vec![0.0; dim], Pcg64::seed(3));
    let mut scratch = MergeScratch::new(dim + 1); // widest payload in play
    for (i, v) in scratch.snapshot.iter_mut().enumerate() {
        *v = 0.1 + 1e-3 * i as f32;
    }
    st.snap.copy_from_slice(&st.params);

    // warm-up: first merges touch everything once
    pairwise.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
    PushSumWeighted::encode(&st.params, st.weight, &mut scratch.publish[..dim + 1]);
    pushsum.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        pairwise.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
        pushsum.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "scratch-threaded merges allocated {} times in 200 interactions",
        after - before
    );
}
