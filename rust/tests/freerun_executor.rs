//! Free-running executor contract tests.
//!
//! `freerun` is **non-replayable by design** (real thread interleaving),
//! so unlike `tests/parallel_executor.rs` nothing here asserts bit
//! equality — the contract is statistical:
//!
//! 1. **Coverage**: every algorithm with a [`MixPolicy`] runs end-to-end
//!    with `n ≥ 8×` the thread count — the pairwise-mixing four (swarm,
//!    poisson, adpsgd, dpsgd) over plain-model slots AND, since the
//!    `MixPolicy` redesign, sgp over weighted push-sum `(x, w)` slots —
//!    while the globally-mixing baselines (localsgd, allreduce) refuse
//!    (no policy).
//! 2. **Telemetry**: the run reports nonzero staleness, real
//!    interactions/sec, per-worker accounting that sums to the total, and
//!    the wire codec's bit/fallback attribution.
//! 3. **Convergence sanity**: quadratic-oracle freerun runs (swarm, dpsgd,
//!    and sgp's Σx/Σw de-biased consensus) land in the same loss ballpark
//!    as `run_serial` (tolerance-based), guarding against silent
//!    divergence in the lock-free slot path.
//!
//! [`MixPolicy`]: swarm_sgd::coordinator::MixPolicy

use swarm_sgd::backend::Backend;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun, run_serial, AlgoOptions, Algorithm, AveragingMode, LocalSteps,
    LrSchedule, MixPolicy, PayloadKind, RunSpec, SwarmSgd, WireCodec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn quad(n: usize, dim: usize, sigma: f64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 11)
}

fn graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn spec(n: usize, t: u64, eval_every: u64) -> RunSpec {
    RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed: 9,
        name: "freerun-it".into(),
        eval_every,
        track_gamma: false,
    }
}

#[test]
fn freerun_runs_every_policy_algorithm_with_sharded_nodes() {
    // n = 8 × threads: node-sharding must carry n >> cores. sgp is in the
    // loop — the MixPolicy redesign's acceptance criterion — running over
    // weighted (x, w) slots rather than plain model snapshots.
    let n = 32;
    let threads = 4;
    let t = 600u64;
    for name in ["swarm", "poisson", "adpsgd", "dpsgd", "sgp"] {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        let policy = algo.mix_policy().expect("must be freerun-capable");
        if name == "sgp" {
            assert_eq!(policy.payload(), PayloadKind::PushSumWeighted, "{name}");
        } else {
            assert_eq!(policy.payload(), PayloadKind::Plain, "{name}");
        }
        let backend = quad(n, 32, 0.1);
        let cost = CostModel::deterministic(0.4);
        let m =
            run_freerun(algo.as_ref(), &backend, &spec(n, t, 200), &graph(n), &cost, threads, 8);
        assert_eq!(m.executor, "freerun", "{name}");
        assert_eq!(m.threads, threads);
        assert_eq!(m.interactions, t);
        assert!(m.local_steps > 0, "{name}: no local steps recorded");
        assert!(m.sim_time > 0.0);
        assert!(m.final_eval_loss.is_finite(), "{name}: diverged");
        assert!(!m.curve.is_empty());

        let fr = m.freerun.as_ref().expect("freerun telemetry must be present");
        assert_eq!(fr.threads, threads);
        assert_eq!(fr.shards, 8);
        // one staleness observation per interaction, and the partner
        // snapshots must actually be stale (version lag > 0 somewhere)
        assert_eq!(fr.staleness.count(), t, "{name}");
        assert!(fr.staleness.max_observed() > 0, "{name}: staleness never nonzero");
        assert!(fr.staleness.p99() >= fr.staleness.p50());
        assert!(fr.interactions_per_sec > 0.0);
        assert!(fr.wall_secs > 0.0);
        assert_eq!(fr.workers.len(), threads);
        assert_eq!(
            fr.workers.iter().map(|w| w.interactions).sum::<u64>(),
            t,
            "{name}: per-worker interaction counts must sum to the total"
        );
        assert!(fr.busy_total() > 0.0);
        // wire attribution: the default policies run the f32 codec, and
        // the freerun stats carry the full bit/fallback attribution
        assert_eq!(fr.codec, "f32", "{name}");
        assert_eq!(fr.wire_bits, m.total_bits, "{name}");
        assert_eq!(fr.wire_fallbacks, m.quant_fallbacks, "{name}");
        assert!(fr.wire_bits > 0, "{name}: nothing crossed the wire");
    }
}

#[test]
fn globally_mixing_algorithms_refuse_freerun() {
    // localsgd and allreduce mix through an irreducible global mean — no
    // initiator-driven decomposition, so no MixPolicy. sgp is deliberately
    // NOT in this list anymore: weighted (x, w) slots gave push-sum a
    // free-running policy.
    for name in ["localsgd", "allreduce"] {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        assert!(
            algo.mix_policy().is_none(),
            "{name} mixes through a global mean; it must not return a mix policy"
        );
    }
    for name in ["swarm", "poisson", "adpsgd", "dpsgd", "sgp"] {
        assert!(
            make_algorithm(name, &AlgoOptions::default())
                .unwrap()
                .mix_policy()
                .is_some(),
            "{name} must be freerun-eligible"
        );
    }
}

#[test]
fn freerun_sgp_conserves_debiased_mass_at_lr_zero() {
    // push-sum's defining invariant, surviving the weighted-slot freerun:
    // with lr = 0 every (x, w) pair anywhere (state or slot) stays
    // (c·x0, c) for a scalar c — takes halve, absorbs sum, always the SAME
    // linear ops on both lanes — so the de-biased consensus Σx/Σw (and
    // every individual z = x/w) equals the common init model up to f32
    // rounding, regardless of staleness, interleaving, or dropped
    // cross-writes.
    let n = 16;
    let backend = quad(n, 16, 0.1);
    let (p0, _) = backend.init();
    let init_loss = backend.eval(&p0).loss;
    let algo = make_algorithm("sgp", &AlgoOptions::default()).unwrap();
    let cost = CostModel::deterministic(0.1);
    let mut s = spec(n, 1500, 300);
    s.lr = LrSchedule::Constant(0.0);
    let m = run_freerun(algo.as_ref(), &backend, &s, &graph(n), &cost, 4, 8);
    assert_eq!(m.interactions, 1500);
    let final_loss = m.final_eval_loss;
    assert!(
        (final_loss - init_loss).abs() < 1e-3 * init_loss.abs().max(1.0),
        "weighted-slot consensus drifted at lr=0: {init_loss} -> {final_loss}"
    );
}

#[test]
fn freerun_sgp_convergence_matches_serial_ballpark() {
    // the redesign's payoff scenario: --algorithm sgp --executor freerun
    // runs end-to-end via weighted slots and its Σx/Σw de-biased consensus
    // lands in the same loss ballpark as the serial push-sum reference.
    // Budgets are step-matched: serial runs t/n synchronous rounds (n
    // de-biased steps each), freerun runs t interactions (1 step each).
    let n = 16;
    let t = 4800u64; // 300 serial rounds (sgp needs more rounds than dpsgd)
    let backend = quad(n, 16, 0.1);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let algo = make_algorithm("sgp", &AlgoOptions::default()).unwrap();
    let cost = CostModel::deterministic(0.4);
    let g = graph(n);
    let serial = run_serial(
        algo.as_ref(),
        &backend,
        &spec(n, t / n as u64, 50),
        &g,
        &cost,
    );
    let free = run_freerun(algo.as_ref(), &backend, &spec(n, t, 1000), &g, &cost, 4, 8);
    assert_eq!(free.executor, "freerun");
    assert_eq!(free.interactions, t);
    let gap_serial = (serial.final_eval_loss - f_star) / gap0;
    let gap_free = (free.final_eval_loss - f_star) / gap0;
    assert!(gap_serial < 0.15, "serial sgp reference off the rails: {gap_serial}");
    assert!(
        gap_free < 0.2,
        "freerun sgp normalized gap {gap_free} vs serial {gap_serial} — \
         the weighted-slot de-biasing diverged"
    );
    let fr = free.freerun.as_ref().unwrap();
    assert_eq!(fr.staleness.count(), t);
}

#[test]
fn freerun_dpsgd_convergence_matches_serial_ballpark() {
    // --executor freerun --algorithm dpsgd runs (no refusal) and lands in
    // the same loss ballpark as the serial reference. Budgets are
    // step-matched: the serial reference runs t/n phased rounds (n steps
    // each), freerun runs t pairwise interactions (1 step each).
    let n = 16;
    let t = 2400u64;
    let backend = quad(n, 16, 0.1);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let algo = make_algorithm("dpsgd", &AlgoOptions::default()).unwrap();
    let cost = CostModel::deterministic(0.4);
    let g = graph(n);
    let serial = run_serial(
        algo.as_ref(),
        &backend,
        &spec(n, t / n as u64, 50),
        &g,
        &cost,
    );
    let free = run_freerun(algo.as_ref(), &backend, &spec(n, t, 500), &g, &cost, 4, 8);
    assert_eq!(free.executor, "freerun");
    assert_eq!(free.interactions, t);
    let gap_serial = (serial.final_eval_loss - f_star) / gap0;
    let gap_free = (free.final_eval_loss - f_star) / gap0;
    assert!(gap_serial < 0.1, "serial dpsgd reference off the rails: {gap_serial}");
    assert!(
        gap_free < 0.15,
        "freerun dpsgd normalized gap {gap_free} vs serial {gap_serial} — \
         the initiator-driven degradation diverged"
    );
}

#[test]
fn freerun_convergence_matches_serial_ballpark() {
    // the convergence-sanity guard: same backend, same event budget; the
    // free-running lock-free path must land in the same loss ballpark as
    // the serial reference (no seeded-schedule equality is possible)
    let n = 16;
    let t = 2500u64;
    let backend = quad(n, 16, 0.1);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let cost = CostModel::deterministic(0.4);
    let g = graph(n);
    let s = spec(n, t, 500);
    let serial = run_serial(&algo, &backend, &s, &g, &cost);
    let free = run_freerun(&algo, &backend, &s, &g, &cost, 2, 4);
    let gap_serial = (serial.final_eval_loss - f_star) / gap0;
    let gap_free = (free.final_eval_loss - f_star) / gap0;
    assert!(gap_serial < 0.1, "serial reference off the rails: {gap_serial}");
    assert!(
        gap_free < 0.15,
        "freerun normalized gap {gap_free} vs serial {gap_serial} — lock-free path diverged"
    );
}

#[test]
fn freerun_lattice_wire_saves_bits_and_is_attributed() {
    // the wire-codec axis on the free-running executor: the same merge
    // rule over the lattice codec moves < 50% of the full-precision bits,
    // and the codec's accounting reaches FreerunStats
    let n = 16;
    let t = 500u64;
    let g = graph(n);
    let cost = CostModel::deterministic(0.4);
    let run = |wire: WireCodec| {
        let backend = quad(n, 256, 0.05);
        let algo = make_algorithm("swarm", &AlgoOptions { wire, ..AlgoOptions::default() })
            .unwrap();
        run_freerun(algo.as_ref(), &backend, &spec(n, t, 0), &g, &cost, 2, 0)
    };
    let mq = run(WireCodec::Lattice { bits: 8, eps: 1e-2 });
    let mf = run(WireCodec::F32);
    assert!(mq.final_eval_loss.is_finite());
    assert!(mq.total_bits > 0);
    assert!(
        (mq.total_bits as f64) < 0.5 * mf.total_bits as f64,
        "lattice slots {} bits vs full-precision {} bits (fallbacks {})",
        mq.total_bits,
        mf.total_bits,
        mq.quant_fallbacks
    );
    let frq = mq.freerun.as_ref().unwrap();
    assert_eq!(frq.codec, "lattice");
    assert_eq!(frq.wire_bits, mq.total_bits);
    assert_eq!(frq.wire_fallbacks, mq.quant_fallbacks);
    assert_eq!(mf.freerun.as_ref().unwrap().codec, "f32");
}

#[test]
fn freerun_quantized_mode_saves_wire_bits() {
    // mode=quantized (the swarm/poisson spelling of nonblocking + lattice
    // wire) keeps working through the policy mapping
    let n = 16;
    let t = 500u64;
    let g = graph(n);
    let cost = CostModel::deterministic(0.4);
    let run = |mode: AveragingMode| {
        let backend = quad(n, 256, 0.05);
        let algo = SwarmSgd { local_steps: LocalSteps::Fixed(2), mode };
        run_freerun(&algo, &backend, &spec(n, t, 0), &g, &cost, 2, 0)
    };
    let mq = run(AveragingMode::Quantized { bits: 8, eps: 1e-2 });
    let mf = run(AveragingMode::NonBlocking);
    assert!(mq.final_eval_loss.is_finite());
    assert!(mq.total_bits > 0);
    assert!(
        (mq.total_bits as f64) < 0.5 * mf.total_bits as f64,
        "quantized slots {} bits vs full-precision {} bits (fallbacks {})",
        mq.total_bits,
        mf.total_bits,
        mq.quant_fallbacks
    );
    assert_eq!(mq.freerun.as_ref().unwrap().codec, "lattice");
}

#[test]
fn freerun_single_thread_and_tiny_cluster_edge_cases() {
    // threads > shards > n-degenerate setups must still complete
    let n = 4;
    let backend = quad(n, 8, 0.1);
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(1),
        mode: AveragingMode::NonBlocking,
    };
    let cost = CostModel::deterministic(0.1);
    // more threads than nodes: surplus workers own nothing and exit
    let m = run_freerun(&algo, &backend, &spec(n, 200, 0), &graph(n), &cost, 8, 64);
    assert_eq!(m.interactions, 200);
    assert!(m.final_eval_loss.is_finite());
    // single worker: still free-running (its own clocks), still telemetered
    let m1 = run_freerun(&algo, &backend, &spec(n, 200, 0), &graph(n), &cost, 1, 1);
    assert_eq!(m1.interactions, 200);
    let fr = m1.freerun.as_ref().unwrap();
    assert_eq!(fr.workers.len(), 1);
    assert_eq!(fr.staleness.count(), 200);
}
