//! Free-running executor contract tests.
//!
//! `freerun` is **non-replayable by design** (real thread interleaving),
//! so unlike `tests/parallel_executor.rs` nothing here asserts bit
//! equality — the contract is statistical:
//!
//! 1. **Coverage**: every pairwise-mixing algorithm (swarm, poisson,
//!    adpsgd, and — since the phased-event redesign decomposed its
//!    matching average into per-edge events — dpsgd) runs end-to-end with
//!    `n ≥ 8×` the thread count, and the globally-mixing baselines refuse
//!    (no [`GossipProfile`]).
//! 2. **Telemetry**: the run reports nonzero staleness, real
//!    interactions/sec, and per-worker accounting that sums to the total.
//! 3. **Convergence sanity**: a quadratic-oracle freerun run lands in the
//!    same loss ballpark as `run_serial` (tolerance-based), guarding
//!    against silent divergence in the lock-free slot path.
//!
//! [`GossipProfile`]: swarm_sgd::coordinator::GossipProfile

use swarm_sgd::backend::Backend;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun, run_serial, AlgoOptions, Algorithm, AveragingMode, LocalSteps,
    LrSchedule, RunSpec, SwarmSgd,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn quad(n: usize, dim: usize, sigma: f64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 11)
}

fn graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn spec(n: usize, t: u64, eval_every: u64) -> RunSpec {
    RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed: 9,
        name: "freerun-it".into(),
        eval_every,
        track_gamma: false,
    }
}

#[test]
fn freerun_runs_every_gossip_algorithm_with_sharded_nodes() {
    // n = 8 × threads: node-sharding must carry n >> cores
    let n = 32;
    let threads = 4;
    let t = 600u64;
    for name in ["swarm", "poisson", "adpsgd", "dpsgd"] {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        assert!(algo.gossip_profile().is_some(), "{name} must be freerun-capable");
        let backend = quad(n, 32, 0.1);
        let cost = CostModel::deterministic(0.4);
        let m =
            run_freerun(algo.as_ref(), &backend, &spec(n, t, 200), &graph(n), &cost, threads, 8);
        assert_eq!(m.executor, "freerun", "{name}");
        assert_eq!(m.threads, threads);
        assert_eq!(m.interactions, t);
        assert!(m.local_steps > 0, "{name}: no local steps recorded");
        assert!(m.sim_time > 0.0);
        assert!(m.final_eval_loss.is_finite(), "{name}: diverged");
        assert!(!m.curve.is_empty());

        let fr = m.freerun.as_ref().expect("freerun telemetry must be present");
        assert_eq!(fr.threads, threads);
        assert_eq!(fr.shards, 8);
        // one staleness observation per interaction, and the partner
        // snapshots must actually be stale (version lag > 0 somewhere)
        assert_eq!(fr.staleness.count(), t, "{name}");
        assert!(fr.staleness.max_observed() > 0, "{name}: staleness never nonzero");
        assert!(fr.staleness.p99() >= fr.staleness.p50());
        assert!(fr.interactions_per_sec > 0.0);
        assert!(fr.wall_secs > 0.0);
        assert_eq!(fr.workers.len(), threads);
        assert_eq!(
            fr.workers.iter().map(|w| w.interactions).sum::<u64>(),
            t,
            "{name}: per-worker interaction counts must sum to the total"
        );
        assert!(fr.busy_total() > 0.0);
    }
}

#[test]
fn globally_mixing_algorithms_refuse_freerun() {
    // sgp (push-sum), localsgd and allreduce (global mean) mix over the
    // whole cluster at once — no pairwise decomposition, so no profile.
    // dpsgd is deliberately NOT in this list anymore: its matching average
    // decomposed into per-edge events, making it the fourth
    // freerun-eligible algorithm.
    for name in ["sgp", "localsgd", "allreduce"] {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        assert!(
            algo.gossip_profile().is_none(),
            "{name} mixes globally per round; it must not advertise a gossip profile"
        );
    }
    assert!(
        make_algorithm("dpsgd", &AlgoOptions::default())
            .unwrap()
            .gossip_profile()
            .is_some(),
        "dpsgd's per-edge mixing makes it freerun-eligible"
    );
}

#[test]
fn freerun_dpsgd_convergence_matches_serial_ballpark() {
    // the redesign's payoff scenario: --executor freerun --algorithm dpsgd
    // runs (no refusal) and lands in the same loss ballpark as the serial
    // reference. Budgets are step-matched: the serial reference runs
    // t/n phased rounds (n steps each), freerun runs t pairwise
    // interactions (1 step each).
    let n = 16;
    let t = 2400u64;
    let backend = quad(n, 16, 0.1);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let algo = make_algorithm("dpsgd", &AlgoOptions::default()).unwrap();
    let cost = CostModel::deterministic(0.4);
    let g = graph(n);
    let serial = run_serial(
        algo.as_ref(),
        &backend,
        &spec(n, t / n as u64, 50),
        &g,
        &cost,
    );
    let free = run_freerun(algo.as_ref(), &backend, &spec(n, t, 500), &g, &cost, 4, 8);
    assert_eq!(free.executor, "freerun");
    assert_eq!(free.interactions, t);
    let gap_serial = (serial.final_eval_loss - f_star) / gap0;
    let gap_free = (free.final_eval_loss - f_star) / gap0;
    assert!(gap_serial < 0.1, "serial dpsgd reference off the rails: {gap_serial}");
    assert!(
        gap_free < 0.15,
        "freerun dpsgd normalized gap {gap_free} vs serial {gap_serial} — \
         the initiator-driven degradation diverged"
    );
}

#[test]
fn freerun_convergence_matches_serial_ballpark() {
    // the convergence-sanity guard: same backend, same event budget; the
    // free-running lock-free path must land in the same loss ballpark as
    // the serial reference (no seeded-schedule equality is possible)
    let n = 16;
    let t = 2500u64;
    let backend = quad(n, 16, 0.1);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let cost = CostModel::deterministic(0.4);
    let g = graph(n);
    let s = spec(n, t, 500);
    let serial = run_serial(&algo, &backend, &s, &g, &cost);
    let free = run_freerun(&algo, &backend, &s, &g, &cost, 2, 4);
    let gap_serial = (serial.final_eval_loss - f_star) / gap0;
    let gap_free = (free.final_eval_loss - f_star) / gap0;
    assert!(gap_serial < 0.1, "serial reference off the rails: {gap_serial}");
    assert!(
        gap_free < 0.15,
        "freerun normalized gap {gap_free} vs serial {gap_serial} — lock-free path diverged"
    );
}

#[test]
fn freerun_quantized_mode_saves_wire_bits() {
    let n = 16;
    let t = 500u64;
    let g = graph(n);
    let cost = CostModel::deterministic(0.4);
    let run = |mode: AveragingMode| {
        let backend = quad(n, 256, 0.05);
        let algo = SwarmSgd { local_steps: LocalSteps::Fixed(2), mode };
        run_freerun(&algo, &backend, &spec(n, t, 0), &g, &cost, 2, 0)
    };
    let mq = run(AveragingMode::Quantized { bits: 8, eps: 1e-2 });
    let mf = run(AveragingMode::NonBlocking);
    assert!(mq.final_eval_loss.is_finite());
    assert!(mq.total_bits > 0);
    assert!(
        (mq.total_bits as f64) < 0.5 * mf.total_bits as f64,
        "quantized slots {} bits vs full-precision {} bits (fallbacks {})",
        mq.total_bits,
        mf.total_bits,
        mq.quant_fallbacks
    );
}

#[test]
fn freerun_single_thread_and_tiny_cluster_edge_cases() {
    // threads > shards > n-degenerate setups must still complete
    let n = 4;
    let backend = quad(n, 8, 0.1);
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(1),
        mode: AveragingMode::NonBlocking,
    };
    let cost = CostModel::deterministic(0.1);
    // more threads than nodes: surplus workers own nothing and exit
    let m = run_freerun(&algo, &backend, &spec(n, 200, 0), &graph(n), &cost, 8, 64);
    assert_eq!(m.interactions, 200);
    assert!(m.final_eval_loss.is_finite());
    // single worker: still free-running (its own clocks), still telemetered
    let m1 = run_freerun(&algo, &backend, &spec(n, 200, 0), &graph(n), &cost, 1, 1);
    assert_eq!(m1.interactions, 200);
    let fr = m1.freerun.as_ref().unwrap();
    assert_eq!(fr.workers.len(), 1);
    assert_eq!(fr.staleness.count(), 200);
}
