//! Scenario-engine integration tests: the heterogeneity axes (topology,
//! speed classes, time-varying graphs) threaded through the executors.
//!
//! 1. **Replay determinism under every topology family** (the tentpole
//!    acceptance criterion): serial ≡ parallel bit-for-bit on complete,
//!    ring, torus, hypercube, random-regular, and power-law graphs — the
//!    graph constraint changes *which* pairs gossip, never the
//!    interleaving-independence contract.
//! 2. **Legacy equivalence**: a default scenario (uniform speeds, one
//!    static undirected graph) resolved from config consumes RNG
//!    byte-for-byte like the pre-scenario direct-graph path, so the
//!    committed goldens stay valid.
//! 3. **Edge membership**: every pre-drawn gossip pair — swarm, poisson,
//!    adpsgd draws and dpsgd matchings alike — is an edge of the graph in
//!    force at that event's tick, including across topology-schedule stage
//!    boundaries.
//! 4. **Heterogeneous replay**: bimodal/pareto speed classes and epoch-
//!    indexed graph schedules keep the serial ≡ parallel bit contract.
//! 5. **Feasibility errors**: infeasible topology/n combos, bad speed
//!    specs, and malformed schedules fail `Scenario::from_config` with
//!    actionable messages (never panics).
//! 6. **Freerun convergence at n=64** on ring and torus: the lock-free
//!    executor under graph-constrained partner sampling still lands in the
//!    serial reference's loss ballpark.

use swarm_sgd::backend::Backend;
use swarm_sgd::config::RunConfig;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun_scenario, run_parallel_scenario, run_serial, run_serial_scenario,
    AlgoOptions, EventKind, LrSchedule, RunMetrics, RunSpec,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::obs::ObsOptions;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::scenario::Scenario;
use swarm_sgd::topology::{Graph, Topology};

/// All static families at a size every one of them accepts (16 = 4² = 2⁴).
const FAMILIES: [&str; 6] = ["complete", "ring", "torus", "hypercube", "regular4", "powerlaw"];

fn cfg(pairs: &[(&str, &str)]) -> RunConfig {
    let mut c = RunConfig::default();
    for (k, v) in pairs {
        c.set(k, v).unwrap_or_else(|e| panic!("set {k}={v}: {e}"));
    }
    c
}

fn scenario(pairs: &[(&str, &str)]) -> Scenario {
    Scenario::from_config(&cfg(pairs)).expect("feasible scenario")
}

fn quad(n: usize, dim: usize, sigma: f64, seed: u64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, seed)
}

fn spec(n: usize, t: u64, seed: u64, eval_every: u64) -> RunSpec {
    RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed,
        name: "scenario-it".into(),
        eval_every,
        track_gamma: false,
    }
}

/// Every externally observable metric must agree to the bit (same contract
/// as `tests/parallel_executor.rs`).
fn assert_replay_identical(serial: &RunMetrics, parallel: &RunMetrics) {
    assert_eq!(serial.curve.len(), parallel.curve.len());
    for (a, b) in serial.curve.iter().zip(&parallel.curve) {
        assert_eq!(a.t, b.t);
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits(), "eval_loss at t={}", a.t);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "train_loss at t={}", a.t);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "sim_time at t={}", a.t);
        assert_eq!(a.bits, b.bits, "bits at t={}", a.t);
    }
    assert_eq!(serial.final_eval_loss.to_bits(), parallel.final_eval_loss.to_bits());
    assert_eq!(serial.total_bits, parallel.total_bits);
    assert_eq!(serial.quant_fallbacks, parallel.quant_fallbacks);
    assert_eq!(serial.local_steps, parallel.local_steps);
    assert_eq!(serial.sim_time.to_bits(), parallel.sim_time.to_bits());
    assert_eq!(serial.compute_time_total.to_bits(), parallel.compute_time_total.to_bits());
    assert_eq!(serial.comm_time_total.to_bits(), parallel.comm_time_total.to_bits());
}

#[test]
fn serial_parallel_replay_is_bit_identical_under_every_topology_family() {
    let n = 16;
    let t = 300u64;
    for topo in FAMILIES {
        let scn = scenario(&[("topology", topo), ("n", "16"), ("seed", "7")]);
        for name in ["swarm", "adpsgd"] {
            let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
            let backend = quad(n, 24, 0.1, 3);
            let cost = CostModel::deterministic(0.4);
            let s = spec(n, t, 21, 100);
            let serial = run_serial_scenario(algo.as_ref(), &backend, &s, &scn, &cost);
            for threads in [2, 4] {
                let par =
                    run_parallel_scenario(algo.as_ref(), &backend, &s, &scn, &cost, threads);
                assert_eq!(par.threads, threads, "{topo}/{name}");
                assert_replay_identical(&serial, &par);
            }
        }
    }
}

#[test]
fn default_scenario_reproduces_the_legacy_direct_graph_path() {
    // the bit-compat guarantee: Scenario::from_config with uniform speeds
    // and one static graph is indistinguishable — graph edges AND executor
    // RNG consumption — from handing run_serial the graph directly
    let n = 16;
    let c = cfg(&[("topology", "random4"), ("n", "16"), ("seed", "7")]);
    let scn = Scenario::from_config(&c).unwrap();
    assert!(scn.uniform_speeds());
    assert!(!scn.is_time_varying());

    // the config path builds its graph from Pcg64::seed(cfg.seed), exactly
    // like the legacy CLI did
    let mut grng = Pcg64::seed(7);
    let legacy_graph = Graph::build(Topology::RandomRegular(4), n, &mut grng);
    assert_eq!(scn.graph0().edges(), legacy_graph.edges());

    let algo = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
    let backend = quad(n, 24, 0.1, 3);
    let cost = CostModel::deterministic(0.4);
    let s = spec(n, 250, 21, 50);
    let legacy = run_serial(algo.as_ref(), &backend, &s, &legacy_graph, &cost);
    let scenic = run_serial_scenario(algo.as_ref(), &backend, &s, &scn, &cost);
    assert_replay_identical(&legacy, &scenic);
}

#[test]
fn predrawn_gossip_pairs_are_edges_of_the_graph_in_force() {
    // every 2-node Gossip event — swarm/poisson/adpsgd partner draws and
    // dpsgd matching pairs alike — must be an edge of graph_at(ev.tick)
    let n = 16;
    let t = 200u64;
    let mut static_scns: Vec<(String, Scenario)> = FAMILIES
        .iter()
        .map(|&f| (f.to_string(), scenario(&[("topology", f), ("n", "16"), ("seed", "7")])))
        .collect();
    // a stage boundary mid-run: pairs before tick 100 must be ring edges,
    // pairs at or after it torus edges
    static_scns.push((
        "ring@0,torus@100".into(),
        scenario(&[("topology-schedule", "ring@0,torus@100"), ("n", "16"), ("seed", "7")]),
    ));
    for (label, scn) in &static_scns {
        for name in ["swarm", "poisson", "adpsgd", "dpsgd"] {
            let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
            let mut rng = Pcg64::seed(33);
            let sched = algo.schedule(n, t, scn, &mut rng);
            let mut gossips = 0usize;
            for ev in &sched.events {
                if ev.kind != EventKind::Gossip {
                    continue;
                }
                gossips += 1;
                let (i, j) = (ev.nodes[0], ev.nodes[1]);
                let g = scn.graph_at(ev.tick);
                assert!(
                    g.neighbors(i).contains(&j),
                    "{label}/{name}: pre-drawn pair ({i}, {j}) at tick {} is \
                     not an edge of the graph in force",
                    ev.tick
                );
            }
            assert!(gossips > 0, "{label}/{name}: schedule drew no gossip pairs");
        }
    }
}

#[test]
fn speed_classes_and_topology_schedules_keep_the_replay_contract() {
    // structural stragglers (rate-weighted initiators) and mid-run graph
    // swaps are still pre-drawn once — serial ≡ parallel stays bit-exact
    let n = 16;
    let t = 300u64;
    let cases: [&[(&str, &str)]; 3] = [
        &[("topology", "torus"), ("n", "16"), ("seed", "7"), ("speeds", "bimodal:0.25:4")],
        &[("topology", "ring"), ("n", "16"), ("seed", "7"), ("speeds", "pareto:2.5")],
        &[
            ("topology-schedule", "ring@0,torus@150"),
            ("n", "16"),
            ("seed", "7"),
            ("speeds", "bimodal:0.5:8"),
        ],
    ];
    for pairs in cases {
        let scn = Scenario::from_config(&cfg(pairs)).unwrap();
        assert!(!scn.uniform_speeds());
        for name in ["swarm", "poisson"] {
            let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
            let backend = quad(n, 24, 0.1, 3);
            let cost = CostModel::deterministic(0.4);
            let s = spec(n, t, 21, 100);
            let serial = run_serial_scenario(algo.as_ref(), &backend, &s, &scn, &cost);
            let par = run_parallel_scenario(algo.as_ref(), &backend, &s, &scn, &cost, 4);
            assert_replay_identical(&serial, &par);
        }
    }
}

#[test]
fn directed_push_sum_on_a_ring_keeps_the_replay_contract() {
    // --directed resolves the rotor orientation of the ring; sgp mixes over
    // one-way arcs and the replay contract must survive
    let scn = scenario(&[
        ("topology", "ring"),
        ("n", "16"),
        ("seed", "7"),
        ("directed", "true"),
        ("algo", "sgp"),
    ]);
    assert!(scn.graph0().is_directed());
    let algo = make_algorithm("sgp", &AlgoOptions::default()).unwrap();
    let backend = quad(16, 24, 0.1, 3);
    let cost = CostModel::deterministic(0.4);
    let s = spec(16, 40, 21, 10);
    let serial = run_serial_scenario(algo.as_ref(), &backend, &s, &scn, &cost);
    assert!(serial.final_eval_loss.is_finite());
    let par = run_parallel_scenario(algo.as_ref(), &backend, &s, &scn, &cost, 4);
    assert_replay_identical(&serial, &par);
}

#[test]
fn infeasible_scenarios_fail_with_actionable_errors() {
    let expect_err = |pairs: &[(&str, &str)], needle: &str| {
        let err = Scenario::from_config(&cfg(pairs)).expect_err(&format!("{pairs:?} must fail"));
        assert!(
            err.contains(needle),
            "error for {pairs:?} should mention '{needle}', got: {err}"
        );
    };
    expect_err(&[("topology", "hypercube"), ("n", "12")], "power of two");
    expect_err(&[("topology", "torus"), ("n", "15")], "square");
    expect_err(&[("topology", "ring"), ("n", "2")], "n >= 3");
    expect_err(&[("topology", "regular3"), ("n", "9")], "even");
    expect_err(&[("topology", "regular16"), ("n", "16")], "2 <= r < n");
    expect_err(&[("topology", "powerlaw5"), ("n", "6")], "m+2");
    // a mid-run stage must be feasible too, and the error names the stage
    expect_err(
        &[("n", "12"), ("topology-schedule", "ring@0,hypercube@100")],
        "stage at tick 100",
    );
    // directed graphs need push-sum and an orientable family
    expect_err(&[("topology", "ring"), ("n", "16"), ("directed", "true")], "push-sum");
    expect_err(
        &[("topology", "regular4"), ("n", "16"), ("directed", "true"), ("algo", "sgp")],
        "orientable",
    );

    // malformed *specs* (as opposed to infeasible topology/n combos) are
    // caught eagerly at the config layer, before from_config
    let set_err = |key: &str, value: &str, needle: &str| {
        let err = RunConfig::default()
            .set(key, value)
            .expect_err(&format!("{key}={value} must be rejected at set time"));
        assert!(
            err.contains(needle),
            "error for {key}={value} should mention '{needle}', got: {err}"
        );
    };
    set_err("topology", "smallworld", "unknown topology");
    set_err("speeds", "gaussian:2", "unknown speeds");
    set_err("speeds", "bimodal:1.5:4", "[0, 1]");
    set_err("speeds", "pareto:0", "> 0");
    set_err("topology-schedule", "ring@5,torus@10", "tick 0");
    set_err("topology-schedule", "ring@0,torus@0", "strictly increasing");
    set_err("dirichlet", "-1", "positive");
}

#[test]
fn freerun_converges_on_ring_and_torus_at_n_64() {
    // the acceptance run: graph-constrained partner sampling on the
    // lock-free executor, n = 64 >> threads, sparse topologies — the
    // normalized loss gap must land in the serial reference's ballpark
    let n = 64;
    let t = 12_000u64;
    for topo in ["ring", "torus"] {
        let scn = scenario(&[("topology", topo), ("n", "64"), ("seed", "7")]);
        let algo = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
        let backend = quad(n, 16, 0.1, 11);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.eval(&p).loss - f_star
        };
        let cost = CostModel::deterministic(0.4);
        let s = spec(n, t, 9, 3000);
        let serial = run_serial_scenario(algo.as_ref(), &backend, &s, &scn, &cost);
        let free = run_freerun_scenario(
            algo.as_ref(),
            &backend,
            &s,
            &scn,
            &cost,
            4,
            8,
            &ObsOptions::default(),
        );
        assert_eq!(free.executor, "freerun", "{topo}");
        assert_eq!(free.interactions, t);
        let gap_serial = (serial.final_eval_loss - f_star) / gap0;
        let gap_free = (free.final_eval_loss - f_star) / gap0;
        assert!(gap_serial < 0.2, "{topo}: serial reference off the rails: {gap_serial}");
        assert!(
            gap_free < 0.3,
            "{topo}: freerun normalized gap {gap_free} vs serial {gap_serial} — \
             graph-constrained lock-free path diverged"
        );
        let fr = free.freerun.as_ref().expect("freerun telemetry");
        assert_eq!(fr.staleness.count(), t, "{topo}");
        assert!(fr.staleness.p99() >= fr.staleness.p50(), "{topo}");
    }
}
