//! The **pre-redesign monolithic-round baselines**, preserved verbatim as
//! the golden reference for the phased-event redesign.
//!
//! Before the `EventKind` API, each synchronous round of
//! dpsgd/sgp/localsgd/allreduce was one whole-cluster event whose interact
//! body did everything: per-node SGD steps, the mixing step, and the
//! barrier time accounting. These structs keep those interact bodies
//! bit-for-bit (scheduled as a single whole-cluster `Mix` event per round,
//! which is exactly how the old executor ran them: all locks, role order
//! `0..n`). `parallel_executor.rs` asserts that the new phased schedules
//! (n per-node `Compute` events + per-edge/whole-cluster mixing) reproduce
//! these references metric-for-metric, bit-for-bit, on the same seed —
//! the golden acceptance criterion of the redesign.

use swarm_sgd::coordinator::{
    average_into_both, barrier_all, local_phase, mean_params, pair_at, step_once, Algorithm,
    Event, EventOutcome, InteractionSchedule, NodeState, RoundModels, StepCtx,
};
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::Graph;

/// One whole-cluster event per round — the pre-redesign schedule shape
/// shared by all four monolithic references.
fn monolithic_schedule(n: usize, events: u64, rng: &mut Pcg64) -> InteractionSchedule {
    let mut s = InteractionSchedule::new(n);
    for _ in 0..events {
        let seed = rng.next_u64();
        s.push_mix((0..n).collect(), seed);
        s.seal_round();
    }
    s
}

/// Pre-redesign D-PSGD: step all nodes, average along a random matching
/// drawn from the event seed, barrier on one exchange.
pub struct MonoDPsgd;

impl Algorithm for MonoDPsgd {
    fn name(&self) -> &'static str {
        "dpsgd-monolithic"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        monolithic_schedule(n, events, rng)
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
        for (k, st) in parts.iter_mut().enumerate() {
            step_once(ctx, ev.nodes[k], st);
        }
        let mut er = Pcg64::seed(ev.seed);
        let matching = ctx.graph.random_matching(&mut er);
        let mut bits = 0u64;
        for &(u, v) in &matching {
            let (a, b) = pair_at(parts, u, v);
            average_into_both(&mut a.params, &mut b.params);
            a.comm.copy_from_slice(&a.params);
            b.comm.copy_from_slice(&b.params);
            a.interactions += 1;
            b.interactions += 1;
            bits += 2 * 8 * bytes;
        }
        barrier_all(parts, ctx.cost.exchange_time(bytes));
        EventOutcome { bits, fallbacks: 0 }
    }

    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}

/// Pre-redesign SGP: de-biased steps with the round-max compute charge,
/// push-sum halve-and-push, absorb, barrier on the p2p cost.
pub struct MonoSgp;

impl Algorithm for MonoSgp {
    fn name(&self) -> &'static str {
        "sgp-monolithic"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        monolithic_schedule(n, events, rng)
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let n = parts.len();
        debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        let mut er = Pcg64::seed(ev.seed);
        let mut max_comp: f64 = 0.0;
        for (k, st) in parts.iter_mut().enumerate() {
            let agent = ev.nodes[k];
            let w = st.weight as f32;
            for (z, &x) in st.snap.iter_mut().zip(&st.params) {
                *z = x / w;
            }
            st.last_loss =
                ctx.backend.step(agent, &mut st.snap, &mut st.mom, ctx.lr, &mut st.rng);
            st.steps += 1;
            for (x, &z) in st.params.iter_mut().zip(&st.snap) {
                *x = z * w;
            }
            let dt = ctx.cost.compute_time(&mut st.rng);
            max_comp = max_comp.max(dt);
        }
        for st in parts.iter_mut() {
            st.time += max_comp;
            st.compute += max_comp;
        }
        for st in parts.iter_mut() {
            st.inbox.iter_mut().for_each(|v| *v = 0.0);
        }
        let mut inbox_w = vec![0.0f64; n];
        let mut bits = 0u64;
        for k in 0..n {
            let dst = ctx.graph.sample_neighbor(ev.nodes[k], &mut er);
            inbox_w[dst] += 0.5 * parts[k].weight;
            let (src, dstst) = pair_at(parts, k, dst);
            for (s, &v) in dstst.inbox.iter_mut().zip(&src.params) {
                *s += 0.5 * v;
            }
            bits += 8 * bytes + 64;
        }
        for (k, st) in parts.iter_mut().enumerate() {
            for (x, &add) in st.params.iter_mut().zip(&st.inbox) {
                *x = 0.5 * *x + add;
            }
            st.weight = 0.5 * st.weight + inbox_w[k];
            st.comm.copy_from_slice(&st.params);
            st.interactions += 1;
        }
        barrier_all(parts, ctx.cost.p2p_time(bytes));
        EventOutcome { bits, fallbacks: 0 }
    }

    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }

    fn round_metrics(&self, states: &[&NodeState], pick: usize) -> RoundModels {
        let wsum: f64 = states.iter().map(|s| s.weight).sum();
        let dim = states.first().map_or(0, |s| s.params.len());
        let mut acc = vec![0.0f64; dim];
        for s in states {
            for (a, &v) in acc.iter_mut().zip(&s.params) {
                *a += v as f64;
            }
        }
        let consensus = acc.into_iter().map(|v| (v / wsum) as f32).collect();
        let w = states[pick].weight as f32;
        let individual = states[pick].params.iter().map(|&v| v / w).collect();
        RoundModels { consensus, individual }
    }
}

/// Pre-redesign local SGD: h local steps per node, global mean, allreduce
/// barrier. (The old whole-cluster event carried `h` per node in `ev.h`;
/// the constant lives on the struct here, which is the same value.)
pub struct MonoLocalSgd {
    pub h: u64,
}

impl Algorithm for MonoLocalSgd {
    fn name(&self) -> &'static str {
        "localsgd-monolithic"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(self.h >= 1);
        monolithic_schedule(n, events, rng)
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let n = parts.len();
        debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        for (k, st) in parts.iter_mut().enumerate() {
            local_phase(ctx, ev.nodes[k], st, self.h);
        }
        let mu = mean_params(parts.iter().map(|s| s.params.as_slice()), ctx.dim, n);
        for st in parts.iter_mut() {
            st.params.copy_from_slice(&mu);
            st.comm.copy_from_slice(&mu);
            st.interactions += 1;
        }
        barrier_all(parts, ctx.cost.allreduce_time(n, bytes));
        EventOutcome { bits: 2 * 8 * bytes * n as u64, fallbacks: 0 }
    }

    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}

/// Pre-redesign allreduce SGD: one step per node, global mean, ring
/// allreduce barrier.
pub struct MonoAllReduce;

impl Algorithm for MonoAllReduce {
    fn name(&self) -> &'static str {
        "allreduce-monolithic"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        monolithic_schedule(n, events, rng)
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let n = parts.len();
        debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        for (k, st) in parts.iter_mut().enumerate() {
            step_once(ctx, ev.nodes[k], st);
        }
        let mu = mean_params(parts.iter().map(|s| s.params.as_slice()), ctx.dim, n);
        for st in parts.iter_mut() {
            st.params.copy_from_slice(&mu);
            st.comm.copy_from_slice(&mu);
            st.interactions += 1;
        }
        barrier_all(parts, ctx.cost.allreduce_time(n, bytes));
        let bits = (2 * (n as u64 - 1) / n as u64).max(1) * 8 * bytes * n as u64;
        EventOutcome { bits, fallbacks: 0 }
    }

    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}
