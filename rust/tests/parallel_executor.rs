//! Parallel-executor contract tests.
//!
//! 1. **Replay determinism** (the CI-enforced contract): a run on N worker
//!    threads is bit-identical, metric for metric, to a serial replay of the
//!    same seed — for fixed H across blocking, non-blocking, and quantized
//!    averaging.
//! 2. **Stress**: a larger quantized non-blocking run (n=64, 4 threads)
//!    completes without deadlock or poisoned locks, and its decode-fallback
//!    counter matches the serial replay.
//! 3. **Algorithmic agreement**: the executor converges like the original
//!    discrete-event [`SwarmRunner`] on the same workload (statistically —
//!    the two draw noise from different stream layouts by design).
//!
//! Caveat on (1): replay and parallel share `run_schedule`'s per-interaction
//! code, so bit equality proves *interleaving independence* (the concurrency
//! contract), not the update rule itself — that is what (3) plus the serial
//! runner's own unit tests cover.

use swarm_sgd::backend::SyncBackend;
use swarm_sgd::coordinator::{
    run_parallel, run_replay_serial, AveragingMode, LocalSteps, LrSchedule, RunContext,
    RunMetrics, SwarmConfig, SwarmRunner,
};
use swarm_sgd::grad::QuadraticOracle;
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

fn quad(n: usize, dim: usize, sigma: f64, seed: u64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, seed)
}

fn graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn swarm_cfg(n: usize, t: u64, h: u64, mode: AveragingMode, seed: u64) -> SwarmConfig {
    SwarmConfig {
        n,
        local_steps: LocalSteps::Fixed(h),
        mode,
        lr: LrSchedule::Constant(0.05),
        interactions: t,
        seed,
        name: "par-it".into(),
    }
}

/// Every externally observable metric must agree to the bit.
fn assert_replay_identical(serial: &RunMetrics, parallel: &RunMetrics) {
    assert_eq!(serial.curve.len(), parallel.curve.len());
    for (a, b) in serial.curve.iter().zip(&parallel.curve) {
        assert_eq!(a.t, b.t);
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits(), "eval_loss at t={}", a.t);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "train_loss at t={}", a.t);
        assert_eq!(a.indiv_loss.to_bits(), b.indiv_loss.to_bits(), "indiv_loss at t={}", a.t);
        assert_eq!(a.gamma.to_bits(), b.gamma.to_bits(), "gamma at t={}", a.t);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "sim_time at t={}", a.t);
        assert_eq!(a.bits, b.bits, "bits at t={}", a.t);
    }
    // "identical final loss to 1e-12" — trivially implied by bit equality,
    // asserted explicitly as the acceptance-criterion statement
    assert!((serial.final_eval_loss - parallel.final_eval_loss).abs() <= 1e-12);
    assert_eq!(serial.final_eval_loss.to_bits(), parallel.final_eval_loss.to_bits());
    assert_eq!(serial.total_bits, parallel.total_bits);
    assert_eq!(serial.quant_fallbacks, parallel.quant_fallbacks);
    assert_eq!(serial.local_steps, parallel.local_steps);
    assert_eq!(serial.sim_time.to_bits(), parallel.sim_time.to_bits());
    assert_eq!(
        serial.compute_time_total.to_bits(),
        parallel.compute_time_total.to_bits()
    );
    assert_eq!(serial.comm_time_total.to_bits(), parallel.comm_time_total.to_bits());
}

#[test]
fn fixed_h_replay_is_bit_identical_across_thread_counts() {
    let n = 16;
    for mode in [
        AveragingMode::NonBlocking,
        AveragingMode::Blocking,
        AveragingMode::Quantized { bits: 8, eps: 1e-2 },
    ] {
        let cfg = swarm_cfg(n, 1000, 3, mode, 0xA11CE);
        let g = graph(n);
        let backend = quad(n, 32, 0.2, 7);
        // jittery cost model: time accounting must replay exactly too
        let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
        let serial = run_replay_serial(&cfg, &g, &cost, &backend, 250, true);
        assert_eq!(serial.executor, "serial-replay");
        for threads in [2, 4, 8] {
            let par = run_parallel(&cfg, threads, &g, &cost, &backend, 250, true);
            assert_eq!(par.executor, "parallel");
            assert_eq!(par.threads, threads);
            assert_replay_identical(&serial, &par);
        }
    }
}

#[test]
fn geometric_h_replay_is_bit_identical() {
    // H is pre-drawn in the schedule, so even the geometric regime replays
    let n = 8;
    let cfg = SwarmConfig {
        local_steps: LocalSteps::Geometric(3.0),
        ..swarm_cfg(n, 600, 1, AveragingMode::NonBlocking, 0xBEE)
    };
    let g = graph(n);
    let backend = quad(n, 16, 0.1, 3);
    let cost = CostModel::deterministic(0.4);
    let serial = run_replay_serial(&cfg, &g, &cost, &backend, 150, false);
    let par = run_parallel(&cfg, 4, &g, &cost, &backend, 150, false);
    assert_replay_identical(&serial, &par);
}

#[test]
fn stress_quantized_nonblocking_n64_4threads() {
    // n=64, quantized non-blocking, tight eps so fallbacks actually occur;
    // completing at all proves no deadlock / no poisoned lock (any worker
    // panic would propagate through thread::scope and fail the test).
    let n = 64;
    let cfg = swarm_cfg(n, 4000, 2, AveragingMode::Quantized { bits: 6, eps: 5e-4 }, 0xD15C);
    let g = graph(n);
    let backend = quad(n, 64, 0.3, 13);
    let cost = CostModel::deterministic(0.4);
    let par = run_parallel(&cfg, 4, &g, &cost, &backend, 1000, false);
    assert!(par.final_eval_loss.is_finite());
    assert_eq!(par.interactions, 4000);
    assert_eq!(par.local_steps, 4000 * 2 * 2);
    assert!(par.total_bits > 0);
    // fallback counters match the serial replay exactly (stronger than the
    // "within tolerance" requirement)
    let serial = run_replay_serial(&cfg, &g, &cost, &backend, 1000, false);
    assert_eq!(par.quant_fallbacks, serial.quant_fallbacks);
    assert_replay_identical(&serial, &par);
}

#[test]
fn parallel_executor_converges_like_serial_swarm_runner() {
    // the executors use different RNG layouts, so agreement is statistical:
    // both must reach a small normalized gap on the same quadratic workload
    let n = 16;
    let t = 2000;
    let backend = quad(n, 32, 0.1, 21);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.common_init();
        backend.eval_at(&p).loss - f_star
    };
    let g = graph(n);
    let cost = CostModel::deterministic(0.4);
    let cfg = swarm_cfg(n, t, 2, AveragingMode::NonBlocking, 0xFAB);
    let par = run_parallel(&cfg, 4, &g, &cost, &backend, 0, false);
    let gap_par = ((par.final_eval_loss - f_star) / gap0).max(1e-9);

    let mut serial_backend = quad(n, 32, 0.1, 21);
    let mut rng = Pcg64::seed(0xFAB);
    let mut ctx = RunContext {
        backend: &mut serial_backend,
        graph: &g,
        cost: &cost,
        rng: &mut rng,
        eval_every: 0,
        track_gamma: false,
    };
    let m = SwarmRunner::new(cfg.clone(), &mut ctx).run(&mut ctx);
    let gap_serial = ((m.final_eval_loss - f_star) / gap0).max(1e-9);

    assert!(gap_par < 0.1, "parallel normalized gap {gap_par}");
    assert!(gap_serial < 0.1, "serial normalized gap {gap_serial}");
    let ratio = gap_par / gap_serial;
    assert!(
        (0.2..5.0).contains(&ratio),
        "parallel gap {gap_par} vs serial gap {gap_serial}"
    );
}

#[test]
fn quantized_parallel_saves_bits_vs_full_precision() {
    let n = 16;
    let g = graph(n);
    let backend = quad(n, 256, 0.05, 31);
    let cost = CostModel::deterministic(0.4);
    let q = run_parallel(
        &swarm_cfg(n, 800, 2, AveragingMode::Quantized { bits: 8, eps: 1e-2 }, 1),
        4,
        &g,
        &cost,
        &backend,
        0,
        false,
    );
    let f = run_parallel(
        &swarm_cfg(n, 800, 2, AveragingMode::NonBlocking, 1),
        4,
        &g,
        &cost,
        &backend,
        0,
        false,
    );
    assert!(
        (q.total_bits as f64) < 0.5 * f.total_bits as f64,
        "quantized {} vs full {} (fallbacks {})",
        q.total_bits,
        f.total_bits,
        q.quant_fallbacks
    );
}
