//! Executor contract tests for the Algorithm × Backend × Executor matrix.
//!
//! 1. **Replay determinism** (the CI-enforced contract): a run on N worker
//!    threads is bit-identical, metric for metric, to the serial run of the
//!    same seed — for SwarmSGD across blocking, non-blocking, and quantized
//!    averaging; for AD-PSGD (the asynchronous baseline); for SwarmSGD
//!    on the softmax oracle (caller-RNG batch draws); and for all four
//!    phased round-based baselines at every thread count in {1, 2, 4, 8}.
//! 2. **Coverage**: all seven `--algorithm` selections run on BOTH
//!    executors and agree bit-for-bit — the acceptance criterion of the
//!    API redesign.
//! 3. **Golden**: the phased schedules (per-node `Compute` events + `Mix`
//!    barrier per round) reproduce the *pre-redesign monolithic rounds*
//!    bit-for-bit — the monolithic interact bodies are preserved verbatim
//!    in `tests/monolithic/mod.rs` as the golden reference.
//! 4. **Stress**: a larger quantized non-blocking run (n=64, 4 threads)
//!    completes without deadlock or poisoned locks, and its decode-fallback
//!    counter matches the serial run.
//!
//! Caveat on (1): serial and parallel share the per-event code, so bit
//! equality proves *interleaving independence* (the concurrency contract),
//! not the update rule itself — that is what the per-algorithm unit tests
//! and the monolithic golden references cover.

use swarm_sgd::backend::Backend;
use swarm_sgd::coordinator::{
    make_algorithm, run_parallel, run_serial, AlgoOptions, AveragingMode, LocalSteps,
    LrSchedule, RunMetrics, RunSpec, SwarmSgd, ALGORITHM_NAMES,
};
use swarm_sgd::grad::{QuadraticOracle, SoftmaxOracle};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

mod monolithic;

fn quad(n: usize, dim: usize, sigma: f64, seed: u64) -> QuadraticOracle {
    QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, seed)
}

fn graph(n: usize) -> Graph {
    let mut rng = Pcg64::seed(5);
    Graph::build(Topology::Complete, n, &mut rng)
}

fn spec(n: usize, t: u64, seed: u64, eval_every: u64, track_gamma: bool) -> RunSpec {
    RunSpec {
        n,
        events: t,
        lr: LrSchedule::Constant(0.05),
        seed,
        name: "par-it".into(),
        eval_every,
        track_gamma,
    }
}

fn swarm(h: u64, mode: AveragingMode) -> SwarmSgd {
    SwarmSgd { local_steps: LocalSteps::Fixed(h), mode }
}

/// Every externally observable metric must agree to the bit.
fn assert_replay_identical(serial: &RunMetrics, parallel: &RunMetrics) {
    assert_eq!(serial.curve.len(), parallel.curve.len());
    for (a, b) in serial.curve.iter().zip(&parallel.curve) {
        assert_eq!(a.t, b.t);
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits(), "eval_loss at t={}", a.t);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "train_loss at t={}", a.t);
        assert_eq!(a.indiv_loss.to_bits(), b.indiv_loss.to_bits(), "indiv_loss at t={}", a.t);
        assert_eq!(a.eval_acc.to_bits(), b.eval_acc.to_bits(), "eval_acc at t={}", a.t);
        assert_eq!(a.gamma.to_bits(), b.gamma.to_bits(), "gamma at t={}", a.t);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "sim_time at t={}", a.t);
        assert_eq!(a.epochs.to_bits(), b.epochs.to_bits(), "epochs at t={}", a.t);
        assert_eq!(a.bits, b.bits, "bits at t={}", a.t);
    }
    // "identical final loss to 1e-12" — trivially implied by bit equality,
    // asserted explicitly as the acceptance-criterion statement
    assert!((serial.final_eval_loss - parallel.final_eval_loss).abs() <= 1e-12);
    assert_eq!(serial.final_eval_loss.to_bits(), parallel.final_eval_loss.to_bits());
    assert_eq!(serial.total_bits, parallel.total_bits);
    assert_eq!(serial.quant_fallbacks, parallel.quant_fallbacks);
    assert_eq!(serial.local_steps, parallel.local_steps);
    assert_eq!(serial.sim_time.to_bits(), parallel.sim_time.to_bits());
    assert_eq!(
        serial.compute_time_total.to_bits(),
        parallel.compute_time_total.to_bits()
    );
    assert_eq!(serial.comm_time_total.to_bits(), parallel.comm_time_total.to_bits());
}

#[test]
fn fixed_h_replay_is_bit_identical_across_thread_counts() {
    let n = 16;
    for mode in [
        AveragingMode::NonBlocking,
        AveragingMode::Blocking,
        AveragingMode::Quantized { bits: 8, eps: 1e-2 },
    ] {
        let algo = swarm(3, mode);
        let g = graph(n);
        let backend = quad(n, 32, 0.2, 7);
        // jittery cost model: time accounting must replay exactly too
        let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
        let s = spec(n, 1000, 0xA11CE, 250, true);
        let serial = run_serial(&algo, &backend, &s, &g, &cost);
        assert_eq!(serial.executor, "serial");
        for threads in [2, 4, 8] {
            let par = run_parallel(&algo, &backend, &s, &g, &cost, threads);
            assert_eq!(par.executor, "parallel");
            assert_eq!(par.threads, threads);
            assert_replay_identical(&serial, &par);
        }
    }
}

#[test]
fn geometric_h_replay_is_bit_identical() {
    // H is pre-drawn in the schedule, so even the geometric regime replays
    let n = 8;
    let algo = SwarmSgd {
        local_steps: LocalSteps::Geometric(3.0),
        mode: AveragingMode::NonBlocking,
    };
    let g = graph(n);
    let backend = quad(n, 16, 0.1, 3);
    let cost = CostModel::deterministic(0.4);
    let s = spec(n, 600, 0xBEE, 150, false);
    let serial = run_serial(&algo, &backend, &s, &g, &cost);
    let par = run_parallel(&algo, &backend, &s, &g, &cost, 4);
    assert_replay_identical(&serial, &par);
}

#[test]
fn adpsgd_parallel_is_bit_identical_to_serial() {
    // the asynchronous baseline under the new Algorithm API: pairwise
    // events, so it genuinely parallelizes — and must still replay exactly
    let n = 16;
    let algo = make_algorithm("adpsgd", &AlgoOptions::default()).unwrap();
    let g = graph(n);
    let backend = quad(n, 32, 0.2, 17);
    let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
    let s = spec(n, 1200, 0xADP5, 300, true);
    let serial = run_serial(algo.as_ref(), &backend, &s, &g, &cost);
    for threads in [2, 4, 8] {
        let par = run_parallel(algo.as_ref(), &backend, &s, &g, &cost, threads);
        assert_replay_identical(&serial, &par);
    }
}

#[test]
fn softmax_oracle_swarm_replay_is_bit_identical() {
    // satellite: the softmax oracle's batch draws come from the caller's
    // per-node stream, so SwarmSGD on it replays bit-for-bit too — and its
    // accuracy/epochs curves (non-NaN here) must agree as well
    let n = 8;
    let algo = swarm(2, AveragingMode::NonBlocking);
    let g = graph(n);
    let backend = SoftmaxOracle::synthetic(2048, 16, 4, n, 32, 4.0, 23);
    let cost = CostModel::deterministic(0.4);
    let s = spec(n, 300, 0x50F7, 75, false);
    let serial = run_serial(&algo, &backend, &s, &g, &cost);
    assert!(serial.final_eval_acc.is_finite());
    assert!(serial.epochs > 0.0);
    for threads in [2, 4] {
        let par = run_parallel(&algo, &backend, &s, &g, &cost, threads);
        assert_replay_identical(&serial, &par);
    }
}

#[test]
fn round_baselines_parallel_bit_identical_at_threads_1_2_4_8() {
    // the phased-event acceptance criterion: every round-based baseline
    // (n per-node compute events + mix barrier per round) is bit-identical
    // between run_serial and run_parallel at every thread count — under a
    // jittery cost model, so per-node RNG stream alignment is exercised too
    let n = 8;
    let g = graph(n);
    let backend = quad(n, 16, 0.2, 19);
    let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
    for name in ["dpsgd", "sgp", "localsgd", "allreduce"] {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        let s = spec(n, 80, 0x9A5E, 20, true);
        let serial = run_serial(algo.as_ref(), &backend, &s, &g, &cost);
        // phased rounds still count one interaction per round
        assert_eq!(serial.interactions, 80, "{name}");
        assert!(serial.final_eval_loss.is_finite(), "{name}");
        for threads in [1usize, 2, 4, 8] {
            let par = run_parallel(algo.as_ref(), &backend, &s, &g, &cost, threads);
            assert_eq!(par.threads, threads, "{name}");
            assert_replay_identical(&serial, &par);
        }
    }
}

#[test]
fn phased_rounds_match_pre_redesign_monolithic_golden() {
    // the golden test: the phased schedules must reproduce the
    // pre-redesign monolithic whole-cluster rounds bit-for-bit on a fixed
    // seed. The monolithic interact bodies are preserved verbatim in
    // tests/monolithic/mod.rs; a StepDecay lr schedule pins
    // the tick-based lr semantics (lr depends on the *round*, not on the
    // expanded event index), and the jittery cost model pins per-node
    // stream alignment. Checked on the serial executor AND on 4 worker
    // threads (phased parallel ≡ monolithic serial, transitively).
    let n = 8;
    let g = graph(n);
    let backend = quad(n, 16, 0.2, 43);
    let cost = CostModel { jitter: 0.05, straggler_prob: 0.01, ..CostModel::default() };
    let opts = AlgoOptions { h_localsgd: 5, ..AlgoOptions::default() };
    let golden: Vec<(&str, Box<dyn swarm_sgd::coordinator::Algorithm>)> = vec![
        ("dpsgd", Box::new(monolithic::MonoDPsgd)),
        ("sgp", Box::new(monolithic::MonoSgp)),
        ("localsgd", Box::new(monolithic::MonoLocalSgd { h: 5 })),
        ("allreduce", Box::new(monolithic::MonoAllReduce)),
    ];
    for (name, mono) in golden {
        let phased = make_algorithm(name, &opts).unwrap();
        let mut s = spec(n, 60, 0x601D, 15, true);
        s.lr = LrSchedule::StepDecay { base: 0.05, total: 60 };
        let reference = run_serial(mono.as_ref(), &backend, &s, &g, &cost);
        let serial = run_serial(phased.as_ref(), &backend, &s, &g, &cost);
        assert_replay_identical(&reference, &serial);
        let par = run_parallel(phased.as_ref(), &backend, &s, &g, &cost, 4);
        assert_replay_identical(&reference, &par);
    }
}

#[test]
fn all_algorithms_run_on_both_executors_bit_identically() {
    // the acceptance criterion of the API redesign: every --algorithm value
    // runs on --executor serial AND --executor parallel, agreeing exactly
    let n = 8;
    let g = graph(n);
    let backend = quad(n, 16, 0.1, 29);
    let cost = CostModel::deterministic(0.2);
    for name in ALGORITHM_NAMES {
        let algo = make_algorithm(name, &AlgoOptions::default()).unwrap();
        let s = spec(n, 120, 0xC0DE, 40, true);
        let serial = run_serial(algo.as_ref(), &backend, &s, &g, &cost);
        assert_eq!(serial.interactions, 120, "{name}");
        assert!(serial.final_eval_loss.is_finite(), "{name}");
        let par = run_parallel(algo.as_ref(), &backend, &s, &g, &cost, 4);
        assert_replay_identical(&serial, &par);
    }
}

#[test]
fn stress_quantized_nonblocking_n64_4threads() {
    // n=64, quantized non-blocking, tight eps so fallbacks actually occur;
    // completing at all proves no deadlock / no poisoned lock (any worker
    // panic would propagate through thread::scope and fail the test).
    let n = 64;
    let algo = swarm(2, AveragingMode::Quantized { bits: 6, eps: 5e-4 });
    let g = graph(n);
    let backend = quad(n, 64, 0.3, 13);
    let cost = CostModel::deterministic(0.4);
    let s = spec(n, 4000, 0xD15C, 1000, false);
    let par = run_parallel(&algo, &backend, &s, &g, &cost, 4);
    assert!(par.final_eval_loss.is_finite());
    assert_eq!(par.interactions, 4000);
    assert_eq!(par.local_steps, 4000 * 2 * 2);
    assert!(par.total_bits > 0);
    // fallback counters match the serial run exactly (stronger than the
    // "within tolerance" requirement)
    let serial = run_serial(&algo, &backend, &s, &g, &cost);
    assert_eq!(par.quant_fallbacks, serial.quant_fallbacks);
    assert_replay_identical(&serial, &par);
}

#[test]
fn parallel_executor_converges_on_quadratic() {
    let n = 16;
    let t = 2000;
    let backend = quad(n, 32, 0.1, 21);
    let f_star = backend.f_star();
    let gap0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss - f_star
    };
    let g = graph(n);
    let cost = CostModel::deterministic(0.4);
    let algo = swarm(2, AveragingMode::NonBlocking);
    let par = run_parallel(&algo, &backend, &spec(n, t, 0xFAB, 0, false), &g, &cost, 4);
    let gap = ((par.final_eval_loss - f_star) / gap0).max(1e-9);
    assert!(gap < 0.1, "parallel normalized gap {gap}");
}

#[test]
fn quantized_parallel_saves_bits_vs_full_precision() {
    let n = 16;
    let g = graph(n);
    let backend = quad(n, 256, 0.05, 31);
    let cost = CostModel::deterministic(0.4);
    let q = run_parallel(
        &swarm(2, AveragingMode::Quantized { bits: 8, eps: 1e-2 }),
        &backend,
        &spec(n, 800, 1, 0, false),
        &g,
        &cost,
        4,
    );
    let f = run_parallel(
        &swarm(2, AveragingMode::NonBlocking),
        &backend,
        &spec(n, 800, 1, 0, false),
        &g,
        &cost,
        4,
    );
    assert!(
        (q.total_bits as f64) < 0.5 * f.total_bits as f64,
        "quantized {} vs full {} (fallbacks {})",
        q.total_bits,
        f.total_bits,
        q.quant_fallbacks
    );
}
