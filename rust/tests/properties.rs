//! Property-based tests (hand-rolled: proptest is unavailable offline).
//! Each property runs over many seeded random cases; failures print the
//! offending seed so cases are reproducible.

use swarm_sgd::analysis::gamma_potential;
use swarm_sgd::coordinator::average_into_both;
use swarm_sgd::data::{dirichlet_shards, iid_shards, label_shards};
use swarm_sgd::quant::{
    decode, encode, pack_bits, qsgd_decode, qsgd_encode, quantize_unbiased, unpack_bits,
    QuantError,
};
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{spectral_gap, Graph, Topology};

/// Run `f` over `cases` seeded RNGs; panic with the failing seed.
fn prop(cases: u64, f: impl Fn(&mut Pcg64) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Pcg64::seed(0xBEEF_0000 + seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

// ---------------------------------------------------------------------------
// quantizer properties (paper Appendix G requirements)
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_roundtrip_exact_under_distance_criterion() {
    prop(50, |rng| {
        let d = 1 + rng.below_usize(3000);
        let bits = 4 + rng.below(9) as u32; // 4..=12
        let eps = 10f32.powf(-(1.0 + rng.f32() * 2.0)); // 1e-1 .. 1e-3
        let margin = ((1u64 << bits) / 2 - 1) as f32 * eps;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 2.0).collect();
        // receiver reference strictly inside the criterion
        let y: Vec<f32> = x
            .iter()
            .map(|v| v + (rng.f32() - 0.5) * margin)
            .collect();
        let seed = rng.next_u32();
        let msg = encode(&x, eps, bits, seed);
        let got = decode(&msg, &y).map_err(|e| format!("decode failed: {e}"))?;
        let want = quantize_unbiased(&x, eps, seed);
        if got != want {
            return Err(format!("d={d} bits={bits} eps={eps}: decode != sender rounding"));
        }
        Ok(())
    });
}

#[test]
fn prop_lattice_roundtrip_recovers_input_within_eps_and_wire_bits_match_payload() {
    // satellite: end-to-end encode→decode recovers the *original* vector to
    // within the lattice resolution eps (because decode == the sender's
    // unbiased rounding, whose per-coordinate error is < eps), and the
    // advertised wire_bits must equal the packed payload size plus the
    // fixed checksum + header overhead.
    prop(40, |rng| {
        let d = 1 + rng.below_usize(2000);
        let bits = 4 + rng.below(9) as u32; // 4..=12
        let eps = 10f32.powf(-(1.0 + rng.f32() * 2.0));
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        // receiver close to the sender (well inside the criterion)
        let y: Vec<f32> = x.iter().map(|v| v + eps * (rng.f32() - 0.5)).collect();
        let seed = rng.next_u32();
        let msg = encode(&x, eps, bits, seed);
        // wire accounting: d·bits payload + 64-bit checksum + 96-bit header
        let expect_bits = d as u64 * bits as u64 + 64 + 96;
        if msg.wire_bits() != expect_bits {
            return Err(format!(
                "wire_bits {} != payload accounting {expect_bits} (d={d}, bits={bits})",
                msg.wire_bits()
            ));
        }
        // and the physical payload actually holds d residues of `bits` bits
        if msg.payload.len() != (d * bits as usize).div_ceil(8) {
            return Err(format!(
                "payload {} bytes != ceil(d*bits/8) = {}",
                msg.payload.len(),
                (d * bits as usize).div_ceil(8)
            ));
        }
        let got = decode(&msg, &y).map_err(|e| format!("decode failed: {e}"))?;
        let want = quantize_unbiased(&x, eps, seed);
        if got != want {
            return Err("decode disagrees with quantize_unbiased".into());
        }
        for (g, v) in got.iter().zip(&x) {
            let err = (g - v).abs();
            if err > eps * 1.001 {
                return Err(format!("roundtrip error {err} > eps {eps}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_qsgd_roundtrip_same_shape_and_bounded_error() {
    // satellite: the QSGD counterpoint codec must decode to the input's
    // shape with per-coordinate error bounded by ||x||/s (its level grid)
    prop(40, |rng| {
        let d = 1 + rng.below_usize(1000);
        let bits = 2 + rng.below(7) as u32; // 2..=8
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let msg = qsgd_encode(&x, bits, rng);
        if msg.len != d || msg.levels.len() != d {
            return Err(format!("message shape {} != input {d}", msg.levels.len()));
        }
        if msg.wire_bits() != d as u64 * bits as u64 + 32 {
            return Err("qsgd wire_bits accounting".into());
        }
        let back = qsgd_decode(&msg);
        if back.len() != d {
            return Err(format!("decoded shape {} != input {d}", back.len()));
        }
        let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        let s = ((1u32 << (bits - 1)) - 1).max(1) as f32;
        let tol = norm / s + 1e-6;
        for (b, v) in back.iter().zip(&x) {
            if (b - v).abs() > tol {
                return Err(format!("qsgd error {} > ||x||/s {tol}", (b - v).abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_error_below_eps() {
    prop(30, |rng| {
        let d = 1 + rng.below_usize(2000);
        let eps = 10f32.powf(-(1.0 + rng.f32() * 2.5));
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let q = quantize_unbiased(&x, eps, rng.next_u32());
        for (qi, xi) in q.iter().zip(&x) {
            let err = (qi - xi).abs();
            if err > eps * 1.001 {
                return Err(format!("err {err} > eps {eps}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_failure_always_detected_not_silent() {
    // when the distance criterion is violated grossly, decode must either
    // fail loudly (checksum) or — never — return wrong values silently
    prop(40, |rng| {
        let d = 64 + rng.below_usize(512);
        let bits = 3 + rng.below(3) as u32; // 3..=5: tiny modulus
        let eps = 1e-3f32;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let shift = ((1u64 << bits) as f32) * eps * (2.0 + rng.f32() * 10.0);
        let y: Vec<f32> = x.iter().map(|v| v + shift).collect();
        let msg = encode(&x, eps, bits, rng.next_u32());
        match decode(&msg, &y) {
            Err(QuantError::ChecksumMismatch) => Ok(()),
            Err(e) => Err(format!("unexpected error {e}")),
            Ok(vals) => {
                // acceptable only if actually equal to the true rounding
                let want = quantize_unbiased(&x, eps, msg.seed);
                if vals == want {
                    Ok(())
                } else {
                    Err("silent wrong decode".into())
                }
            }
        }
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    prop(100, |rng| {
        let bits = 1 + rng.below(16) as u32;
        let n = rng.below_usize(500);
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32() & mask).collect();
        let got = unpack_bits(&pack_bits(&vals, bits), bits, n);
        if got != vals {
            return Err(format!("bits={bits} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pack_payload_is_exactly_the_bit_budget() {
    // the wire accounting everywhere (QuantizedMsg::wire_bits, figures)
    // assumes a packed payload of exactly ceil(n*bits/8) bytes
    prop(80, |rng| {
        let bits = 1 + rng.below(16) as u32;
        let n = rng.below_usize(700);
        let mask = (1u32 << bits) - 1;
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32() & mask).collect();
        let packed = pack_bits(&vals, bits);
        let want = (n * bits as usize).div_ceil(8);
        if packed.len() != want {
            return Err(format!("bits={bits} n={n}: {} bytes != {want}", packed.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_pack_unpack_boundary_widths_and_lengths() {
    // bit-width edge cases: every width in 1..=16 at lengths straddling
    // byte and word boundaries, with extremal (all-max / all-zero) values
    for bits in 1..=16u32 {
        let max = (1u64 << bits) as u32 - 1;
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65] {
            let maxed = vec![max; n];
            assert_eq!(
                unpack_bits(&pack_bits(&maxed, bits), bits, n),
                maxed,
                "all-max roundtrip bits={bits} n={n}"
            );
            let zeros = vec![0u32; n];
            let packed = pack_bits(&zeros, bits);
            assert!(packed.iter().all(|&b| b == 0), "zero payload bits={bits} n={n}");
            assert_eq!(unpack_bits(&packed, bits, n), zeros);
            // an alternating pattern exercises cross-byte carries
            let alt: Vec<u32> = (0..n).map(|i| if i % 2 == 0 { max } else { 0 }).collect();
            assert_eq!(
                unpack_bits(&pack_bits(&alt, bits), bits, n),
                alt,
                "alternating roundtrip bits={bits} n={n}"
            );
        }
    }
}

#[test]
fn prop_pack_masks_high_bits_and_unpack_zero_fills_short_input() {
    // pack must keep only the low `bits` of each value…
    prop(40, |rng| {
        let bits = 1 + rng.below(15) as u32; // 1..=15 so high bits exist
        let n = 1 + rng.below_usize(100);
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mask = (1u32 << bits) - 1;
        let want: Vec<u32> = vals.iter().map(|v| v & mask).collect();
        if unpack_bits(&pack_bits(&vals, bits), bits, n) != want {
            return Err(format!("high bits leaked (bits={bits} n={n})"));
        }
        Ok(())
    });
    // …and unpack of a truncated stream reads missing bytes as zero
    let vals = vec![0x3FFu32; 8];
    let mut packed = pack_bits(&vals, 10);
    packed.truncate(packed.len() - 2);
    let got = unpack_bits(&packed, 10, 8);
    assert_eq!(&got[..6], &vals[..6]);
    assert!(got[7] < 0x3FF, "tail values must come from zero-fill, not garbage");
}

// ---------------------------------------------------------------------------
// topology properties
// ---------------------------------------------------------------------------

#[test]
fn prop_random_regular_always_regular_connected() {
    prop(30, |rng| {
        let n = 6 + 2 * rng.below_usize(40); // even, 6..=84
        let r = 2 + rng.below_usize((n - 2).min(7)); // 2..=8 < n
        let g = Graph::random_regular(n, r, rng);
        if g.regular_degree() != Some(r) {
            return Err(format!("n={n} r={r}: not regular"));
        }
        if !g.is_connected() {
            return Err(format!("n={n} r={r}: disconnected"));
        }
        if g.edges().len() != n * r / 2 {
            return Err("edge count".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lambda2_positive_and_at_most_n() {
    prop(15, |rng| {
        let n = 6 + 2 * rng.below_usize(15);
        let r = 2 + rng.below_usize(4);
        let g = Graph::random_regular(n, r, rng);
        let l2 = g.lambda2();
        if !(l2 > 1e-9 && l2 <= n as f64 + 1e-9) {
            return Err(format!("λ₂={l2} out of (0, {n}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_power_law_connected_with_exact_edge_count_and_even_degree_sum() {
    // BA growth: an (m+1)-clique seed plus m edges per attached node, so
    // the edge count is exact and the graph is connected by construction
    prop(30, |rng| {
        let m = 1 + rng.below_usize(4); // 1..=4
        let n = m + 2 + rng.below_usize(120);
        let g = Graph::power_law(n, m, rng);
        if !g.is_connected() {
            return Err(format!("n={n} m={m}: disconnected"));
        }
        let want = (m + 1) * m / 2 + (n - m - 1) * m;
        if g.edges().len() != want {
            return Err(format!("n={n} m={m}: {} edges != {want}", g.edges().len()));
        }
        let degree_sum: usize = (0..n).map(|u| g.degree(u)).sum();
        if degree_sum != 2 * g.edges().len() {
            return Err(format!("n={n} m={m}: degree sum {degree_sum} odd-handed"));
        }
        if spectral_gap(&g) <= 0.0 {
            return Err(format!("n={n} m={m}: connected graph with zero gap"));
        }
        Ok(())
    });
}

#[test]
fn prop_sample_neighbor_lands_on_a_graph_edge_for_every_family() {
    prop(15, |rng| {
        let side = 3 + rng.below_usize(3); // torus side 3..=5
        let d = 2 + rng.below_usize(4); // hypercube dim 2..=5
        let graphs = [
            Graph::build(Topology::Complete, 2 + rng.below_usize(20), rng),
            Graph::build(Topology::Ring, 3 + rng.below_usize(30), rng),
            Graph::build(Topology::Torus, side * side, rng),
            Graph::build(Topology::Hypercube, 1 << d, rng),
            Graph::build(Topology::RandomRegular(4), 6 + 2 * rng.below_usize(20), rng),
            Graph::build(Topology::PowerLaw(2), 8 + rng.below_usize(40), rng),
        ];
        for g in &graphs {
            for _ in 0..40 {
                let u = rng.below_usize(g.n());
                let v = g.sample_neighbor(u, rng);
                if !g.neighbors(u).contains(&v) {
                    return Err(format!("n={}: {v} not adjacent to {u}", g.n()));
                }
                if v == u {
                    return Err(format!("n={}: self-loop sampled at {u}", g.n()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spectral_gap_is_zero_exactly_on_disconnected_graphs() {
    prop(20, |rng| {
        // two rings with no bridge: disconnected, gap must be exactly 0.0
        let half = 3 + rng.below_usize(6);
        let mut edges = Vec::new();
        for u in 0..half {
            edges.push((u, (u + 1) % half));
            edges.push((half + u, half + (u + 1) % half));
        }
        let split = Graph::from_edges(2 * half, edges.clone());
        if split.is_connected() {
            return Err("two components reported connected".into());
        }
        if spectral_gap(&split) != 0.0 {
            return Err(format!("disconnected gap {} != 0.0", spectral_gap(&split)));
        }
        // adding one bridge reconnects it and the gap turns positive
        edges.push((0, half));
        let bridged = Graph::from_edges(2 * half, edges);
        if !bridged.is_connected() || spectral_gap(&bridged) <= 0.0 {
            return Err("bridged graph should be connected with positive gap".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matching_is_disjoint_subset_of_edges() {
    prop(30, |rng| {
        let n = 6 + 2 * rng.below_usize(20);
        let g = Graph::random_regular(n, 4, rng);
        let m = g.random_matching(rng);
        let edgeset: std::collections::HashSet<(usize, usize)> = g
            .edges()
            .iter()
            .map(|&(u, v)| (u.min(v), u.max(v)))
            .collect();
        let mut used = std::collections::HashSet::new();
        for (u, v) in m {
            if !edgeset.contains(&(u.min(v), u.max(v))) {
                return Err("matching edge not in graph".into());
            }
            if !used.insert(u) || !used.insert(v) {
                return Err("vertex reused".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// sharding properties
// ---------------------------------------------------------------------------

#[test]
fn prop_all_shard_modes_partition() {
    prop(40, |rng| {
        let n = 20 + rng.below_usize(400);
        let agents = 2 + rng.below_usize(10.min(n / 2));
        let classes = 2 + rng.below(8) as i32;
        let labels: Vec<i32> = (0..n).map(|_| rng.below(classes as u64) as i32).collect();
        for (name, shards) in [
            ("iid", iid_shards(n, agents, rng)),
            ("label", label_shards(&labels, agents)),
            ("dirichlet", dirichlet_shards(&labels, agents, 0.5, rng)),
        ] {
            let mut all: Vec<usize> = shards.concat();
            all.sort_unstable();
            let expect: Vec<usize> = (0..n).collect();
            if all != expect {
                return Err(format!("{name}: not a partition (n={n}, a={agents})"));
            }
            if shards.iter().any(|s| s.is_empty()) {
                return Err(format!("{name}: empty shard"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dirichlet_concentrates_to_a_balanced_split_as_alpha_grows() {
    // Dirichlet(α) proportions concentrate on the uniform simplex point as
    // α → ∞, so every agent holds ≈ count(label)/agents of each class; a
    // small α produces the opposite — heavily skewed per-agent label mixes
    prop(10, |rng| {
        let agents = 4;
        let classes = 5usize;
        let per_class = 400usize;
        let labels: Vec<i32> =
            (0..classes * per_class).map(|i| (i % classes) as i32).collect();
        let expect = per_class as f64 / agents as f64;
        let class_counts = |shard: &[usize]| {
            let mut counts = vec![0usize; classes];
            for &ix in shard {
                counts[labels[ix] as usize] += 1;
            }
            counts
        };
        // α → ∞: every agent/class cell within 25% of the uniform split
        for shard in &dirichlet_shards(&labels, agents, 1e4, rng) {
            for (c, &k) in class_counts(shard).iter().enumerate() {
                let dev = (k as f64 - expect).abs() / expect;
                if dev > 0.25 {
                    return Err(format!("alpha=1e4 class {c}: {k} far from {expect}"));
                }
            }
        }
        // small α: at least one cell deviates grossly (the skew axis works)
        let skewed = dirichlet_shards(&labels, agents, 0.05, rng)
            .iter()
            .flat_map(|s| class_counts(s))
            .any(|k| (k as f64 - expect).abs() / expect > 0.5);
        if !skewed {
            return Err("alpha=0.05 produced a near-uniform split".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------------

fn random_models(rng: &mut Pcg64, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn mean_of(models: &[Vec<f32>]) -> Vec<f64> {
    let d = models[0].len();
    let mut mu = vec![0.0f64; d];
    for m in models {
        for (s, &v) in mu.iter_mut().zip(m) {
            *s += v as f64;
        }
    }
    mu.iter_mut().for_each(|v| *v /= models.len() as f64);
    mu
}

#[test]
fn prop_pairwise_averaging_preserves_mean() {
    // the conservation law behind the paper's μ_t analysis
    prop(40, |rng| {
        let n = 2 + rng.below_usize(10);
        let d = 1 + rng.below_usize(50);
        let mut models = random_models(rng, n, d);
        let mu_before = mean_of(&models);
        for _ in 0..20 {
            let i = rng.below_usize(n);
            let mut j = rng.below_usize(n);
            while j == i {
                j = rng.below_usize(n);
            }
            let (lo, hi) = (i.min(j), i.max(j));
            let (a, b) = models.split_at_mut(hi);
            average_into_both(&mut a[lo], &mut b[0]);
        }
        let mu_after = mean_of(&models);
        for (x, y) in mu_before.iter().zip(&mu_after) {
            if (x - y).abs() > 1e-4 {
                return Err(format!("mean moved: {x} -> {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_averaging_contracts_gamma() {
    prop(40, |rng| {
        let n = 3 + rng.below_usize(8);
        let d = 2 + rng.below_usize(20);
        let mut models = random_models(rng, n, d);
        let before = gamma_potential(&models);
        let i = rng.below_usize(n);
        let mut j = rng.below_usize(n);
        while j == i {
            j = rng.below_usize(n);
        }
        let (lo, hi) = (i.min(j), i.max(j));
        let (a, b) = models.split_at_mut(hi);
        average_into_both(&mut a[lo], &mut b[0]);
        let after = gamma_potential(&models);
        if after > before + 1e-5 {
            return Err(format!("Γ increased: {before} -> {after}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_determinism_and_stream_independence() {
    prop(20, |rng| {
        let seed = rng.next_u64();
        let mut a = Pcg64::seed(seed);
        let mut b = Pcg64::seed(seed);
        for _ in 0..100 {
            if a.next_u64() != b.next_u64() {
                return Err("same seed diverged".into());
            }
        }
        let mut c = Pcg64::seed(seed ^ 1);
        let hits = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        if hits > 2 {
            return Err(format!("adjacent seeds correlated ({hits} hits)"));
        }
        Ok(())
    });
}
