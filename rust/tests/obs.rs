//! Integration tests of the obs subsystem's exported artifacts: the
//! Chrome trace-event JSON written by `--trace-out` and the Prometheus
//! text exposition served by `/metrics` and appended to `--metrics-out`.
//!
//! The golden contract here is *parseability by the real consumers*: the
//! trace JSON must survive an actual JSON parse (a minimal hand-rolled
//! recursive-descent parser below — the crate has no JSON dependency, and
//! neither does its test suite) and round-trip its event count, and every
//! metrics sample line must tokenize as `name value`.

use swarm_sgd::obs::{metrics, MetricsRegistry, SpanKind, TraceDrain, TraceRing};

/// A parsed JSON value — just enough structure to navigate the exports.
#[derive(Debug, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> &Json {
        match self {
            Json::Obj(kvs) => kvs
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .unwrap_or_else(|| panic!("no key {key:?} in {self:?}")),
            _ => panic!("not an object: {self:?}"),
        }
    }

    fn num(&self) -> f64 {
        match self {
            Json::Num(v) => *v,
            _ => panic!("not a number: {self:?}"),
        }
    }

    fn str_(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("not a string: {self:?}"),
        }
    }

    fn arr(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => panic!("not an array: {self:?}"),
        }
    }
}

/// Minimal strict JSON parser (ASCII payloads; the exporters emit nothing
/// else). Rejects trailing garbage, unterminated strings, and bad commas —
/// exactly the malformations string-concatenation serializers produce.
struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(format!("trailing bytes at {}", p.i));
    }
    Ok(v)
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.s.get(self.i).is_some_and(|b| b.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        self.ws();
        if self.s.get(self.i) == Some(&b) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.s.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .s
            .get(self.i)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.s.get(self.i) {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    // the exporters never emit escapes beyond \" and \\
                    self.i += 1;
                    out.push(*self.s.get(self.i).ok_or("truncated escape")? as char);
                    self.i += 1;
                }
                Some(&b) => {
                    out.push(b as char);
                    self.i += 1;
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.s.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.s.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array separator at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.s.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.eat(b':')?;
            out.push((key, self.value()?));
            self.ws();
            match self.s.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object separator at byte {}", self.i)),
            }
        }
    }
}

const SPAN_NAMES: &[&str] = &[
    "compute",
    "merge",
    "publish",
    "slot_retry",
    "gossip_tx",
    "gossip_rx",
    "heartbeat",
];

#[test]
fn chrome_trace_json_parses_and_round_trips_its_event_count() {
    // three workers' rings on one epoch, mixed span kinds, no wraparound
    let epoch = std::time::Instant::now();
    let rings: Vec<TraceRing> = (0..3).map(|_| TraceRing::with_epoch(256, epoch)).collect();
    for (w, ring) in rings.iter().enumerate() {
        for i in 0..10 * (w as u64 + 1) {
            ring.record(SpanKind::Compute, w as u32, i * 1_000, 500, i);
            ring.record(SpanKind::Merge, w as u32, i * 1_000 + 500, 250, 96);
        }
        ring.record(SpanKind::GossipTx, w as u32, 99_000, 10, 64);
        ring.record(SpanKind::Heartbeat, w as u32, 100_000, 0, 1);
    }
    let drain = TraceDrain::from_rings(&rings);
    assert_eq!(drain.total, 2 * (10 + 20 + 30) + 6);
    assert_eq!(drain.dropped, 0);

    let doc = parse_json(&drain.to_chrome_json()).expect("trace JSON parses");
    let events = doc.get("traceEvents").arr();
    assert_eq!(events.len(), drain.events.len(), "event count round-trips");
    assert_eq!(doc.get("otherData").get("total").num() as u64, drain.total);
    assert_eq!(doc.get("otherData").get("dropped").num() as u64, drain.dropped);
    for e in events {
        assert!(SPAN_NAMES.contains(&e.get("name").str_()), "unknown span {e:?}");
        assert_eq!(e.get("ph").str_(), "X", "complete events only");
        assert_eq!(e.get("cat").str_(), "swarm");
        assert!(e.get("ts").num() >= 0.0 && e.get("dur").num() >= 0.0, "{e:?}");
        assert!((0.0..3.0).contains(&e.get("tid").num()), "worker id range: {e:?}");
        e.get("args").get("v").num();
    }
    // the drain is time-sorted, and the export must preserve that
    let ts: Vec<f64> = events.iter().map(|e| e.get("ts").num()).collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "events out of order");
}

#[test]
fn wrapped_and_empty_rings_still_export_valid_json() {
    let wrapped = TraceRing::new(4);
    for i in 0..9 {
        wrapped.record(SpanKind::Publish, 0, i, 1, i);
    }
    let doc = parse_json(&TraceDrain::from_rings([&wrapped]).to_chrome_json()).unwrap();
    assert_eq!(doc.get("traceEvents").arr().len(), 4, "capacity bounds retention");
    assert_eq!(doc.get("otherData").get("total").num(), 9.0);
    assert_eq!(doc.get("otherData").get("dropped").num(), 5.0, "drops are accounted");

    let empty = TraceDrain::from_rings([&TraceRing::new(8)]);
    let doc = parse_json(&empty.to_chrome_json()).unwrap();
    assert!(doc.get("traceEvents").arr().is_empty());
}

#[test]
fn prometheus_exposition_tokenizes_as_name_value_samples() {
    let reg = MetricsRegistry::new();
    reg.counter("swarm_interactions_total", "interactions completed").set(1234);
    reg.gauge("swarm_interactions_per_sec", "throughput").set(8123.25);
    reg.gauge("swarm_staleness_p99", "p99 staleness").set(17.0);
    let text = reg.render();
    let mut samples = 0;
    for line in text.lines() {
        if line.starts_with('#') {
            assert!(
                line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                "unknown comment: {line}"
            );
            continue;
        }
        let mut it = line.split_whitespace();
        let name = it.next().expect("metric name");
        assert!(name.starts_with("swarm_"), "namespace: {line}");
        it.next().expect("sample value").parse::<f64>().expect("numeric value");
        assert!(it.next().is_none(), "extra tokens: {line}");
        samples += 1;
    }
    assert_eq!(samples, 3);
    assert!(text.contains("swarm_interactions_total 1234\n"), "{text}");
    assert!(text.contains("swarm_interactions_per_sec 8123.25\n"), "{text}");
    assert!(text.contains("swarm_staleness_p99 17\n"), "integral gauges: {text}");
}

#[test]
fn metrics_out_snapshots_append_as_separated_scrapes() {
    let path = std::env::temp_dir().join(format!("swarm_obs_snap_{}.prom", std::process::id()));
    let reg = MetricsRegistry::new();
    let c = reg.counter("swarm_interactions_total", "interactions completed");
    {
        let mut f = std::fs::File::create(&path).unwrap();
        c.set(10);
        metrics::append_snapshot(&mut f, &reg).unwrap();
        c.set(25);
        metrics::append_snapshot(&mut f, &reg).unwrap();
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(text.matches("# scrape ts_ms=").count(), 2, "{text}");
    assert!(text.contains("swarm_interactions_total 10\n"), "{text}");
    assert!(text.contains("swarm_interactions_total 25\n"), "{text}");
}
