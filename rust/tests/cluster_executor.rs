//! End-to-end tests of `--executor cluster`: real OS processes, real
//! sockets, real failures.
//!
//! The cluster executor inherits freerun's non-replayability and adds OS
//! scheduling and TCP on top, so — like `tests/freerun_executor.rs` — the
//! contract here is statistical, never bit-exact:
//!
//! 1. **Convergence**: 1 coordinator + 2 workers over loopback on the
//!    quadratic oracle land inside the same normalized-gap band as the
//!    in-process executors, with nonzero *measured* wire traffic under the
//!    lattice codec, zero recoveries, and clean exits all around.
//! 2. **Recovery**: freezing a worker mid-run (SIGSTOP — the socket stays
//!    open, so only the heartbeat timer can notice) makes the coordinator
//!    declare it dead, reassign its shard from the last checkpoint, and
//!    still drive the job to completion with `recoveries ≥ 1`.
//!
//! Both tests drive the real binary via `CARGO_BIN_EXE_swarm` and parse
//! the stdout lines the coordinator prints for exactly this purpose.

#![cfg(unix)] // SIGSTOP/loopback-process orchestration; CI runs Linux

use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use swarm_sgd::backend::{build_backend, quadratic_preset, Backend};
use swarm_sgd::config::RunConfig;

const BIN: &str = env!("CARGO_BIN_EXE_swarm");

/// Kill-on-drop child guard so a failed assertion can't leak processes
/// that keep the test runner (and CI) hanging.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

impl Proc {
    fn wait_success(&mut self, what: &str, deadline: Duration) {
        let end = Instant::now() + deadline;
        loop {
            match self.0.try_wait().expect("try_wait") {
                Some(status) => {
                    assert!(status.success(), "{what} exited with {status}");
                    return;
                }
                None if Instant::now() > end => panic!("{what} still running after {deadline:?}"),
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }
}

/// Pump a child's stdout into a channel from a thread, so every wait can
/// carry a deadline (a blocked read can't hang the test).
fn pump_lines(out: ChildStdout) -> mpsc::Receiver<String> {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(out).lines() {
            let Ok(line) = line else { return };
            if tx.send(line).is_err() {
                return;
            }
        }
    });
    rx
}

/// Relay coordinator lines until one matches, with a hard deadline.
fn await_line(
    rx: &mpsc::Receiver<String>,
    what: &str,
    deadline: Duration,
    pred: impl Fn(&str) -> bool,
) -> String {
    let end = Instant::now() + deadline;
    loop {
        let left = end.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(line) => {
                println!("[coord] {line}");
                if pred(&line) {
                    return line;
                }
            }
            Err(_) => panic!("timed out after {deadline:?} waiting for {what}"),
        }
    }
}

/// One raw HTTP/1.1 GET against the coordinator's introspection endpoint
/// (no client library — the server is hand-rolled, so is the test client).
fn http_get(addr: &str, path: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connect introspection endpoint");
    s.set_read_timeout(Some(Duration::from_secs(5))).expect("read timeout");
    write!(s, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .expect("write request");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    buf
}

/// The value of one sample line (`name value`) in Prometheus text.
fn prom_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let mut it = l.split_whitespace();
        (it.next() == Some(name)).then(|| it.next())??.parse().ok()
    })
}

/// Pull `key=value` off the coordinator's machine-readable final line.
fn parse_kv(line: &str, key: &str) -> f64 {
    let prefix = format!("{key}=");
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&prefix))
        .unwrap_or_else(|| panic!("no {key}= in {line:?}"))
        .parse()
        .unwrap_or_else(|e| panic!("bad {key} in {line:?}: {e}"))
}

fn spawn_coordinator(
    dir: &std::path::Path,
    extra: &[&str],
    set: &str,
) -> (Proc, mpsc::Receiver<String>) {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "train",
        "--executor",
        "cluster",
        "--role",
        "coordinator",
        "--listen",
        "127.0.0.1:0",
        "--workers",
        "2",
        "--checkpoint-dir",
    ])
    .arg(dir)
    .args(extra)
    .args(["--set", set])
    .stdout(Stdio::piped());
    let mut child = cmd.spawn().expect("spawn coordinator");
    let rx = pump_lines(child.stdout.take().expect("piped stdout"));
    (Proc(child), rx)
}

fn spawn_worker(addr: &str, extra: &[&str]) -> Proc {
    let child = Command::new(BIN)
        .args(["train", "--executor", "cluster", "--role", "worker", "--connect", addr])
        .args(extra)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn worker");
    Proc(child)
}

fn listen_addr(rx: &mpsc::Receiver<String>) -> String {
    let line = await_line(rx, "the coordinator's listen line", Duration::from_secs(30), |l| {
        l.starts_with("cluster coordinator listening on ")
    });
    line.strip_prefix("cluster coordinator listening on ")
        .and_then(|rest| rest.split_whitespace().next())
        .expect("address token")
        .to_string()
}

/// The convergence band, normalized the same way as the freerun tests:
/// `(loss − f*) / (loss(init) − f*)` on the coordinator's own oracle.
fn normalized_gap(cfg: &RunConfig, final_loss: f64) -> f64 {
    let backend = build_backend(cfg).expect("backend");
    let f_star = quadratic_preset(cfg).f_star();
    let (p0, _) = backend.init();
    let gap0 = backend.eval(&p0).loss - f_star;
    (final_loss - f_star) / gap0
}

#[test]
fn cluster_loopback_run_converges_with_real_wire_bits() {
    let dir = std::env::temp_dir().join(format!("swarm_cluster_conv_{}", std::process::id()));
    // throttled workers stretch the run past a couple of metrics-sweep
    // cadences, so the introspection GETs below land mid-run
    let set = "algo=swarm,preset=oracle:quadratic,n=16,interactions=2500,eval_every=0";
    let (mut coord, rx) = spawn_coordinator(
        &dir,
        &["--wire", "lattice", "--heartbeat-timeout", "10", "--metrics-addr", "127.0.0.1:0"],
        set,
    );
    let addr = listen_addr(&rx);
    let metrics_addr = await_line(&rx, "the metrics serving line", Duration::from_secs(30), |l| {
        l.starts_with("cluster metrics serving on ")
    })
    .strip_prefix("cluster metrics serving on ")
    .expect("serving address")
    .trim()
    .to_string();
    let mut w0 = spawn_worker(&addr, &["--throttle-us", "1000"]);
    let mut w1 = spawn_worker(&addr, &["--throttle-us", "1000"]);

    // live introspection while the job is in flight: poll until a sweep has
    // published both workers alive with nonzero progress, pre-drain
    let poll_end = Instant::now() + Duration::from_secs(60);
    let (status, metrics) = loop {
        assert!(Instant::now() < poll_end, "introspection never showed 2 live workers mid-run");
        let status = http_get(&metrics_addr, "/status");
        let metrics = http_get(&metrics_addr, "/metrics");
        if status.contains("\"alive\":2")
            && status.contains("\"draining\":false")
            && prom_value(&metrics, "swarm_interactions_total").unwrap_or(0.0) > 0.0
        {
            break (status, metrics);
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.contains("\"workers\":2"), "status: {status}");
    assert!(status.contains("\"rank\":0") && status.contains("\"rank\":1"), "status: {status}");
    // no recovery has happened, so the shard assignment is still roster
    // epoch 0 — both at the run level and per worker
    assert!(status.contains("\"roster_epoch\":0"), "status: {status}");
    assert!(status.contains("\"epoch\":0"), "status: {status}");
    assert_eq!(prom_value(&metrics, "swarm_cluster_workers_alive"), Some(2.0), "{metrics}");
    assert!(metrics.contains("# TYPE swarm_interactions_total counter"), "{metrics}");

    let final_line = await_line(&rx, "the final report", Duration::from_secs(120), |l| {
        l.starts_with("cluster: final ")
    });
    coord.wait_success("coordinator", Duration::from_secs(30));
    w0.wait_success("worker 0", Duration::from_secs(30));
    w1.wait_success("worker 1", Duration::from_secs(30));

    let events = parse_kv(&final_line, "events");
    let recoveries = parse_kv(&final_line, "recoveries");
    let wire_bits = parse_kv(&final_line, "wire_bits");
    assert!(events >= 2500.0, "stopped short of the target: {final_line}");
    assert_eq!(recoveries, 0.0, "healthy run recovered: {final_line}");
    assert!(wire_bits > 0.0, "lattice gossip put nothing on the wire: {final_line}");

    let mut cfg = RunConfig::default();
    cfg.set("preset", "oracle:quadratic").unwrap();
    cfg.set("n", "16").unwrap();
    let gap = normalized_gap(&cfg, parse_kv(&final_line, "eval_loss"));
    assert!(gap < 0.15, "cluster run off the convergence band: normalized gap {gap}");
}

#[test]
fn cluster_recovers_a_frozen_worker_from_checkpoint() {
    let dir = std::env::temp_dir().join(format!("swarm_cluster_reco_{}", std::process::id()));
    // throttled workers (~1k interactions/s each) with a target far enough
    // out that the surviving worker alone needs well over the heartbeat
    // timeout to finish — the freeze must be *detected*, not outrun
    let set = "algo=swarm,preset=oracle:quadratic,n=16,interactions=8000,eval_every=0";
    let (mut coord, rx) = spawn_coordinator(&dir, &["--heartbeat-timeout", "2"], set);
    let addr = listen_addr(&rx);
    let mut w0 = spawn_worker(&addr, &["--throttle-us", "1000"]);
    let mut w1 = spawn_worker(&addr, &["--throttle-us", "1000"]);

    // let the cluster checkpoint first, so the adoption has state to resume
    await_line(&rx, "the first checkpoint", Duration::from_secs(60), |l| {
        l.starts_with("cluster: checkpoint at ")
    });

    // SIGSTOP keeps worker 0's sockets open: no EOF anywhere, so only the
    // heartbeat timer can notice. (Peers survive its full TCP buffers via
    // the gossip write timeout.)
    let stop = Command::new("kill")
        .args(["-STOP", &w0.0.id().to_string()])
        .status()
        .expect("send SIGSTOP");
    assert!(stop.success(), "kill -STOP failed");

    await_line(&rx, "the recovery announcement", Duration::from_secs(60), |l| {
        l.starts_with("cluster: recovery #")
    });
    let final_line = await_line(&rx, "the final report", Duration::from_secs(120), |l| {
        l.starts_with("cluster: final ")
    });
    coord.wait_success("coordinator", Duration::from_secs(30));
    w1.wait_success("surviving worker", Duration::from_secs(30));
    let _ = w0.0.kill(); // SIGKILL the frozen worker; Drop reaps it

    assert!(
        parse_kv(&final_line, "recoveries") >= 1.0,
        "no shard reassignment reported: {final_line}"
    );
    assert!(
        parse_kv(&final_line, "events") >= 8000.0,
        "job did not complete after the recovery: {final_line}"
    );
}
