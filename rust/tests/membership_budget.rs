//! RSS-proxy pin of the scale engine's bytes-per-node budget at n=100k.
//!
//! A tracking global allocator (same single-test-per-file discipline as
//! `merge_no_alloc.rs` — no other test may share the process and pollute
//! the counters) records live heap bytes and their high-water mark. The
//! test runs a real `run_scale` at n=100,000 / d=64 with the budget gate
//! armed at 512 B/node and asserts the *measured peak heap growth* of the
//! whole run stays under `n · budget` — so the budget the engine enforces
//! arithmetically is also the budget the process actually observes. A
//! lower bound (the store arena itself) proves the proxy measured the run
//! rather than trivially passing, and the exact 212 B/node accounting pins
//! the d=64 record layout against regressions.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use swarm_sgd::coordinator::{make_algorithm, AlgoOptions, LrSchedule, RunSpec};
use swarm_sgd::grad::ProcQuadraticOracle;
use swarm_sgd::membership::{run_scale, ChurnSpec, NodeStore, ScaleOptions};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::topology::Topology;

/// Live heap bytes right now (alloc adds, dealloc subtracts).
static LIVE: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of `LIVE` — the resident-set proxy.
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct PeakAlloc;

impl PeakAlloc {
    fn credit(size: usize) {
        let now = LIVE.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(now, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            Self::credit(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            Self::credit(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            Self::credit(new_size);
        }
        p
    }
}

#[global_allocator]
static A: PeakAlloc = PeakAlloc;

#[test]
fn scale_run_at_100k_stays_under_the_bytes_per_node_budget() {
    const N: usize = 100_000;
    const DIM: usize = 64;
    const BUDGET: u64 = 512;

    // the d=64 record layout, pinned exactly: 48-byte header + 128-byte
    // lattice payload (8-aligned) + 24 bytes of per-slot atomics = 200,
    // and the engine accounts roster generation (4) + speed rate (8) on top
    assert_eq!(NodeStore::record_bytes(DIM), 200);

    let algo = make_algorithm("swarm", &AlgoOptions::default()).expect("known algorithm");
    let backend = ProcQuadraticOracle::new(DIM, N, 1.0, 0.5, 2.0, 0.2, 5);
    let cost = CostModel::deterministic(0.2);
    let spec = RunSpec {
        n: N,
        events: 30_000,
        lr: LrSchedule::Constant(0.02),
        seed: 13,
        name: "budget-proxy".into(),
        eval_every: 0,
        track_gamma: false,
    };
    let opts = ScaleOptions {
        threads: 2,
        topology: Topology::Expander(8),
        churn: ChurnSpec::none(),
        node_budget: BUDGET,
        ..ScaleOptions::default()
    };

    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    let m = run_scale(algo.as_ref(), &backend, &spec, &cost, &opts).expect("scale run");
    let grown = PEAK.load(Ordering::Relaxed).saturating_sub(baseline);

    let ms = m
        .freerun
        .expect("scale telemetry")
        .membership
        .expect("membership telemetry");
    assert_eq!(ms.bytes_per_node, 212, "accounted d=64 record layout moved");
    assert_eq!(ms.node_budget, BUDGET);
    assert!(ms.bytes_per_node <= BUDGET);
    assert_eq!(ms.decode_failures, 0);

    // the proxy really measured the run: peak growth covers at least the
    // store arena (100k × 176-byte records)
    let arena_floor = N * 176;
    assert!(
        grown >= arena_floor,
        "peak heap growth {grown} B below the {arena_floor} B arena — the \
         allocator proxy measured nothing"
    );
    // and the whole run — arena, roster, rates, worklists, worker scratch,
    // eval buffers — stays under the budget the gate promises per node
    let ceiling = N * BUDGET as usize;
    assert!(
        grown <= ceiling,
        "peak heap growth {grown} B exceeds n·budget = {ceiling} B \
         ({:.1} B/node measured vs {BUDGET} budgeted)",
        grown as f64 / N as f64
    );
}
