//! Integration tests over the REAL three-layer path: AOT artifacts loaded
//! through PJRT, driven by the coordinator. Compiled only with the `pjrt`
//! feature (the default build has no XLA runtime), and additionally gated on
//! `artifacts/manifest.txt` existing (run `make artifacts` first); they skip
//! cleanly otherwise so `cargo test --features pjrt` works in a fresh
//! checkout.
#![cfg(feature = "pjrt")]

use std::path::Path;
use swarm_sgd::backend::Backend;
use swarm_sgd::config::ShardMode;
use swarm_sgd::coordinator::{
    run_serial, AveragingMode, LocalSteps, LrSchedule, RunSpec, SwarmSgd,
};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::runtime::{XlaBackend, XlaBackendConfig};
use swarm_sgd::topology::{Graph, Topology};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn load_mlp(agents: usize) -> Option<XlaBackend> {
    let dir = artifacts_dir()?;
    let cfg = XlaBackendConfig {
        agents,
        data_per_agent: 256,
        shard: ShardMode::Iid,
        separation: 3.0,
        seed: 5,
        eval_batches: 2,
    };
    Some(XlaBackend::load(dir, "mlp_s", cfg).expect("load mlp_s"))
}

#[test]
fn xla_backend_single_agent_learns() {
    let Some(b) = load_mlp(1) else { return };
    let (mut p, mut m) = b.init();
    let mut rng = Pcg64::seed(1);
    assert_eq!(p.len(), b.dim());
    let before = b.eval(&p);
    for _ in 0..30 {
        b.step(0, &mut p, &mut m, 0.05, &mut rng);
    }
    let after = b.eval(&p);
    assert!(
        after.loss < before.loss * 0.8,
        "loss {} -> {}",
        before.loss,
        after.loss
    );
    assert!(after.accuracy >= before.accuracy);
}

#[test]
fn xla_step_burst_matches_unit_steps_statistically() {
    // step_burst uses the lax.scan artifact; same data distribution so the
    // loss trajectory must be comparable (not identical: different batches).
    let Some(b) = load_mlp(1) else { return };
    let mut rng = Pcg64::seed(2);
    let (mut p, mut m) = b.init();
    let burst_loss = {
        for _ in 0..5 {
            b.step_burst(0, &mut p, &mut m, 0.05, 4, &mut rng);
        }
        b.eval(&p).loss
    };
    let (mut p2, mut m2) = b.init();
    let unit_loss = {
        for _ in 0..20 {
            b.step(0, &mut p2, &mut m2, 0.05, &mut rng);
        }
        b.eval(&p2).loss
    };
    assert!(
        (burst_loss - unit_loss).abs() < 0.5 * unit_loss.max(0.2),
        "burst {burst_loss} vs unit {unit_loss}"
    );
}

#[test]
fn swarm_on_xla_mlp_converges() {
    let n = 4;
    let Some(backend) = load_mlp(n) else { return };
    let mut rng = Pcg64::seed(3);
    let graph = Graph::build(Topology::Complete, n, &mut rng);
    let cost = CostModel::deterministic(0.4);
    let f0 = {
        let (p, _) = backend.init();
        backend.eval(&p).loss
    };
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let spec = RunSpec {
        n,
        events: 120,
        lr: LrSchedule::Constant(0.05),
        seed: 1,
        name: "swarm-xla".into(),
        eval_every: 30,
        track_gamma: true,
    };
    let m = run_serial(&algo, &backend, &spec, &graph, &cost);
    assert!(
        m.final_eval_loss < 0.5 * f0,
        "loss {} vs init {}",
        m.final_eval_loss,
        f0
    );
    assert!(m.final_eval_acc > 0.5, "acc {}", m.final_eval_acc);
    // Γ stayed finite and bounded
    let gmax = m.curve.iter().map(|p| p.gamma).fold(0.0, f64::max);
    assert!(gmax.is_finite());
}

#[test]
fn xla_qavg_kernel_matches_rust_codec() {
    // cross-layer contract: the Pallas lattice kernel (L1, via PJRT) and the
    // Rust codec (L3) implement the same hash -> identical lattice points.
    let Some(b) = load_mlp(1) else { return };
    let d = b.dim();
    let mut rng = Pcg64::seed(9);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
    let y: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
    let seed = 42u32;
    let eps = b.manifest().qavg_eps;
    let got = b.qavg(&x, &y, seed).expect("qavg artifact");
    let q = swarm_sgd::quant::quantize_unbiased(&y, eps, seed);
    for i in 0..d {
        let want = 0.5 * (x[i] + q[i]);
        assert!(
            (got[i] - want).abs() < 1e-5,
            "coord {i}: xla {} vs rust {}",
            got[i],
            want
        );
    }
}

#[test]
fn quantized_swarm_on_xla_runs() {
    let n = 4;
    let Some(backend) = load_mlp(n) else { return };
    let mut rng = Pcg64::seed(4);
    let graph = Graph::build(Topology::Complete, n, &mut rng);
    let cost = CostModel::deterministic(0.4);
    let algo = SwarmSgd {
        local_steps: LocalSteps::Geometric(2.0),
        mode: AveragingMode::Quantized { bits: 8, eps: 1e-3 },
    };
    let spec = RunSpec {
        n,
        events: 60,
        lr: LrSchedule::Constant(0.05),
        seed: 2,
        name: "swarm-xla-q".into(),
        eval_every: 0,
        track_gamma: false,
    };
    let m = run_serial(&algo, &backend, &spec, &graph, &cost);
    assert!(m.final_eval_loss.is_finite());
    assert!(m.total_bits > 0);
}
