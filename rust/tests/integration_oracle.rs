//! Oracle-backed integration tests: algorithm-level behaviour that the
//! paper's analysis predicts, checked end-to-end through the coordinator
//! (no artifacts required — these always run).

use swarm_sgd::backend::Backend;
use swarm_sgd::coordinator::{
    make_algorithm, run_serial, AlgoOptions, AveragingMode, LocalSteps, LrSchedule, RunMetrics,
    RunSpec, SwarmSgd,
};
use swarm_sgd::figures::{run_arm, Arm, BackendSpec};
use swarm_sgd::grad::{LogisticOracle, QuadraticOracle};
use swarm_sgd::netmodel::CostModel;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::topology::{Graph, Topology};

#[allow(clippy::too_many_arguments)]
fn swarm_run(
    backend: &dyn Backend,
    n: usize,
    t: u64,
    h: u64,
    mode: AveragingMode,
    lr: LrSchedule,
    topo: Topology,
    seed: u64,
) -> RunMetrics {
    let mut rng = Pcg64::seed(seed);
    let graph = Graph::build(topo, n, &mut rng);
    let cost = CostModel::deterministic(0.4);
    let algo = SwarmSgd { local_steps: LocalSteps::Fixed(h), mode };
    let spec = RunSpec {
        n,
        events: t,
        lr,
        seed,
        name: "it".into(),
        eval_every: (t / 8).max(1),
        track_gamma: true,
    };
    run_serial(&algo, backend, &spec, &graph, &cost)
}

#[test]
fn convergence_improves_with_t() {
    // the O(1/sqrt(T)) trend: doubling T shrinks the average gradient proxy
    let gaps: Vec<f64> = [500u64, 2000, 8000]
        .iter()
        .map(|&t| {
            let b = QuadraticOracle::new(16, 8, 1.0, 0.5, 2.0, 0.3, 5);
            let f_star = b.f_star();
            let m = swarm_run(
                &b,
                8,
                t,
                2,
                AveragingMode::NonBlocking,
                LrSchedule::Theory { n: 8, t },
                Topology::Complete,
                9,
            );
            (m.final_eval_loss - f_star).max(0.0)
        })
        .collect();
    assert!(
        gaps[2] < gaps[0],
        "gap should shrink with T: {gaps:?}"
    );
}

#[test]
fn noniid_logistic_swarm_beats_isolated_agents() {
    // Theorem 4.2 regime: label-skewed shards. Swarm must pull the agents
    // to a model that classifies BOTH classes (isolated agents can't).
    let n = 4;
    let b = LogisticOracle::synthetic(2000, 8, n, 32, /*iid=*/ false, 11);
    let m = swarm_run(
        &b,
        n,
        600,
        2,
        AveragingMode::NonBlocking,
        LrSchedule::Constant(0.05),
        Topology::Complete,
        13,
    );
    assert!(
        m.final_eval_acc > 0.85,
        "non-iid swarm acc {}",
        m.final_eval_acc
    );
}

#[test]
fn ring_concentrates_worse_than_complete() {
    let run = |topo| {
        let b = QuadraticOracle::new(16, 16, 1.0, 0.5, 2.0, 0.5, 21);
        let m = swarm_run(
            &b,
            16,
            4000,
            2,
            AveragingMode::NonBlocking,
            LrSchedule::Constant(0.02),
            topo,
            23,
        );
        let gs: Vec<f64> = m.curve.iter().map(|p| p.gamma).collect();
        gs[gs.len() / 2..].iter().sum::<f64>() / (gs.len() / 2) as f64
    };
    let complete = run(Topology::Complete);
    let ring = run(Topology::Ring);
    assert!(
        ring > 1.5 * complete,
        "ring Γ {ring} should exceed complete Γ {complete}"
    );
}

#[test]
fn gamma_scales_roughly_h_squared() {
    let steady = |h: u64| {
        let b = QuadraticOracle::new(16, 16, 1.0, 0.5, 2.0, 0.5, 41);
        let m = swarm_run(
            &b,
            16,
            4000,
            h,
            AveragingMode::NonBlocking,
            LrSchedule::Constant(0.02),
            Topology::Complete,
            43,
        );
        let gs: Vec<f64> = m.curve.iter().map(|p| p.gamma).collect();
        gs[gs.len() / 2..].iter().sum::<f64>() / (gs.len() / 2) as f64
    };
    let g1 = steady(1);
    let g4 = steady(4);
    let ratio = g4 / g1;
    // Lemma F.3 predicts 16x; accept a broad band around the H² law
    assert!(
        (4.0..64.0).contains(&ratio),
        "Γ(H=4)/Γ(H=1) = {ratio}, expected ~16"
    );
}

#[test]
fn quantized_tracks_full_precision_loss() {
    let run = |mode| {
        let b = QuadraticOracle::new(128, 8, 1.0, 0.5, 2.0, 0.1, 61);
        swarm_run(
            &b,
            8,
            1500,
            2,
            mode,
            LrSchedule::Constant(0.05),
            Topology::Complete,
            67,
        )
        .final_eval_loss
    };
    let full = run(AveragingMode::NonBlocking);
    let quant = run(AveragingMode::Quantized { bits: 8, eps: 5e-3 });
    assert!(
        (quant - full).abs() < 0.2 * full.max(0.1),
        "quantized {quant} vs full {full}"
    );
}

#[test]
fn runs_are_deterministic_given_seed() {
    let run = || {
        let b = QuadraticOracle::new(16, 8, 1.0, 0.5, 2.0, 0.3, 5);
        swarm_run(
            &b,
            8,
            400,
            2,
            AveragingMode::NonBlocking,
            LrSchedule::Constant(0.05),
            Topology::Complete,
            77,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.curve.len(), b.curve.len());
    for (pa, pb) in a.curve.iter().zip(&b.curve) {
        assert_eq!(pa.eval_loss.to_bits(), pb.eval_loss.to_bits(), "t={}", pa.t);
        assert_eq!(pa.gamma.to_bits(), pb.gamma.to_bits());
    }
    assert_eq!(a.total_bits, b.total_bits);
}

#[test]
fn blocking_and_nonblocking_agree_in_the_limit() {
    // same budget, both must reach comparable quality (Appendix F claims
    // the staleness costs only constants)
    let run = |mode| {
        let b = QuadraticOracle::new(32, 8, 1.0, 0.5, 2.0, 0.2, 81);
        let f_star = b.f_star();
        let m = swarm_run(
            &b,
            8,
            3000,
            2,
            mode,
            LrSchedule::Constant(0.03),
            Topology::Complete,
            83,
        );
        (m.final_eval_loss - f_star).max(1e-9)
    };
    let blocking = run(AveragingMode::Blocking);
    let nonblocking = run(AveragingMode::NonBlocking);
    let ratio = nonblocking / blocking;
    assert!(
        (0.2..5.0).contains(&ratio),
        "blocking {blocking} vs nonblocking {nonblocking}"
    );
}

#[test]
fn localsgd_and_adpsgd_reach_quadratic_optimum() {
    let cost = CostModel::deterministic(0.4);
    for algo_name in ["localsgd", "adpsgd"] {
        let b = QuadraticOracle::new(16, 8, 1.0, 0.5, 2.0, 0.1, 91);
        let f_star = b.f_star();
        let gap0 = {
            let (p, _) = b.init();
            b.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, 8, &mut rng);
        let algo = make_algorithm(algo_name, &AlgoOptions::default()).unwrap();
        let spec = RunSpec {
            n: 8,
            events: 500,
            lr: LrSchedule::Constant(0.05),
            seed: 5,
            name: algo_name.into(),
            eval_every: 0,
            track_gamma: false,
        };
        let m = run_serial(algo.as_ref(), &b, &spec, &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "{algo_name} normalized gap {gap}");
    }
}

#[test]
fn figure_arm_api_smoke() {
    // the figures' public API surfaces (used by examples) stay callable
    let spec = BackendSpec::Quadratic { dim: 8, spread: 1.0, sigma: 0.1, seed: 1 };
    let cost = CostModel::deterministic(0.1);
    let m = run_arm(
        &Arm::swarm("x", 2, 64, 0.05),
        &spec,
        4,
        Topology::Complete,
        &cost,
        3,
        16,
        true,
    )
    .unwrap();
    assert_eq!(m.interactions, 64);
    assert!(m.curve.len() >= 4);
}

#[test]
fn extension_arbitrary_irregular_graph_still_converges() {
    // Paper §6 future work: "generalize the bounds to arbitrary
    // communication graphs". The implementation already supports any
    // connected simple graph (uniform edge sampling); check convergence on
    // a deliberately irregular one (two hubs + leaves + a bridge).
    let n = 8;
    let edges = vec![
        (0, 1), (0, 2), (0, 3),          // hub 0
        (4, 5), (4, 6), (4, 7),          // hub 4
        (0, 4),                          // bridge
        (1, 2), (5, 6),                  // a couple of chords
    ];
    let graph = Graph::from_edges(n, edges);
    assert!(graph.is_connected());
    assert!(graph.regular_degree().is_none(), "meant to be irregular");
    let b = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.2, 101);
    let f_star = b.f_star();
    let gap0 = {
        let (p, _) = b.init();
        b.full_loss(&p) - f_star
    };
    let cost = CostModel::deterministic(0.4);
    let algo = SwarmSgd {
        local_steps: LocalSteps::Fixed(2),
        mode: AveragingMode::NonBlocking,
    };
    let spec = RunSpec {
        n,
        events: 1500,
        lr: LrSchedule::Constant(0.04),
        seed: 3,
        name: "irregular".into(),
        eval_every: 0,
        track_gamma: false,
    };
    let m = run_serial(&algo, &b, &spec, &graph, &cost);
    let gap = (m.final_eval_loss - f_star) / gap0;
    assert!(gap < 0.15, "irregular-graph normalized gap {gap}");
}

#[test]
fn lambda2_predicts_cross_topology_ordering() {
    // quantitative version of the r²/λ₂² factor: steady Γ ordering follows
    // the topology factor ordering across three graphs.
    let factor = |topo| {
        let mut rng = Pcg64::seed(2);
        let g = Graph::build(topo, 16, &mut rng);
        let r = g.regular_degree().unwrap() as f64;
        let l2 = g.lambda2();
        r * r / (l2 * l2)
    };
    let gamma = |topo| {
        let b = QuadraticOracle::new(16, 16, 1.0, 0.5, 2.0, 0.5, 21);
        let m = swarm_run(
            &b,
            16,
            3000,
            2,
            AveragingMode::NonBlocking,
            LrSchedule::Constant(0.02),
            topo,
            23,
        );
        let gs: Vec<f64> = m.curve.iter().map(|p| p.gamma).collect();
        gs[gs.len() / 2..].iter().sum::<f64>() / (gs.len() / 2) as f64
    };
    let topos = [Topology::Complete, Topology::Hypercube, Topology::Ring];
    let fs: Vec<f64> = topos.iter().map(|&t| factor(t)).collect();
    let gs: Vec<f64> = topos.iter().map(|&t| gamma(t)).collect();
    // factors strictly increase complete < hypercube < ring; Γ must follow
    assert!(fs[0] < fs[1] && fs[1] < fs[2], "factors {fs:?}");
    assert!(gs[0] < gs[1] && gs[1] < gs[2], "gammas {gs:?} factors {fs:?}");
}
