//! # swarm-sgd
//!
//! Production-grade reproduction of **“Decentralized SGD with Asynchronous,
//! Local, and Quantized Updates”** (Nadiradze et al., NeurIPS 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SwarmSGD coordinator: discrete-event cluster
//!   engine, pairwise gossip scheduling, blocking/non-blocking/quantized
//!   averaging, the decentralized baselines (AD-PSGD, D-PSGD, SGP, local
//!   SGD, allreduce SGD), topology/spectral math, the lattice codec, and
//!   the figure-regeneration harnesses.
//! * **L2 (python/compile)** — JAX models (MLP / CNN / transformer LM) with
//!   flat-packed parameters, lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul, fused
//!   lattice quantize-average, fused SGD update) with pure-jnp oracles.
//!
//! Python never runs at training time: `make artifacts` AOT-compiles the
//! models; the [`runtime`] module loads them through PJRT (behind the
//! `pjrt` feature — default builds substitute a stub and stay hermetic).
//!
//! # Executors
//!
//! Two executors run the SwarmSGD interaction sequence:
//!
//! * **Serial** ([`coordinator::SwarmRunner`], `--executor serial`) — the
//!   discrete-event reference: one interaction at a time, simulated
//!   per-node clocks supplying the paper's time axes.
//! * **Parallel** ([`coordinator::run_parallel`], `--executor parallel
//!   --threads K`) — N shared-memory worker threads over per-node
//!   `Mutex<NodeState>`; Algorithm 1 rendezvous uses ordered two-lock
//!   acquisition, Algorithms 2/G read partners' communication copies from
//!   lock-free double-buffered slots, so "nobody waits" is executed, not
//!   simulated.
//!
//! **Replay-determinism contract:** a parallel run pre-draws its whole
//! interaction schedule and gives every node a private
//! [`rngx::Pcg64::stream`]; workers commit interactions in per-node
//! dependency order, which fixes the dataflow DAG independently of thread
//! interleaving. [`coordinator::run_replay_serial`] executes the identical
//! schedule in program order and must match **bit-for-bit** on every
//! metric — `tests/parallel_executor.rs` asserts this for blocking,
//! non-blocking, and quantized modes, and `.github/workflows/ci.yml` runs
//! those tests (plus fmt/clippy gates and a non-blocking throughput bench
//! that archives `BENCH_parallel.json`) on every push and PR.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod grad;
pub mod netmodel;
pub mod output;
pub mod quant;
pub mod rngx;
pub mod runtime;
pub mod topology;
