//! # swarm-sgd
//!
//! Production-grade reproduction of **“Decentralized SGD with Asynchronous,
//! Local, and Quantized Updates”** (Nadiradze et al., NeurIPS 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the Algorithm plug-in API
//!   (SwarmSGD + the §5 baselines), two schedule executors, topology/
//!   spectral math, the lattice codec, and the figure-regeneration
//!   harnesses.
//! * **L2 (python/compile)** — JAX models (MLP / CNN / transformer LM) with
//!   flat-packed parameters, lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul, fused
//!   lattice quantize-average, fused SGD update) with pure-jnp oracles.
//!
//! Python never runs at training time: `make artifacts` AOT-compiles the
//! models; the [`runtime`] module loads them through PJRT (behind the
//! `pjrt` feature — default builds substitute a stub and stay hermetic).
//!
//! # The Algorithm × Backend × Executor matrix
//!
//! PR 2 collapsed the crate around three orthogonal axes; any combination
//! runs:
//!
//! * **Algorithm** ([`coordinator::Algorithm`], CLI `--algorithm`):
//!   `swarm` (blocking / non-blocking / quantized averaging, fixed or
//!   geometric H), `poisson` (Poisson-clock scheduling), and the five
//!   baselines `adpsgd | dpsgd | sgp | localsgd | allreduce`. An algorithm
//!   pre-draws an event schedule (`schedule`), executes one event over its
//!   participants' [`coordinator::NodeState`]s (`interact`), and maps
//!   states to the evaluated models (`round_metrics`). Events are typed
//!   ([`coordinator::EventKind`]): gossip algorithms schedule 2-node
//!   `Gossip` events; the round-based baselines schedule **phased rounds**
//!   — `n` single-node `Compute` events (each node's local SGD phase, on
//!   its private RNG stream) closed by a `Mix` barrier, with `seq`
//!   dependency tokens wiring compute → mix — so *every* algorithm's
//!   per-node work spreads across all parallel workers, and only the
//!   mixing step is the barrier its semantics requires. One phased round
//!   costs one logical tick, so lr schedules, eval cadence, and reported
//!   interaction counts are unchanged from the monolithic rounds — and the
//!   metrics are bit-identical to them (golden-tested).
//! * **Backend** ([`backend::Backend`], config `preset=`): the quadratic /
//!   softmax / logistic gradient oracles and the PJRT-compiled models. One
//!   `&self + Sync` trait; all stochasticity comes from the caller's
//!   [`rngx::Pcg64`] stream.
//! * **Wire codec** ([`coordinator::WireCodec`], CLI `--wire lattice|f32`):
//!   whether model payloads cross the simulated wire lattice-quantized
//!   (Appendix G; `quant_bits`/`quant_eps`) or at full precision — a
//!   per-algorithm axis honored by *all three* executors, with bits and
//!   decode-fallbacks attributed through [`coordinator::EventOutcome`] and
//!   the freerun telemetry. (`mode=quantized` is the swarm/poisson
//!   spelling of non-blocking merge + lattice wire; localsgd/allreduce mix
//!   through full-precision collectives and reject `--wire lattice` with
//!   an actionable error.)
//! * **Kernel** ([`kernels::Kernel`], CLI `--kernel scalar|simd`, INI
//!   `kernel=`, default `scalar`): which fused merge-kernel implementation
//!   every interaction's decode + merge + publish traversal dispatches to.
//!   `scalar` is the element-at-a-time reference; `simd` processes f32
//!   lanes in chunks of 8 (auto-vectorized fixed-size arrays). Both are
//!   **bit-exact** with the historical two-pass path — lane math is
//!   elementwise and checksums fold in element order — so the axis is
//!   honored by all three executors without weakening the replay contract,
//!   and the selected kernel is surfaced in
//!   [`coordinator::RunMetrics::kernel`] / freerun telemetry for
//!   kernel-tagged bench rows (`benches/bench_qavg.rs`).
//! * **Executor** (CLI `--executor serial|parallel|freerun|cluster
//!   --threads K [--shards S]`): four generic drivers over
//!   `&dyn Algorithm × &dyn Backend`, split into two contract classes:
//!
//!   | executor | mechanism | contract |
//!   |---|---|---|
//!   | [`coordinator::run_serial`] | pre-drawn schedule, program order | **bit-replayable** (the reference) |
//!   | [`coordinator::run_parallel`] | same schedule, K workers, per-node locks, dependency-order commits | **bit-replayable** (≡ serial at any K) |
//!   | [`coordinator::run_freerun`] | **no schedule**: K workers own S node shards, live Poisson clocks pick partners on the fly, seqlock model slots, initiator never blocks the partner | **throughput-faithful, non-replayable** (statistical assertions only) |
//!   | [`cluster::run_coordinator`] / [`cluster::run_worker`] | freerun's protocol across **OS processes**: a coordinator assigns node shards, workers gossip `WireCodec`-encoded payloads over TCP ([`cluster::proto`] frames), heartbeat-timeout failover reassigns dead shards from checkpoints | **throughput-faithful, non-replayable** — and `wire_bits` is measured from the socket, not modeled |
//!
//! **The contract split.** `serial`/`parallel` exist to *simulate*
//! faithfully: the schedule (participants, local-step counts, event seeds)
//! is pre-drawn from a dedicated [`rngx::Pcg64::stream`], every node draws
//! noise/jitter from its private stream, and workers commit in dependency
//! order — so the dataflow DAG, and therefore every f32 operation, is
//! fixed before any thread starts, making a parallel run at any thread
//! count **bit-identical** to the serial run of the same seed, for every
//! algorithm on the oracle backends. (The PJRT backend is excluded: its
//! fused-step heuristic races wall-clock timings.) `freerun` exists to
//! *measure* what replay cannot: real threads race on real memory, so two
//! runs of one seed legitimately differ in the bits — and in exchange it
//! reports true interactions/sec, per-interaction staleness (version-lag)
//! histograms, seqlock contention counters, and per-worker busy/wait
//! splits through [`coordinator::RunMetrics::freerun`]
//! (see [`coordinator::telemetry`]). Tests against it are tolerance-based
//! (`tests/freerun_executor.rs`), never bit-equality.
//!
//! `tests/parallel_executor.rs` asserts the replay contract for SwarmSGD
//! (all averaging modes, quadratic and softmax oracles), AD-PSGD, and the
//! four phased round-based baselines at threads {1, 2, 4, 8} — plus a
//! golden test pinning the phased schedules to the pre-redesign monolithic
//! rounds bit-for-bit — and `.github/workflows/ci.yml` runs both suites
//! (plus fmt/clippy/doc gates, a `cargo bench --no-run` compile gate, and
//! non-blocking throughput benches that append algorithm-tagged
//! `BENCH_parallel.json` / `BENCH_freerun.json` rows to the committed
//! perf trajectory) on every push and PR.
//!
//! Freerun eligibility is an open API: an algorithm is admitted by
//! returning an object-safe [`coordinator::MixPolicy`] from
//! [`Algorithm::mix_policy`](coordinator::Algorithm::mix_policy). A policy
//! owns the slot payload it publishes ([`coordinator::SlotPayload`]:
//! [`coordinator::PlainModel`] snapshots, or [`coordinator::PushSumWeighted`]
//! `(x, w)` pairs — the seqlock `ModelSlot` is generic over the layout),
//! the merge rule the initiator applies to a possibly-stale partner
//! snapshot, the local-step policy per interaction, and the wire codec.
//! swarm, poisson, adpsgd, and dpsgd use the plain-model
//! [`coordinator::PairwisePolicy`]; sgp — formerly refused for its global
//! push-sum — freeruns through the weighted-slot
//! [`coordinator::PushSumPolicy`]: `x` and `w` cross the wire and merge by
//! the same linear rule, so the de-biased `Σx/Σw` consensus stays correct
//! under staleness and dropped cross-writes. localsgd and allreduce mix
//! through an irreducible global mean; they parallelize on the replay
//! executors through their phased compute events but return no policy and
//! refuse `--executor freerun` with an actionable error.
//!
//! # The Scenario axis
//!
//! Every executor runs *under a scenario* ([`scenario::Scenario`]): the
//! heterogeneity model the paper's analysis actually quantifies over,
//! resolved once from config and threaded through all four drivers.
//!
//! * **Topology** (`--topology complete|ring|torus|hypercube|regular<r>|
//!   powerlaw`, `--directed` for push-sum orientations): gossip partners
//!   are sampled from the configured graph's edge set everywhere — the
//!   replay executors pre-draw graph-constrained pairs (serial ≡ parallel
//!   stays bit-identical under every topology), freerun workers sample
//!   neighbors from their private streams, and the cluster gossip plane
//!   dials only graph edges. Infeasible topology/n combinations (torus
//!   needs square n, hypercube a power of two, regular n·r even) are
//!   rejected at config time with actionable errors, and `lambda2`
//!   reports exactly 0.0 for disconnected graphs.
//! * **Speed classes** (`--speeds uniform|bimodal:<frac>:<slowdown>|
//!   pareto:<alpha>`): per-node Poisson clock rates, so stragglers are
//!   *structural* — the replay executors weight initiator draws by rate,
//!   freerun/cluster workers scale their clock-arm exponentials — unlike
//!   the cost model's i.i.d. per-step straggler coin.
//! * **Data skew** (`--dirichlet <alpha>`, sugar for
//!   `shard=dirichlet:<alpha>`): Dirichlet-α non-iid label sharding from
//!   [`data::dirichlet_shards`].
//! * **Dynamic graphs** (`topology_schedule=ring@0,torus@5000,...`): an
//!   epoch-indexed graph schedule; each event samples from the graph in
//!   force at its tick.
//!
//! The default scenario (complete graph, uniform speeds, static topology)
//! consumes RNG streams byte-identically to the pre-scenario executors, so
//! all committed goldens still pin today's bits.
//! `benches/bench_scenario.rs` sweeps the topology × algorithm matrix and
//! emits `BENCH_scenario.json` (convergence vs staleness p99 vs spectral
//! gap per topology).
//!
//! # Scale regime
//!
//! The executors above materialize every node densely (five `dim`-wide
//! vectors per node plus a double-buffered slot), which is the right
//! trade below ~65k nodes and an impossible one at a million. The
//! [`membership`] subsystem owns the scale regime:
//!
//! * **Compact node state** — [`membership::NodeStore`] parks each node's
//!   model lattice-encoded against the initial model (the wire codec
//!   reused as a storage codec: 16 bits/coordinate, ~200 bytes/node at
//!   d=64 including the RNG/steps header and per-slot atomics), decoded
//!   into per-worker scratch only while an interaction touches it. A
//!   sticky full-precision escape catches models that drift out of
//!   lattice range; `node_budget=` enforces a bytes-per-node ceiling
//!   *before* allocation.
//! * **Shard-local sampling** — [`membership::ProcGraph`] resolves
//!   complete/ring/torus/hypercube/expander overlays to O(1) closed-form
//!   neighbor draws above the 65 536-node materialize cutover, and every
//!   worker samples on its private [`rngx::Pcg64`] stream — no global
//!   RNG, no global edge list.
//! * **Live churn** — `--churn join:<r>,leave:<r>` runs an open roster
//!   ([`membership::Roster`]): generation-stamped slots (recycled slots
//!   never alias departed incarnations), joiners bootstrapping from a
//!   live neighbor snapshot, stationary live count `n·min(1, join/leave)`
//!   pinned by statistical tests.
//!
//! `--executor freerun` routes to [`membership::run_scale`] when n
//! exceeds the dense cutover or churn is requested (`node_store=` forces
//! either path); the engine keeps freerun's checkout → local phase →
//! snapshot merge → commit semantics and its non-replayable,
//! throughput-faithful contract, and reports roster/storage telemetry in
//! [`coordinator::MembershipStats`]. What the compact record does *not*
//! persist — momentum and per-node simulated clocks — is documented on
//! [`membership::engine`]. `benches/bench_scale.rs` tracks
//! interactions/sec and resident bytes/node against n in
//! `BENCH_scale.json`.
//!
//! # Observability
//!
//! The [`obs`] module is the cross-cutting layer that makes a run's
//! wall-clock behavior visible *while it happens* (zero new dependencies,
//! hand-rolled like [`cluster::proto`]):
//!
//! * **Event tracing** — `--trace-out trace.json` records typed spans
//!   (compute, merge, publish, seqlock retry, gossip tx/rx, heartbeat)
//!   into per-worker lock-free ring buffers ([`obs::TraceRing`]) and
//!   drains them post-run into Chrome trace-event JSON loadable in
//!   `chrome://tracing` / Perfetto. `--trace-sample R` traces a
//!   deterministic R-fraction of interactions; overhead at full sampling
//!   is pinned within a few percent by a `BENCH_freerun.json` comparison
//!   row (`cargo bench --bench bench_freerun`).
//! * **Metrics export** — `--metrics-out metrics.prom` appends Prometheus
//!   text snapshots ([`obs::MetricsRegistry`]) at a fixed cadence:
//!   interactions/sec, staleness p50/p99, wire bits, conflict counts as
//!   time series instead of run-end totals.
//! * **Live introspection** — `--metrics-addr HOST:PORT` on a cluster
//!   coordinator serves `/metrics` (Prometheus text), `/status` (JSON:
//!   per-worker shards, liveness, last-progress age, heartbeat RTT) and
//!   `/trace` (drain-so-far) over hand-rolled HTTP/1.1 while the run
//!   executes. Unauthenticated loopback-grade plumbing — auth/TLS for
//!   multi-host deployments remains open (ROADMAP item 3).
//! * **Leveled logging** — every diagnostic routes through [`obs::log`];
//!   `--log-level error|warn|info|debug` (default `info`) gates the
//!   chatter. Machine-parsed protocol lines stay on stdout, unleveled.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod grad;
pub mod kernels;
pub mod membership;
pub mod netmodel;
pub mod obs;
pub mod output;
pub mod quant;
pub mod rngx;
pub mod runtime;
pub mod scenario;
pub mod topology;
