//! # swarm-sgd
//!
//! Production-grade reproduction of **“Decentralized SGD with Asynchronous,
//! Local, and Quantized Updates”** (Nadiradze et al., NeurIPS 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the Algorithm plug-in API
//!   (SwarmSGD + the §5 baselines), two schedule executors, topology/
//!   spectral math, the lattice codec, and the figure-regeneration
//!   harnesses.
//! * **L2 (python/compile)** — JAX models (MLP / CNN / transformer LM) with
//!   flat-packed parameters, lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul, fused
//!   lattice quantize-average, fused SGD update) with pure-jnp oracles.
//!
//! Python never runs at training time: `make artifacts` AOT-compiles the
//! models; the [`runtime`] module loads them through PJRT (behind the
//! `pjrt` feature — default builds substitute a stub and stay hermetic).
//!
//! # The Algorithm × Backend × Executor matrix
//!
//! PR 2 collapsed the crate around three orthogonal axes; any combination
//! runs:
//!
//! * **Algorithm** ([`coordinator::Algorithm`], CLI `--algorithm`):
//!   `swarm` (blocking / non-blocking / quantized averaging, fixed or
//!   geometric H), `poisson` (Poisson-clock scheduling), and the five
//!   baselines `adpsgd | dpsgd | sgp | localsgd | allreduce`. An algorithm
//!   pre-draws an event schedule (`schedule`), executes one event over its
//!   participants' [`coordinator::NodeState`]s (`interact`), and maps
//!   states to the evaluated models (`round_metrics`).
//! * **Backend** ([`backend::Backend`], config `preset=`): the quadratic /
//!   softmax / logistic gradient oracles and the PJRT-compiled models. One
//!   `&self + Sync` trait; all stochasticity comes from the caller's
//!   [`rngx::Pcg64`] stream.
//! * **Executor** ([`coordinator::run_serial`] /
//!   [`coordinator::run_parallel`], CLI `--executor serial|parallel
//!   --threads K`): generic drivers over `&dyn Algorithm × &dyn Backend`.
//!   Serial walks the schedule in program order; parallel drains it on K
//!   shared-memory worker threads with per-node locks, committing events in
//!   per-node dependency order.
//!
//! **Replay-determinism contract:** the schedule (participants, local-step
//! counts, event seeds) is pre-drawn from a dedicated
//! [`rngx::Pcg64::stream`], every node draws noise/jitter from its private
//! stream, and workers commit in dependency order — so the dataflow DAG,
//! and therefore every f32 operation, is fixed before any thread starts. A
//! parallel run at any thread count is **bit-identical** to the serial run
//! of the same seed, for every algorithm on the oracle backends. (The PJRT
//! backend is excluded: its fused-step heuristic races wall-clock timings,
//! so its runs are correct but not bit-replayable.)
//! `tests/parallel_executor.rs`
//! asserts this for SwarmSGD (all averaging modes, quadratic and softmax
//! oracles) and AD-PSGD, and `.github/workflows/ci.yml` runs those tests
//! (plus fmt/clippy/doc gates and a non-blocking throughput bench that
//! archives algorithm-tagged `BENCH_parallel.json` rows) on every push and
//! PR.
//!
//! Gossip algorithms (swarm, poisson, adpsgd) schedule 2-node events and
//! genuinely parallelize; the synchronous baselines schedule whole-cluster
//! events — a global barrier per round is their semantics, executed
//! faithfully.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod grad;
pub mod netmodel;
pub mod output;
pub mod quant;
pub mod rngx;
pub mod runtime;
pub mod topology;
