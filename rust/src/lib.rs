//! # swarm-sgd
//!
//! Production-grade reproduction of **“Decentralized SGD with Asynchronous,
//! Local, and Quantized Updates”** (Nadiradze et al., NeurIPS 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SwarmSGD coordinator: discrete-event cluster
//!   engine, pairwise gossip scheduling, blocking/non-blocking/quantized
//!   averaging, the decentralized baselines (AD-PSGD, D-PSGD, SGP, local
//!   SGD, allreduce SGD), topology/spectral math, the lattice codec, and
//!   the figure-regeneration harnesses.
//! * **L2 (python/compile)** — JAX models (MLP / CNN / transformer LM) with
//!   flat-packed parameters, lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul, fused
//!   lattice quantize-average, fused SGD update) with pure-jnp oracles.
//!
//! Python never runs at training time: `make artifacts` AOT-compiles the
//! models; the [`runtime`] module loads them through PJRT.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod grad;
pub mod netmodel;
pub mod output;
pub mod quant;
pub mod rngx;
pub mod runtime;
pub mod topology;
