//! Hand-rolled HTTP/1.1 introspection server (zero dependencies, same
//! ethos as `cluster/proto.rs`): enough of the protocol for `curl` and a
//! Prometheus scraper — GET, fixed routes, `Content-Length`,
//! `Connection: close`. One connection is handled at a time; every
//! response here is tiny and the coordinator's control loop never blocks
//! on this thread.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One HTTP response: status + content type + body.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body: body.into().into() }
    }

    pub fn json(body: impl Into<String>) -> Response {
        Response { status: 200, content_type: "application/json", body: body.into().into() }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            404 => "Not Found",
            405 => "Method Not Allowed",
            _ => "Error",
        }
    }
}

type Handler = Box<dyn Fn() -> Response + Send + Sync>;

/// Fixed route table, built once before the server spawns.
#[derive(Default)]
pub struct Router {
    routes: Vec<(String, Handler)>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register `path` (exact match, query string ignored) → handler.
    pub fn route(mut self, path: &str, f: impl Fn() -> Response + Send + Sync + 'static) -> Router {
        self.routes.push((path.to_string(), Box::new(f)));
        self
    }

    fn dispatch(&self, method: &str, path: &str) -> Response {
        if method != "GET" {
            return Response::text(405, "only GET is supported\n");
        }
        let path = path.split('?').next().unwrap_or("");
        match self.routes.iter().find(|(p, _)| p == path) {
            Some((_, h)) => h(),
            None => {
                let known: Vec<&str> = self.routes.iter().map(|(p, _)| p.as_str()).collect();
                Response::text(404, format!("no route {path}; try {}\n", known.join(" ")))
            }
        }
    }
}

/// A running introspection server. Dropping (or calling
/// [`shutdown`](HttpServer::shutdown)) stops the accept loop and joins its
/// thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read the real one
    /// back from [`addr`](HttpServer::addr)) and serve `router` on a
    /// background thread.
    pub fn spawn(addr: &str, router: Router) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so the loop can observe the stop flag
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let join = std::thread::Builder::new()
            .name("obs-http".into())
            .spawn(move || accept_loop(listener, router, flag))
            .expect("spawn obs-http thread");
        Ok(HttpServer { addr, stop, join: Some(join) })
    }

    /// The actually-bound address (resolves `:0` requests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the server thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, router: Router, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // best-effort: a broken client connection must not take
                // down the introspection thread
                let _ = handle(stream, &router);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle(mut stream: TcpStream, router: &Router) -> std::io::Result<()> {
    // accepted sockets inherit the listener's non-blocking mode on some
    // platforms; force blocking with a deadline for the header read
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut buf = Vec::with_capacity(512);
    let mut tmp = [0u8; 512];
    // read until the end of the header block; bodies are ignored (GET)
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") && buf.len() < 8192 {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let text = String::from_utf8_lossy(&buf);
    let mut line = text.lines().next().unwrap_or("").split_whitespace();
    let method = line.next().unwrap_or("");
    let path = line.next().unwrap_or("/");
    let resp = router.dispatch(method, path);
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal test client: one GET, returns (status line, body).
    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.lines().next().unwrap_or("").to_string(), body.to_string())
    }

    #[test]
    fn serves_routes_and_404s_unknown_paths() {
        let srv = HttpServer::spawn(
            "127.0.0.1:0",
            Router::new()
                .route("/metrics", || Response::text(200, "swarm_up 1\n"))
                .route("/status", || Response::json("{\"ok\":true}")),
        )
        .unwrap();
        let addr = srv.addr();
        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "swarm_up 1\n");
        let (head, body) = get(addr, "/status");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "{\"ok\":true}");
        let (head, body) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
        assert!(body.contains("/metrics") && body.contains("/status"), "{body}");
        // query strings route to the bare path
        let (head, _) = get(addr, "/metrics?format=prometheus");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        srv.shutdown();
    }

    #[test]
    fn non_get_methods_are_rejected() {
        let srv = HttpServer::spawn(
            "127.0.0.1:0",
            Router::new().route("/metrics", || Response::text(200, "x")),
        )
        .unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nContent-Length: 0\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");
    }

    #[test]
    fn shutdown_joins_the_server_thread() {
        let srv =
            HttpServer::spawn("127.0.0.1:0", Router::new().route("/", || Response::text(200, "")))
                .unwrap();
        let addr = srv.addr();
        srv.shutdown();
        // after shutdown the port stops accepting (connect may succeed
        // briefly on some platforms' backlog, but a fresh bind must work)
        let rebind = TcpListener::bind(addr);
        assert!(rebind.is_ok(), "port still held after shutdown");
    }
}
