//! Observability: low-overhead event tracing, metrics export, leveled
//! logging, and a live HTTP introspection endpoint.
//!
//! The paper's claims are about *wall-clock behavior* — staleness spikes,
//! seqlock conflict storms, load imbalance, wire-bit bursts — phenomena
//! that end-of-run counters average away. This module is the cross-cutting
//! layer that makes them visible, with zero new dependencies (hand-rolled
//! like [`crate::cluster::proto`]):
//!
//! * [`trace`] — per-worker lock-free ring buffers of typed spans
//!   (compute, merge, publish, seqlock retry, gossip tx/rx, heartbeat),
//!   drained post-run into Chrome trace-event JSON (`--trace-out`,
//!   loadable in Perfetto). Sampling via [`Sampler`] keeps the overhead
//!   within a few percent at full throughput.
//! * [`metrics`] — an in-process [`MetricsRegistry`] the executors publish
//!   into at a fixed cadence; rendered as Prometheus text to
//!   `--metrics-out` and the coordinator's `/metrics` endpoint.
//! * [`http`] — a minimal HTTP/1.1 server for the cluster coordinator's
//!   `/metrics`, `/status`, and `/trace` routes (`--metrics-addr`).
//! * [`log`] — the leveled event log (`--log-level`) every `eprintln!`
//!   diagnostic in the crate routes through.

pub mod http;
pub mod log;
pub mod metrics;
pub mod trace;

pub use http::{HttpServer, Response, Router};
pub use metrics::{AtomicHistogram, Counter, Gauge, MetricsRegistry};
pub use trace::{Sampler, SpanKind, TraceDrain, TraceEvent, TraceRing};

/// Default per-worker trace ring capacity when tracing is on: 64Ki events
/// × 32 bytes = 2 MiB per worker.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Cadence at which executors publish registry snapshots (and append to
/// `--metrics-out`).
pub const METRICS_CADENCE: std::time::Duration = std::time::Duration::from_millis(500);

/// Observability switches threaded into an executor run. `Default` is
/// everything off — the zero-overhead path.
#[derive(Clone, Debug, Default)]
pub struct ObsOptions {
    /// per-worker trace ring capacity in events; 0 disables tracing
    pub trace_capacity: usize,
    /// fraction of interactions traced, in (0, 1]; sampled per worker with
    /// a seed derived from the worker id (deterministic)
    pub trace_sample: f64,
    /// append Prometheus text snapshots here at [`METRICS_CADENCE`]
    pub metrics_out: Option<String>,
}

impl ObsOptions {
    pub fn tracing(&self) -> bool {
        self.trace_capacity > 0
    }

    /// The effective sampling rate (an unset 0.0 means "trace everything").
    pub fn sample_rate(&self) -> f64 {
        if self.trace_sample <= 0.0 {
            1.0
        } else {
            self.trace_sample.min(1.0)
        }
    }
}
