//! Per-worker lock-free trace ring buffers + Chrome trace-event export.
//!
//! A [`TraceRing`] is a fixed-capacity ring of 4-word slots claimed by a
//! relaxed `fetch_add` on a monotone head counter. Writers never block and
//! never allocate; when the ring wraps, the oldest events are overwritten
//! and counted as dropped — nothing is ever silently lost. Each executor
//! worker gets its own ring (sharing one epoch so timestamps align), and
//! the cluster worker threads share one ring (the true concurrent-writer
//! case the slot layout is designed for).
//!
//! Slots are plain `AtomicU64`s written with relaxed stores. Two writers
//! that race on a wrapped slot, or a mid-run drain racing a writer, can
//! observe a *torn* slot (words from two different events). Post-run
//! drains happen after the workers quiesce and are exact; the live
//! `/trace` endpoint is documented best-effort. A kind byte of 0 marks a
//! never-written slot, so partially filled rings drain cleanly.
//!
//! [`TraceDrain::to_chrome_json`] emits the Chrome trace-event format
//! (`chrome://tracing` / Perfetto): complete `"ph":"X"` events with
//! microsecond timestamps, `tid` = worker id.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::rngx::Pcg64;

/// What one trace event describes. Discriminants are packed into ring
/// slots; 0 is reserved for "empty slot", so kinds start at 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// local SGD steps of one interaction (freerun/cluster compute body)
    Compute = 1,
    /// pairwise quantize-average merge of two model payloads
    Merge = 2,
    /// seqlock publish of a merged payload (duration includes retries)
    Publish = 3,
    /// a seqlock read or publish attempt that had to retry; arg = retries
    SlotRetry = 4,
    /// one gossip frame written to a peer socket; arg = payload bytes
    GossipTx = 5,
    /// one gossip frame decoded off a peer socket; arg = payload bytes
    GossipRx = 6,
    /// a progress heartbeat sent to the coordinator
    Heartbeat = 7,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Merge => "merge",
            SpanKind::Publish => "publish",
            SpanKind::SlotRetry => "slot_retry",
            SpanKind::GossipTx => "gossip_tx",
            SpanKind::GossipRx => "gossip_rx",
            SpanKind::Heartbeat => "heartbeat",
        }
    }

    fn from_u8(v: u8) -> Option<SpanKind> {
        Some(match v {
            1 => SpanKind::Compute,
            2 => SpanKind::Merge,
            3 => SpanKind::Publish,
            4 => SpanKind::SlotRetry,
            5 => SpanKind::GossipTx,
            6 => SpanKind::GossipRx,
            7 => SpanKind::Heartbeat,
            _ => return None,
        })
    }
}

/// One decoded trace event. Timestamps are nanoseconds since the ring's
/// epoch (shared across all rings of one run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub kind: SpanKind,
    /// worker/thread id (`tid` in the Chrome export)
    pub worker: u32,
    pub t_start_ns: u64,
    pub dur_ns: u64,
    /// kind-specific payload (bytes, retries, partner id, ...)
    pub arg: u64,
}

/// One ring slot: kind|worker, start, duration, arg — all relaxed atomics
/// so concurrent writers and mid-run readers are race-free (if torn).
#[derive(Default)]
struct Slot {
    w: [AtomicU64; 4],
}

/// Fixed-capacity multi-writer trace ring. Capacity 0 is a fully disabled
/// ring: `record` is a no-op and `enabled()` lets hot loops skip the
/// timestamp capture too.
pub struct TraceRing {
    slots: Box<[Slot]>,
    /// monotone claim counter; `head % cap` is the next slot, anything
    /// beyond `cap` has overwritten (dropped) the oldest events
    head: AtomicU64,
    epoch: Instant,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing::with_epoch(capacity, Instant::now())
    }

    /// Build a ring against a caller-supplied epoch, so every ring of one
    /// run reports timestamps on the same axis.
    pub fn with_epoch(capacity: usize, epoch: Instant) -> TraceRing {
        let slots = (0..capacity).map(|_| Slot::default()).collect();
        TraceRing { slots, head: AtomicU64::new(0), epoch }
    }

    /// False for a capacity-0 ring — check before paying for `Instant`s.
    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    /// Nanoseconds since this ring's epoch (the `t_start_ns` clock).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one event. Lock-free, allocation-free, wait-free: one
    /// `fetch_add` plus four relaxed stores.
    pub fn record(&self, kind: SpanKind, worker: u32, t_start_ns: u64, dur_ns: u64, arg: u64) {
        if self.slots.is_empty() {
            return;
        }
        let i = (self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64) as usize;
        let s = &self.slots[i];
        s.w[1].store(t_start_ns, Ordering::Relaxed);
        s.w[2].store(dur_ns, Ordering::Relaxed);
        s.w[3].store(arg, Ordering::Relaxed);
        // kind word last: a drain racing this write classifies the slot by
        // its kind byte, so stale kinds are likelier than phantom ones
        s.w[0].store(kind as u64 | (worker as u64) << 8, Ordering::Relaxed);
    }

    /// Convenience: record a span that started at `t_start_ns` and ends
    /// now.
    pub fn span(&self, kind: SpanKind, worker: u32, t_start_ns: u64, arg: u64) {
        let now = self.now_ns();
        self.record(kind, worker, t_start_ns, now.saturating_sub(t_start_ns), arg);
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events lost to ring wraparound so far.
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.slots.len() as u64)
    }

    /// Decode the currently retained events (unordered). Exact after the
    /// writers quiesce; best-effort while they run.
    pub fn events(&self) -> Vec<TraceEvent> {
        let retained = (self.total().min(self.slots.len() as u64)) as usize;
        let mut out = Vec::with_capacity(retained);
        for s in self.slots.iter() {
            let w0 = s.w[0].load(Ordering::Relaxed);
            let Some(kind) = SpanKind::from_u8(w0 as u8) else { continue };
            out.push(TraceEvent {
                kind,
                worker: (w0 >> 8) as u32,
                t_start_ns: s.w[1].load(Ordering::Relaxed),
                dur_ns: s.w[2].load(Ordering::Relaxed),
                arg: s.w[3].load(Ordering::Relaxed),
            });
        }
        out
    }
}

/// The merged result of draining every ring of a run: time-ordered events
/// plus the loss accounting (drops are counted, never hidden).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceDrain {
    /// all retained events, sorted by start time
    pub events: Vec<TraceEvent>,
    /// events ever recorded across the rings
    pub total: u64,
    /// events lost to wraparound across the rings
    pub dropped: u64,
}

impl TraceDrain {
    /// Drain and merge a set of rings into one time-sorted event list.
    pub fn from_rings<'a>(rings: impl IntoIterator<Item = &'a TraceRing>) -> TraceDrain {
        let mut d = TraceDrain::default();
        for r in rings {
            d.events.extend(r.events());
            d.total += r.total();
            d.dropped += r.dropped();
        }
        d.events.sort_by_key(|e| (e.t_start_ns, e.worker));
        d
    }

    /// Serialize to Chrome trace-event JSON (the object form, loadable in
    /// `chrome://tracing` and Perfetto). Timestamps convert to the
    /// format's microsecond unit.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"otherData\":{\"total\":");
        out.push_str(&self.total.to_string());
        out.push_str(",\"dropped\":");
        out.push_str(&self.dropped.to_string());
        out.push_str("},\"traceEvents\":[");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"swarm\",\"ph\":\"X\",\"ts\":{:.3},\
                 \"dur\":{:.3},\"pid\":0,\"tid\":{},\"args\":{{\"v\":{}}}}}",
                e.kind.name(),
                e.t_start_ns as f64 / 1_000.0,
                e.dur_ns as f64 / 1_000.0,
                e.worker,
                e.arg,
            ));
        }
        out.push_str("]}\n");
        out
    }
}

/// Deterministic Bernoulli sampler for the trace-everything-is-too-much
/// case: `hit()` answers "trace this interaction?" at the configured rate,
/// reproducibly for a fixed seed (one sampler per worker, seeded from the
/// worker's id).
#[derive(Clone, Debug)]
pub struct Sampler {
    rng: Pcg64,
    /// accept when the next draw is below this; `u64::MAX` short-circuits
    /// the draw entirely (rate 1.0 must not perturb the RNG stream)
    threshold: u64,
}

impl Sampler {
    pub fn new(rate: f64, seed: u64) -> Sampler {
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else if rate <= 0.0 {
            0
        } else {
            (rate * u64::MAX as f64) as u64
        };
        Sampler { rng: Pcg64::seed(seed), threshold }
    }

    pub fn hit(&mut self) -> bool {
        self.threshold == u64::MAX || self.rng.next_u64() < self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_drains_in_order() {
        let r = TraceRing::new(8);
        r.record(SpanKind::Compute, 3, 100, 10, 0);
        r.record(SpanKind::Publish, 3, 200, 5, 2);
        let d = TraceDrain::from_rings([&r]);
        assert_eq!(d.total, 2);
        assert_eq!(d.dropped, 0);
        assert_eq!(d.events.len(), 2);
        assert_eq!(d.events[0].kind, SpanKind::Compute);
        assert_eq!(d.events[1].t_start_ns, 200);
        assert_eq!(d.events[1].worker, 3);
    }

    #[test]
    fn wraparound_counts_drops_instead_of_losing_them() {
        let r = TraceRing::new(4);
        for i in 0..10u64 {
            r.record(SpanKind::Merge, 0, i, 1, i);
        }
        assert_eq!(r.total(), 10);
        assert_eq!(r.dropped(), 6);
        let d = TraceDrain::from_rings([&r]);
        assert_eq!(d.events.len(), 4, "ring retains exactly its capacity");
        assert_eq!(d.total, 10);
        assert_eq!(d.dropped, 6);
        // the survivors are the newest four
        let args: Vec<u64> = d.events.iter().map(|e| e.arg).collect();
        assert_eq!(args, vec![6, 7, 8, 9]);
    }

    #[test]
    fn disabled_ring_is_a_no_op() {
        let r = TraceRing::new(0);
        assert!(!r.enabled());
        r.record(SpanKind::Compute, 0, 1, 1, 1);
        assert_eq!(r.total(), 0);
        assert!(TraceDrain::from_rings([&r]).events.is_empty());
    }

    #[test]
    fn concurrent_writers_account_for_every_event() {
        let r = TraceRing::new(1 << 14);
        const WRITERS: u32 = 4;
        const EACH: u64 = 1_000;
        std::thread::scope(|s| {
            for w in 0..WRITERS {
                let r = &r;
                s.spawn(move || {
                    for i in 0..EACH {
                        r.record(SpanKind::Compute, w, i, 1, i);
                    }
                });
            }
        });
        let d = TraceDrain::from_rings([&r]);
        assert_eq!(d.total, WRITERS as u64 * EACH);
        assert_eq!(d.dropped, 0, "ring is large enough to retain everything");
        assert_eq!(d.events.len(), (WRITERS as u64 * EACH) as usize);
        // every writer's full sequence must be present (nothing lost)
        for w in 0..WRITERS {
            let mut args: Vec<u64> =
                d.events.iter().filter(|e| e.worker == w).map(|e| e.arg).collect();
            args.sort_unstable();
            assert_eq!(args, (0..EACH).collect::<Vec<_>>(), "writer {w}");
        }
    }

    #[test]
    fn sampler_is_deterministic_for_a_fixed_seed() {
        let draws = |rate: f64, seed: u64| {
            let mut s = Sampler::new(rate, seed);
            (0..256).map(|_| s.hit()).collect::<Vec<bool>>()
        };
        assert_eq!(draws(0.25, 7), draws(0.25, 7), "same seed, same decisions");
        assert_ne!(draws(0.25, 7), draws(0.25, 8), "different seed diverges");
        assert!(draws(1.0, 1).iter().all(|&b| b), "rate 1.0 always hits");
        assert!(!draws(0.0, 1).iter().any(|&b| b), "rate 0.0 never hits");
        let hits = draws(0.25, 42).iter().filter(|&&b| b).count();
        assert!((32..96).contains(&hits), "rate 0.25 over 256 draws gave {hits}");
    }

    #[test]
    fn chrome_json_has_the_trace_event_shape() {
        let r = TraceRing::new(8);
        r.record(SpanKind::GossipTx, 1, 1_500, 2_000, 64);
        let json = TraceDrain::from_rings([&r]).to_chrome_json();
        assert!(json.contains("\"traceEvents\":["), "{json}");
        assert!(json.contains("\"name\":\"gossip_tx\""), "{json}");
        assert!(json.contains("\"ph\":\"X\""), "{json}");
        assert!(json.contains("\"ts\":1.500"), "µs conversion: {json}");
        assert!(json.contains("\"dur\":2.000"), "µs conversion: {json}");
        assert!(json.contains("\"tid\":1"), "{json}");
    }
}
