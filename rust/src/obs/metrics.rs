//! In-process metrics registry with Prometheus text exposition.
//!
//! Hot paths hold a [`Counter`] or [`Gauge`] handle (one `Arc<AtomicU64>`
//! each — updates are a relaxed atomic op, no lock); the registry mutex is
//! only taken at registration and render time. [`MetricsRegistry::render`]
//! emits the Prometheus text format (`# HELP` / `# TYPE` / sample lines),
//! served live by the coordinator's `/metrics` endpoint and appended
//! periodically to `--metrics-out` as a poor man's time series.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone counter. Clone freely — clones share the cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Counters are monotone on the wire, but the publishers here re-derive
    /// totals from executor state each cadence — `set` keeps that cheap.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

/// Last-value gauge storing an `f64` as its bit pattern.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
}

struct Entry {
    name: String,
    help: String,
    kind: Kind,
    cell: Arc<AtomicU64>,
}

/// Named-metric registry. Clones share the underlying table, so the
/// executor, its monitor thread, and an HTTP server can all hold one.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Vec<Entry>>>,
}

/// Gauge sample formatting: integral values render without a fraction,
/// which keeps the text diff-friendly and parseable either way.
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

/// Prometheus metric-name grammar: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn valid_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn register(&self, name: &str, help: &str, kind: Kind) -> Arc<AtomicU64> {
        assert!(valid_name(name), "invalid metric name '{name}'");
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.iter().find(|e| e.name == name) {
            assert!(e.kind == kind, "metric '{name}' re-registered with a different type");
            return e.cell.clone();
        }
        let cell = Arc::new(AtomicU64::new(0));
        inner.push(Entry { name: name.into(), help: help.into(), kind, cell: cell.clone() });
        cell
    }

    /// Register (or look up) a counter. Same name twice returns the same
    /// cell; same name as a gauge panics — that's a programming error.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        Counter(self.register(name, help, Kind::Counter))
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        Gauge(self.register(name, help, Kind::Gauge))
    }

    /// Render every metric in Prometheus text exposition format, in
    /// registration order.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for e in inner.iter() {
            if !e.help.is_empty() {
                out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            }
            let (ty, value) = match e.kind {
                Kind::Counter => ("counter", e.cell.load(Ordering::Relaxed).to_string()),
                Kind::Gauge => ("gauge", fmt_value(f64::from_bits(e.cell.load(Ordering::Relaxed)))),
            };
            out.push_str(&format!("# TYPE {} {ty}\n{} {value}\n", e.name, e.name));
        }
        out
    }
}

/// Append one rendered snapshot to `f`, preceded by a scrape-separator
/// comment carrying the unix timestamp in milliseconds — a `--metrics-out`
/// file is a sequence of these blocks, a poor man's time series that stays
/// parseable as Prometheus text (separators are comments).
pub fn append_snapshot(f: &mut std::fs::File, registry: &MetricsRegistry) -> std::io::Result<()> {
    use std::io::Write;
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    write!(f, "# scrape ts_ms={ts}\n{}", registry.render())
}

/// Lock-free log2-bucketed histogram for *live* quantile gauges: exact
/// counts, power-of-two value resolution. The executors' exact
/// [`crate::coordinator::StalenessHistogram`]s stay worker-local and merge
/// at join; this one is shared and written concurrently, trading value
/// resolution for a wait-free `record`.
#[derive(Debug)]
pub struct AtomicHistogram {
    /// bucket `b` holds values in `[2^(b-1), 2^b)`; bucket 0 holds 0
    buckets: [AtomicU64; 64],
}

impl Default for AtomicHistogram {
    // std's array Default stops at 32 elements, so spelled out
    fn default() -> AtomicHistogram {
        AtomicHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram::default()
    }

    pub fn record(&self, v: u64) {
        let b = (64 - v.leading_zeros()).min(63) as usize;
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile: the upper bound of the bucket where the
    /// cumulative count crosses `q` (so the true value is ≤ the answer,
    /// within a factor of 2). Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            seen += bucket.load(Ordering::Relaxed);
            if seen >= target {
                return if b == 0 { 0 } else { (1u64 << b) - 1 };
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_prometheus_text() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("swarm_interactions_total", "interactions completed");
        let g = reg.gauge("swarm_staleness_p99", "p99 staleness in interactions");
        c.add(41);
        c.inc();
        g.set(7.5);
        let text = reg.render();
        assert!(text.contains("# TYPE swarm_interactions_total counter"), "{text}");
        assert!(text.contains("swarm_interactions_total 42"), "{text}");
        assert!(text.contains("# TYPE swarm_staleness_p99 gauge"), "{text}");
        assert!(text.contains("swarm_staleness_p99 7.5"), "{text}");
        // every non-comment line is exactly `name value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut it = line.split_whitespace();
            assert!(valid_name(it.next().unwrap()), "{line}");
            assert!(it.next().unwrap().parse::<f64>().is_ok(), "{line}");
            assert!(it.next().is_none(), "{line}");
        }
    }

    #[test]
    fn reregistration_returns_the_same_cell() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("swarm_x", "");
        let b = reg.counter("swarm_x", "ignored on re-register");
        a.add(3);
        assert_eq!(b.get(), 3);
        assert_eq!(reg.render().matches("# TYPE swarm_x").count(), 1);
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn kind_conflict_panics() {
        let reg = MetricsRegistry::new();
        let _c = reg.counter("swarm_y", "");
        let _g = reg.gauge("swarm_y", "");
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn invalid_name_panics() {
        MetricsRegistry::new().counter("9starts-with-digit", "");
    }

    #[test]
    fn integral_gauges_render_without_fraction() {
        let reg = MetricsRegistry::new();
        reg.gauge("swarm_workers", "").set(3.0);
        assert!(reg.render().contains("swarm_workers 3\n"));
    }

    #[test]
    fn atomic_histogram_quantiles_bound_the_true_value() {
        let h = AtomicHistogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        for v in [0u64, 1, 1, 2, 3, 4, 7, 8, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        let p50 = h.quantile(0.5);
        // the true p50 is 3; the log2 bucket upper bound for [2,4) is 3
        assert!((3..=7).contains(&p50), "p50 bucket bound was {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= 1000 && p99 < 2048, "p99 bucket bound was {p99}");
        assert_eq!(h.quantile(0.0), 0, "q=0 lands in the lowest bucket");
    }
}
