//! Leveled structured event log: the one chokepoint every diagnostic in
//! the crate routes through, so `--log-level warn` can silence info-level
//! chatter in CI runs without touching call sites.
//!
//! Zero dependencies and zero allocation on the disabled path: callers
//! pass `format_args!(..)`, so a filtered-out message never formats.
//! Output goes to stderr — stdout stays reserved for the machine-parsed
//! protocol lines (coordinator address, checkpoint markers, final report).

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severities, most severe first. The active level admits itself and
/// everything more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a CLI/config spelling. The error names every accepted value.
    pub fn parse(s: &str) -> Result<Level, String> {
        match s {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown log level '{other}' (expected error | warn | info | debug)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // f.pad (not write_str) so the `{level:5}` column format in `log`
        // actually pads
        f.pad(self.name())
    }
}

/// Process-global active level (default `info`, matching the pre-obs
/// behavior where every diagnostic printed unconditionally).
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the active level (normally once, from `--log-level`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The currently active level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Would a message at `l` be emitted right now?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Monotonic epoch for the relative timestamps (first use wins, so all
/// threads share one origin).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Emit one structured line: `[  12.345s level target] message`.
pub fn log(l: Level, target: &str, msg: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = epoch().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {l:5} {target}] {msg}");
}

pub fn error(target: &str, msg: fmt::Arguments<'_>) {
    log(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: fmt::Arguments<'_>) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: fmt::Arguments<'_>) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: fmt::Arguments<'_>) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_roundtrip() {
        for (s, l) in [
            ("error", Level::Error),
            ("warn", Level::Warn),
            ("warning", Level::Warn),
            ("info", Level::Info),
            ("debug", Level::Debug),
        ] {
            assert_eq!(Level::parse(s).unwrap(), l);
        }
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()).unwrap(), l);
        }
        let err = Level::parse("verbose").unwrap_err();
        assert!(err.contains("verbose") && err.contains("debug"), "{err}");
    }

    #[test]
    fn severity_ordering_gates_enabled() {
        // Error is admitted at every level; Debug only at Debug. Uses the
        // Ord on Level directly rather than mutating the global level,
        // which other tests in the process may be relying on.
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
