//! The compute-backend abstraction every coordinator algorithm runs against.
//!
//! Two families implement it (DESIGN.md §3 "dual backend"):
//!   * [`crate::runtime::XlaBackend`] — the real three-layer path: per-agent
//!     minibatches fed into the AOT-compiled JAX+Pallas train step via PJRT.
//!   * [`crate::grad`] oracles — pure-Rust objectives (quadratic, logistic,
//!     softmax-linear) for theory figures, property tests, and large-n
//!     sweeps where XLA dispatch would dominate.
//!
//! The coordinator only ever sees flat `f32` model vectors — the paper's
//! model-space view (models are points in R^d that get averaged).

/// Held-out evaluation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    /// classification accuracy in [0,1] (token accuracy for LMs);
    /// NaN when the objective has no accuracy notion (quadratic).
    pub accuracy: f64,
}

/// A training backend: owns the data shards and the step/eval computation.
/// `agent` indexes the shard (non-iid support); parameters live with the
/// caller so the coordinator fully controls averaging/quantization.
pub trait TrainBackend {
    /// Dimension `d` of the flat model vector.
    fn param_count(&self) -> usize;

    /// Fresh (params, momentum) for a given seed. All agents start from the
    /// same point in the paper (x_0 arbitrary but common); callers pass the
    /// same seed to every agent for that behaviour.
    fn init(&mut self, seed: i64) -> (Vec<f32>, Vec<f32>);

    /// One local SGD step for `agent` on its own shard: updates `params`
    /// and `mom` in place, returns the minibatch training loss.
    fn step(&mut self, agent: usize, params: &mut [f32], mom: &mut [f32], lr: f32) -> f64;

    /// `h` consecutive local steps (the paper's local-update phase).
    /// Backends may fuse these (the XLA backend dispatches a single
    /// lax.scan executable per `k` steps); the default just loops.
    /// Returns the last minibatch loss.
    fn step_burst(
        &mut self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        h: u64,
    ) -> f64 {
        let mut last = f64::NAN;
        for _ in 0..h {
            last = self.step(agent, params, mom, lr);
        }
        last
    }

    /// Evaluate `params` on the backend's held-out set.
    fn eval(&mut self, params: &[f32]) -> EvalResult;

    /// Exact/full training objective `f(x)` if cheaply available
    /// (oracles: yes; XLA models: sampled estimate).
    fn full_loss(&mut self, params: &[f32]) -> f64 {
        self.eval(params).loss
    }

    /// Squared norm of the true gradient at `params`, if the backend can
    /// compute it (theory figures); `None` otherwise.
    fn grad_norm_sq(&mut self, _params: &[f32]) -> Option<f64> {
        None
    }

    /// Fractional data epochs consumed by `agent` so far.
    fn epochs(&self, _agent: usize) -> f64 {
        0.0
    }
}

/// A thread-safe training backend for the shared-memory parallel executor
/// ([`crate::coordinator::run_parallel`]).
///
/// Differs from [`TrainBackend`] in two load-bearing ways:
///
/// * every method takes `&self` and the trait requires `Sync`, so N worker
///   threads can step different agents concurrently without a global lock;
/// * all randomness (gradient noise, batch draws) comes from the
///   caller-supplied `rng` — the executor hands each node its own
///   [`Pcg64::stream`], which is what makes a parallel run independent of
///   thread interleaving and hence serially replayable bit-for-bit.
///
/// Method names deliberately do not collide with [`TrainBackend`] so a type
/// can implement both and call sites stay unambiguous.
pub trait SyncBackend: Sync {
    /// Dimension `d` of the flat model vector.
    fn dim(&self) -> usize;

    /// The common starting point (params, momentum) — the paper's shared x₀.
    fn common_init(&self) -> (Vec<f32>, Vec<f32>);

    /// One local SGD step for `agent`, drawing all stochasticity from `rng`.
    /// Returns the minibatch training loss.
    fn step_with(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut crate::rngx::Pcg64,
    ) -> f64;

    /// Evaluate `params` on the backend's held-out objective.
    fn eval_at(&self, params: &[f32]) -> EvalResult;
}
