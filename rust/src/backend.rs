//! The single compute-backend abstraction every coordinator algorithm runs
//! against (PR 2 collapsed the former `TrainBackend`/`SyncBackend` duality).
//!
//! Two families implement it (DESIGN.md §3 "dual backend"):
//!   * [`crate::runtime::XlaBackend`] — the real three-layer path: per-agent
//!     minibatches fed into the AOT-compiled JAX+Pallas train step via PJRT.
//!   * [`crate::grad`] oracles — pure-Rust objectives (quadratic, logistic,
//!     softmax-linear) for theory figures, property tests, and large-n
//!     sweeps where XLA dispatch would dominate.
//!
//! # Contract
//!
//! * Every method takes `&self` and the trait requires `Sync`, so the
//!   shared-memory parallel executor can step different agents from N
//!   worker threads without a global lock.
//! * **All stochasticity** (gradient noise, batch draws) comes from the
//!   caller-supplied [`Pcg64`] — the executor hands each node its own
//!   [`Pcg64::stream`], which is what makes a parallel run independent of
//!   thread interleaving and hence bit-identical to its serial replay
//!   (the PR-1 replay-determinism contract).
//! * The coordinator only ever sees flat `f32` model vectors — the paper's
//!   model-space view (models are points in R^d that get averaged).

use crate::rngx::Pcg64;

/// Held-out evaluation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    /// classification accuracy in [0,1] (token accuracy for LMs);
    /// NaN when the objective has no accuracy notion (quadratic).
    pub accuracy: f64,
}

/// A training backend: owns the data shards and the step/eval computation.
/// `agent` indexes the shard (non-iid support); parameters live with the
/// caller so the coordinator fully controls averaging/quantization.
pub trait Backend: Sync {
    /// Dimension `d` of the flat model vector.
    fn dim(&self) -> usize;

    /// The common starting point (params, momentum) — the paper's shared x₀.
    /// Deterministic per backend instance, so every agent starts identical.
    fn init(&self) -> (Vec<f32>, Vec<f32>);

    /// One local SGD step for `agent` on its own shard, drawing all
    /// stochasticity from `rng`: updates `params` and `mom` in place and
    /// returns the minibatch training loss.
    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64;

    /// `h` consecutive local steps (the paper's local-update phase).
    /// Backends may fuse these (the XLA backend dispatches a single
    /// lax.scan executable per `k` steps); the default just loops.
    /// Returns the last minibatch loss.
    fn step_burst(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        h: u64,
        rng: &mut Pcg64,
    ) -> f64 {
        let mut last = f64::NAN;
        for _ in 0..h {
            last = self.step(agent, params, mom, lr, rng);
        }
        last
    }

    /// Evaluate `params` on the backend's held-out set.
    fn eval(&self, params: &[f32]) -> EvalResult;

    /// Exact/full training objective `f(x)` if cheaply available
    /// (oracles: yes; XLA models: sampled estimate).
    fn full_loss(&self, params: &[f32]) -> f64 {
        self.eval(params).loss
    }

    /// Squared norm of the true gradient at `params`, if the backend can
    /// compute it (theory figures); `None` otherwise.
    fn grad_norm_sq(&self, _params: &[f32]) -> Option<f64> {
        None
    }

    /// Fractional data epochs consumed by `agent` after `steps` local
    /// steps. Stateless because the backend no longer owns cursors: the
    /// executor tracks per-node step counts and asks for the conversion.
    fn epochs(&self, _agent: usize, _steps: u64) -> f64 {
        0.0
    }
}

#[allow(dead_code)]
fn _assert_backend_object_safe(_: &dyn Backend) {}

/// The `oracle:quadratic` preset — single definition (dim 64, σ = 0.2) so
/// every executor, every cluster process role, and the integration tests
/// train the *identical* objective for a given `(n, seed)`.
pub fn quadratic_preset(cfg: &crate::config::RunConfig) -> crate::grad::QuadraticOracle {
    crate::grad::QuadraticOracle::new(64, cfg.n, 1.0, 0.5, 2.0, 0.2, cfg.seed)
}

/// The `oracle:quadratic-proc` preset — the table-free twin of
/// `oracle:quadratic` with the *same* constants, for the scale regime
/// where the dense oracle's `d`/`c` tables (agents × dim × 16 bytes —
/// ~1 GiB at n = 1M) would dominate memory. Same step math; global
/// statistics are sampled above [`crate::grad::EVAL_AGENT_SAMPLE`] agents.
pub fn proc_quadratic_preset(
    cfg: &crate::config::RunConfig,
) -> crate::grad::ProcQuadraticOracle {
    crate::grad::ProcQuadraticOracle::new(64, cfg.n, 1.0, 0.5, 2.0, 0.2, cfg.seed)
}

/// Build the backend a config names: an `oracle:*` gradient oracle or the
/// PJRT artifact path. Lives in the library (not the CLI binary) because
/// the cluster executor's worker processes rebuild their backend from a
/// config received over the wire.
pub fn build_backend(
    cfg: &crate::config::RunConfig,
) -> Result<Box<dyn Backend>, String> {
    use crate::runtime::{XlaBackend, XlaBackendConfig};
    if let Some(kind) = cfg.preset.strip_prefix("oracle:") {
        return Ok(match kind {
            "quadratic" => Box::new(quadratic_preset(cfg)),
            "quadratic-proc" => Box::new(proc_quadratic_preset(cfg)),
            "softmax" => Box::new(crate::grad::SoftmaxOracle::synthetic(
                cfg.data_per_agent * cfg.n,
                32,
                10,
                cfg.n,
                32,
                4.0,
                cfg.seed,
            )),
            "logistic" => Box::new(crate::grad::LogisticOracle::synthetic(
                cfg.data_per_agent * cfg.n,
                16,
                cfg.n,
                32,
                cfg.shard == crate::config::ShardMode::Iid,
                cfg.seed,
            )),
            k => {
                return Err(format!(
                    "unknown oracle '{k}' (known: quadratic, quadratic-proc, \
                     softmax, logistic)"
                ))
            }
        });
    }
    let xcfg = XlaBackendConfig {
        agents: cfg.n,
        data_per_agent: cfg.data_per_agent,
        shard: cfg.shard,
        separation: 3.0,
        seed: cfg.seed,
        eval_batches: 2,
    };
    Ok(Box::new(
        XlaBackend::load(std::path::Path::new(&cfg.artifacts_dir), &cfg.preset, xcfg)
            .map_err(|e| format!("{e:#}"))?,
    ))
}
