//! Hand-rolled CLI argument parsing (offline build: no clap).
//!
//! Grammar: `swarm <subcommand> [--flag value] [--bool-flag] ...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub subcommand: String,
    flags: HashMap<String, String>,
    /// positional arguments after the subcommand
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse `args` (excluding argv[0]). Flags may be `--k v` or `--k=v`;
    /// a flag followed by another flag (or end) is boolean `true`.
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                cli.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            cli.flags.insert(flag.to_string(), it.next().unwrap().clone());
                        }
                        _ => {
                            cli.flags.insert(flag.to_string(), "true".to_string());
                        }
                    }
                }
            } else if a.starts_with('-') && a.len() > 1 {
                return Err(format!("unknown short flag '{a}' (use --long flags)"));
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn parse_flag<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("flag --{key}: cannot parse '{v}'")),
        }
    }

    /// All `--set k=v` style repeated overrides (we accept `--set` once with
    /// comma separation: `--set n=8,h=3`).
    pub fn overrides(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        if let Some(sets) = self.get("set") {
            for pair in sets.split(',') {
                if let Some((k, v)) = pair.split_once('=') {
                    out.push((k.trim().to_string(), v.trim().to_string()));
                }
            }
        }
        out
    }
}

pub const USAGE: &str = "\
swarm — SwarmSGD: decentralized SGD with asynchronous, local & quantized updates
        (reproduction of Nadiradze et al., NeurIPS 2021)

USAGE:
  swarm train   [--config run.ini] [--set k=v,k=v] [--quick]
                [--algorithm swarm|poisson|adpsgd|dpsgd|sgp|localsgd|allreduce]
                [--executor serial|parallel|freerun|cluster]
                [--threads K] [--shards S]
                [--wire lattice|f32] [--kernel scalar|simd]
                [--topology complete|ring|torus|hypercube|regular<r>|
                            powerlaw[<m>]|expander[<r>]]
                [--speeds uniform|bimodal:<frac>:<slowdown>|pareto:<alpha>]
                [--dirichlet ALPHA] [--directed]
                [--topology-schedule topo@0,topo@T1,...]
                [--churn join:<rate>,leave:<rate>]
                [--node-store auto|dense|compact] [--node-budget BYTES]
                [--role coordinator|worker] [--listen HOST:PORT]
                [--connect HOST:PORT] [--workers W] [--heartbeat-timeout S]
                [--checkpoint-dir DIR] [--throttle-us U]
                [--trace-out F.json] [--trace-sample P] [--metrics-out F]
                [--metrics-addr HOST:PORT]
                [--log-level error|warn|info|debug]
                train one algorithm on one backend; keys: algo, preset, n,
                topology, interactions, h, geometric, mode, wire, quant_bits,
                quant_eps, lr, lr_schedule, seed, eval_every, track_gamma,
                shard, data_per_agent, artifacts_dir, batch_time, jitter,
                straggler_prob, straggle_factor, latency, bandwidth,
                model_bytes, out_csv, executor, threads, shards, kernel,
                workers, heartbeat_timeout, trace_out, trace_sample,
                metrics_out, metrics_addr, log_level, speeds, directed,
                dirichlet, topology_schedule, churn, node_store, node_budget
                --algorithm picks the training process (SwarmSGD or any §5
                baseline) and is orthogonal to --executor: every algorithm
                runs on the serial discrete-event executor AND on K
                shared-memory worker threads (K=0: one per core). Gossip
                algorithms schedule 2-node events; the round-based
                baselines schedule *phased* rounds (n per-node compute
                events + one mix barrier), so all seven genuinely
                parallelize. For the oracle:* presets the same seed
                produces bit-identical metrics on both replay executors at
                any thread count (the replay-determinism contract; the
                PJRT path's fused-step heuristic is wall-clock-raced, so
                it is excluded).
                --executor freerun (algorithms with a MixPolicy: swarm,
                poisson, adpsgd, dpsgd, and sgp via weighted push-sum
                slots) drops the schedule: K workers own S node shards
                (omit --shards for one per worker; n >> cores supported),
                ring live Poisson clocks, and merge against non-blocking
                seqlock slot payloads per the algorithm's policy.
                Non-replayable by contract — in exchange it measures real
                interactions/s, per-interaction staleness (version lag),
                seqlock contention, worker busy/wait, and the wire codec's
                bit/fallback attribution. localsgd/allreduce mix through
                an irreducible global mean and refuse freerun.
                THE SCALE REGIME: above 65536 nodes (node_store=auto), on
                any --churn, or with --node-store compact, freerun routes
                to the membership scale engine: per-node models rest
                lattice-encoded in a compact NodeStore (~200 bytes/node at
                d=64; --node-budget B fails fast, pre-allocation, if the
                per-node footprint would exceed B bytes), partner draws
                are procedural (O(1), no materialized graph — complete,
                ring, torus, hypercube, expander[<r>]), and
                --churn join:<rate>,leave:<rate> runs a live birth-death
                roster: leavers' slots recycle under fresh generations,
                joiners bootstrap from a live neighbor's snapshot, and the
                stationary live count is n*min(1, join/leave). Rates are
                per-event weights, >= 0 and finite. Pair with
                preset=oracle:quadratic-proc (the table-free oracle) to
                keep the backend O(1)-resident too; n=1,000,000 fits in a
                few hundred MB. --node-store dense opts back out at any n
                (but conflicts with --churn). sgp's weighted payloads and
                --trace-out/--topology-schedule/--directed stay on the
                dense executors.
                --executor cluster runs the freerun protocol across OS
                processes: start ONE coordinator (--role coordinator
                --listen HOST:PORT; PORT 0 picks an ephemeral port, printed
                on stdout), then `workers` workers (--role worker --connect
                HOST:PORT). The coordinator assigns node shards, ships the
                run config over the wire (worker-side --set is ignored),
                aggregates streamed progress, checkpoints to
                --checkpoint-dir, and on a missed --heartbeat-timeout (s)
                reassigns the dead worker's shard from its last checkpoint.
                Workers gossip WireCodec-encoded payloads peer-to-peer over
                TCP, so wire bits are MEASURED from the socket — the
                simulated-wire knobs (latency, bandwidth, model_bytes) are
                ignored with a warning. Same eligibility as freerun;
                non-replayable, statistical assertions only.
                --wire lattice|f32 picks the wire codec on EVERY executor:
                lattice sends model payloads through the Appendix-G
                lattice quantizer (quant_bits/quant_eps; decode fallbacks
                counted), f32 is full precision. mode=quantized is the
                swarm/poisson spelling of nonblocking+lattice and takes
                precedence over --wire f32 (the default) — to run full
                precision, set mode=nonblocking. localsgd and allreduce
                (full-precision collectives) reject lattice.
                The scenario axis shapes the run environment on EVERY
                executor. --topology constrains partner sampling to a
                graph family: complete, ring, torus (square n), hypercube
                (power-of-two n), regular<r> (random r-regular, n*r even),
                powerlaw[<m>] (connected preferential attachment, m edges
                per new node, default 2), expander[<r>] (random circulant
                of even degree r, default 8 — spectral-gap-certified at
                small n, procedural at scale); infeasible topology/n
                combos are rejected up front with an actionable error. --speeds maps
                per-node speed classes onto the Poisson clock rates:
                bimodal:<frac>:<slowdown> slows round(n*frac) nodes by
                <slowdown> (>= 1), pareto:<alpha> draws heavy-tailed
                slowdowns — structural stragglers whose staleness the
                freerun/cluster telemetry measures. --dirichlet ALPHA is
                shorthand for shard=dirichlet:<alpha> (label-skewed data
                assignment; small alpha = near single-label nodes).
                --directed (sgp only, complete|ring|torus) orients the
                gossip graph so push targets follow arcs.
                --topology-schedule ring@0,torus@5000,... switches the
                graph at event-index boundaries (first stage at @0,
                strictly increasing). The default scenario (uniform
                speeds, one static undirected graph) is bit-identical to
                the legacy path, so serial/parallel replay goldens hold.
                --kernel scalar|simd picks the fused quantize-average
                merge-kernel implementation on every executor: scalar is
                the one-element-at-a-time reference, simd processes
                8-element chunks the compiler auto-vectorizes. Both are
                bit-exact (identical per-lane math, checksums folded in
                element order), so this is a pure performance axis; the
                choice is tagged in the run summary and bench rows.
                Observability (freerun + cluster): --trace-out writes a
                Chrome trace-event JSON (chrome://tracing / Perfetto) of
                per-worker compute/merge/publish/retry/gossip spans, drained
                from lock-free rings after the run (cluster workers write
                F.rank<R>.json); --trace-sample P traces each interaction
                with probability P in [0, 1] (deterministic per worker;
                default 1 = every interaction, 0 = tracing off; out-of-range
                values are rejected). --metrics-out appends
                Prometheus text snapshots (throughput, staleness p50/p99,
                wire bits, contention) every 500ms. --metrics-addr serves
                the cluster coordinator's live introspection endpoint over
                plain HTTP/1.1 (GET /metrics Prometheus text, /status JSON
                with per-worker shard/liveness/heartbeat-RTT/progress-age,
                /trace drain-so-far; no auth/TLS — bind loopback). The
                chosen address is printed on stdout as
                'cluster metrics serving on HOST:PORT'. --log-level gates
                the leveled stderr diagnostics (default info); stdout
                protocol lines are never filtered.
  swarm figure  --id <table1|table2|fig1a|fig1b|fig2a|fig2b|fig3a|fig5|
                      fig6a|fig6b|fig7|fig8a|fig8b|gamma|all>
                [--quick] [--out results]
                regenerate a paper table/figure (prints rows + writes CSV)
  swarm inspect [--artifacts artifacts]
                list compiled artifacts and their metadata
  swarm topo    --n <n> [--topology complete|ring|torus|hypercube|random<r>|
                         regular<r>|powerlaw[<m>]]
                print graph stats (degree, edges, connectivity, lambda2,
                spectral gap, theory factors); validates topology/n
                feasibility with the same errors train uses
  swarm help    show this message

EXAMPLES:
  swarm train --set algo=swarm,preset=mlp_s,n=8,h=3,interactions=400
  swarm train --algorithm adpsgd --set preset=oracle:quadratic,n=16
  swarm train --algorithm sgp --executor parallel --threads 4 \\
              --set preset=oracle:softmax,n=8,interactions=200
  swarm train --algorithm swarm --executor freerun --threads 4 --shards 16 \\
              --set preset=oracle:quadratic,n=64,interactions=20000
  swarm train --algorithm sgp --executor freerun --threads 4 --wire lattice \\
              --set preset=oracle:quadratic,n=32,interactions=5000
  swarm train --set preset=oracle:quadratic,model_bytes=45000000,latency=1e-4
  swarm train --algorithm swarm --executor freerun --threads 4 \\
              --trace-out trace.json --metrics-out metrics.prom \\
              --set preset=oracle:quadratic,n=32,interactions=10000
  swarm train --executor cluster --role coordinator --listen 127.0.0.1:0 \\
              --workers 2 --set preset=oracle:quadratic,n=16,interactions=2000
  swarm train --algorithm swarm --topology torus --speeds bimodal:0.25:8 \\
              --set preset=oracle:quadratic,n=64,interactions=20000
  swarm train --algorithm sgp --topology ring --directed \\
              --dirichlet 0.1 --set preset=oracle:softmax,n=16
  swarm train --topology-schedule ring@0,torus@10000 \\
              --set preset=oracle:quadratic,n=64,interactions=20000
  swarm train --algorithm swarm --executor freerun --topology expander \\
              --churn join:0.001,leave:0.001 --node-budget 512 \\
              --set preset=oracle:quadratic-proc,n=1000000,interactions=2000000
  swarm train --executor cluster --role worker --connect 127.0.0.1:7000
  swarm figure --id table1 --quick
  swarm figure --id all --out results
";

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &[&str]) -> Cli {
        Cli::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = p(&["figure", "--id", "table1", "--quick"]);
        assert_eq!(c.subcommand, "figure");
        assert_eq!(c.get("id"), Some("table1"));
        assert!(c.has("quick"));
        assert!(!c.has("nope"));
    }

    #[test]
    fn equals_form() {
        let c = p(&["train", "--config=x.ini", "--set", "n=8,h=2"]);
        assert_eq!(c.get("config"), Some("x.ini"));
        assert_eq!(
            c.overrides(),
            vec![("n".into(), "8".into()), ("h".into(), "2".into())]
        );
    }

    #[test]
    fn typed_flags() {
        let c = p(&["topo", "--n", "16"]);
        assert_eq!(c.parse_flag::<usize>("n").unwrap(), Some(16));
        assert!(c.parse_flag::<usize>("missing").unwrap().is_none());
        let bad = p(&["topo", "--n", "xyz"]);
        assert!(bad.parse_flag::<usize>("n").is_err());
    }

    #[test]
    fn rejects_short_flags() {
        let args: Vec<String> = vec!["train".into(), "-x".into()];
        assert!(Cli::parse(&args).is_err());
    }
}
