//! Deterministic, seedable RNG + distributions (no external crates).
//!
//! Everything stochastic in the coordinator — edge sampling, geometric local
//! step counts, Poisson clocks, data synthesis, quantizer seeds — flows
//! through [`Pcg64`], so every experiment is reproducible from a single
//! `u64` seed. PCG-XSL-RR 128/64 (O'Neill 2014).

mod pcg;

pub use pcg::Pcg64;

impl Pcg64 {
    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (both outputs used: the sine twin is
    /// cached, halving the ln/sqrt/trig cost in gradient-noise hot loops).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
                self.spare_normal = Some(r * s);
                return r * c;
            }
        }
    }

    /// Normal with given mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean `1/rate`).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Geometric on `{1, 2, 3, ...}` with mean `m >= 1`
    /// (success prob `p = 1/m`) — the paper's `H_i` distribution.
    pub fn geometric(&mut self, mean: f64) -> u64 {
        debug_assert!(mean >= 1.0);
        if mean <= 1.0 {
            return 1;
        }
        let p = 1.0 / mean;
        // inverse CDF: ceil(ln(1-u) / ln(1-p))
        let u = self.f64();
        let g = ((1.0 - u).ln() / (1.0 - p).ln()).ceil();
        g.max(1.0) as u64
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Symmetric Dirichlet(alpha) sample of dimension `k` via Gamma draws.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seed(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn below_is_unbiased() {
        let mut r = Pcg64::seed(11);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Pcg64::seed(9);
        for target in [1.0, 2.0, 4.0, 8.0] {
            let n = 100_000;
            let s: u64 = (0..n).map(|_| r.geometric(target)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - target).abs() < 0.1 * target.max(1.0),
                "target={target} mean={mean}"
            );
            // support is {1, 2, ...}
            assert!((0..1000).all(|_| r.geometric(target) >= 1));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seed(13);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        assert!((s / n as f64 - 0.25).abs() < 0.01);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::seed(17);
        for alpha in [0.1, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 8);
            assert_eq!(v.len(), 8);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seed(29);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed(31);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0u32; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[0] as f64 / 1e5 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / 1e5 - 0.3).abs() < 0.01);
        assert!((counts[2] as f64 / 1e5 - 0.6).abs() < 0.01);
    }
}
