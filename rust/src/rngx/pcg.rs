//! PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-low + random
//! rotation output. Passes PractRand/BigCrush; one multiply + shift per
//! draw, so cheap enough for the hot loop.

const MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;
const INC: u128 = 0x5851_F42D_4C95_7F2D_1405_7B7E_F767_814F;

/// Deterministic 64-bit PRNG. `Clone` so experiment arms can fork identical
/// streams; use [`Pcg64::split`] for statistically independent substreams.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    /// cached second output of the last Box–Muller draw
    pub(crate) spare_normal: Option<f64>,
}

impl Pcg64 {
    /// Seed from a single u64 (SplitMix64-expanded into the 128-bit state).
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let hi = next() as u128;
        let lo = next() as u128;
        let mut rng = Self { state: (hi << 64) | lo, spare_normal: None };
        rng.next_u64(); // discard first output (decorrelate from seed)
        rng
    }

    /// Derive an independent substream (e.g. one per agent).
    pub fn split(&mut self, tag: u64) -> Self {
        let a = self.next_u64();
        Self::seed(a ^ tag.wrapping_mul(0xD6E8_FEB8_6659_FD93))
    }

    /// Stateless stream derivation: a deterministic, statistically
    /// independent generator keyed by `(root, tag)` alone. Unlike
    /// [`Pcg64::split`] it consumes no generator state, so any party that
    /// knows the pair reconstructs the identical stream — the foundation of
    /// the parallel executor's per-node noise/jitter streams and its
    /// replay-determinism contract (every thread interleaving sees node `k`
    /// draw the same sequence).
    pub fn stream(root: u64, tag: u64) -> Self {
        fn mix(mut z: u64) -> u64 {
            z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        }
        Self::seed(mix(root) ^ mix(tag.wrapping_mul(0xD6E8_FEB8_6659_FD93)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(INC);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// The raw 128-bit LCG state, for compact external persistence (the
    /// membership `NodeStore` parks each node's stream in 16 bytes).
    /// A cached Box–Muller half is *not* captured — see [`Pcg64::from_raw_state`].
    #[inline]
    pub fn state_raw(&self) -> u128 {
        self.state
    }

    /// Rebuild a generator from [`Pcg64::state_raw`]. The `spare_normal`
    /// Box–Muller cache is dropped across the round-trip: the resumed
    /// stream may differ from the uninterrupted one by one discarded
    /// gaussian half. That is fine for the statistical (non-replayable)
    /// executors this exists for; replayable paths keep their `Pcg64`
    /// values alive instead of round-tripping them.
    #[inline]
    pub fn from_raw_state(state: u128) -> Self {
        Self { state, spare_normal: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_streams_are_independent_ish() {
        let mut root = Pcg64::seed(99);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let matches = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(matches, 0);
    }

    #[test]
    fn no_short_cycles() {
        // weak check: outputs over 100k draws are mostly distinct
        let mut r = Pcg64::seed(1);
        let mut v: Vec<u64> = (0..100_000).map(|_| r.next_u64()).collect();
        v.sort_unstable();
        v.dedup();
        assert!(v.len() > 99_990);
    }

    #[test]
    fn raw_state_roundtrips_the_u64_stream() {
        let mut a = Pcg64::seed(17);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Pcg64::from_raw_state(a.state_raw());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn stream_is_stateless_and_tag_separated() {
        // same (root, tag) → identical stream, independent of call order
        let mut a = Pcg64::stream(42, 7);
        let mut b = Pcg64::stream(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // different tags (and adjacent tags) decorrelate
        let mut c = Pcg64::stream(42, 8);
        let hits = (0..1000).filter(|_| b.next_u64() == c.next_u64()).count();
        assert_eq!(hits, 0);
        // deriving a stream consumes nothing from any other generator
        let mut root = Pcg64::seed(42);
        let before = root.clone().next_u64();
        let _ = Pcg64::stream(42, 3);
        assert_eq!(root.next_u64(), before);
    }
}
