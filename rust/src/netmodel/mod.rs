//! Communication/compute cost models — the simulated Piz Daint (DESIGN.md §2).
//!
//! The paper's time-axis figures (1b, 2b/4, 5, 7, 8b) measure wall-clock on a
//! Cray XC50 with Aries interconnect.  We charge time in a calibrated model:
//!
//! * compute: per-batch time with optional log-normal jitter/stragglers —
//!   the paper's Figure 4 base value (0.4 s/batch for ResNet18 on P100) is
//!   the default so the y-axes line up;
//! * point-to-point: `latency + bytes/bandwidth` (Aries-ish: 1.5 µs, ~10 GB/s
//!   effective per flow);
//! * ring allreduce: `2(n−1)/n · bytes/bandwidth + 2 log₂n · latency`
//!   (bandwidth-optimal ring; what NCCL does for large messages);
//! * gossip pairwise exchange: both models cross the wire (send + recv ≈
//!   full duplex → one transfer time), plus a handshake latency.
//!
//! All values are configurable; figures sweep them where the paper does.
//!
//! # Simulated vs. real wire
//!
//! This model prices the wire for the **in-process** executors (serial,
//! parallel, freerun): their `sim_time` axes come from these formulas, and
//! `latency`/`bandwidth`/`model_bytes` scale them. The **cluster** executor
//! ([`crate::cluster`]) is the other side of that split — its gossip
//! crosses real TCP sockets, so nothing here applies to its communication:
//! `wire_bits` is counted from actual socket writes and transfer time is
//! whatever the kernel delivers. Setting a wire knob off its default under
//! `--executor cluster` earns a one-line warning naming the ignored keys
//! ([`crate::config::RunConfig::simulated_wire_overrides`]). The
//! *compute-side* knobs (`batch_time`, `jitter`, `straggler_prob`,
//! `straggle_factor`) stay meaningful everywhere: cluster workers charge
//! them inside the local SGD phase exactly like freerun workers.

use crate::rngx::Pcg64;

#[derive(Clone, Debug)]
pub struct CostModel {
    /// mean compute time per local SGD step (seconds)
    pub batch_time: f64,
    /// log-normal jitter sigma on compute (0 = deterministic)
    pub jitter: f64,
    /// probability a step is a straggler (multiplied by `straggle_factor`)
    pub straggler_prob: f64,
    pub straggle_factor: f64,
    /// p2p message latency (seconds)
    pub latency: f64,
    /// p2p effective bandwidth (bytes/second)
    pub bandwidth: f64,
    /// override for the model's wire size (simulate paper-scale models —
    /// e.g. ResNet18's 45 MB — while computing on a small stand-in)
    pub model_bytes_override: Option<u64>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            batch_time: 0.4,        // paper Fig. 4 base value (ResNet18/P100)
            jitter: 0.05,
            straggler_prob: 0.01,
            straggle_factor: 3.0,
            latency: 1.5e-6,        // Aries-class
            bandwidth: 10.0e9,      // effective per-flow
            model_bytes_override: None,
        }
    }
}

impl CostModel {
    /// Deterministic variant (tests, theory figures).
    pub fn deterministic(batch_time: f64) -> Self {
        Self {
            batch_time,
            jitter: 0.0,
            straggler_prob: 0.0,
            straggle_factor: 1.0,
            ..Self::default()
        }
    }

    /// Time for one local SGD step on one node.
    pub fn compute_time(&self, rng: &mut Pcg64) -> f64 {
        let mut t = self.batch_time;
        if self.jitter > 0.0 {
            t *= (rng.normal() * self.jitter).exp();
        }
        if self.straggler_prob > 0.0 && rng.bernoulli(self.straggler_prob) {
            t *= self.straggle_factor;
        }
        t
    }

    /// One-way p2p transfer of `bytes`.
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Pairwise gossip exchange (full-duplex swap of `bytes` each way +
    /// handshake round-trip).
    pub fn exchange_time(&self, bytes: u64) -> f64 {
        2.0 * self.latency + bytes as f64 / self.bandwidth
    }

    /// Ring allreduce over `n` nodes of `bytes` (reduce-scatter + allgather).
    pub fn allreduce_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2.0 * (n as f64).log2();
        2.0 * ((n - 1) as f64 / n as f64) * bytes as f64 / self.bandwidth
            + steps * self.latency
    }

    /// Model size on the wire at full precision.
    pub fn model_bytes(d: usize) -> u64 {
        4 * d as u64
    }

    /// Wire size for a `d`-parameter model, honoring the override.
    pub fn wire_bytes(&self, d: usize) -> u64 {
        self.model_bytes_override.unwrap_or(4 * d as u64)
    }

    /// Scale quantized wire bits when an override is active (the override
    /// re-scales the full-precision size; quantized payloads shrink by the
    /// same ratio).
    pub fn scale_bits(&self, bits: u64, d: usize) -> u64 {
        match self.model_bytes_override {
            None => bits,
            Some(ov) => {
                let full = (4 * d as u64).max(1);
                (bits as f64 * ov as f64 / full as f64) as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_compute_is_constant() {
        let m = CostModel::deterministic(0.4);
        let mut r = Pcg64::seed(1);
        for _ in 0..10 {
            assert_eq!(m.compute_time(&mut r), 0.4);
        }
    }

    #[test]
    fn jitter_changes_times_but_keeps_mean() {
        let m = CostModel { jitter: 0.2, straggler_prob: 0.0, ..CostModel::default() };
        let mut r = Pcg64::seed(2);
        let ts: Vec<f64> = (0..20_000).map(|_| m.compute_time(&mut r)).collect();
        let mean = ts.iter().sum::<f64>() / ts.len() as f64;
        // lognormal mean = batch_time * exp(sigma^2/2)
        let expect = 0.4 * (0.02f64).exp();
        assert!((mean - expect).abs() < 0.01, "mean={mean}");
        assert!(ts.iter().any(|&t| (t - 0.4).abs() > 0.01));
    }

    #[test]
    fn allreduce_scales_with_n_and_bytes() {
        let m = CostModel::default();
        let t8 = m.allreduce_time(8, 1 << 20);
        let t64 = m.allreduce_time(64, 1 << 20);
        assert!(t64 > t8); // latency term grows, bandwidth term saturates
        let tbig = m.allreduce_time(8, 1 << 24);
        assert!(tbig > 10.0 * t8 / 16.0);
        assert_eq!(m.allreduce_time(1, 1 << 20), 0.0);
    }

    #[test]
    fn exchange_cheaper_than_allreduce_at_scale() {
        // the core SwarmSGD claim: pairwise cost is independent of n
        let m = CostModel::default();
        let bytes = CostModel::model_bytes(25_000_000); // 100 MB model
        let pair = m.exchange_time(bytes);
        let ar64 = m.allreduce_time(64, bytes);
        assert!(pair < ar64, "pair={pair} ar={ar64}");
    }

    #[test]
    fn straggler_inflates_tail() {
        let m = CostModel {
            jitter: 0.0,
            straggler_prob: 0.5,
            straggle_factor: 4.0,
            ..CostModel::default()
        };
        let mut r = Pcg64::seed(3);
        let ts: Vec<f64> = (0..1000).map(|_| m.compute_time(&mut r)).collect();
        let slow = ts.iter().filter(|&&t| t > 1.0).count();
        assert!((300..700).contains(&slow));
    }
}
