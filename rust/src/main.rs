//! `swarm` — the leader binary: train, regenerate paper figures, inspect
//! artifacts, probe topologies.  See `swarm help`.
//!
//! Training dispatch is the Algorithm × Backend × Executor matrix:
//! `--algorithm` picks the training process (SwarmSGD or any §5 baseline),
//! the `preset` key picks the compute backend (gradient oracles or the
//! PJRT path), and `--executor serial|parallel|freerun|cluster` picks the
//! driver.
//! serial/parallel replay the pre-drawn schedule and agree bit-for-bit per
//! seed — since the phased-event redesign that includes the round-based
//! baselines, whose per-node compute events spread across all workers;
//! freerun is the free-running sharded runtime (algorithms with a
//! `MixPolicy`: swarm, poisson, adpsgd, dpsgd, and sgp via weighted
//! push-sum slots) that trades replayability for real contention/staleness
//! telemetry; cluster runs the same protocol across OS processes gossiping
//! over TCP (`--role coordinator|worker`), so wire bits are measured from
//! the socket. `--wire lattice|f32` selects the wire codec on every
//! executor, and `--kernel scalar|simd` selects the (bit-exact) fused
//! merge-kernel implementation every interaction dispatches to.

use std::path::Path;
use swarm_sgd::backend::{build_backend, Backend};
use swarm_sgd::cli::{Cli, USAGE};
use swarm_sgd::cluster::{self, ClusterOpts, Role};
use swarm_sgd::config::RunConfig;
use swarm_sgd::coordinator::{
    make_algorithm, run_freerun_scenario, run_parallel_scenario, run_serial_scenario,
    AlgoOptions, Algorithm, RunMetrics, RunSpec,
};
use swarm_sgd::figures::{run_figure, write_curves};
use swarm_sgd::membership::{run_scale, ScaleOptions};
use swarm_sgd::obs;
use swarm_sgd::output::Table;
use swarm_sgd::rngx::Pcg64;
use swarm_sgd::runtime::load_manifest;
use swarm_sgd::scenario::{Scenario, SpeedClass};
use swarm_sgd::topology::{spectral_gap, Graph};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            obs::log::error("cli", format_args!("{e}"));
            eprintln!("\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cli.subcommand.as_str() {
        "train" => cmd_train(&cli),
        "figure" => cmd_figure(&cli),
        "inspect" => cmd_inspect(&cli),
        "topo" => cmd_topo(&cli),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n\n{USAGE}")),
    };
    if let Err(e) = result {
        obs::log::error("swarm", format_args!("{e}"));
        std::process::exit(1);
    }
}

fn cmd_train(cli: &Cli) -> Result<(), String> {
    let mut cfg = match cli.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            RunConfig::from_ini(&text)?
        }
        None => RunConfig::default(),
    };
    for (k, v) in cli.overrides() {
        cfg.set(&k, &v)?;
    }
    for key in [
        "algorithm",
        "executor",
        "threads",
        "shards",
        "wire",
        "kernel",
        "workers",
        "topology",
        "speeds",
        "directed",
        "dirichlet",
        "topology-schedule",
        "churn",
        "node-store",
        "node-budget",
        "trace-out",
        "trace-sample",
        "metrics-out",
        "metrics-addr",
        "log-level",
    ] {
        if let Some(v) = cli.get(key) {
            cfg.set(key, v)?;
        }
    }
    if let Some(v) = cli.get("heartbeat-timeout") {
        cfg.set("heartbeat_timeout", v)?;
    }
    if cli.has("quick") {
        cfg.interactions = cfg.interactions.min(100);
    }
    // the level gates every leveled diagnostic from here on; protocol
    // lines on stdout are never filtered
    obs::log::set_level(obs::log::Level::parse(&cfg.log_level)?);
    // the cluster executor dispatches before any single-process setup:
    // workers receive the config from the coordinator over the wire, and
    // the coordinator validates algorithm eligibility itself
    if cfg.executor == "cluster" && cfg.churn_spec()?.active() {
        return Err(
            "--churn is a scale-engine feature of --executor freerun; the \
             cluster executor keeps a fixed roster (its coordinator tracks \
             roster epochs for shard reassignment only) — drop the --churn \
             flag, or run --executor freerun"
                .into(),
        );
    }
    if let Some(opts) = cluster::from_cli(cli, &cfg)? {
        return cmd_cluster(&cfg, &opts);
    }
    if !cfg.metrics_addr.is_empty() {
        return Err(
            "--metrics-addr serves the cluster coordinator's live introspection \
             endpoint; this is a single-process run — use --executor cluster \
             --role coordinator, or --metrics-out for file snapshots"
                .into(),
        );
    }
    println!("config: {cfg:?}\n");

    // no silent clamp: h=0 (or negative h) reaches the factory as 0, which
    // rejects it for localsgd with an actionable error
    let algo: Box<dyn Algorithm> = make_algorithm(
        &cfg.algo,
        &AlgoOptions {
            local_steps: cfg.local_steps(),
            mode: cfg.averaging_mode()?,
            h_localsgd: cfg.h.round().max(0.0) as u64,
            wire: cfg.wire_codec()?,
            kernel: cfg.kernel_enum()?,
        },
    )?;
    let backend = build_backend(&cfg)?;
    // the scale regime routes before the Scenario is built: materializing
    // a million-node graph (or dense per-node states) is exactly what the
    // membership subsystem exists to avoid
    if cfg.executor == "freerun" && cfg.scale_engine_selected()? {
        return cmd_train_scale(&cfg, algo.as_ref(), backend.as_ref());
    }
    // the scenario resolves the whole run environment — topology stages,
    // per-node speed classes, directedness — and rejects infeasible combos
    // (torus on a non-square n, hypercube off a power of two, ...) here
    let scn = Scenario::from_config(&cfg)?;
    let g0 = scn.graph0();
    println!(
        "topology: {} n={} degree={:?} lambda2={:.4} spectral_gap={:.4}{}",
        cfg.topology,
        cfg.n,
        g0.regular_degree(),
        g0.lambda2(),
        spectral_gap(g0),
        if g0.is_directed() { " (directed)" } else { "" }
    );
    if scn.is_time_varying() {
        println!(
            "topology schedule: {} stage(s) ({})",
            scn.stages().len(),
            cfg.topology_schedule
        );
    }
    if !scn.uniform_speeds() {
        println!("speed classes: {} (rate-weighted Poisson clocks)", cfg.speeds);
    }
    let cost = cfg.cost_model();
    let spec = RunSpec {
        n: cfg.n,
        events: cfg.interactions,
        lr: cfg.lr_schedule_enum()?,
        seed: cfg.seed,
        name: format!("{}-{}", cfg.algo, cfg.executor),
        eval_every: cfg.eval_every,
        track_gamma: cfg.track_gamma,
    };

    if (!cfg.trace_out.is_empty() || !cfg.metrics_out.is_empty()) && cfg.executor != "freerun" {
        obs::log::warn(
            "train",
            format_args!(
                "tracing/metrics export cover the freerun and cluster executors; \
                 the '{}' executor ignores them",
                cfg.executor
            ),
        );
    }
    let started = std::time::Instant::now();
    let metrics = match cfg.executor.as_str() {
        "parallel" => {
            let threads = cfg.effective_threads();
            println!(
                "parallel executor: {} worker thread(s), algorithm={} n={} topology={}",
                threads, cfg.algo, cfg.n, cfg.topology
            );
            run_parallel_scenario(algo.as_ref(), backend.as_ref(), &spec, &scn, &cost, threads)
        }
        "freerun" => {
            if algo.mix_policy().is_none() {
                return Err(format!(
                    "--executor freerun requires a free-running MixPolicy \
                     (freerun-eligible: swarm, poisson, adpsgd, dpsgd, and sgp via \
                     weighted push-sum slots); '{}' mixes through an irreducible \
                     global mean — use --executor serial|parallel",
                    cfg.algo
                ));
            }
            let threads = cfg.effective_threads();
            let shards = cfg.effective_shards();
            println!(
                "freerun executor: {} worker thread(s) over {} shard(s), \
                 algorithm={} n={} topology={} (non-replayable)",
                threads, shards, cfg.algo, cfg.n, cfg.topology
            );
            run_freerun_scenario(
                algo.as_ref(),
                backend.as_ref(),
                &spec,
                &scn,
                &cost,
                threads,
                shards,
                &cfg.obs_options(),
            )
        }
        _ => run_serial_scenario(algo.as_ref(), backend.as_ref(), &spec, &scn, &cost),
    };
    let wall = started.elapsed();
    println!(
        "throughput: {:.0} events/s wall-clock ({} executor)",
        metrics.interactions as f64 / wall.as_secs_f64().max(1e-9),
        metrics.executor
    );
    report_run(&cfg, metrics, wall)
}

/// The membership scale-engine path — `--executor freerun` routed here by
/// [`RunConfig::scale_engine_selected`] (large n under `node_store=auto`,
/// any active `--churn`, or an explicit `node_store=compact`). Node state
/// rests lattice-encoded in the compact store and partner draws are
/// procedural, so nothing here is O(n·dim) resident except the store
/// arena itself.
fn cmd_train_scale(
    cfg: &RunConfig,
    algo: &dyn Algorithm,
    backend: &dyn Backend,
) -> Result<(), String> {
    if cfg.directed {
        return Err(
            "--directed is push-sum (sgp) machinery; the scale engine carries \
             plain payloads over undirected procedural graphs — drop \
             --directed, or run sgp on the dense freerun executor"
                .into(),
        );
    }
    if !cfg.topology_schedule.is_empty() {
        return Err(
            "--topology-schedule is not supported on the scale engine (its \
             graphs are procedural, not staged); drop the schedule, or stay \
             below the materialize cutover with node_store=dense"
                .into(),
        );
    }
    if !cfg.trace_out.is_empty() {
        return Err(
            "--trace-out is not supported on the scale engine (per-event \
             span rings don't scale to millions of nodes); use --metrics-out \
             for cadenced Prometheus snapshots instead"
                .into(),
        );
    }
    let opts = ScaleOptions {
        threads: cfg.threads,
        topology: cfg.topology_enum()?,
        speeds: SpeedClass::parse(&cfg.speeds)?,
        churn: cfg.churn_spec()?,
        node_budget: cfg.node_budget,
        eval_sample: 0,
        metrics_out: if cfg.metrics_out.is_empty() {
            None
        } else {
            Some(cfg.metrics_out.clone())
        },
    };
    let spec = RunSpec {
        n: cfg.n,
        events: cfg.interactions,
        lr: cfg.lr_schedule_enum()?,
        seed: cfg.seed,
        name: format!("{}-scale", cfg.algo),
        eval_every: cfg.eval_every,
        track_gamma: cfg.track_gamma,
    };
    let cost = cfg.cost_model();
    println!(
        "scale engine: {} worker thread(s), compact node store, algorithm={} \
         n={} topology={}{} (non-replayable)",
        cfg.effective_threads(),
        cfg.algo,
        cfg.n,
        cfg.topology,
        if opts.churn.active() { format!(" churn={}", opts.churn) } else { String::new() },
    );
    let started = std::time::Instant::now();
    let metrics = run_scale(algo, backend, &spec, &cost, &opts)?;
    let wall = started.elapsed();
    println!(
        "throughput: {:.0} events/s wall-clock (scale engine)",
        metrics.interactions as f64 / wall.as_secs_f64().max(1e-9),
    );
    report_run(cfg, metrics, wall)
}

/// The `--executor cluster` entry point: one process per role.
fn cmd_cluster(cfg: &RunConfig, opts: &ClusterOpts) -> Result<(), String> {
    match &opts.role {
        Role::Coordinator { listen } => {
            // the gossip plane crosses real sockets, so the simulated-wire
            // knobs have nothing to scale — flag any that were moved
            let ignored = cfg.simulated_wire_overrides();
            if !ignored.is_empty() {
                obs::log::warn(
                    "cluster",
                    format_args!(
                        "--executor cluster measures the wire instead of \
                         simulating it; ignoring {} (compute-side knobs like \
                         batch_time/jitter/stragglers still apply)",
                        ignored.join(", ")
                    ),
                );
            }
            std::fs::create_dir_all(&opts.checkpoint_dir)
                .map_err(|e| format!("{}: {e}", opts.checkpoint_dir.display()))?;
            println!("config: {cfg:?}\n");
            let report = cluster::run_coordinator(cfg, listen, &opts.checkpoint_dir)?;
            println!(
                "throughput: {:.0} events/s wall-clock (cluster executor, \
                 {} recoveries)",
                report.interactions_per_sec, report.recoveries
            );
            Ok(())
        }
        // workers take everything (config included) from the coordinator;
        // local --set/--config values only seed the connection itself
        Role::Worker { connect } => cluster::run_worker(connect, opts.throttle_us),
    }
}

fn report_run(
    cfg: &RunConfig,
    metrics: RunMetrics,
    wall: std::time::Duration,
) -> Result<(), String> {
    println!("\nloss curve (eval on consensus model μ_t):");
    let mut table =
        Table::new(&["t", "par.time", "sim time", "train loss", "eval loss", "acc", "gamma"]);
    for p in &metrics.curve {
        table.row(&[
            p.t.to_string(),
            format!("{:.1}", p.parallel_time),
            format!("{:.1}", p.sim_time),
            format!("{:.4}", p.train_loss),
            format!("{:.4}", p.eval_loss),
            if p.eval_acc.is_nan() { "-".into() } else { format!("{:.3}", p.eval_acc) },
            if p.gamma.is_nan() { "-".into() } else { format!("{:.4}", p.gamma) },
        ]);
    }
    table.print();
    println!(
        "\nsummary: interactions={} local_steps={} epochs/agent={:.2}\n\
         sim_time={:.1}s (compute {:.1}s, comm {:.1}s)  wire={:.3} GB  \
         quant_fallbacks={}  kernel={}\nwall-clock: {:.1}s",
        metrics.interactions,
        metrics.local_steps,
        metrics.epochs,
        metrics.sim_time,
        metrics.compute_time_total,
        metrics.comm_time_total,
        metrics.total_bits as f64 / 8e9,
        metrics.quant_fallbacks,
        metrics.kernel,
        wall.as_secs_f64(),
    );
    if let Some(fr) = &metrics.freerun {
        println!(
            "\nfreerun telemetry ({} thread(s) × {} shard(s), wall {:.2}s):\n\
             real throughput  : {:.0} interactions/s\n\
             wire codec       : {} ({:.3} GB on the wire, {} decode fallbacks)\n\
             merge kernel     : {}\n\
             staleness (events): p50={} p99={} max={} mean={:.1}\n\
             slot contention  : {} read retries, {} publish retries, \
             {} dropped cross-writes\n\
             worker activity  : {:.2}s busy / {:.3}s slot-sync across workers",
            fr.threads,
            fr.shards,
            fr.wall_secs,
            fr.interactions_per_sec,
            fr.codec,
            fr.wire_bits as f64 / 8e9,
            fr.wire_fallbacks,
            fr.kernel,
            fr.staleness.p50(),
            fr.staleness.p99(),
            fr.staleness.max_observed(),
            fr.staleness.mean(),
            fr.slot_read_retries,
            fr.slot_publish_retries,
            fr.slot_push_conflicts,
            fr.busy_total(),
            fr.wait_total(),
        );
        if let Some(ms) = &fr.membership {
            println!(
                "\nmembership (scale engine, roster capacity {}):\n\
                 live nodes       : {} -> {} ({} joins, {} leaves, \
                 {} rejected joins)\n\
                 churn collisions : {} dropped partner/cross-writes, \
                 {} skipped events\n\
                 node store       : {} bytes/node resident{}, \
                 {} raw-escaped node(s), {} decode failure(s)\n\
                 final eval       : {} live node(s) sampled",
                ms.capacity,
                ms.live_start,
                ms.live_end,
                ms.joins,
                ms.leaves,
                ms.rejected_joins,
                ms.churn_misses,
                ms.skipped_events,
                ms.bytes_per_node,
                if ms.node_budget > 0 {
                    format!(" (budget {})", ms.node_budget)
                } else {
                    String::new()
                },
                ms.raw_nodes,
                ms.decode_failures,
                ms.eval_sample,
            );
        }
    }
    if !cfg.trace_out.is_empty() {
        if let Some(tr) = &metrics.trace {
            std::fs::write(&cfg.trace_out, tr.to_chrome_json())
                .map_err(|e| format!("{}: {e}", cfg.trace_out))?;
            println!(
                "trace written to {} ({} events, {} dropped)",
                cfg.trace_out,
                tr.events.len(),
                tr.dropped
            );
        }
    }
    if !cfg.metrics_out.is_empty() && metrics.freerun.is_some() {
        println!("metrics snapshots appended to {}", cfg.metrics_out);
    }
    if !cfg.out_csv.is_empty() {
        write_curves(Path::new(&cfg.out_csv), &[metrics]).map_err(|e| e.to_string())?;
        println!("curve written to {}", cfg.out_csv);
    }
    Ok(())
}

fn cmd_figure(cli: &Cli) -> Result<(), String> {
    let id = cli
        .get("id")
        .ok_or("figure: missing --id (try --id all)")?
        .to_string();
    let quick = cli.has("quick");
    let out = cli.get_or("out", "results");
    run_figure(&id, quick, Path::new(&out))
}

fn cmd_inspect(cli: &Cli) -> Result<(), String> {
    let dir = cli.get_or("artifacts", "artifacts");
    let manifests = load_manifest(Path::new(&dir))?;
    let mut table =
        Table::new(&["preset", "model", "params", "batch", "k", "kind", "artifacts"]);
    for m in &manifests {
        table.row(&[
            m.name.clone(),
            m.model.clone(),
            m.param_count.to_string(),
            m.batch.to_string(),
            m.k.to_string(),
            format!("{:?}", m.kind()),
            m.artifacts.len().to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_topo(cli: &Cli) -> Result<(), String> {
    let n: usize = cli.parse_flag("n")?.unwrap_or(16);
    let cfg = RunConfig {
        n,
        topology: cli.get_or("topology", "complete"),
        ..RunConfig::default()
    };
    let topo = cfg.topology_enum()?;
    // same feasibility gate the scenario applies before a training run
    topo.validate(n)?;
    let mut rng = Pcg64::seed(1);
    let g = Graph::build(topo, n, &mut rng);
    let r = g.regular_degree().unwrap_or(0) as f64;
    let l2 = g.lambda2();
    println!("topology {} n={n}", cfg.topology);
    println!("  degree r        = {:?}", g.regular_degree());
    println!("  edges           = {}", g.edges().len());
    println!("  connected       = {}", g.is_connected());
    println!("  lambda2         = {l2:.6}");
    println!("  spectral gap    = {:.6}  (0 iff disconnected)", spectral_gap(&g));
    println!(
        "  r^2/lambda2^2+1 = {:.4}  (theorem topology factor)",
        r * r / (l2 * l2) + 1.0
    );
    Ok(())
}
