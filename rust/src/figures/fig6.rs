//! Figure 6 — CIFAR-10/ResNet20 slot ablations on the softmax-linear oracle
//! (large node counts are tractable without XLA dispatch):
//! (a) convergence vs epochs for n ∈ {8..256} — converges at all n, with
//!     oscillations at high node counts;
//! (b) accuracy vs (epoch multiplier × local steps) — epochs dominate, H
//!     matters much less.

use super::common::{run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::netmodel::CostModel;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::topology::Topology;
use std::path::Path;

const DIM: usize = 32;
const CLASSES: usize = 10;
const BATCH: usize = 32;

pub fn run_a(quick: bool, out_dir: &Path) -> Result<(), String> {
    let nodes: &[usize] = if quick { &[8, 32, 64] } else { &[8, 32, 64, 128, 256] };
    let epochs = 8.0f64;
    let per_agent = 256usize;
    let lr = 0.1;
    let h = 2u64;
    let cost = CostModel::deterministic(0.1);

    let mut table = Table::new(&["nodes", "final acc", "final loss", "epochs/agent"]);
    let mut all = Vec::new();
    for &n in nodes {
        let spec = BackendSpec::Softmax {
            n_train: per_agent * n,
            dim: DIM,
            classes: CLASSES,
            batch: BATCH,
            seed: 53,
        };
        let steps_per_epoch = per_agent as f64 / BATCH as f64;
        let t = (epochs * steps_per_epoch * n as f64 / (2.0 * h as f64)).ceil() as u64;
        let arm = Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t },
            ..Arm::swarm(&format!("n={n}"), h, t, lr)
        };
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 67, (t / 16).max(1), false)?;
        table.row(&[
            n.to_string(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.2}", m.epochs),
        ]);
        all.push(m);
    }
    println!("\nFigure 6(a) — convergence vs epochs across node counts:");
    table.print();
    write_curves(&out_dir.join("fig6a_curves.csv"), &all).map_err(|e| e.to_string())?;
    println!(
        "\npaper shape: SGD accuracy recovered at every node count (up to \
         256), with noisier curves at high n."
    );
    Ok(())
}

pub fn run_b(quick: bool, out_dir: &Path) -> Result<(), String> {
    let n = if quick { 8 } else { 8 };
    let per_agent = 256usize;
    let lr = 0.1;
    let cost = CostModel::deterministic(0.1);
    let mults: &[f64] = if quick { &[1.0, 2.0] } else { &[0.5, 1.0, 2.0, 3.0] };
    let hs: &[u64] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };

    let mut table = Table::new(&["epoch mult", "H", "final acc", "final loss"]);
    let mut csv = CsvWriter::create(
        out_dir.join("fig6b_grid.csv"),
        &["multiplier", "h", "acc", "loss"],
    )
    .map_err(|e| e.to_string())?;
    let base_epochs = 4.0;
    for &mult in mults {
        for &h in hs {
            let spec = BackendSpec::Softmax {
                n_train: per_agent * n,
                dim: DIM,
                classes: CLASSES,
                batch: BATCH,
                seed: 59,
            };
            let steps_per_epoch = per_agent as f64 / BATCH as f64;
            let t = (base_epochs * mult * steps_per_epoch * n as f64 / (2.0 * h as f64))
                .ceil() as u64;
            let arm = Arm {
                lr: LrSchedule::StepDecay { base: lr, total: t },
                ..Arm::swarm(&format!("x{mult} H={h}"), h, t, lr)
            };
            let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 71, 0, false)?;
            table.row(&[
                format!("{mult:.1}"),
                h.to_string(),
                format!("{:.3}", m.final_eval_acc),
                format!("{:.4}", m.final_eval_loss),
            ]);
            csv.row_mixed(&[
                CsvVal::F(mult),
                CsvVal::I(h as i64),
                CsvVal::F(m.final_eval_acc),
                CsvVal::F(m.final_eval_loss),
            ])
            .map_err(|e| e.to_string())?;
        }
    }
    println!("\nFigure 6(b) — accuracy vs epochs x local steps (n={n}):");
    table.print();
    println!(
        "\npaper shape: accuracy correlates strongly with total epochs and \
         only weakly with the number of local steps."
    );
    csv.flush().map_err(|e| e.to_string())
}
