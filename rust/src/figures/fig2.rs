//! Figure 2(a)/3(b) — convergence vs number of local steps H (all variants
//! recover accuracy; more local steps converge slower per interaction), and
//! Figure 2(b)/4 — average time per batch across methods and node counts
//! (the paper's headline systems plot: Swarm's communication share stays
//! constant and small as n grows).

use super::common::{paper_cost, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::topology::Topology;
use std::path::Path;

pub fn run_a(quick: bool, out_dir: &Path) -> Result<(), String> {
    let (preset, n, t_base, data) = if quick {
        ("mlp_s", 8usize, 160u64, 256usize)
    } else {
        ("cnn_m", 16, 480, 512)
    };
    let lr = 0.05;
    let cost = paper_cost("resnet18");
    let spec = BackendSpec::xla(preset, n, data, 29);

    let mut table = Table::new(&["H", "final acc", "final loss", "epochs", "sim time"]);
    let mut all = Vec::new();
    for h in [1u64, 2, 3, 4] {
        // same total local-step budget across H: T ∝ 1/H
        let t = t_base / h;
        let arm = Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t },
            ..Arm::swarm(&format!("H={h}"), h, t, lr)
        };
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 77, (t / 10).max(1), false)?;
        table.row(&[
            h.to_string(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.2}", m.epochs),
            format!("{:.0}", m.sim_time),
        ]);
        all.push(m);
    }
    println!("\nFigure 2(a) — convergence vs local steps (n={n}, {preset}):");
    table.print();
    write_curves(&out_dir.join("fig2a_curves.csv"), &all).map_err(|e| e.to_string())?;
    println!(
        "\npaper shape: all H recover the target accuracy; larger H shows \
         slightly slower convergence per epoch (variance term ~H²)."
    );
    Ok(())
}

pub fn run_b(quick: bool, out_dir: &Path) -> Result<(), String> {
    // Pure systems measurement: average per-step time decomposition. The
    // oracle backend supplies cheap gradients; timing comes from the
    // paper-calibrated cost model with a ResNet18-sized wire override.
    let nodes: &[usize] = if quick { &[16, 32] } else { &[16, 32, 64] };
    let t_per_node = 60u64;
    let lr = 0.02;
    let cost = paper_cost("resnet18");

    let mut table = Table::new(&[
        "method", "nodes", "time/batch (s)", "comm share (s)", "paper shape",
    ]);
    let mut csv = CsvWriter::create(
        out_dir.join("fig2b_time_per_batch.csv"),
        &["method", "nodes", "time_per_batch", "comm_per_batch"],
    )
    .map_err(|e| e.to_string())?;

    for &n in nodes {
        let spec = BackendSpec::Quadratic { dim: 1024, spread: 1.0, sigma: 0.05, seed: 3 };
        let arms = vec![
            Arm::baseline("Allreduce-SGD", "allreduce", t_per_node, lr),
            Arm::baseline("D-PSGD", "dpsgd", t_per_node, lr),
            Arm::baseline("SGP", "sgp", t_per_node, lr),
            Arm::baseline("AD-PSGD", "adpsgd", t_per_node * n as u64 / 2, lr),
            Arm::swarm("SwarmSGD H=2", 2, t_per_node * n as u64 / 4, lr),
            Arm::swarm("SwarmSGD H=3", 3, t_per_node * n as u64 / 6, lr),
        ];
        for arm in arms {
            let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 91, 0, false)?;
            // per-local-step busy time (compute + communication), per node —
            // the quantity Fig. 4 stacks above the 0.4 s compute base
            let time_per_batch =
                (m.compute_time_total + m.comm_time_total) / m.local_steps as f64;
            let comm_share = m.comm_time_total / m.local_steps as f64;
            let shape = match arm.name.as_str() {
                s if s.starts_with("Swarm") => "flat, smallest",
                "AD-PSGD" => "flat-ish, medium",
                _ => "grows with n",
            };
            table.row(&[
                arm.name.clone(),
                n.to_string(),
                format!("{time_per_batch:.3}"),
                format!("{comm_share:.3}"),
                shape.to_string(),
            ]);
            csv.row_mixed(&[
                CsvVal::S(arm.name.clone()),
                CsvVal::I(n as i64),
                CsvVal::F(time_per_batch),
                CsvVal::F(comm_share),
            ])
            .map_err(|e| e.to_string())?;
        }
    }
    println!("\nFigure 2(b)/4 — average time per batch (compute base 0.4 s):");
    table.print();
    println!(
        "\npaper shape: Swarm's time/batch is the lowest and stays constant \
         in n (communication amortized over H local steps); D-PSGD/SGP pay \
         ~2x batch time; allreduce grows with n."
    );
    csv.flush().map_err(|e| e.to_string())
}
