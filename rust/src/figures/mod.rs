//! Figure/table regeneration harnesses — one per artifact in the paper's
//! evaluation (DESIGN.md §5 maps each id to workload and modules).
//!
//! Every harness prints paper-style rows/series to stdout and writes
//! `results/<id>*.csv`.  `--quick` shrinks workloads for smoke runs.

mod common;
mod fig1;
mod fig2;
mod fig3;
mod fig5;
mod fig6;
mod fig7;
mod fig8;
mod gamma;
mod table1;
mod table2;

pub use common::{
    interactions_for_epochs, paper_cost, run_arm, write_curves, Arm, BackendSpec,
};

use std::path::Path;

/// All known figure ids, in paper order.
pub const ALL_FIGURES: &[&str] = &[
    "table1", "table2", "fig1a", "fig1b", "fig2a", "fig2b", "fig3a", "fig5",
    "fig6a", "fig6b", "fig7", "fig8a", "fig8b", "gamma",
];

/// Run one harness by id. `quick` shrinks sizes; outputs CSVs to `out_dir`.
pub fn run_figure(id: &str, quick: bool, out_dir: &Path) -> Result<(), String> {
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    match id {
        "table1" => table1::run(quick, out_dir),
        "table2" => table2::run(quick, out_dir),
        "fig1a" => fig1::run_a(quick, out_dir),
        "fig1b" => fig1::run_b(quick, out_dir),
        "fig2a" => fig2::run_a(quick, out_dir),
        "fig2b" | "fig4" => fig2::run_b(quick, out_dir),
        "fig3a" => fig3::run(quick, out_dir),
        "fig5" => fig5::run(quick, out_dir),
        "fig6a" => fig6::run_a(quick, out_dir),
        "fig6b" => fig6::run_b(quick, out_dir),
        "fig7" => fig7::run(quick, out_dir),
        "fig8a" => fig8::run(quick, out_dir, false),
        "fig8b" => fig8::run(quick, out_dir, true),
        "gamma" => gamma::run(quick, out_dir),
        "all" => {
            // one subprocess per figure: XLA CPU compilation + execution
            // retain large allocations for the process lifetime, so a
            // single long-lived process accumulates tens of GB across the
            // full suite (observed OOM); child processes bound the peak.
            let exe = std::env::current_exe().map_err(|e| e.to_string())?;
            for f in ALL_FIGURES {
                println!("\n================ {f} ================");
                let mut cmd = std::process::Command::new(&exe);
                cmd.arg("figure").arg("--id").arg(f).arg("--out").arg(out_dir);
                if quick {
                    cmd.arg("--quick");
                }
                let status = cmd.status().map_err(|e| e.to_string())?;
                if !status.success() {
                    return Err(format!("figure {f} failed: {status}"));
                }
            }
            Ok(())
        }
        other => Err(format!(
            "unknown figure id '{other}'; known: {} or 'all'",
            ALL_FIGURES.join(", ")
        )),
    }
}
