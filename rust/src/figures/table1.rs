//! Table 1 — full-accuracy recovery: SGD vs large-batch SGD vs SwarmSGD
//! (with epoch multiplier + local steps), on the synthetic-image CNN
//! workload standing in for CIFAR-10/ImageNet (DESIGN.md §2).
//!
//! Paper shape to reproduce: Swarm *matches or slightly exceeds* the
//! large-batch baseline's accuracy, but needs an epoch multiplier > 1.

use super::common::{interactions_for_epochs, run_arm, Arm, BackendSpec};
use crate::coordinator::{AveragingMode, LocalSteps, LrSchedule};
use crate::netmodel::CostModel;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::topology::Topology;
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let (preset, n, data_per_agent, batch, base_epochs, lr) = if quick {
        ("mlp_s", 4usize, 256usize, 32usize, 4.0f64, 0.05f32)
    } else {
        ("cnn_s", 8, 512, 32, 12.0, 0.05)
    };
    let cost = CostModel::deterministic(0.4);
    // low separation: a hard task, so the epoch multiplier visibly matters
    let sep = if quick { 2.0 } else { 1.1 };
    let spec = BackendSpec::xla_sep(preset, n, data_per_agent, 17, sep);
    let steps_per_epoch = data_per_agent as f64 / batch as f64;

    let mut table = Table::new(&[
        "method", "epochs", "local steps", "top-1 acc", "eval loss", "epoch mult",
    ]);
    let mut csv = CsvWriter::create(
        out_dir.join("table1.csv"),
        &["method", "epochs", "local_steps", "acc", "loss", "multiplier"],
    )
    .map_err(|e| e.to_string())?;

    let mut record = |name: &str, epochs: f64, h: f64, acc: f64, loss: f64, mult: f64| {
        table.row(&[
            name.to_string(),
            format!("{epochs:.0}"),
            format!("{h:.0}"),
            format!("{:.2}%", acc * 100.0),
            format!("{loss:.4}"),
            format!("{mult:.1}x"),
        ]);
        let _ = csv.row_mixed(&[
            CsvVal::S(name.into()),
            CsvVal::F(epochs),
            CsvVal::F(h),
            CsvVal::F(acc),
            CsvVal::F(loss),
            CsvVal::F(mult),
        ]);
    };

    // --- sequential SGD reference (single node, base epochs over the FULL
    // dataset: n x data_per_agent examples) ---
    let sgd_rounds = (base_epochs * steps_per_epoch * n as f64) as u64;
    let sgd = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr, total: sgd_rounds },
            ..Arm::baseline("SGD (1 node)", "allreduce", sgd_rounds, lr)
        },
        &BackendSpec::xla_sep(preset, 1, data_per_agent * n, 17, sep),
        1,
        Topology::Complete,
        &cost,
        100,
        0,
        false,
    )?;
    record("SGD (1 node)", base_epochs, 1.0, sgd.final_eval_acc, sgd.final_eval_loss, 1.0);

    // --- large-batch SGD: n nodes, allreduce every step ---
    let lb_rounds = (base_epochs * steps_per_epoch) as u64;
    let lb = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr * (n as f32).sqrt(), total: lb_rounds },
            ..Arm::baseline("LB-SGD", "allreduce", lb_rounds, lr)
        },
        &spec,
        n,
        Topology::Complete,
        &cost,
        100,
        0,
        false,
    )?;
    record("LB-SGD", base_epochs, 1.0, lb.final_eval_acc, lb.final_eval_loss, 1.0);

    // --- SwarmSGD at several (multiplier, H) as in Table 1 ---
    for (mult, h) in [(1.0f64, 2u64), (1.5, 2), (1.5, 3), (2.0, 4)] {
        let t = interactions_for_epochs(base_epochs * mult, n, h as f64, data_per_agent, batch);
        let arm = Arm {
            name: format!("SwarmSGD x{mult:.1} H={h}"),
            algo: "swarm".into(),
            mode: AveragingMode::NonBlocking,
            local_steps: LocalSteps::Fixed(h),
            t,
            lr: LrSchedule::StepDecay { base: lr, total: t },
            h_localsgd: 5,
        };
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 100, 0, false)?;
        record(&arm.name, base_epochs * mult, h as f64, m.final_eval_acc, m.final_eval_loss, mult);
    }

    println!("\nTable 1 — accuracy recovery ({preset}, n={n}):");
    table.print();
    println!(
        "\npaper shape: Swarm recovers/exceeds LB-SGD accuracy, needing a \
         multiplier > 1 at higher H (CIFAR/ImageNet: 1.4–2.7x)."
    );
    csv.flush().map_err(|e| e.to_string())
}
