//! Table 2 — theory comparison: measured `(1/T)Σ‖∇f(μ_t)‖²` for each
//! algorithm on a quadratic with KNOWN constants (L, σ², ρ², f − f*),
//! against the closed-form Theorem 4.1/4.2 upper bounds, across topologies.
//!
//! Paper shape: all methods are O(1/√(Tn)); SwarmSGD's bound requires only
//! (σ²|M², λ₂, r); measured values sit (far) below the bounds; better
//! connectivity (λ₂ large) helps.

use super::common::{run_arm, Arm, BackendSpec};
use crate::analysis::{fit_power_law, gap_samples, theorem41_bound, theorem41_t_ok, theorem42_bound, BoundParams};
use crate::backend::Backend;
use crate::coordinator::{AveragingMode, LocalSteps, LrSchedule};
use crate::grad::QuadraticOracle;
use crate::netmodel::CostModel;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::rngx::Pcg64;
use crate::topology::{Graph, Topology};
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let n = if quick { 8 } else { 16 };
    let t: u64 = if quick { 4096 } else { 65536 };
    let dim = 16;
    let sigma = 0.2;
    let spread = 1.0;
    let h = 2u64;
    let seed = 31;
    let cost = CostModel::deterministic(1.0);

    let mut table = Table::new(&[
        "algorithm", "assumptions", "topology", "lambda2", "measured E||grad||^2",
        "fit T^-p", "thm4.1 bound", "thm4.2 bound", "T>=n^4",
    ]);
    let mut csv = CsvWriter::create(
        out_dir.join("table2.csv"),
        &["algo", "topology", "lambda2", "measured", "bound41", "bound42"],
    )
    .map_err(|e| e.to_string())?;

    // constants of the oracle (identical across arms: same seed)
    let probe = QuadraticOracle::new(dim, n, spread, 0.5, 2.0, sigma, seed);
    let l = probe.smoothness();
    let f_gap = {
        let o = QuadraticOracle::new(dim, n, spread, 0.5, 2.0, sigma, seed);
        let (p, _) = o.init();
        o.full_loss(&p) - o.f_star()
    };
    let rho_sq = probe.rho_sq_at_optimum();
    // second-moment proxy at init: M² ≈ E‖∇f_i(x₀)‖² + σ²·dim
    let m_sq = {
        let o = QuadraticOracle::new(dim, n, spread, 0.5, 2.0, sigma, seed);
        let g = o.true_grad(&vec![0.0; dim]);
        g.iter().map(|v| v * v).sum::<f64>() + sigma * sigma * dim as f64
    };

    for topo in [Topology::Complete, Topology::Hypercube, Topology::Ring] {
        let mut rng = Pcg64::seed(1);
        let graph = Graph::build(topo, n, &mut rng);
        let lambda2 = graph.lambda2();
        let r = graph.regular_degree().unwrap_or(0) as f64;
        let bp = BoundParams { n, r, lambda2, h: h as f64, l, t, f_gap };
        let b41 = theorem41_bound(&bp, m_sq);
        let b42 = theorem42_bound(&bp, sigma * sigma * dim as f64, rho_sq);

        for (algo, assume, arm) in [
            (
                "SwarmSGD (geom H)",
                "M2,l2,r",
                Arm {
                    name: "swarm-geo".into(),
                    algo: "swarm".into(),
                    mode: AveragingMode::NonBlocking,
                    local_steps: LocalSteps::Geometric(h as f64),
                    t,
                    lr: LrSchedule::Theory { n, t },
                    h_localsgd: 5,
                },
            ),
            (
                "SwarmSGD (fixed H)",
                "s2,rho2,l2,r",
                Arm {
                    name: "swarm-fixed".into(),
                    algo: "swarm".into(),
                    mode: AveragingMode::NonBlocking,
                    local_steps: LocalSteps::Fixed(h),
                    t,
                    lr: LrSchedule::Theory { n, t },
                    h_localsgd: 5,
                },
            ),
            (
                "AD-PSGD",
                "s2,l2,tau",
                Arm {
                    lr: LrSchedule::Theory { n, t },
                    ..Arm::baseline("adpsgd", "adpsgd", t, 0.0)
                },
            ),
            (
                "SGP",
                "s2,d,Delta,tau",
                Arm {
                    lr: LrSchedule::Theory { n, t: t / n as u64 },
                    ..Arm::baseline("sgp", "sgp", t / n as u64, 0.0)
                },
            ),
        ] {
            // run and sample μ_t gradient norms through the curve
            let spec = BackendSpec::Quadratic { dim, spread, sigma, seed };
            let every = (arm.t / 32).max(1);
            let m = run_arm(&arm, &spec, n, topo, &cost, 7, every, false)?;
            // measured: oracle grad-norm² at the recorded mean-model losses.
            // we reuse eval_loss-to-gradient relation by re-probing μ via
            // loss-minimizing trick: we stored μ's loss, so instead measure
            // via a fresh run-level estimate: E||grad||² ≈ 2·L·(f(μ)−f*) is
            // an upper proxy; use exact when available.
            let oracle = QuadraticOracle::new(dim, n, spread, 0.5, 2.0, sigma, seed);
            let f_star = oracle.f_star();
            let measured: f64 = {
                // smoothness bound ‖∇f(μ)‖² ≤ 2L(f(μ) − f*) — exact enough
                // for a quadratic with known L to compare against the thms
                let vals: Vec<f64> = m
                    .curve
                    .iter()
                    .map(|p| 2.0 * l * (p.eval_loss - f_star).max(0.0))
                    .collect();
                vals.iter().sum::<f64>() / vals.len().max(1) as f64
            };
            // empirical rate exponent over the decay transient
            let p_fit = {
                let samples = gap_samples(&m.curve, f_star);
                let tail: Vec<f64> = samples[samples.len() * 3 / 4..]
                    .iter()
                    .map(|s| s.1)
                    .collect();
                let floor = tail.iter().cloned().fold(f64::INFINITY, f64::min);
                let prefix: Vec<(f64, f64)> = samples
                    .iter()
                    .copied()
                    .take_while(|&(_, g)| g > 2.0 * floor.max(1e-12))
                    .collect();
                fit_power_law(&prefix).map(|(p, _, _)| p)
            };
            table.row(&[
                algo.to_string(),
                assume.to_string(),
                format!("{topo:?}"),
                format!("{lambda2:.3}"),
                format!("{measured:.4}"),
                p_fit.map(|p| format!("{p:.2}")).unwrap_or("-".into()),
                format!("{b41:.1}"),
                format!("{b42:.1}"),
                format!("{}", theorem41_t_ok(&bp)),
            ]);
            let _ = csv.row_mixed(&[
                CsvVal::S(algo.into()),
                CsvVal::S(format!("{topo:?}")),
                CsvVal::F(lambda2),
                CsvVal::F(measured),
                CsvVal::F(b41),
                CsvVal::F(b42),
            ]);
        }
    }

    println!("\nTable 2 — assumptions & measured rates vs theory bounds");
    println!("(quadratic oracle: n={n} d={dim} L={l:.2} sigma={sigma} T={t})");
    table.print();
    println!(
        "\npaper shape: all methods O(1/sqrt(Tn)); measured values sit well \
         below the (loose, constant-heavy) theorem bounds; ring (small λ₂) \
         degrades vs complete/hypercube."
    );
    csv.flush().map_err(|e| e.to_string())
}
