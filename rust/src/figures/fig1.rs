//! Figure 1 — the Transformer/WMT task (synthetic Markov corpus stand-in):
//! (a) loss/accuracy vs simulated time at 16 & 32 nodes, multiplier 1;
//! (b) throughput (local steps/s) vs node count.
//!
//! Paper shape: LB-SGD throughput collapses for the large model; Swarm is
//! ~1.5x faster end-to-end at 16 nodes and beats AD-PSGD (~30% slower) and
//! local SGD; per-node time stays ~constant as n grows.

use super::common::{paper_cost, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::topology::Topology;
use std::path::Path;

/// Budget-matched arms: every method performs `steps_per_node` local SGD
/// steps per node (multiplier 1 — same data passes for everyone).
fn arms(steps_per_node: u64, n: usize, lr: f32) -> Vec<Arm> {
    let s = steps_per_node;
    vec![
        // swarm: each interaction = 2 endpoints x H=2 steps over n nodes
        Arm::swarm("SwarmSGD H=2", 2, s * n as u64 / 4, lr),
        Arm {
            lr: LrSchedule::Constant(lr),
            // adpsgd: 2 steps per interaction over n nodes
            ..Arm::baseline("AD-PSGD", "adpsgd", s * n as u64 / 2, lr)
        },
        Arm {
            h_localsgd: 5,
            // localsgd: 5 steps/node per communication round
            ..Arm::baseline("Local SGD (H=5)", "localsgd", s / 5, lr)
        },
        // allreduce: 1 step/node per round
        Arm::baseline("LB-SGD", "allreduce", s, lr),
    ]
}

pub fn run_a(quick: bool, out_dir: &Path) -> Result<(), String> {
    let preset = "transformer_xs"; // CPU-tractable stand-in (DESIGN.md §2)
    let (steps_per_node, data) = if quick { (20u64, 4096usize) } else { (60, 8192) };
    let lr = 0.25;
    let cost = paper_cost("transformer");

    let mut table = Table::new(&[
        "nodes", "method", "final loss", "token acc", "sim time (s)", "epochs",
    ]);
    let mut all = Vec::new();
    for n in [16usize, 32] {
        let spec = BackendSpec::xla(preset, n, data / n, 23);
        for arm in arms(steps_per_node, n, lr) {
            let every = (arm.t / 12).max(1);
            let mut m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 55, every, false)?;
            m.name = format!("n{n} {}", arm.name);
            table.row(&[
                n.to_string(),
                arm.name.clone(),
                format!("{:.4}", m.final_eval_loss),
                format!("{:.3}", m.final_eval_acc),
                format!("{:.0}", m.sim_time),
                format!("{:.2}", m.epochs),
            ]);
            all.push(m);
        }
    }
    println!("\nFigure 1(a) — Transformer loss vs (simulated) time, multiplier 1:");
    table.print();
    write_curves(&out_dir.join("fig1a_curves.csv"), &all).map_err(|e| e.to_string())?;
    println!("curves -> results/fig1a_curves.csv");
    println!(
        "\npaper shape: Swarm reaches the lowest loss per unit time; AD-PSGD \
         trails (communicates every step); LB-SGD is slowest at this scale."
    );
    Ok(())
}

pub fn run_b(quick: bool, out_dir: &Path) -> Result<(), String> {
    let preset = "transformer_xs";
    let nodes: &[usize] = if quick { &[8, 16] } else { &[8, 16, 32, 64] };
    let steps_per_node = if quick { 10u64 } else { 25 };
    let lr = 0.25;
    let cost = paper_cost("transformer");

    let mut table = Table::new(&["nodes", "method", "steps/s", "sim time", "steps"]);
    let mut csv = CsvWriter::create(
        out_dir.join("fig1b_throughput.csv"),
        &["nodes", "method", "steps_per_sec"],
    )
    .map_err(|e| e.to_string())?;
    for &n in nodes {
        let spec = BackendSpec::xla(preset, n, 2048, 23);
        for arm in arms(steps_per_node, n, lr) {
            let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 55, 0, false)?;
            let tput = m.steps_per_sec();
            table.row(&[
                n.to_string(),
                arm.name.clone(),
                format!("{tput:.2}"),
                format!("{:.0}", m.sim_time),
                m.local_steps.to_string(),
            ]);
            csv.row_mixed(&[
                CsvVal::I(n as i64),
                CsvVal::S(arm.name.clone()),
                CsvVal::F(tput),
            ])
            .map_err(|e| e.to_string())?;
        }
    }
    println!("\nFigure 1(b) — throughput scaling (simulated cluster):");
    table.print();
    println!(
        "\npaper shape: Swarm throughput grows ~linearly in n; LB-SGD \
         saturates (allreduce of a ~840MB model dominates)."
    );
    csv.flush().map_err(|e| e.to_string())
}
