//! Figure 8 — quantized SwarmSGD (WideResNet-28-2/CIFAR-10 slot, multiplier
//! 1): (a) convergence vs steps — quantized tracks full-precision within
//! <0.3% accuracy; (b) convergence vs time — ~10% end-to-end speedup from
//! 8-bit lattice exchange.

use super::common::{interactions_for_epochs, paper_cost, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::{AveragingMode, LocalSteps, LrSchedule};
use crate::output::Table;
use crate::topology::Topology;
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path, time_axis: bool) -> Result<(), String> {
    let (preset, n, data, epochs) = if quick {
        ("mlp_s", 8usize, 256usize, 4.0f64)
    } else {
        ("cnn_s", 8, 512, 12.0)
    };
    let batch = 32;
    let h = 2u64;
    let lr = 0.05;
    // the quantized variant's time win comes from shipping ~4x fewer bytes
    let cost = paper_cost("wideresnet28");
    let spec = BackendSpec::xla(preset, n, data, 97);
    let t = interactions_for_epochs(epochs, n, h as f64, data, batch);

    let arms = vec![
        Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t },
            ..Arm::swarm("Swarm fp32", h, t, lr)
        },
        Arm {
            name: "Swarm 8-bit lattice".into(),
            algo: "swarm".into(),
            mode: AveragingMode::Quantized { bits: 8, eps: 2e-3 },
            local_steps: LocalSteps::Fixed(h),
            t,
            lr: LrSchedule::StepDecay { base: lr, total: t },
            h_localsgd: 5,
        },
        Arm {
            name: "Swarm 4-bit lattice".into(),
            algo: "swarm".into(),
            mode: AveragingMode::Quantized { bits: 4, eps: 2e-3 },
            local_steps: LocalSteps::Fixed(h),
            t,
            lr: LrSchedule::StepDecay { base: lr, total: t },
            h_localsgd: 5,
        },
    ];

    let axis = if time_axis { "time" } else { "steps" };
    let mut table = Table::new(&[
        "variant", "final acc", "final loss", "sim time (s)", "GB on wire", "fallbacks",
    ]);
    let mut all = Vec::new();
    for arm in arms {
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 13, (t / 12).max(1), false)?;
        table.row(&[
            arm.name.clone(),
            format!("{:.4}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.1}", m.sim_time),
            format!("{:.3}", m.total_bits as f64 / 8e9),
            m.quant_fallbacks.to_string(),
        ]);
        all.push(m);
    }
    println!("\nFigure 8({}) — quantized Swarm vs fp32, multiplier 1 ({preset}, n={n}):",
             if time_axis { "b" } else { "a" });
    table.print();
    let f = if time_axis { "fig8b_curves.csv" } else { "fig8a_curves.csv" };
    write_curves(&out_dir.join(f), &all).map_err(|e| e.to_string())?;
    println!(
        "\npaper shape ({axis} axis): 8-bit matches fp32 accuracy within \
         ~0.3%; the quantized variant finishes ~10% sooner (smaller \
         exchanges), and 4-bit starts to cost accuracy/fallbacks."
    );
    Ok(())
}
