//! Figure 5 — convergence vs (simulated) time: LB-SGD vs SwarmSGD with the
//! paper's 2.7x epoch multiplier.  The extra passes roughly cancel Swarm's
//! per-step speed advantage on the vision workload — the paper's honest
//! negative result.

use super::common::{interactions_for_epochs, paper_cost, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::output::Table;
use crate::topology::Topology;
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let (preset, n, data, epochs) = if quick {
        ("mlp_s", 8usize, 256usize, 4.0f64)
    } else {
        ("cnn_m", 8, 384, 6.0)
    };
    let batch = 32;
    let lr = 0.05;
    let cost = paper_cost("resnet18");
    let spec = BackendSpec::xla(preset, n, data, 47);

    // LB-SGD for `epochs` epochs
    let lb_rounds = (epochs * data as f64 / batch as f64) as u64;
    let lb = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr, total: lb_rounds },
            ..Arm::baseline("LB-SGD", "allreduce", lb_rounds, lr)
        },
        &spec,
        n,
        Topology::Complete,
        &cost,
        61,
        (lb_rounds / 12).max(1),
        false,
    )?;

    // Swarm for 2.7x the epochs
    let h = 3u64;
    let t = interactions_for_epochs(epochs * 2.7, n, h as f64, data, batch);
    let swarm = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t },
            ..Arm::swarm("SwarmSGD H=3 x2.7", h, t, lr)
        },
        &spec,
        n,
        Topology::Complete,
        &cost,
        61,
        (t / 12).max(1),
        false,
    )?;

    let mut table = Table::new(&[
        "method", "final acc", "final loss", "sim time (s)", "epochs/agent",
    ]);
    for m in [&lb, &swarm] {
        table.row(&[
            m.name.clone(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.0}", m.sim_time),
            format!("{:.2}", m.epochs),
        ]);
    }
    println!("\nFigure 5 — end-to-end time, LB-SGD vs Swarm(2.7x epochs), n={n}:");
    table.print();
    write_curves(&out_dir.join("fig5_curves.csv"), &[lb, swarm]).map_err(|e| e.to_string())?;
    println!(
        "\npaper shape: similar end-to-end runtime — Swarm's per-iteration \
         scalability is offset by the 2.7x extra passes on this workload."
    );
    Ok(())
}
