//! Shared machinery for the figure/table harnesses: backend factories and
//! a uniform "arm" runner so every figure compares algorithms on identical
//! data, topology, and cost models. Arms are dispatched through the
//! [`make_algorithm`] factory — the same path as the CLI's `--algorithm`
//! selector — and run on the serial executor.

use crate::backend::Backend;
use crate::config::ShardMode;
use crate::coordinator::{
    make_algorithm, run_serial, AlgoOptions, AveragingMode, LocalSteps, LrSchedule, RunMetrics,
    RunSpec,
};
use crate::grad::{QuadraticOracle, SoftmaxOracle};
use crate::netmodel::CostModel;
use crate::output::CsvWriter;
use crate::rngx::Pcg64;
use crate::runtime::{XlaBackend, XlaBackendConfig};
use crate::topology::{Graph, Topology};
use std::path::{Path, PathBuf};

/// Which compute backend a figure runs on.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// heterogeneous quadratic (theory figures)
    Quadratic { dim: usize, spread: f64, sigma: f64, seed: u64 },
    /// linear softmax on Gaussian mixture (large-n scaling)
    Softmax { n_train: usize, dim: usize, classes: usize, batch: usize, seed: u64 },
    /// the real three-layer path
    Xla { preset: String, artifacts: PathBuf, cfg: XlaBackendConfig },
}

impl BackendSpec {
    pub fn xla(preset: &str, agents: usize, data_per_agent: usize, seed: u64) -> Self {
        Self::xla_sep(preset, agents, data_per_agent, seed, 3.0)
    }

    /// Like [`BackendSpec::xla`] with a custom class separation (smaller =
    /// harder task; used where the figure needs methods to differentiate).
    pub fn xla_sep(
        preset: &str,
        agents: usize,
        data_per_agent: usize,
        seed: u64,
        separation: f32,
    ) -> Self {
        BackendSpec::Xla {
            preset: preset.to_string(),
            artifacts: PathBuf::from("artifacts"),
            cfg: XlaBackendConfig {
                agents,
                data_per_agent,
                shard: ShardMode::Iid,
                separation,
                seed,
                eval_batches: 2,
            },
        }
    }

    /// Build a fresh backend (same seed → same data across arms).
    pub fn build(&self, agents: usize) -> Result<Box<dyn Backend>, String> {
        Ok(match self {
            BackendSpec::Quadratic { dim, spread, sigma, seed } => Box::new(
                QuadraticOracle::new(*dim, agents, *spread, 0.5, 2.0, *sigma, *seed),
            ),
            BackendSpec::Softmax { n_train, dim, classes, batch, seed } => Box::new(
                SoftmaxOracle::synthetic(*n_train, *dim, *classes, agents, *batch, 4.0, *seed),
            ),
            BackendSpec::Xla { preset, artifacts, cfg } => {
                let mut c = cfg.clone();
                c.agents = agents;
                Box::new(
                    XlaBackend::load(artifacts, preset, c)
                        .map_err(|e| format!("XLA backend: {e:#}"))?,
                )
            }
        })
    }
}

/// One comparison arm: an algorithm + its knobs.
#[derive(Clone, Debug)]
pub struct Arm {
    pub name: String,
    /// swarm | poisson | adpsgd | dpsgd | sgp | localsgd | allreduce
    pub algo: String,
    pub mode: AveragingMode,
    pub local_steps: LocalSteps,
    /// interactions (gossip) or rounds (synchronous)
    pub t: u64,
    pub lr: LrSchedule,
    /// local-SGD communication period
    pub h_localsgd: u64,
}

impl Arm {
    pub fn swarm(name: &str, h: u64, t: u64, lr: f32) -> Self {
        Self {
            name: name.into(),
            algo: "swarm".into(),
            mode: AveragingMode::NonBlocking,
            local_steps: LocalSteps::Fixed(h),
            t,
            lr: LrSchedule::Constant(lr),
            h_localsgd: 5,
        }
    }

    pub fn baseline(name: &str, algo: &str, t: u64, lr: f32) -> Self {
        Self {
            name: name.into(),
            algo: algo.into(),
            mode: AveragingMode::NonBlocking,
            local_steps: LocalSteps::Fixed(1),
            t,
            lr: LrSchedule::Constant(lr),
            h_localsgd: 5,
        }
    }
}

/// Run one arm on a fresh backend. All stochastic choices derive from
/// `seed`, so arms are reproducible and comparable.
#[allow(clippy::too_many_arguments)]
pub fn run_arm(
    arm: &Arm,
    spec: &BackendSpec,
    n: usize,
    topo: Topology,
    cost: &CostModel,
    seed: u64,
    eval_every: u64,
    track_gamma: bool,
) -> Result<RunMetrics, String> {
    let backend = spec.build(n)?;
    let mut rng = Pcg64::seed(seed);
    let graph = Graph::build(topo, n, &mut rng);
    let algo = make_algorithm(
        &arm.algo,
        &AlgoOptions {
            local_steps: arm.local_steps,
            mode: arm.mode,
            h_localsgd: arm.h_localsgd,
            ..AlgoOptions::default()
        },
    )?;
    let run = RunSpec {
        n,
        events: arm.t,
        lr: arm.lr,
        seed,
        name: arm.name.clone(),
        eval_every,
        track_gamma,
    };
    Ok(run_serial(algo.as_ref(), backend.as_ref(), &run, &graph, cost))
}

/// Dump the loss curves of several runs into one long-format CSV.
pub fn write_curves(path: &Path, runs: &[RunMetrics]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "arm", "t", "parallel_time", "sim_time", "epochs", "train_loss",
            "eval_loss", "eval_acc", "indiv_loss", "gamma", "bits",
        ],
    )?;
    for r in runs {
        for p in &r.curve {
            w.row_mixed(&[
                crate::output::CsvVal::S(r.name.clone()),
                crate::output::CsvVal::I(p.t as i64),
                crate::output::CsvVal::F(p.parallel_time),
                crate::output::CsvVal::F(p.sim_time),
                crate::output::CsvVal::F(p.epochs),
                crate::output::CsvVal::F(p.train_loss),
                crate::output::CsvVal::F(p.eval_loss),
                crate::output::CsvVal::F(p.eval_acc),
                crate::output::CsvVal::F(p.indiv_loss),
                crate::output::CsvVal::F(p.gamma),
                crate::output::CsvVal::I(p.bits as i64),
            ])?;
        }
    }
    w.flush()
}

/// Interactions needed for a target number of epochs-per-agent under
/// SwarmSGD: each interaction contributes 2H local steps spread over n
/// agents; one epoch/agent = data_per_agent / batch steps.
pub fn interactions_for_epochs(
    epochs: f64,
    n: usize,
    h: f64,
    data_per_agent: usize,
    batch: usize,
) -> u64 {
    let steps_per_epoch = data_per_agent as f64 / batch as f64;
    (epochs * steps_per_epoch * n as f64 / (2.0 * h)).ceil() as u64
}

/// Paper-style cost model used by the timing figures: Fig-4's 0.4 s
/// compute base and a wire size override matching the named paper model.
pub fn paper_cost(paper_model: &str) -> CostModel {
    let bytes = match paper_model {
        "resnet18" => 45_000_000,      // ~11.2M params
        "resnet50" => 100_000_000,     // ~25.5M params
        "transformer" => 840_000_000,  // Transformer-large ~210M params
        "wideresnet28" => 6_000_000,   // WRN-28-2 ~1.5M params
        _ => 45_000_000,
    };
    CostModel {
        batch_time: 0.4,
        jitter: 0.05,
        straggler_prob: 0.01,
        straggle_factor: 2.0,
        model_bytes_override: Some(bytes),
        // effective per-flow bandwidth calibrated so a ResNet18 exchange
        // costs ~150 ms, matching the paper's measured Fig-4 comm shares
        // (far below the Aries peak: protocol + framework overheads)
        bandwidth: 0.3e9,
        latency: 5e-5,
        ..CostModel::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interactions_for_epochs_math() {
        // 512/32 = 16 steps/epoch; ×8 agents / (2·2) = 32 interactions/epoch
        assert_eq!(interactions_for_epochs(1.0, 8, 2.0, 512, 32), 32);
        assert_eq!(interactions_for_epochs(2.0, 8, 2.0, 512, 32), 64);
    }

    #[test]
    fn oracle_arm_runs() {
        let spec = BackendSpec::Quadratic { dim: 8, spread: 1.0, sigma: 0.05, seed: 3 };
        let arm = Arm::swarm("s", 2, 100, 0.05);
        let cost = CostModel::deterministic(0.1);
        let m = run_arm(&arm, &spec, 4, Topology::Complete, &cost, 7, 50, false).unwrap();
        assert_eq!(m.interactions, 100);
        assert!(m.final_eval_loss.is_finite());
    }

    #[test]
    fn all_baseline_arms_run() {
        let spec = BackendSpec::Quadratic { dim: 8, spread: 1.0, sigma: 0.05, seed: 3 };
        let cost = CostModel::deterministic(0.1);
        for algo in ["adpsgd", "dpsgd", "sgp", "localsgd", "allreduce"] {
            let arm = Arm::baseline(algo, algo, 50, 0.05);
            let m = run_arm(&arm, &spec, 4, Topology::Complete, &cost, 7, 0, false)
                .unwrap_or_else(|e| panic!("{algo}: {e}"));
            assert!(m.final_eval_loss.is_finite(), "{algo}");
        }
    }
}
