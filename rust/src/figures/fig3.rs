//! Figure 3(a) — ResNet50/ImageNet slot: SwarmSGD recovers the baseline
//! accuracy on the deeper CNN preset, tracked vs gradient steps.

use super::common::{interactions_for_epochs, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::netmodel::CostModel;
use crate::output::Table;
use crate::topology::Topology;
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let (preset, n, data, epochs) = if quick {
        ("cnn_s", 4usize, 256usize, 6.0f64)
    } else {
        ("cnn_m", 8, 384, 8.0)
    };
    let batch = 32;
    let lr = 0.05;
    let cost = CostModel::deterministic(0.4);
    let spec = BackendSpec::xla(preset, n, data, 41);

    // single-node SGD reference
    let sgd_rounds = (epochs * data as f64 * n as f64 / batch as f64) as u64 / n as u64;
    let sgd = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr, total: sgd_rounds },
            ..Arm::baseline("SGD baseline", "allreduce", sgd_rounds, lr)
        },
        &BackendSpec::xla(preset, 1, data * n, 41),
        1,
        Topology::Complete,
        &cost,
        19,
        (sgd_rounds / 10).max(1),
        false,
    )?;

    // Swarm with 2x multiplier (paper: ResNet50 needed 240/90 ≈ 2.7x)
    let h = 2u64;
    let t = interactions_for_epochs(epochs * 2.0, n, h as f64, data, batch);
    let swarm = run_arm(
        &Arm {
            lr: LrSchedule::StepDecay { base: lr, total: t },
            ..Arm::swarm("SwarmSGD H=2 x2.0", h, t, lr)
        },
        &spec,
        n,
        Topology::Complete,
        &cost,
        19,
        (t / 10).max(1),
        false,
    )?;

    let mut table = Table::new(&["method", "final acc", "final loss", "epochs/agent"]);
    for m in [&sgd, &swarm] {
        table.row(&[
            m.name.clone(),
            format!("{:.3}", m.final_eval_acc),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.2}", m.epochs),
        ]);
    }
    println!("\nFigure 3(a) — deep-CNN accuracy recovery ({preset}, n={n}):");
    table.print();
    write_curves(&out_dir.join("fig3a_curves.csv"), &[sgd, swarm])
        .map_err(|e| e.to_string())?;
    println!(
        "\npaper shape: Swarm recovers the baseline top accuracy given the \
         epoch multiplier."
    );
    Ok(())
}
