//! Γ_t analysis figure (paper §4 / Lemma F.3): the model-variance potential
//! stays bounded independently of t, scales ~H² in the local steps, and is
//! controlled by the topology's r²/λ₂² — measured against the closed-form
//! Lemma F.3 bound on a quadratic with known constants.

use super::common::{run_arm, Arm, BackendSpec};
use crate::analysis::lemma_f3_bound;
use crate::coordinator::LrSchedule;
use crate::netmodel::CostModel;
use crate::output::{CsvVal, CsvWriter, Table};
use crate::rngx::Pcg64;
use crate::topology::{Graph, Topology};
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let n = 16usize;
    let t: u64 = if quick { 4000 } else { 20000 };
    let dim = 16;
    let sigma = 0.5;
    let eta = 0.02f32;
    let cost = CostModel::deterministic(1.0);

    let mut table = Table::new(&[
        "topology", "H", "lambda2", "steady Gamma", "max Gamma", "F.3 bound", "bound/measured",
    ]);
    let mut csv = CsvWriter::create(
        out_dir.join("gamma.csv"),
        &["topology", "h", "lambda2", "steady_gamma", "max_gamma", "f3_bound"],
    )
    .map_err(|e| e.to_string())?;

    // M² estimate: gradient second moment near the operating region
    let m_sq = {
        let o = crate::grad::QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 41);
        let g = o.true_grad(&vec![0.0; dim]);
        g.iter().map(|v| v * v).sum::<f64>() + sigma * sigma * dim as f64
    };

    for topo in [Topology::Complete, Topology::Hypercube, Topology::Ring] {
        let (lambda2, r) = {
            let mut rng = Pcg64::seed(2);
            let g = Graph::build(topo, n, &mut rng);
            (g.lambda2(), g.regular_degree().unwrap() as f64)
        };
        for h in [1u64, 2, 4, 8] {
            let spec = BackendSpec::Quadratic { dim, spread: 1.0, sigma, seed: 41 };
            let arm = Arm {
                lr: LrSchedule::Constant(eta),
                ..Arm::swarm(&format!("{topo:?}-H{h}"), h, t, eta)
            };
            let m = run_arm(&arm, &spec, n, topo, &cost, 3, (t / 64).max(1), true)?;
            let gammas: Vec<f64> = m
                .curve
                .iter()
                .map(|p| p.gamma)
                .filter(|g| g.is_finite())
                .collect();
            let steady = gammas[gammas.len() / 2..].iter().sum::<f64>()
                / (gammas.len() - gammas.len() / 2) as f64;
            let gmax = gammas.iter().cloned().fold(0.0, f64::max);
            let bound = lemma_f3_bound(r, lambda2, n, eta as f64, h as f64, m_sq);
            table.row(&[
                format!("{topo:?}"),
                h.to_string(),
                format!("{lambda2:.3}"),
                format!("{steady:.4}"),
                format!("{gmax:.4}"),
                format!("{bound:.2}"),
                format!("{:.0}x", bound / steady.max(1e-12)),
            ]);
            csv.row_mixed(&[
                CsvVal::S(format!("{topo:?}")),
                CsvVal::I(h as i64),
                CsvVal::F(lambda2),
                CsvVal::F(steady),
                CsvVal::F(gmax),
                CsvVal::F(bound),
            ])
            .map_err(|e| e.to_string())?;
        }
    }
    println!("\nGamma potential vs Lemma F.3 bound (n={n}, eta={eta}, T={t}):");
    table.print();
    println!(
        "\npaper shape: Γ_t is bounded independent of t; grows ~H²; ring \
         (small λ₂) concentrates worse than complete/hypercube; the F.3 \
         bound holds with (large) constant slack."
    );
    csv.flush().map_err(|e| e.to_string())
}
