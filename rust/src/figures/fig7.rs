//! Figure 7 — Transformer objective loss vs (simulated) time at 16 nodes,
//! all methods on one axis.

use super::common::{paper_cost, run_arm, write_curves, Arm, BackendSpec};
use crate::coordinator::LrSchedule;
use crate::output::Table;
use crate::topology::Topology;
use std::path::Path;

pub fn run(quick: bool, out_dir: &Path) -> Result<(), String> {
    let n = 16usize;
    // per-node local-step budget (multiplier 1 across all methods)
    let (s_node, data) = if quick { (16u64, 4096usize) } else { (50, 8192) };
    let t = s_node * n as u64 / 4; // swarm interactions for that budget
    let lr = 0.25;
    let cost = paper_cost("transformer");
    let spec = BackendSpec::xla("transformer_xs", n, data / n, 73);

    let arms = vec![
        Arm::swarm("SwarmSGD H=2", 2, t, lr),
        Arm {
            lr: LrSchedule::Constant(lr),
            ..Arm::baseline("AD-PSGD", "adpsgd", s_node * n as u64 / 2, lr)
        },
        Arm::baseline("D-PSGD", "dpsgd", s_node, lr),
        Arm::baseline("SGP", "sgp", s_node, lr),
        Arm {
            h_localsgd: 5,
            ..Arm::baseline("Local SGD (H=5)", "localsgd", s_node / 5, lr)
        },
        Arm::baseline("LB-SGD", "allreduce", s_node, lr),
    ];

    let mut table = Table::new(&["method", "final loss", "sim time (s)", "loss@t/2"]);
    let mut all = Vec::new();
    for arm in arms {
        let m = run_arm(&arm, &spec, n, Topology::Complete, &cost, 83, (arm.t / 10).max(1), false)?;
        let mid = m
            .curve
            .get(m.curve.len() / 2)
            .map(|p| p.eval_loss)
            .unwrap_or(f64::NAN);
        table.row(&[
            arm.name.clone(),
            format!("{:.4}", m.final_eval_loss),
            format!("{:.0}", m.sim_time),
            format!("{mid:.4}"),
        ]);
        all.push(m);
    }
    println!("\nFigure 7 — Transformer loss vs time at 16 nodes:");
    table.print();
    write_curves(&out_dir.join("fig7_curves.csv"), &all).map_err(|e| e.to_string())?;
    println!(
        "\npaper shape: Swarm's loss-vs-time curve dominates; AD-PSGD next; \
         LB-SGD slowest for the large model."
    );
    Ok(())
}
