//! Runtime-selected fused merge kernels — the one-pass quantize-average
//! primitive behind every merge path (ROADMAP item 2).
//!
//! Every interaction in every executor runs the same inner loop: decode the
//! partner's lattice payload, combine it with the local model under the
//! policy's rule, and hand the result back for publication. The two-pass
//! reference ([`crate::coordinator::quantized_transfer`] followed by a
//! separate averaging sweep) walks the model twice and allocates the decoded
//! vector; the fused kernels here do decode + merge in a **single traversal**
//! writing into a caller-provided buffer, with zero allocation.
//!
//! Two implementations are selectable at runtime via `--kernel` (INI
//! `kernel=`, default `scalar`):
//!
//! - [`Kernel::Scalar`] — the reference loop, element at a time, folding the
//!   checksums in element order. This is *definitionally* bit-identical to
//!   the two-pass `encode → pack → unpack → decode → merge` path: packing is
//!   lossless for residues in `[0, 2^bits)` and the per-element arithmetic
//!   is the same operations in the same order.
//! - [`Kernel::Simd`] — processes f32 lanes in chunks of 8 through
//!   fixed-size array temporaries that LLVM auto-vectorizes (stable Rust;
//!   `std::simd` is still nightly-only). All lane math is elementwise with
//!   no reduction-order change, and the checksums are folded scalar-wise in
//!   element order after each chunk, so this path is **bit-exact** with the
//!   scalar kernel — which is why the replay executors may select it too
//!   without breaking the parallel ≡ serial contract. The property tests in
//!   `tests/fused_kernels.rs` pin this equivalence.
//!
//! The kernels are reached through [`crate::coordinator::MergeScratch`]
//! (per-worker reusable buffers) so the hot path allocates nothing per
//! interaction.
//!
//! # Example
//!
//! Fused quantize-average versus the two-pass reference:
//!
//! ```
//! use swarm_sgd::coordinator::quantized_transfer;
//! use swarm_sgd::kernels::{lattice_qavg_into, Kernel};
//!
//! let remote: Vec<f32> = (0..64).map(|i| i as f32 * 1e-3).collect();
//! let local: Vec<f32> = remote.iter().map(|v| v + 5e-3).collect();
//! let (eps, bits, seed) = (1e-3, 8, 42);
//!
//! // two passes: decode the remote model, then average separately
//! let tr = quantized_transfer(&remote, &local, eps, bits, seed);
//! let want: Vec<f32> =
//!     local.iter().zip(&tr.decoded).map(|(l, d)| 0.5 * (l + d)).collect();
//!
//! // one pass: decode + average fused, into a caller buffer
//! let mut out = vec![0.0f32; remote.len()];
//! let (wire, fell_back) =
//!     lattice_qavg_into(Kernel::Scalar, &remote, &local, eps, bits, seed, &mut out);
//!
//! assert_eq!(out, want);
//! assert_eq!(wire, tr.bits);
//! assert!(!fell_back && !tr.fell_back);
//! ```

use crate::quant::{checksum_step, uniform01, CHECKSUM_INIT};

/// Valid `--kernel` values, in the order the CLI lists them.
pub const KERNEL_NAMES: &[&str] = &["scalar", "simd"];

/// Chunk width of the vectorized lane path (f32x8 ≙ one AVX2 register).
const LANES: usize = 8;

/// Which fused-kernel implementation the merge paths dispatch to.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Kernel {
    /// Element-at-a-time reference loop (the default).
    #[default]
    Scalar,
    /// Chunk-of-8 lane path; bit-exact with `Scalar` (see module docs).
    Simd,
}

impl Kernel {
    /// The wire/config name (`scalar` / `simd`).
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
        }
    }

    /// Parse a `kernel=`/`--kernel` value, listing valid options on error.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "scalar" => Ok(Kernel::Scalar),
            "simd" => Ok(Kernel::Simd),
            other => Err(format!(
                "unknown kernel '{other}' (known: {})",
                KERNEL_NAMES.join("|")
            )),
        }
    }
}

/// out ← (a + b)/2, elementwise.
pub fn avg_into(kernel: Kernel, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    match kernel {
        Kernel::Scalar => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = 0.5 * (x + y);
            }
        }
        Kernel::Simd => {
            let n = a.len();
            let mut i = 0;
            while i + LANES <= n {
                let mut va = [0.0f32; LANES];
                let mut vb = [0.0f32; LANES];
                va.copy_from_slice(&a[i..i + LANES]);
                vb.copy_from_slice(&b[i..i + LANES]);
                let mut vo = [0.0f32; LANES];
                for l in 0..LANES {
                    vo[l] = 0.5 * (va[l] + vb[l]);
                }
                out[i..i + LANES].copy_from_slice(&vo);
                i += LANES;
            }
            for k in i..n {
                out[k] = 0.5 * (a[k] + b[k]);
            }
        }
    }
}

/// out ← b/2, elementwise (the push-sum "take half" rule).
pub fn half_into(kernel: Kernel, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(b.len(), out.len());
    match kernel {
        Kernel::Scalar => {
            for (o, &y) in out.iter_mut().zip(b) {
                *o = 0.5 * y;
            }
        }
        Kernel::Simd => {
            let n = b.len();
            let mut i = 0;
            while i + LANES <= n {
                let mut vb = [0.0f32; LANES];
                vb.copy_from_slice(&b[i..i + LANES]);
                let mut vo = [0.0f32; LANES];
                for l in 0..LANES {
                    vo[l] = 0.5 * vb[l];
                }
                out[i..i + LANES].copy_from_slice(&vo);
                i += LANES;
            }
            for k in i..n {
                out[k] = 0.5 * b[k];
            }
        }
    }
}

/// In-place midpoint of both operands: a ← b ← (a+b)/2 — the kernelized
/// [`crate::coordinator::average_into_both`], bit-identical on both paths.
pub fn avg_into_both(kernel: Kernel, a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    match kernel {
        Kernel::Scalar => {
            for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                let m = 0.5 * (*x + *y);
                *x = m;
                *y = m;
            }
        }
        Kernel::Simd => {
            let n = a.len();
            let mut i = 0;
            while i + LANES <= n {
                let mut va = [0.0f32; LANES];
                let mut vb = [0.0f32; LANES];
                va.copy_from_slice(&a[i..i + LANES]);
                vb.copy_from_slice(&b[i..i + LANES]);
                let mut vm = [0.0f32; LANES];
                for l in 0..LANES {
                    vm[l] = 0.5 * (va[l] + vb[l]);
                }
                a[i..i + LANES].copy_from_slice(&vm);
                b[i..i + LANES].copy_from_slice(&vm);
                i += LANES;
            }
            for k in i..n {
                let m = 0.5 * (a[k] + b[k]);
                a[k] = m;
                b[k] = m;
            }
        }
    }
}

/// What the fused lattice traversal does with each decoded coordinate.
#[derive(Clone, Copy)]
enum FuseRule {
    /// out ← (reference + decoded)/2 — pair averaging.
    Qavg,
    /// out ← decoded/2 — push-sum take-half.
    TakeHalf,
    /// out ← decoded — plain decode (the `decode_into` codec entry point).
    Decode,
}

#[inline(always)]
fn fuse(rule: FuseRule, reference: f32, dec: f32) -> f32 {
    match rule {
        FuseRule::Qavg => 0.5 * (reference + dec),
        FuseRule::TakeHalf => 0.5 * dec,
        FuseRule::Decode => dec,
    }
}

/// One element of the fused traversal: the sender's true lattice coordinate
/// of `x` and the receiver's nearest-representative reconstruction against
/// `y` — exactly `encode` + `decode` without the pack/unpack round (lossless
/// for residues `< 2^bits`, so bit-identical).
#[inline(always)]
fn lattice_coords(x: f32, y: f32, eps: f32, u: f32, m: i64, half: i64) -> (i64, i64) {
    let c = (x / eps + u).floor() as i64;
    let r = c.rem_euclid(m);
    let yc = (y / eps + u).floor() as i64;
    let mut diff = (r - yc.rem_euclid(m)) % m;
    if diff >= half {
        diff -= m;
    } else if diff < -half {
        diff += m;
    }
    (c, yc + diff)
}

/// Shared core of the fused lattice kernels: quantize `remote`, decode it
/// against `reference`, apply `rule`, all in one traversal. Returns
/// `(wire_bits, fell_back)` with the exact accounting of the two-pass path
/// ([`crate::coordinator::quantized_transfer`]): on checksum mismatch the
/// result is recomputed from the full-precision `remote` and the failed
/// attempt plus the 32-bit/coord resend are both charged.
fn lattice_fused(
    kernel: Kernel,
    rule: FuseRule,
    remote: &[f32],
    reference: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
    out: &mut [f32],
) -> (u64, bool) {
    assert!((2..=16).contains(&bits), "bits must be in 2..=16");
    debug_assert_eq!(remote.len(), reference.len());
    debug_assert_eq!(remote.len(), out.len());
    let n = remote.len();
    let m = 1i64 << bits;
    let half = m / 2;
    let mut cs_send: u64 = CHECKSUM_INIT;
    let mut cs_recv: u64 = CHECKSUM_INIT;
    match kernel {
        Kernel::Scalar => {
            for i in 0..n {
                let u = uniform01(i as u32, seed);
                let (c, rc) = lattice_coords(remote[i], reference[i], eps, u, m, half);
                cs_send = checksum_step(cs_send, c);
                cs_recv = checksum_step(cs_recv, rc);
                out[i] = fuse(rule, reference[i], rc as f32 * eps);
            }
        }
        Kernel::Simd => {
            let mut i = 0;
            while i + LANES <= n {
                let mut cs = [0i64; LANES];
                let mut rcs = [0i64; LANES];
                let mut dec = [0.0f32; LANES];
                for l in 0..LANES {
                    let idx = i + l;
                    let u = uniform01(idx as u32, seed);
                    let (c, rc) =
                        lattice_coords(remote[idx], reference[idx], eps, u, m, half);
                    cs[l] = c;
                    rcs[l] = rc;
                    dec[l] = rc as f32 * eps;
                }
                // checksums fold scalar-wise in element order: bit-exact
                // with the scalar kernel (no reduction-order change)
                for l in 0..LANES {
                    cs_send = checksum_step(cs_send, cs[l]);
                    cs_recv = checksum_step(cs_recv, rcs[l]);
                }
                for l in 0..LANES {
                    out[i + l] = fuse(rule, reference[i + l], dec[l]);
                }
                i += LANES;
            }
            for k in i..n {
                let u = uniform01(k as u32, seed);
                let (c, rc) = lattice_coords(remote[k], reference[k], eps, u, m, half);
                cs_send = checksum_step(cs_send, c);
                cs_recv = checksum_step(cs_recv, rc);
                out[k] = fuse(rule, reference[k], rc as f32 * eps);
            }
        }
    }
    // wire accounting mirrors QuantizedMsg::wire_bits(): payload + 64-bit
    // checksum + 96-bit header
    let wire = n as u64 * bits as u64 + 160;
    if cs_send == cs_recv {
        (wire, false)
    } else {
        // fallback: full-precision resend — the decoded value becomes the
        // remote model verbatim, matching quantized_transfer
        for i in 0..n {
            out[i] = fuse(rule, reference[i], remote[i]);
        }
        (wire + 32 * n as u64, true)
    }
}

/// Fused quantize-average: `out ← (reference + decode(encode(remote)))/2`
/// in one traversal. Returns `(wire_bits, fell_back)`.
pub fn lattice_qavg_into(
    kernel: Kernel,
    remote: &[f32],
    reference: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
    out: &mut [f32],
) -> (u64, bool) {
    lattice_fused(kernel, FuseRule::Qavg, remote, reference, eps, bits, seed, out)
}

/// Fused quantize-take-half: `out ← decode(encode(remote))/2` — the
/// push-sum halve-and-push payload. Returns `(wire_bits, fell_back)`.
pub fn lattice_take_half_into(
    kernel: Kernel,
    remote: &[f32],
    reference: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
    out: &mut [f32],
) -> (u64, bool) {
    lattice_fused(kernel, FuseRule::TakeHalf, remote, reference, eps, bits, seed, out)
}

/// Fused quantize-decode without a merge rule: `out ← decode(encode(remote))`
/// against `reference` — the allocation-free codec decode entry point.
/// Returns `(wire_bits, fell_back)`.
pub fn lattice_decode_into(
    kernel: Kernel,
    remote: &[f32],
    reference: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
    out: &mut [f32],
) -> (u64, bool) {
    lattice_fused(kernel, FuseRule::Decode, remote, reference, eps, bits, seed, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::quantized_transfer;
    use crate::rngx::Pcg64;

    fn close_pair(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed(seed);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
        let y: Vec<f32> = x.iter().map(|v| v + 0.01 * rng.normal() as f32).collect();
        (x, y)
    }

    #[test]
    fn kernel_names_and_parse() {
        assert_eq!(Kernel::parse("scalar"), Ok(Kernel::Scalar));
        assert_eq!(Kernel::parse("simd"), Ok(Kernel::Simd));
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Simd.name(), "simd");
        assert_eq!(Kernel::default(), Kernel::Scalar);
        let err = Kernel::parse("avx-512").unwrap_err();
        assert!(err.contains("unknown kernel 'avx-512'"), "{err}");
        assert!(err.contains("scalar|simd"), "{err}");
    }

    #[test]
    fn fused_scalar_matches_two_pass_lattice() {
        // fused qavg == quantized_transfer + separate midpoint, bit for bit,
        // across the full lattice bit-width range
        for bits in 2..=16u32 {
            let (x, y) = close_pair(301, bits as u64);
            let eps = 2e-3f32;
            let tr = quantized_transfer(&x, &y, eps, bits, 77);
            let want: Vec<f32> =
                y.iter().zip(&tr.decoded).map(|(a, d)| 0.5 * (a + d)).collect();
            let mut out = vec![0.0f32; x.len()];
            let (b, fb) =
                lattice_qavg_into(Kernel::Scalar, &x, &y, eps, bits, 77, &mut out);
            assert_eq!(out, want, "bits={bits}");
            assert_eq!(b, tr.bits, "bits={bits}");
            assert_eq!(fb, tr.fell_back, "bits={bits}");
        }
    }

    #[test]
    fn fused_fallback_matches_two_pass() {
        // models far apart: checksum fires, both paths resend full precision
        let x = vec![0.0f32; 130];
        let y = vec![10.0f32; 130];
        let tr = quantized_transfer(&x, &y, 1e-3, 4, 5);
        assert!(tr.fell_back);
        let want: Vec<f32> =
            y.iter().zip(&tr.decoded).map(|(a, d)| 0.5 * (a + d)).collect();
        let mut out = vec![0.0f32; x.len()];
        let (b, fb) = lattice_qavg_into(Kernel::Scalar, &x, &y, 1e-3, 4, 5, &mut out);
        assert!(fb);
        assert_eq!(b, tr.bits);
        assert_eq!(out, want);
    }

    #[test]
    fn decode_rule_matches_quantized_transfer() {
        let (x, y) = close_pair(257, 9);
        let tr = quantized_transfer(&x, &y, 1e-3, 8, 3);
        let mut out = vec![0.0f32; x.len()];
        let (b, fb) = lattice_decode_into(Kernel::Scalar, &x, &y, 1e-3, 8, 3, &mut out);
        assert_eq!(out, tr.decoded);
        assert_eq!((b, fb), (tr.bits, tr.fell_back));
    }

    #[test]
    fn take_half_is_half_of_decode() {
        let (x, y) = close_pair(100, 11);
        let mut dec = vec![0.0f32; x.len()];
        let mut hlf = vec![0.0f32; x.len()];
        lattice_decode_into(Kernel::Scalar, &x, &y, 1e-3, 8, 2, &mut dec);
        lattice_take_half_into(Kernel::Scalar, &x, &y, 1e-3, 8, 2, &mut hlf);
        let want: Vec<f32> = dec.iter().map(|v| 0.5 * v).collect();
        assert_eq!(hlf, want);
    }

    #[test]
    fn simd_is_bit_exact_with_scalar() {
        // length deliberately not a multiple of the lane width
        let (x, y) = close_pair(1021, 21);
        for (name, f) in [
            ("qavg", lattice_qavg_into as fn(_, &[f32], &[f32], _, _, _, &mut [f32]) -> _),
            ("half", lattice_take_half_into),
            ("decode", lattice_decode_into),
        ] {
            let mut a = vec![0.0f32; x.len()];
            let mut b = vec![0.0f32; x.len()];
            let ra = f(Kernel::Scalar, &x, &y, 1e-3, 8, 13, &mut a);
            let rb = f(Kernel::Simd, &x, &y, 1e-3, 8, 13, &mut b);
            assert_eq!(a, b, "{name}");
            assert_eq!(ra, rb, "{name}");
        }
        let mut oa = vec![0.0f32; x.len()];
        let mut ob = vec![0.0f32; x.len()];
        avg_into(Kernel::Scalar, &x, &y, &mut oa);
        avg_into(Kernel::Simd, &x, &y, &mut ob);
        assert_eq!(oa, ob);
        half_into(Kernel::Scalar, &y, &mut oa);
        half_into(Kernel::Simd, &y, &mut ob);
        assert_eq!(oa, ob);
        let (mut a1, mut b1) = (x.clone(), y.clone());
        let (mut a2, mut b2) = (x.clone(), y.clone());
        avg_into_both(Kernel::Scalar, &mut a1, &mut b1);
        avg_into_both(Kernel::Simd, &mut a2, &mut b2);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn f32_kernels_match_reference_ops() {
        let (x, y) = close_pair(37, 4);
        let mut out = vec![0.0f32; x.len()];
        avg_into(Kernel::Scalar, &x, &y, &mut out);
        for ((o, &a), &b) in out.iter().zip(&x).zip(&y) {
            assert_eq!(*o, 0.5 * (a + b));
        }
        let (mut a, mut b) = (x.clone(), y.clone());
        let (mut a2, mut b2) = (x.clone(), y.clone());
        avg_into_both(Kernel::Scalar, &mut a, &mut b);
        crate::coordinator::average_into_both(&mut a2, &mut b2);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }
}
