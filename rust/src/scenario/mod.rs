//! The scenario layer: *which* pairs gossip, *how fast* each node's clock
//! ticks, and *when* the graph itself changes.
//!
//! A [`Scenario`] bundles the three heterogeneity axes the paper's claims
//! cover but a uniform-pairing simulator cannot exercise:
//!
//! * **graph-constrained partner sampling** — gossip pairs are edges of a
//!   configured topology (`--topology complete|ring|torus|hypercube|
//!   regular<r>|powerlaw`, plus directed orientations for push-sum),
//!   optionally **time-varying** via an epoch-indexed graph schedule
//!   (`topology_schedule = ring@0,torus@5000,...`);
//! * **per-node speed classes** (`--speeds uniform|bimodal:<frac>:
//!   <slowdown>|pareto:<alpha>`) mapped onto Poisson clock rates, so
//!   stragglers are *structural* — a slow node is slow for the whole run —
//!   rather than the cost model's i.i.d. per-step coin flips;
//! * **data heterogeneity** rides on the existing `shard` key
//!   (`--dirichlet <alpha>` is sugar for `shard=dirichlet:<alpha>`), kept
//!   in [`crate::data::dirichlet_shards`].
//!
//! Every executor consumes the same `Scenario`: the replay executors
//! (serial/parallel) thread it through schedule pre-drawing — and the
//! **default scenario (uniform speeds, static undirected graph) consumes
//! the caller's RNG byte-for-byte identically to the legacy direct-graph
//! path**, which is what keeps the committed monolithic goldens and the
//! serial ≡ parallel bit-equality contract intact. The freerun and cluster
//! executors sample partners per worker from their own private streams (no
//! global RNG bottleneck) and scale their Poisson clocks by the node rate.

use crate::config::RunConfig;
use crate::rngx::Pcg64;
use crate::topology::{Graph, Topology};

/// Dedicated stream tag for scenario-level draws (per-node speed rates),
/// disjoint from the schedule/node/eval/worker stream tags so enabling a
/// speed class never perturbs any other stream.
pub const STREAM_SCENARIO: u64 = 0x5EED_5CE0_0000_0004;

/// Per-node speed classes (`--speeds`), resolved to Poisson clock rates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpeedClass {
    /// every node at rate 1 (the paper's identical-clocks model)
    Uniform,
    /// a fraction of nodes runs `slowdown`× slower (rate 1/slowdown)
    Bimodal { frac: f64, slowdown: f64 },
    /// heavy-tailed per-node slowdowns: s = (1-u)^(-1/alpha), rate = 1/s
    Pareto { alpha: f64 },
}

impl SpeedClass {
    /// Parse `uniform | bimodal:<frac>:<slowdown> | pareto:<alpha>`.
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "uniform" {
            return Ok(SpeedClass::Uniform);
        }
        if let Some(rest) = s.strip_prefix("bimodal:") {
            let (f, sd) = rest
                .split_once(':')
                .ok_or_else(|| bimodal_err(s, "missing the slowdown part"))?;
            let frac: f64 = f.parse().map_err(|_| bimodal_err(s, "bad fraction"))?;
            let slowdown: f64 = sd.parse().map_err(|_| bimodal_err(s, "bad slowdown"))?;
            if !(0.0..=1.0).contains(&frac) || !frac.is_finite() {
                return Err(bimodal_err(s, "fraction must be in [0, 1]"));
            }
            if !slowdown.is_finite() || slowdown < 1.0 {
                return Err(bimodal_err(s, "slowdown must be >= 1"));
            }
            return Ok(SpeedClass::Bimodal { frac, slowdown });
        }
        if let Some(a) = s.strip_prefix("pareto:") {
            let alpha: f64 = a
                .parse()
                .map_err(|_| format!("bad speeds 'pareto:{a}': alpha must be a number"))?;
            if !alpha.is_finite() || alpha <= 0.0 {
                return Err(format!(
                    "bad speeds '{s}': pareto alpha must be > 0 (smaller alpha = \
                     heavier straggler tail; try pareto:2.5)"
                ));
            }
            return Ok(SpeedClass::Pareto { alpha });
        }
        Err(format!(
            "unknown speeds '{s}' (want uniform, bimodal:<frac>:<slowdown>, \
             or pareto:<alpha>)"
        ))
    }

    /// Resolve to per-node Poisson clock rates. Non-uniform classes draw
    /// from `rng` (callers pass the dedicated [`STREAM_SCENARIO`] stream);
    /// `Uniform` consumes nothing.
    pub fn rates(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        match *self {
            SpeedClass::Uniform => vec![1.0; n],
            SpeedClass::Bimodal { frac, slowdown } => {
                // structural assignment: a deterministic node *count*, with
                // membership shuffled so slow nodes land anywhere in the id
                // (and therefore shard) space
                let slow = ((n as f64) * frac).round() as usize;
                let mut ids: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut ids);
                let mut rates = vec![1.0; n];
                for &i in ids.iter().take(slow) {
                    rates[i] = 1.0 / slowdown;
                }
                rates
            }
            SpeedClass::Pareto { alpha } => (0..n)
                .map(|_| {
                    // inverse-CDF Pareto(1, alpha) slowdown
                    let u = rng.f64();
                    let slowdown = (1.0 - u).max(1e-12).powf(-1.0 / alpha);
                    1.0 / slowdown
                })
                .collect(),
        }
    }
}

fn bimodal_err(s: &str, why: &str) -> String {
    format!("bad speeds '{s}': {why} (want bimodal:<frac>:<slowdown>, e.g. bimodal:0.25:4)")
}

/// Parse a `topology_schedule` value: comma-separated `<topology>@<tick>`
/// stages, first at tick 0, ticks strictly increasing.
pub fn parse_topology_schedule(s: &str) -> Result<Vec<(u64, Topology)>, String> {
    let mut stages = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let (name, tick) = part.split_once('@').ok_or_else(|| {
            format!(
                "bad topology_schedule stage '{part}' (want <topology>@<tick>, \
                 e.g. ring@0,torus@5000)"
            )
        })?;
        let tick: u64 = tick
            .parse()
            .map_err(|_| format!("bad topology_schedule tick in '{part}'"))?;
        stages.push((tick, Topology::parse(name)?));
    }
    if stages.is_empty() {
        return Err("topology_schedule needs at least one <topology>@<tick> stage".into());
    }
    if stages[0].0 != 0 {
        return Err(format!(
            "topology_schedule must start at tick 0 (first stage starts at \
             {} — the run would have no graph before it)",
            stages[0].0
        ));
    }
    if stages.windows(2).any(|w| w[1].0 <= w[0].0) {
        return Err("topology_schedule ticks must be strictly increasing".into());
    }
    Ok(stages)
}

/// One resolved scenario: the tick-indexed graph schedule plus per-node
/// clock rates, shared by all four executors.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// graph stages sorted by start tick; the first always starts at 0
    graphs: Vec<(u64, Graph)>,
    /// per-node Poisson clock rates (all 1.0 under uniform speeds)
    rates: Vec<f64>,
    /// cumulative rate sums for rate-weighted initiator sampling; None
    /// under uniform speeds (the legacy edge-uniform draw is used instead)
    cdf: Option<Vec<f64>>,
    speeds: SpeedClass,
}

impl Scenario {
    /// The legacy single-graph scenario: uniform speeds, static topology.
    /// Wrapping a graph this way reproduces the pre-scenario executors'
    /// RNG consumption exactly.
    pub fn static_graph(graph: Graph) -> Self {
        let n = graph.n();
        Scenario {
            graphs: vec![(0, graph)],
            rates: vec![1.0; n],
            cdf: None,
            speeds: SpeedClass::Uniform,
        }
    }

    /// Resolve the scenario a config describes: validate topology/n
    /// feasibility (actionable errors, not panics), build the graph
    /// schedule, and draw per-node speed rates from the dedicated
    /// [`STREAM_SCENARIO`] stream of `cfg.seed`.
    pub fn from_config(cfg: &RunConfig) -> Result<Self, String> {
        let n = cfg.n;
        let stages: Vec<(u64, Topology)> = if cfg.topology_schedule.is_empty() {
            vec![(0, cfg.topology_enum()?)]
        } else {
            parse_topology_schedule(&cfg.topology_schedule)?
        };
        for &(tick, topo) in &stages {
            topo.validate(n)
                .map_err(|e| format!("topology stage at tick {tick}: {e}"))?;
        }
        if cfg.directed {
            if cfg.algo != "sgp" {
                return Err(format!(
                    "directed=true needs push-sum (algorithm sgp) — '{}' gossips \
                     symmetrically and cannot mix over one-way arcs",
                    cfg.algo
                ));
            }
            for &(tick, topo) in &stages {
                if !matches!(topo, Topology::Complete | Topology::Ring | Topology::Torus) {
                    return Err(format!(
                        "directed=true needs an orientable topology (complete, \
                         ring, or torus); stage at tick {tick} is {topo:?}"
                    ));
                }
            }
        }
        // graph construction consumes Pcg64::seed(cfg.seed) exactly like the
        // legacy single-graph path, so a one-stage undirected scenario is
        // bit-identical to the pre-scenario executors
        let mut grng = Pcg64::seed(cfg.seed);
        let graphs: Vec<(u64, Graph)> = stages
            .into_iter()
            .map(|(tick, topo)| {
                let g = if cfg.directed {
                    Graph::build_directed(topo, n)
                } else {
                    Graph::build(topo, n, &mut grng)
                };
                (tick, g)
            })
            .collect();
        let speeds = SpeedClass::parse(&cfg.speeds)?;
        let rates = speeds.rates(n, &mut Pcg64::stream(cfg.seed, STREAM_SCENARIO));
        let cdf = (speeds != SpeedClass::Uniform).then(|| {
            let mut acc = 0.0;
            rates
                .iter()
                .map(|r| {
                    acc += r;
                    acc
                })
                .collect()
        });
        Ok(Scenario { graphs, rates, cdf, speeds })
    }

    pub fn n(&self) -> usize {
        self.graphs[0].1.n()
    }

    /// The graph in force at logical tick `t` (the last stage whose start
    /// tick is <= t).
    pub fn graph_at(&self, t: u64) -> &Graph {
        let ix = self.graphs.partition_point(|&(start, _)| start <= t);
        &self.graphs[ix - 1].1
    }

    /// The initial graph (tick 0) — what run setup prints and what the
    /// cluster executor's static gossip plane uses.
    pub fn graph0(&self) -> &Graph {
        &self.graphs[0].1
    }

    /// All graph stages, for telemetry/benches.
    pub fn stages(&self) -> &[(u64, Graph)] {
        &self.graphs
    }

    pub fn is_time_varying(&self) -> bool {
        self.graphs.len() > 1
    }

    pub fn speeds(&self) -> SpeedClass {
        self.speeds
    }

    pub fn uniform_speeds(&self) -> bool {
        self.cdf.is_none()
    }

    /// Poisson clock rate of `node` (1.0 under uniform speeds).
    #[inline]
    pub fn rate(&self, node: usize) -> f64 {
        self.rates[node]
    }

    /// Sample a gossip partner for `node` at tick `t` — a uniform neighbor
    /// in the graph in force (an out-neighbor on directed graphs).
    #[inline]
    pub fn sample_partner(&self, node: usize, t: u64, rng: &mut Pcg64) -> usize {
        self.graph_at(t).sample_neighbor(node, rng)
    }

    /// Sample one gossip pair at tick `t`. Under uniform speeds this is
    /// **exactly** the legacy uniform edge draw (same single RNG call), so
    /// default scenarios replay bit-identically; under a speed class the
    /// *initiator* is drawn rate-weighted (fast nodes fire more often —
    /// the Poisson-clock race the freerun executor realizes physically)
    /// and the partner uniformly among its neighbors.
    pub fn sample_pair(&self, t: u64, rng: &mut Pcg64) -> (usize, usize) {
        let g = self.graph_at(t);
        match &self.cdf {
            None => g.sample_edge(rng),
            Some(cdf) => {
                let total = *cdf.last().expect("non-empty scenario");
                let u = rng.f64() * total;
                let i = cdf.partition_point(|&c| c <= u).min(self.rates.len() - 1);
                (i, g.sample_neighbor(i, rng))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pairs: &[(&str, &str)]) -> RunConfig {
        let mut c = RunConfig::default();
        for (k, v) in pairs {
            c.set(k, v).unwrap();
        }
        c
    }

    #[test]
    fn speed_class_parses() {
        assert_eq!(SpeedClass::parse("uniform").unwrap(), SpeedClass::Uniform);
        assert_eq!(
            SpeedClass::parse("bimodal:0.25:4").unwrap(),
            SpeedClass::Bimodal { frac: 0.25, slowdown: 4.0 }
        );
        assert_eq!(
            SpeedClass::parse("pareto:2.5").unwrap(),
            SpeedClass::Pareto { alpha: 2.5 }
        );
        for bad in ["fast", "bimodal:0.25", "bimodal:1.5:2", "bimodal:0.5:0.5", "pareto:0"] {
            assert!(SpeedClass::parse(bad).is_err(), "'{bad}' should fail");
        }
    }

    #[test]
    fn bimodal_rates_have_exact_slow_count() {
        let mut rng = Pcg64::stream(7, STREAM_SCENARIO);
        let rates = SpeedClass::Bimodal { frac: 0.25, slowdown: 4.0 }.rates(16, &mut rng);
        assert_eq!(rates.iter().filter(|&&r| r == 0.25).count(), 4);
        assert_eq!(rates.iter().filter(|&&r| r == 1.0).count(), 12);
    }

    #[test]
    fn pareto_rates_are_heavy_tailed_slowdowns() {
        let mut rng = Pcg64::stream(7, STREAM_SCENARIO);
        let rates = SpeedClass::Pareto { alpha: 2.0 }.rates(2000, &mut rng);
        // all slowdowns >= 1 → all rates in (0, 1]
        assert!(rates.iter().all(|&r| r > 0.0 && r <= 1.0 + 1e-12));
        // heavy tail: some node is at least 3x slower
        assert!(rates.iter().any(|&r| r < 1.0 / 3.0));
        // ...but the typical node is near full speed (median slowdown 2^(1/α))
        let near_full = rates.iter().filter(|&&r| r > 0.5).count();
        assert!(near_full > 1000, "{near_full}");
    }

    #[test]
    fn default_scenario_is_bit_compatible_with_legacy_graph_path() {
        let c = cfg(&[("topology", "ring"), ("n", "16")]);
        let scn = Scenario::from_config(&c).unwrap();
        // the legacy path: seed rng, build graph, then keep drawing
        let mut legacy = Pcg64::seed(c.seed);
        let g = Graph::build(Topology::Ring, 16, &mut legacy);
        // identical graph
        assert_eq!(scn.graph0().edges(), g.edges());
        // identical pair-draw consumption
        let mut a = Pcg64::seed(99);
        let mut b = Pcg64::seed(99);
        for t in 0..200 {
            assert_eq!(scn.sample_pair(t, &mut a), g.sample_edge(&mut b));
        }
        assert_eq!(a.next_u64(), b.next_u64(), "stream positions must agree");
        assert!(scn.uniform_speeds());
        assert!(!scn.is_time_varying());
    }

    #[test]
    fn from_config_rejects_infeasible_topology_with_actionable_error() {
        let e = Scenario::from_config(&cfg(&[("topology", "torus"), ("n", "10")])).unwrap_err();
        assert!(e.contains("square"), "{e}");
        let e =
            Scenario::from_config(&cfg(&[("topology", "hypercube"), ("n", "12")])).unwrap_err();
        assert!(e.contains("power of two"), "{e}");
        let e =
            Scenario::from_config(&cfg(&[("topology", "regular3"), ("n", "9")])).unwrap_err();
        assert!(e.contains("even"), "{e}");
    }

    #[test]
    fn directed_is_sgp_only_on_orientable_families() {
        let e = Scenario::from_config(&cfg(&[("directed", "true")])).unwrap_err();
        assert!(e.contains("sgp"), "{e}");
        let e = Scenario::from_config(&cfg(&[
            ("directed", "true"),
            ("algorithm", "sgp"),
            ("topology", "hypercube"),
            ("n", "16"),
        ]))
        .unwrap_err();
        assert!(e.contains("orientable"), "{e}");
        let scn = Scenario::from_config(&cfg(&[
            ("directed", "true"),
            ("algorithm", "sgp"),
            ("topology", "ring"),
            ("n", "8"),
        ]))
        .unwrap();
        assert!(scn.graph0().is_directed());
        assert!(scn.graph0().is_connected());
    }

    #[test]
    fn graph_schedule_switches_at_stage_ticks() {
        let c = cfg(&[("topology_schedule", "ring@0,torus@100,complete@250"), ("n", "16")]);
        let scn = Scenario::from_config(&c).unwrap();
        assert!(scn.is_time_varying());
        assert_eq!(scn.graph_at(0).regular_degree(), Some(2));
        assert_eq!(scn.graph_at(99).regular_degree(), Some(2));
        assert_eq!(scn.graph_at(100).regular_degree(), Some(4));
        assert_eq!(scn.graph_at(249).regular_degree(), Some(4));
        assert_eq!(scn.graph_at(250).regular_degree(), Some(15));
        assert_eq!(scn.graph_at(u64::MAX).regular_degree(), Some(15));
        // every stage was feasibility-checked against n
        let c = cfg(&[("n", "10")]);
        let mut c2 = c.clone();
        c2.set("topology_schedule", "ring@0,torus@100").unwrap();
        let e = Scenario::from_config(&c2).unwrap_err();
        assert!(e.contains("tick 100"), "{e}");
    }

    #[test]
    fn rate_weighted_pairs_favor_fast_initiators_and_stay_on_edges() {
        let c = cfg(&[("topology", "ring"), ("n", "8"), ("speeds", "bimodal:0.5:8")]);
        let scn = Scenario::from_config(&c).unwrap();
        assert!(!scn.uniform_speeds());
        let mut rng = Pcg64::seed(3);
        let mut fast = 0u64;
        let mut slow = 0u64;
        for _ in 0..4000 {
            let (i, j) = scn.sample_pair(0, &mut rng);
            assert!(scn.graph0().neighbors(i).contains(&j), "({i},{j}) not an edge");
            if scn.rate(i) == 1.0 {
                fast += 1;
            } else {
                slow += 1;
            }
        }
        // 4 fast nodes at rate 1 vs 4 slow at rate 1/8 → fast initiate ~8x
        assert!(fast > 5 * slow, "fast={fast} slow={slow}");
    }

    #[test]
    fn scenario_resolution_is_deterministic_per_seed() {
        let c = cfg(&[("speeds", "pareto:2.0"), ("n", "32")]);
        let a = Scenario::from_config(&c).unwrap();
        let b = Scenario::from_config(&c).unwrap();
        for i in 0..32 {
            assert_eq!(a.rate(i).to_bits(), b.rate(i).to_bits());
        }
    }
}
