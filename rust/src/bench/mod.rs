//! Minimal criterion-style benchmark harness (offline environment: the
//! criterion crate is unavailable, so `benches/*.rs` use `harness = false`
//! and drive this module instead).
//!
//! Method: warmup, then timed batches until both a minimum number of
//! samples and a minimum total time are reached; reports median, mean, and
//! a robust spread (IQR).  Deterministic workloads + median keep the
//! numbers stable enough for the §Perf before/after log.

use std::hint::black_box;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p25: Duration,
    pub p75: Duration,
    /// optional throughput basis (elements processed per iteration)
    pub elements: Option<u64>,
}

impl BenchResult {
    /// elements/second at the median, if a basis was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.median.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t > 1e9 => format!("  {:7.2} Gelem/s", t / 1e9),
            Some(t) if t > 1e6 => format!("  {:7.2} Melem/s", t / 1e6),
            Some(t) if t > 1e3 => format!("  {:7.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:7.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<40} median {:>12?}  mean {:>12?}  [{:?} .. {:?}] n={}{}",
            self.name, self.median, self.mean, self.p25, self.p75, self.samples, tp
        )
    }
}

/// Benchmark runner with tunable budgets.
pub struct Bench {
    pub warmup: Duration,
    pub min_time: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            min_time: Duration::from_secs(1),
            min_samples: 10,
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            min_time: Duration::from_millis(200),
            min_samples: 5,
            max_samples: 50,
            ..Self::default()
        }
    }

    /// Time `f`, which should do one unit of work and return something to
    /// keep alive (prevented from optimizing away via `black_box`).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_elements(name, None, &mut f)
    }

    /// Like [`Bench::run`] with a throughput basis.
    pub fn run_elems<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: F,
    ) -> &BenchResult {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    fn run_with_elements<T>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // sample
        let mut times = Vec::with_capacity(self.min_samples * 2);
        let begin = Instant::now();
        while (times.len() < self.min_samples || begin.elapsed() < self.min_time)
            && times.len() < self.max_samples
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let n = times.len();
        let mean = times.iter().sum::<Duration>() / n as u32;
        let r = BenchResult {
            name: name.to_string(),
            samples: n,
            median: times[n / 2],
            mean,
            p25: times[n / 4],
            p75: times[3 * n / 4],
            elements,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write accumulated results to a CSV (for EXPERIMENTS.md §Perf).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = crate::output::CsvWriter::create(
            path,
            &["name", "median_ns", "mean_ns", "p25_ns", "p75_ns", "samples", "throughput"],
        )?;
        for r in &self.results {
            w.row_mixed(&[
                crate::output::CsvVal::S(r.name.clone()),
                crate::output::CsvVal::I(r.median.as_nanos() as i64),
                crate::output::CsvVal::I(r.mean.as_nanos() as i64),
                crate::output::CsvVal::I(r.p25.as_nanos() as i64),
                crate::output::CsvVal::I(r.p75.as_nanos() as i64),
                crate::output::CsvVal::I(r.samples as i64),
                crate::output::CsvVal::F(r.throughput().unwrap_or(f64::NAN)),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 20,
            results: Vec::new(),
        };
        let r = b
            .run_elems("spin", 1000, || {
                let mut s = 0u64;
                for i in 0..1000u64 {
                    s = s.wrapping_add(i * i);
                }
                s
            })
            .clone();
        assert!(r.samples >= 3);
        assert!(r.median.as_nanos() > 0);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.p25 <= r.median && r.median <= r.p75);
    }
}
