//! Linear softmax classifier oracle on Gaussian-mixture shards — the
//! lightweight "CIFAR-10/ResNet20" stand-in used by the n=256 scaling
//! figure (Fig. 6a) where per-step XLA dispatch would dominate.
//!
//! Implements the unified [`Backend`] trait: the oracle holds only
//! immutable data (datasets + shard index lists), and every batch draw
//! comes from the caller's RNG — so the parallel executor can step agents
//! concurrently and replay them bit-for-bit.

use crate::backend::{Backend, EvalResult};
use crate::data::{draw_batch_indices, Batch, VectorDataset};
use crate::rngx::Pcg64;

pub struct SoftmaxOracle {
    data: VectorDataset,
    test: VectorDataset,
    /// per-agent example index lists (immutable; batches are drawn from the
    /// caller's RNG, uniformly with replacement)
    shards: Vec<Vec<usize>>,
    pub batch: usize,
    dim: usize,
    classes: usize,
    init_seed: u64,
}

impl SoftmaxOracle {
    pub fn new(
        train: VectorDataset,
        test: VectorDataset,
        shard_idxs: Vec<Vec<usize>>,
        batch: usize,
        seed: u64,
    ) -> Self {
        assert!(shard_idxs.iter().all(|s| !s.is_empty()), "empty shard");
        let (dim, classes) = (train.dim, train.classes);
        Self { data: train, test, shards: shard_idxs, batch, dim, classes, init_seed: seed }
    }

    /// Convenience constructor: generate data + iid shards internally.
    pub fn synthetic(
        n_train: usize,
        dim: usize,
        classes: usize,
        agents: usize,
        batch: usize,
        separation: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::seed(seed);
        let (train, test) = VectorDataset::generate_split(
            n_train, n_train / 5 + 32, dim, classes, separation, &mut rng,
        );
        let shards = crate::data::iid_shards(train.len(), agents, &mut rng);
        Self::new(train, test, shards, batch, seed ^ 0xABCD)
    }

    /// Loss+grad of the softmax CE on a batch. W is (dim+1) × classes
    /// (last row = bias), packed row-major into the flat params.
    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[i32], grad: Option<&mut [f32]>) -> f64 {
        let (d, c) = (self.dim, self.classes);
        let bsz = y.len();
        let mut total = 0.0f64;
        let mut g = grad;
        let mut logits = vec![0.0f64; c];
        for b in 0..bsz {
            let xb = &x[b * d..(b + 1) * d];
            for k in 0..c {
                let mut z = params[d * c + k] as f64; // bias row
                for j in 0..d {
                    z += params[j * c + k] as f64 * xb[j] as f64;
                }
                logits[k] = z;
            }
            let m = logits.iter().cloned().fold(f64::MIN, f64::max);
            let se: f64 = logits.iter().map(|z| (z - m).exp()).sum();
            let lse = m + se.ln();
            total += lse - logits[y[b] as usize];
            if let Some(gr) = g.as_deref_mut() {
                for k in 0..c {
                    let p = (logits[k] - lse).exp();
                    let delta = p - f64::from(k as i32 == y[b]);
                    let scale = (delta / bsz as f64) as f32;
                    for j in 0..d {
                        gr[j * c + k] += scale * xb[j];
                    }
                    gr[d * c + k] += scale;
                }
            }
        }
        total / bsz as f64
    }
}

impl Backend for SoftmaxOracle {
    fn dim(&self) -> usize {
        (self.dim + 1) * self.classes
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        let mut r = Pcg64::seed(self.init_seed ^ 0x50F7);
        let scale = 0.01 / (self.dim as f32).sqrt();
        let p = (0..self.dim()).map(|_| r.normal() as f32 * scale).collect();
        (p, vec![0.0; self.dim()])
    }

    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64 {
        let idxs = draw_batch_indices(&self.shards[agent], self.batch, rng);
        let Batch::Dense { x, y } = self.data.batch(&idxs) else {
            unreachable!()
        };
        let mut grad = vec![0.0f32; params.len()];
        let loss = self.loss_grad(params, &x, &y, Some(&mut grad));
        // momentum SGD (mu = 0.9, matching the deep-model recipe)
        for j in 0..params.len() {
            mom[j] = 0.9 * mom[j] + grad[j];
            params[j] -= lr * mom[j];
        }
        loss
    }

    fn eval(&self, params: &[f32]) -> EvalResult {
        let (d, c) = (self.dim, self.classes);
        let n = self.test.len();
        let mut correct = 0usize;
        let loss = self.loss_grad(params, &self.test.x, &self.test.y, None);
        for b in 0..n {
            let xb = &self.test.x[b * d..(b + 1) * d];
            let mut best = (f64::MIN, 0usize);
            for k in 0..c {
                let mut z = params[d * c + k] as f64;
                for j in 0..d {
                    z += params[j * c + k] as f64 * xb[j] as f64;
                }
                if z > best.0 {
                    best = (z, k);
                }
            }
            correct += usize::from(best.1 == self.test.y[b] as usize);
        }
        EvalResult { loss, accuracy: correct as f64 / n as f64 }
    }

    fn full_loss(&self, params: &[f32]) -> f64 {
        self.loss_grad(params, &self.data.x, &self.data.y, None)
    }

    fn epochs(&self, agent: usize, steps: u64) -> f64 {
        steps as f64 * self.batch as f64 / self.shards[agent].len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_learns_separable_mixture() {
        let o = SoftmaxOracle::synthetic(2000, 16, 4, 1, 32, 4.0, 11);
        let (mut p, mut m) = o.init();
        let mut rng = Pcg64::seed(7);
        let start = o.eval(&p);
        for _ in 0..300 {
            o.step(0, &mut p, &mut m, 0.05, &mut rng);
        }
        let end = o.eval(&p);
        assert!(end.loss < start.loss * 0.5, "{} -> {}", start.loss, end.loss);
        assert!(end.accuracy > 0.85, "acc={}", end.accuracy);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let o = SoftmaxOracle::synthetic(64, 6, 3, 1, 8, 3.0, 5);
        let mut r = Pcg64::seed(1);
        let params: Vec<f32> = (0..o.dim()).map(|_| r.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..4 * 6).map(|_| r.normal() as f32).collect();
        let y = vec![0i32, 1, 2, 1];
        let mut grad = vec![0.0f32; params.len()];
        o.loss_grad(&params, &x, &y, Some(&mut grad));
        let h = 1e-3f32;
        for j in [0usize, 5, 11, o.dim() - 1] {
            let mut pp = params.clone();
            pp[j] += h;
            let lp = o.loss_grad(&pp, &x, &y, None);
            pp[j] -= 2.0 * h;
            let lm = o.loss_grad(&pp, &x, &y, None);
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - grad[j] as f64).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {j}: fd={fd} analytic={}",
                grad[j]
            );
        }
    }

    #[test]
    fn epochs_accounting_is_stateless() {
        let o = SoftmaxOracle::synthetic(320, 8, 2, 2, 32, 3.0, 2);
        // agent 0 shard = 160 examples; 5 steps × 32 = 160 = 1 epoch
        assert!((o.epochs(0, 5) - 1.0).abs() < 1e-9, "epochs={}", o.epochs(0, 5));
        assert_eq!(o.epochs(1, 0), 0.0);
        assert!((o.epochs(1, 10) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn step_replays_from_caller_rng() {
        let o = SoftmaxOracle::synthetic(256, 8, 3, 2, 16, 3.0, 9);
        let run = || {
            let (mut p, mut m) = o.init();
            let mut rng = Pcg64::stream(3, 1);
            for _ in 0..20 {
                o.step(1, &mut p, &mut m, 0.05, &mut rng);
            }
            p
        };
        assert_eq!(run(), run());
    }
}
