//! Binary logistic-regression oracle — convex, smooth, bounded-gradient:
//! the cleanest instrument for the non-iid (Theorem 4.2) experiments, since
//! ρ² is driven directly by label-skewed sharding.
//!
//! Implements the unified [`Backend`] trait (immutable data + caller-RNG
//! batch draws), so it runs on both the serial and parallel executors.

use crate::backend::{Backend, EvalResult};
use crate::data::{draw_batch_indices, Batch, VectorDataset};
use crate::rngx::Pcg64;

pub struct LogisticOracle {
    data: VectorDataset,
    test: VectorDataset,
    /// per-agent example index lists (immutable)
    shards: Vec<Vec<usize>>,
    pub batch: usize,
    dim: usize,
    /// L2 regularization (makes the objective strongly convex)
    pub reg: f32,
}

impl LogisticOracle {
    /// (Deterministic given the datasets/shards: batch stochasticity comes
    /// from the caller's RNG at step time, so there is no seed here.)
    pub fn new(
        train: VectorDataset,
        test: VectorDataset,
        shard_idxs: Vec<Vec<usize>>,
        batch: usize,
        reg: f32,
    ) -> Self {
        assert_eq!(train.classes, 2, "logistic oracle is binary");
        assert!(shard_idxs.iter().all(|s| !s.is_empty()), "empty shard");
        let dim = train.dim;
        Self { data: train, test, shards: shard_idxs, batch, dim, reg }
    }

    /// Synthetic two-blob task, split either iid or by label.
    pub fn synthetic(
        n_train: usize,
        dim: usize,
        agents: usize,
        batch: usize,
        iid: bool,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::seed(seed);
        let (train, test) =
            VectorDataset::generate_split(n_train, n_train / 5 + 32, dim, 2, 3.0, &mut rng);
        let shard_idxs = if iid {
            crate::data::iid_shards(train.len(), agents, &mut rng)
        } else {
            crate::data::label_shards(&train.y, agents)
        };
        Self::new(train, test, shard_idxs, batch, 1e-4)
    }

    fn loss_grad(&self, w: &[f32], x: &[f32], y: &[i32], grad: Option<&mut [f32]>) -> f64 {
        let d = self.dim;
        let bsz = y.len();
        let mut total = 0.0f64;
        let mut g = grad;
        for b in 0..bsz {
            let xb = &x[b * d..(b + 1) * d];
            let mut z = w[d] as f64; // bias
            for j in 0..d {
                z += w[j] as f64 * xb[j] as f64;
            }
            let t = f64::from(y[b]); // 0/1
            // stable log(1+e^z) - t*z
            let lse = if z > 0.0 { z + (-z).exp().ln_1p() } else { z.exp().ln_1p() };
            total += lse - t * z;
            if let Some(gr) = g.as_deref_mut() {
                let p = 1.0 / (1.0 + (-z).exp());
                let delta = ((p - t) / bsz as f64) as f32;
                for j in 0..d {
                    gr[j] += delta * xb[j];
                }
                gr[d] += delta;
            }
        }
        let l2: f64 = w.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() * self.reg as f64 / 2.0;
        if let Some(gr) = g {
            for j in 0..w.len() {
                gr[j] += self.reg * w[j];
            }
        }
        total / bsz as f64 + l2
    }
}

impl Backend for LogisticOracle {
    fn dim(&self) -> usize {
        self.dim + 1
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        (vec![0.0; self.dim + 1], vec![0.0; self.dim + 1])
    }

    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64 {
        let idxs = draw_batch_indices(&self.shards[agent], self.batch, rng);
        let Batch::Dense { x, y } = self.data.batch(&idxs) else {
            unreachable!()
        };
        let mut grad = vec![0.0f32; params.len()];
        let loss = self.loss_grad(params, &x, &y, Some(&mut grad));
        for j in 0..params.len() {
            mom[j] = grad[j]; // plain SGD (theory setting)
            params[j] -= lr * grad[j];
        }
        loss
    }

    fn eval(&self, params: &[f32]) -> EvalResult {
        let d = self.dim;
        let loss = self.loss_grad(params, &self.test.x, &self.test.y, None);
        let mut correct = 0usize;
        for b in 0..self.test.len() {
            let xb = &self.test.x[b * d..(b + 1) * d];
            let mut z = params[d] as f64;
            for j in 0..d {
                z += params[j] as f64 * xb[j] as f64;
            }
            correct += usize::from((z > 0.0) == (self.test.y[b] == 1));
        }
        EvalResult { loss, accuracy: correct as f64 / self.test.len() as f64 }
    }

    fn full_loss(&self, params: &[f32]) -> f64 {
        self.loss_grad(params, &self.data.x, &self.data.y, None)
    }

    fn grad_norm_sq(&self, params: &[f32]) -> Option<f64> {
        let mut grad = vec![0.0f32; params.len()];
        self.loss_grad(params, &self.data.x, &self.data.y, Some(&mut grad));
        Some(grad.iter().map(|&g| (g as f64).powi(2)).sum())
    }

    fn epochs(&self, agent: usize, steps: u64) -> f64 {
        steps as f64 * self.batch as f64 / self.shards[agent].len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_two_blobs() {
        let o = LogisticOracle::synthetic(1000, 8, 1, 32, true, 3);
        let (mut p, mut m) = o.init();
        let mut rng = Pcg64::seed(4);
        for _ in 0..400 {
            o.step(0, &mut p, &mut m, 0.1, &mut rng);
        }
        let r = o.eval(&p);
        assert!(r.accuracy > 0.9, "acc={}", r.accuracy);
    }

    #[test]
    fn label_skew_creates_heterogeneity() {
        // non-iid: an agent training alone should drift to a biased model
        let o = LogisticOracle::synthetic(1000, 8, 2, 32, false, 5);
        let (mut p0, mut m0) = o.init();
        let (mut p1, mut m1) = (p0.clone(), m0.clone());
        let mut rng = Pcg64::seed(6);
        for _ in 0..200 {
            o.step(0, &mut p0, &mut m0, 0.1, &mut rng);
            o.step(1, &mut p1, &mut m1, 0.1, &mut rng);
        }
        // agents saw opposite labels -> opposite bias signs
        let b0 = p0[8];
        let b1 = p1[8];
        assert!(
            b0 * b1 < 0.0,
            "expected opposite drift, biases {b0} / {b1}"
        );
    }

    #[test]
    fn grad_matches_finite_difference() {
        let o = LogisticOracle::synthetic(100, 5, 1, 16, true, 9);
        let mut r = Pcg64::seed(2);
        let w: Vec<f32> = (0..6).map(|_| r.normal() as f32 * 0.3).collect();
        let x: Vec<f32> = (0..3 * 5).map(|_| r.normal() as f32).collect();
        let y = vec![1i32, 0, 1];
        let mut grad = vec![0.0f32; 6];
        o.loss_grad(&w, &x, &y, Some(&mut grad));
        for j in 0..6 {
            let h = 1e-3f32;
            let mut wp = w.clone();
            wp[j] += h;
            let lp = o.loss_grad(&wp, &x, &y, None);
            wp[j] -= 2.0 * h;
            let lm = o.loss_grad(&wp, &x, &y, None);
            let fd = (lp - lm) / (2e-3);
            assert!((fd - grad[j] as f64).abs() < 1e-3, "coord {j}");
        }
    }
}
