//! Table-free twin of [`super::QuadraticOracle`] for the scale regime.
//!
//! The dense oracle materializes `d`/`c` as `agents × dim` f64 tables —
//! exactly what the paper's assumptions need for small n, but ~1 GiB at
//! n = 1M, dim 64, which would dwarf the entire compact
//! [`crate::membership::NodeStore`] arena it sits next to. This variant
//! stores **nothing per agent**: every curvature `d_ij ~ U[l_min, l_max]`
//! and optimum coordinate `c_ij ~ N(0, spread²)` is re-derived on access
//! from a splitmix64 finalizer over `(seed, agent·dim + j)`, so the oracle
//! is O(1) memory at any n and two instances with the same seed define the
//! *same* objective in different processes — no tables to ship.
//!
//! The trade for statelessness is exactness of the *global* statistics:
//! `eval`/`full_loss`/`grad_norm_sq` average over a strided sample of
//! [`EVAL_AGENT_SAMPLE`] agents once n exceeds it (below the cutover they
//! are exact, matching the dense oracle's contract). Per-agent `step`
//! math is identical to the dense oracle: `g = d_ij(x − c_ij) + σ·ξ`.

use crate::backend::{Backend, EvalResult};
use crate::rngx::Pcg64;

/// Agents averaged by `eval`/`full_loss`/`grad_norm_sq`; below this count
/// the sampled statistics are exact (stride 1). Matches the scale engine's
/// default model-eval sample so a scale run's loss curve and its oracle
/// loss are estimated at the same resolution.
pub const EVAL_AGENT_SAMPLE: usize = 4096;

/// splitmix64 finalizer keyed on `(seed, idx)` — the per-coordinate field
/// generator. Full-64-bit idx, so any `agents × dim` product is collision-
/// free (the `quant::hash_u32` path would wrap past 4.29e9 coordinates).
#[inline]
fn mix(seed: u64, idx: u64) -> u64 {
    let mut z = seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Top 53 bits → f64 in [0, 1).
#[inline]
fn u01(z: u64) -> f64 {
    (z >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

/// Top 53 bits → f64 in (0, 1] — safe as a log argument in Box–Muller.
#[inline]
fn u01_open(z: u64) -> f64 {
    ((z >> 11) + 1) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

pub struct ProcQuadraticOracle {
    pub dim: usize,
    pub agents: usize,
    /// heterogeneity scale: c_ij ~ N(0, spread²)
    pub spread: f64,
    /// curvature range: d_ij ~ U[l_min, l_max]
    pub l_min: f64,
    pub l_max: f64,
    /// gradient noise stddev (σ of the paper's variance bound)
    pub sigma: f64,
    seed: u64,
}

impl ProcQuadraticOracle {
    pub fn new(
        dim: usize,
        agents: usize,
        spread: f64,
        l_min: f64,
        l_max: f64,
        sigma: f64,
        seed: u64,
    ) -> Self {
        assert!(l_min > 0.0 && l_max >= l_min);
        Self { dim, agents, spread, l_min, l_max, sigma, seed }
    }

    /// Curvature d_ij ∈ [l_min, l_max], re-derived from the hash field.
    #[inline]
    pub fn d_at(&self, agent: usize, j: usize) -> f64 {
        let idx = (agent * self.dim + j) as u64;
        self.l_min + u01(mix(self.seed, 3 * idx)) * (self.l_max - self.l_min)
    }

    /// Local optimum coordinate c_ij ~ N(0, spread²), via Box–Muller over
    /// two independent hash draws.
    #[inline]
    pub fn c_at(&self, agent: usize, j: usize) -> f64 {
        let idx = (agent * self.dim + j) as u64;
        let u1 = u01_open(mix(self.seed, 3 * idx + 1));
        let u2 = u01(mix(self.seed, 3 * idx + 2));
        self.spread * (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Stride of the eval sample: 1 (exact) while agents ≤
    /// [`EVAL_AGENT_SAMPLE`], else every `agents / EVAL_AGENT_SAMPLE`-th
    /// agent.
    #[inline]
    fn eval_stride(&self) -> usize {
        (self.agents / EVAL_AGENT_SAMPLE).max(1)
    }

    /// f(x) averaged over the strided agent sample (exact below the
    /// cutover — see [`EVAL_AGENT_SAMPLE`]).
    pub fn sampled_loss(&self, x: &[f64]) -> f64 {
        let stride = self.eval_stride();
        let mut acc = 0.0;
        let mut count = 0usize;
        for i in (0..self.agents).step_by(stride) {
            for j in 0..self.dim {
                let dx = x[j] - self.c_at(i, j);
                acc += 0.5 * self.d_at(i, j) * dx * dx;
            }
            count += 1;
        }
        acc / count.max(1) as f64
    }

    /// ∇f(x) over the same strided agent sample.
    pub fn sampled_grad(&self, x: &[f64]) -> Vec<f64> {
        let stride = self.eval_stride();
        let mut g = vec![0.0f64; self.dim];
        let mut count = 0usize;
        for i in (0..self.agents).step_by(stride) {
            for j in 0..self.dim {
                g[j] += self.d_at(i, j) * (x[j] - self.c_at(i, j));
            }
            count += 1;
        }
        let inv = 1.0 / count.max(1) as f64;
        for v in &mut g {
            *v *= inv;
        }
        g
    }
}

impl Backend for ProcQuadraticOracle {
    fn dim(&self) -> usize {
        self.dim
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        // deterministic start (paper: x_0 = 0^d), same as the dense oracle
        (vec![0.0; self.dim], vec![0.0; self.dim])
    }

    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64 {
        debug_assert!(agent < self.agents);
        let mut loss = 0.0;
        for j in 0..self.dim {
            let x = params[j] as f64;
            let dij = self.d_at(agent, j);
            let cij = self.c_at(agent, j);
            let noise = if self.sigma > 0.0 { rng.normal() * self.sigma } else { 0.0 };
            let g = dij * (x - cij) + noise;
            loss += 0.5 * dij * (x - cij) * (x - cij);
            // plain SGD (mu=0) — the theory setting; momentum unused here
            mom[j] = g as f32;
            params[j] = (x - lr as f64 * g) as f32;
        }
        loss
    }

    fn eval(&self, params: &[f32]) -> EvalResult {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        EvalResult { loss: self.sampled_loss(&x), accuracy: f64::NAN }
    }

    fn full_loss(&self, params: &[f32]) -> f64 {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        self.sampled_loss(&x)
    }

    fn grad_norm_sq(&self, params: &[f32]) -> Option<f64> {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        Some(self.sampled_grad(&x).iter().map(|g| g * g).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fields_are_in_range_and_seed_deterministic() {
        let a = ProcQuadraticOracle::new(8, 64, 1.5, 0.5, 2.0, 0.0, 7);
        let b = ProcQuadraticOracle::new(8, 64, 1.5, 0.5, 2.0, 0.0, 7);
        let other = ProcQuadraticOracle::new(8, 64, 1.5, 0.5, 2.0, 0.0, 8);
        let mut differs = false;
        for i in 0..64 {
            for j in 0..8 {
                let d = a.d_at(i, j);
                assert!((0.5..=2.0).contains(&d), "d out of range: {d}");
                assert!(a.c_at(i, j).is_finite());
                assert_eq!(d, b.d_at(i, j));
                assert_eq!(a.c_at(i, j), b.c_at(i, j));
                differs |= a.d_at(i, j) != other.d_at(i, j);
            }
        }
        assert!(differs, "seed must change the field");
    }

    #[test]
    fn c_field_has_normal_statistics() {
        // Box–Muller over hash draws: mean ≈ 0, variance ≈ spread² across
        // a large coordinate population.
        let spread = 1.3;
        let o = ProcQuadraticOracle::new(64, 4096, spread, 1.0, 1.0, 0.0, 42);
        let n = 200_000usize;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for k in 0..n {
            let c = o.c_at(k / 64, k % 64);
            s1 += c;
            s2 += c * c;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var / (spread * spread) - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn noiseless_single_agent_converges_to_its_own_optimum() {
        // one agent: f(x) = ½Σ d_j (x_j − c_j)², minimized exactly at c
        let o = ProcQuadraticOracle::new(8, 1, 1.0, 0.5, 2.0, 0.0, 5);
        let (mut p, mut m) = o.init();
        let mut rng = Pcg64::seed(1);
        for _ in 0..500 {
            o.step(0, &mut p, &mut m, 0.1, &mut rng);
        }
        let f = o.full_loss(&p);
        assert!(f < 1e-6, "f={f}");
        for j in 0..8 {
            assert!((p[j] as f64 - o.c_at(0, j)).abs() < 1e-3);
        }
    }

    #[test]
    fn sampled_eval_is_exact_below_the_cutover() {
        // agents ≤ EVAL_AGENT_SAMPLE → stride 1 → sampled == brute force
        let o = ProcQuadraticOracle::new(4, 33, 1.0, 0.5, 2.0, 0.0, 9);
        let x = vec![0.25f64; 4];
        let mut exact = 0.0;
        for i in 0..33 {
            for j in 0..4 {
                let dx = x[j] - o.c_at(i, j);
                exact += 0.5 * o.d_at(i, j) * dx * dx;
            }
        }
        exact /= 33.0;
        assert!((o.sampled_loss(&x) - exact).abs() < 1e-12);
    }

    #[test]
    fn step_is_deterministic_in_caller_rng() {
        let o = ProcQuadraticOracle::new(8, 2, 1.0, 0.5, 2.0, 0.3, 11);
        let run = || {
            let (mut p, mut m) = o.init();
            let mut rng = Pcg64::stream(42, 7);
            for _ in 0..50 {
                o.step(1, &mut p, &mut m, 0.05, &mut rng);
            }
            p
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn oracle_holds_no_per_agent_state() {
        // the whole point: n = 1M costs the same bytes as n = 2
        assert!(std::mem::size_of::<ProcQuadraticOracle>() <= 64);
        let _big = ProcQuadraticOracle::new(64, 1_000_000, 1.0, 0.5, 2.0, 0.2, 1);
    }
}
