//! Pure-Rust gradient oracles (DESIGN.md S15).
//!
//! All three implement the unified [`crate::backend::Backend`] trait
//! (`&self + Sync`, caller-supplied RNG), so every oracle runs on both the
//! serial and the shared-memory parallel executor with bit-identical
//! replay. They exist so that
//! (a) theory experiments (Γ_t, Theorem 4.1/4.2 bound checks) can use
//! objectives with *known* L, σ², ρ², x*, and exact gradients;
//! (b) property/integration tests run in milliseconds;
//! (c) the n=256 scaling figure (paper Fig. 6a) is tractable.

mod logistic;
mod proc_quadratic;
mod quadratic;
mod softmax;

pub use logistic::LogisticOracle;
pub use proc_quadratic::{ProcQuadraticOracle, EVAL_AGENT_SAMPLE};
pub use quadratic::QuadraticOracle;
pub use softmax::SoftmaxOracle;
