//! Pure-Rust gradient oracles (DESIGN.md S15).
//!
//! These implement [`crate::backend::TrainBackend`] without XLA so that
//! (a) theory experiments (Γ_t, Theorem 4.1/4.2 bound checks) can use
//! objectives with *known* L, σ², ρ², x*, and exact gradients;
//! (b) property/integration tests run in milliseconds;
//! (c) the n=256 scaling figure (paper Fig. 6a) is tractable.

mod logistic;
mod quadratic;
mod softmax;

pub use logistic::LogisticOracle;
pub use quadratic::QuadraticOracle;
pub use softmax::SoftmaxOracle;
