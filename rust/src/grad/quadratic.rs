//! Heterogeneous quadratic consensus objective with closed-form optimum.
//!
//!   f_i(x) = ½ (x − c_i)ᵀ D_i (x − c_i),   f = (1/n) Σ f_i
//!
//! with diagonal D_i ≻ 0.  Stochastic gradients add N(0, σ²I) noise, so the
//! oracle satisfies the paper's assumptions *exactly* with
//! L = max_j d_j,  variance bound σ², and data-heterogeneity ρ² measurable
//! from the c_i spread — the ideal instrument for validating Theorems
//! 4.1/4.2 and the Γ_t bound (Lemma F.3).

use crate::backend::{Backend, EvalResult};
use crate::rngx::Pcg64;

pub struct QuadraticOracle {
    pub dim: usize,
    pub agents: usize,
    /// per-agent diagonal curvatures, agents × dim
    d: Vec<f64>,
    /// per-agent optima, agents × dim
    c: Vec<f64>,
    /// gradient noise stddev (σ of the paper's variance bound)
    pub sigma: f64,
}

impl QuadraticOracle {
    /// `spread` controls heterogeneity (ρ): c_i ~ N(0, spread²·I).
    /// Curvatures d_ij ~ U[l_min, l_max].
    pub fn new(
        dim: usize,
        agents: usize,
        spread: f64,
        l_min: f64,
        l_max: f64,
        sigma: f64,
        seed: u64,
    ) -> Self {
        assert!(l_min > 0.0 && l_max >= l_min);
        let mut rng = Pcg64::seed(seed);
        let d: Vec<f64> = (0..agents * dim)
            .map(|_| l_min + rng.f64() * (l_max - l_min))
            .collect();
        let c: Vec<f64> = (0..agents * dim)
            .map(|_| rng.normal() * spread)
            .collect();
        Self { dim, agents, d, c, sigma }
    }

    /// Global optimum x* = (Σ D_i)⁻¹ Σ D_i c_i (coordinate-wise).
    pub fn optimum(&self) -> Vec<f64> {
        (0..self.dim)
            .map(|j| {
                let (num, den) = (0..self.agents).fold((0.0, 0.0), |(s, t), i| {
                    let dij = self.d[i * self.dim + j];
                    (s + dij * self.c[i * self.dim + j], t + dij)
                });
                num / den
            })
            .collect()
    }

    /// Smoothness constant L = max_ij d_ij.
    pub fn smoothness(&self) -> f64 {
        self.d.iter().cloned().fold(0.0, f64::max)
    }

    /// Heterogeneity bound ρ² = max_x (1/n) Σ‖∇f_i(x) − ∇f(x)‖² evaluated
    /// at x* (a representative point; exact sup is unbounded for differing
    /// D_i, so we report the paper-relevant value near the optimum).
    pub fn rho_sq_at_optimum(&self) -> f64 {
        let x = self.optimum();
        let g_mean = self.true_grad(&x);
        let mut acc = 0.0;
        for i in 0..self.agents {
            let mut s = 0.0;
            for j in 0..self.dim {
                let gi = self.d[i * self.dim + j] * (x[j] - self.c[i * self.dim + j]);
                s += (gi - g_mean[j]).powi(2);
            }
            acc += s;
        }
        acc / self.agents as f64
    }

    /// ∇f(x) exactly.
    pub fn true_grad(&self, x: &[f64]) -> Vec<f64> {
        (0..self.dim)
            .map(|j| {
                (0..self.agents)
                    .map(|i| self.d[i * self.dim + j] * (x[j] - self.c[i * self.dim + j]))
                    .sum::<f64>()
                    / self.agents as f64
            })
            .collect()
    }

    /// f(x) exactly.
    pub fn loss(&self, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.agents {
            for j in 0..self.dim {
                let dx = x[j] - self.c[i * self.dim + j];
                acc += 0.5 * self.d[i * self.dim + j] * dx * dx;
            }
        }
        acc / self.agents as f64
    }

    pub fn f_star(&self) -> f64 {
        self.loss(&self.optimum())
    }
}

/// The oracle's `d`/`c` tables are immutable after construction, so the
/// unified backend impl is trivially `&self + Sync`: stepping only needs
/// the caller's per-node RNG. Draw-free when `sigma == 0` so noiseless
/// benches measure pure executor cost.
impl Backend for QuadraticOracle {
    fn dim(&self) -> usize {
        self.dim
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        // deterministic start (paper: x_0 = 0^d)
        (vec![0.0; self.dim], vec![0.0; self.dim])
    }

    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64 {
        debug_assert!(agent < self.agents);
        let dim = self.dim;
        let mut loss = 0.0;
        for j in 0..dim {
            let x = params[j] as f64;
            let dij = self.d[agent * dim + j];
            let cij = self.c[agent * dim + j];
            let noise = if self.sigma > 0.0 { rng.normal() * self.sigma } else { 0.0 };
            let g = dij * (x - cij) + noise;
            loss += 0.5 * dij * (x - cij) * (x - cij);
            // plain SGD (mu=0) — the theory setting; momentum unused here
            mom[j] = g as f32;
            params[j] = (x - lr as f64 * g) as f32;
        }
        loss
    }

    fn eval(&self, params: &[f32]) -> EvalResult {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        EvalResult { loss: self.loss(&x), accuracy: f64::NAN }
    }

    fn full_loss(&self, params: &[f32]) -> f64 {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        self.loss(&x)
    }

    fn grad_norm_sq(&self, params: &[f32]) -> Option<f64> {
        let x: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        Some(self.true_grad(&x).iter().map(|g| g * g).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_has_zero_gradient() {
        let o = QuadraticOracle::new(16, 4, 2.0, 0.5, 3.0, 0.0, 7);
        let g = o.true_grad(&o.optimum());
        assert!(g.iter().all(|v| v.abs() < 1e-10));
    }

    #[test]
    fn loss_minimized_at_optimum() {
        let o = QuadraticOracle::new(8, 3, 1.0, 0.5, 2.0, 0.0, 3);
        let star = o.f_star();
        let mut perturbed = o.optimum();
        perturbed[0] += 0.1;
        assert!(o.loss(&perturbed) > star);
        assert!(star >= 0.0);
    }

    #[test]
    fn noiseless_sgd_converges() {
        let o = QuadraticOracle::new(8, 1, 1.0, 0.5, 2.0, 0.0, 5);
        let (mut p, mut m) = o.init();
        let mut rng = Pcg64::seed(1);
        for _ in 0..500 {
            o.step(0, &mut p, &mut m, 0.1, &mut rng);
        }
        let f = o.full_loss(&p);
        assert!(
            (f - o.f_star()).abs() < 1e-6,
            "f={f} f*={}",
            o.f_star()
        );
    }

    #[test]
    fn stochastic_gradient_is_unbiased() {
        let o = QuadraticOracle::new(4, 2, 1.0, 1.0, 1.0, 0.5, 9);
        let x = vec![0.3f32; 4];
        let mut rng = Pcg64::seed(2);
        let mut acc = vec![0.0f64; 4];
        let trials = 20_000;
        for _ in 0..trials {
            let mut p = x.clone();
            let mut m = vec![0.0; 4];
            o.step(0, &mut p, &mut m, 1.0, &mut rng);
            for j in 0..4 {
                acc[j] += (x[j] - p[j]) as f64; // = lr * g_noisy, lr=1
            }
        }
        // compare against agent-0 local gradient
        for j in 0..4 {
            let g_loc = o.d[j] * (0.3 - o.c[j]);
            assert!(
                (acc[j] / trials as f64 - g_loc).abs() < 0.02,
                "coord {j}"
            );
        }
    }

    #[test]
    fn step_is_deterministic_in_caller_rng() {
        // the replay contract at the oracle level: identical rng streams
        // produce identical trajectories, independent of any hidden state
        let o = QuadraticOracle::new(8, 2, 1.0, 0.5, 2.0, 0.3, 11);
        let run = || {
            let (mut p, mut m) = o.init();
            let mut rng = Pcg64::stream(42, 7);
            for _ in 0..50 {
                o.step(1, &mut p, &mut m, 0.05, &mut rng);
            }
            p
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
    }

    #[test]
    fn smoothness_and_rho_are_finite() {
        let o = QuadraticOracle::new(8, 4, 2.0, 0.5, 3.0, 0.1, 1);
        assert!(o.smoothness() <= 3.0 && o.smoothness() >= 0.5);
        assert!(o.rho_sq_at_optimum().is_finite());
        assert!(o.rho_sq_at_optimum() >= 0.0);
    }
}
