//! The two generic executors: every [`Algorithm`] × every [`Backend`], on
//! one thread or many.
//!
//! * [`run_serial`] walks the pre-drawn [`super::InteractionSchedule`] in
//!   program order — the discrete-event reference execution, and
//!   simultaneously the testable replay oracle for the parallel executor.
//! * [`run_parallel`] drains the identical schedule on N real worker
//!   threads over per-node `Mutex<NodeState>`; an event takes its
//!   participants' locks in ascending node order (a global lock order, so
//!   no two events can deadlock) and workers **commit events in per-node
//!   dependency order**: event t runs only after each participant has
//!   finished all of its earlier scheduled events.
//!
//! Dispatch is by [`EventKind`], exhaustively — `Gossip` events take the
//! allocation-free two-lock fast path, `Compute` events take one lock, and
//! `Mix` barriers lock all participants in ascending node order. Because
//! round-based algorithms schedule *phased* rounds (n single-node compute
//! events + a mix barrier per round, `seq`-ordered compute → mix), their
//! compute phases spread across all K workers; only the mixing step
//! serializes. Schedules are measured in logical **ticks** ([`Event::tick`]:
//! gossip interactions or synchronous rounds) — the lr schedule, eval
//! milestones, and the reported interaction count all count ticks, so a
//! phased round costs one tick exactly like the monolithic round it
//! replaced.
//!
//! # Replay determinism
//!
//! A parallel run is **bit-identical** to the serial run of the same seed,
//! by construction rather than by luck:
//!
//! 1. The whole event sequence (participants, local-step counts H_i, and
//!    event-local randomness seeds) is pre-drawn by
//!    [`Algorithm::schedule`] from a dedicated [`Pcg64::stream`] — it does
//!    not depend on execution order.
//! 2. All node-local randomness (gradient noise, batch draws, compute-time
//!    jitter) comes from that node's own `Pcg64::stream`, consumed in the
//!    node's schedule order.
//! 3. The dependency order fixes the dataflow DAG — and therefore every
//!    f32 operation and operand — so any thread interleaving computes the
//!    same bits. Per-node f64 clock totals are merged once, in node-index
//!    order, at the end.
//!
//! `tests/parallel_executor.rs` asserts metric-for-metric bit equality
//! between the two executors for SwarmSGD (all three averaging modes,
//! quadratic and softmax oracles), AD-PSGD, and the four phased round-based
//! baselines (dpsgd/sgp/localsgd/allreduce at 1/2/4/8 threads) — plus bit
//! equality of the phased schedules against the pre-redesign monolithic
//! rounds — and CI enforces it on every push/PR.
//!
//! Deadlock freedom: ordered lock acquisition within an event, plus the
//! induction that the lowest unfinished schedule index always has all of
//! its dependencies satisfied.

use super::algorithm::{Algorithm, Event, EventKind, NodeState, StepCtx};
use super::metrics::{CurvePoint, RunMetrics};
use super::policy::MergeScratch;
use super::LrSchedule;
use crate::analysis::gamma_potential;
use crate::backend::Backend;
use crate::netmodel::CostModel;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;
use crate::topology::Graph;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Stream tags for the executor's deterministic sub-RNGs (arbitrary,
/// distinct; node streams use `STREAM_NODE_BASE + node`).
const STREAM_SCHEDULE: u64 = 0x5EED_5C8E_D01E_0001;
const STREAM_EVAL: u64 = 0x5EED_E7A1_0000_0002;
const STREAM_NODE_BASE: u64 = 0x5EED_40DE_0000_0003;

/// Everything that parameterizes one run besides the algorithm, backend,
/// graph, and cost model.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub n: usize,
    /// total schedule length: pairwise interactions (gossip algorithms) or
    /// synchronous rounds (round-based algorithms)
    pub events: u64,
    pub lr: LrSchedule,
    pub seed: u64,
    /// metrics tag
    pub name: String,
    /// evaluate every this many events (0 = only at the end)
    pub eval_every: u64,
    /// record Γ_t at eval points
    pub track_gamma: bool,
}

/// Shared run state visible to every worker.
struct Shared<'a> {
    algo: &'a dyn Algorithm,
    backend: &'a dyn Backend,
    cost: &'a CostModel,
    scn: &'a Scenario,
    lr: LrSchedule,
    events: &'a [Event],
    nodes: Vec<Mutex<NodeState>>,
    /// completed-event count per node (the dependency tokens)
    done: Vec<AtomicU64>,
    /// global schedule cursor (next unclaimed event index)
    cursor: AtomicU64,
    bits: AtomicU64,
    fallbacks: AtomicU64,
    /// set when a worker panics so dependency spins stay live
    abort: AtomicBool,
    dim: usize,
    n: usize,
}

/// Flags `abort` if the owning thread unwinds, so sibling workers spinning
/// on a dependency from the dead thread exit instead of hanging.
struct AbortGuard<'a>(&'a AtomicBool);

impl Drop for AbortGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

/// Execute the run's schedule in program order on the calling thread — the
/// discrete-event reference executor (`--executor serial`). Static-graph
/// convenience wrapper over [`run_serial_scenario`].
pub fn run_serial(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    graph: &Graph,
    cost: &CostModel,
) -> RunMetrics {
    run_serial_scenario(algo, backend, spec, &Scenario::static_graph(graph.clone()), cost)
}

/// Drain the identical schedule on `threads` shared-memory worker threads
/// (`--executor parallel --threads K`). Metrics are bit-identical to
/// [`run_serial`] at any thread count. Static-graph convenience wrapper
/// over [`run_parallel_scenario`].
pub fn run_parallel(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    graph: &Graph,
    cost: &CostModel,
    threads: usize,
) -> RunMetrics {
    run_parallel_scenario(
        algo,
        backend,
        spec,
        &Scenario::static_graph(graph.clone()),
        cost,
        threads,
    )
}

/// [`run_serial`] under a full [`Scenario`] (graph schedule + speed
/// classes). The default scenario reproduces the static-graph wrappers
/// bit-for-bit.
pub fn run_serial_scenario(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    scn: &Scenario,
    cost: &CostModel,
) -> RunMetrics {
    run_schedule(algo, backend, spec, scn, cost, 1, "serial")
}

/// [`run_parallel`] under a full [`Scenario`]. Bit-identical to
/// [`run_serial_scenario`] at any thread count for every scenario — the
/// schedule (including its graph-constrained pairs) is pre-drawn before
/// any thread starts.
pub fn run_parallel_scenario(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    scn: &Scenario,
    cost: &CostModel,
    threads: usize,
) -> RunMetrics {
    // no silent clamp: the config layer rejects an explicit threads=0 with
    // an actionable error, so a zero reaching this far is a caller bug
    assert!(threads >= 1, "run_parallel needs at least one worker thread");
    run_schedule(algo, backend, spec, scn, cost, threads, "parallel")
}

fn run_schedule(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    scn: &Scenario,
    cost: &CostModel,
    threads: usize,
    label: &str,
) -> RunMetrics {
    assert!(spec.n >= 1, "need at least one node");
    assert_eq!(spec.n, scn.n(), "spec n must match the scenario graph");
    let schedule = {
        let mut srng = Pcg64::stream(spec.seed, STREAM_SCHEDULE);
        algo.schedule(spec.n, spec.events, scn, &mut srng)
    };
    let dim = backend.dim();
    let (p0, m0) = backend.init();
    assert_eq!(p0.len(), dim, "backend dim() must match its init vector");
    let nodes: Vec<Mutex<NodeState>> = (0..spec.n)
        .map(|k| {
            Mutex::new(NodeState::new(
                p0.clone(),
                m0.clone(),
                Pcg64::stream(spec.seed, STREAM_NODE_BASE + k as u64),
            ))
        })
        .collect();
    let sh = Shared {
        algo,
        backend,
        cost,
        scn,
        lr: spec.lr,
        events: &schedule.events,
        nodes,
        done: (0..spec.n).map(|_| AtomicU64::new(0)).collect(),
        cursor: AtomicU64::new(0),
        bits: AtomicU64::new(0),
        fallbacks: AtomicU64::new(0),
        abort: AtomicBool::new(false),
        dim,
        n: spec.n,
    };
    let mut eval_rng = Pcg64::stream(spec.seed, STREAM_EVAL);
    let mut m = RunMetrics::new(&spec.name);
    // milestones are in logical ticks (gossip interactions / synchronous
    // rounds); each maps to the event-index boundary where its last tick's
    // events end, so evaluation always happens at a round barrier
    let total = schedule.ticks;
    for mark in milestones(total, spec.eval_every) {
        let end = tick_boundary(&schedule.events, mark);
        if threads == 1 {
            chunk_serial(&sh, end);
        } else {
            chunk_parallel(&sh, end, threads);
        }
        record_point(&sh, mark, &mut eval_rng, spec.track_gamma, &mut m);
    }
    let Shared { nodes, bits, fallbacks, .. } = sh;
    let states: Vec<NodeState> = nodes
        .into_iter()
        .map(|n| n.into_inner().expect("node lock poisoned"))
        .collect();
    m.finalize(
        &states,
        backend,
        total,
        bits.into_inner(),
        fallbacks.into_inner(),
        label,
        threads,
        algo.kernel().name(),
    );
    m
}

/// Index of the first event past logical tick `tick - 1`: the schedule
/// prefix `[0, boundary)` contains exactly the events of ticks `< tick`.
/// Events are appended in non-decreasing tick order, so the predicate is
/// partition-monotone.
fn tick_boundary(events: &[Event], tick: u64) -> u64 {
    events.partition_point(|e| e.tick < tick) as u64
}

/// Milestone ticks: every multiple of `eval_every` in `(0, total)`, then
/// `total`. (Shared with the free-running executor, which records all but
/// the final mark from live slot snapshots.)
pub(super) fn milestones(total: u64, eval_every: u64) -> Vec<u64> {
    let mut v = Vec::new();
    if total == 0 {
        return v;
    }
    if eval_every > 0 {
        let mut next = eval_every;
        while next < total {
            v.push(next);
            next += eval_every;
        }
    }
    v.push(total);
    v
}

/// Drain schedule indices `[cursor, end)` on `threads` scoped workers.
fn chunk_parallel(sh: &Shared<'_>, end: u64, threads: usize) {
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _guard = AbortGuard(&sh.abort);
                // one merge scratch per worker, reused for every event it
                // claims — the hot path allocates nothing per interaction
                let mut scratch = MergeScratch::with_kernel(sh.dim, sh.algo.kernel());
                loop {
                    let t = sh.cursor.fetch_add(1, Ordering::Relaxed);
                    if t >= end {
                        break;
                    }
                    let ev = &sh.events[t as usize];
                    if !wait_deps(sh, ev) {
                        break;
                    }
                    execute_event(sh, ev, &mut scratch);
                    // this worker is the unique owner of all participants
                    for (&k, &s) in ev.nodes.iter().zip(&ev.seq) {
                        sh.done[k].store(s + 1, Ordering::Release);
                    }
                }
            });
        }
    });
    // indices over-claimed past `end` were abandoned; hand them to the
    // next chunk
    sh.cursor.store(end, Ordering::Relaxed);
}

/// The single-thread path: plain program order, no spawning.
fn chunk_serial(sh: &Shared<'_>, end: u64) {
    let mut scratch = MergeScratch::with_kernel(sh.dim, sh.algo.kernel());
    loop {
        let t = sh.cursor.load(Ordering::Relaxed);
        if t >= end {
            break;
        }
        sh.cursor.store(t + 1, Ordering::Relaxed);
        let ev = &sh.events[t as usize];
        // program order trivially satisfies the dependency order
        execute_event(sh, ev, &mut scratch);
        for (&k, &s) in ev.nodes.iter().zip(&ev.seq) {
            sh.done[k].store(s + 1, Ordering::Relaxed);
        }
    }
}

/// Spin until every participant of `ev` has completed all earlier scheduled
/// events. Returns false if the run is aborting (sibling panic).
fn wait_deps(sh: &Shared<'_>, ev: &Event) -> bool {
    let mut spins = 0u32;
    loop {
        let ready = ev
            .nodes
            .iter()
            .zip(&ev.seq)
            .all(|(&k, &s)| sh.done[k].load(Ordering::Acquire) == s);
        if ready {
            return true;
        }
        if sh.abort.load(Ordering::Relaxed) {
            return false;
        }
        spins = spins.wrapping_add(1);
        if spins % 64 == 0 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Execute one scheduled event: dispatch on its [`EventKind`] (never on
/// participant arity — a new kind is a compile error here, not a silent
/// misroute), take the participants' locks in ascending node order, hand
/// exclusive borrows to the algorithm in role order, merge the wire
/// accounting.
fn execute_event(sh: &Shared<'_>, ev: &Event, scratch: &mut MergeScratch) {
    let ctx = StepCtx {
        backend: sh.backend,
        cost: sh.cost,
        // interact-time neighbor draws (SGP's push targets) see the graph
        // in force at the event's tick
        graph: sh.scn.graph_at(ev.tick),
        // the paper numbers interactions/rounds from 1
        lr: sh.lr.at(ev.tick + 1),
        dim: sh.dim,
        n: sh.n,
    };
    let outcome = match ev.kind {
        EventKind::Gossip => {
            // pairwise fast path: two ordered locks, no allocation
            debug_assert_eq!(ev.nodes.len(), 2, "gossip events are 2-node");
            let (i, j) = (ev.nodes[0], ev.nodes[1]);
            let (lo, hi) = (i.min(j), i.max(j));
            let mut g_lo = sh.nodes[lo].lock().expect("node lock poisoned");
            let mut g_hi = sh.nodes[hi].lock().expect("node lock poisoned");
            let (a, b) = if lo == i {
                (&mut *g_lo, &mut *g_hi)
            } else {
                (&mut *g_hi, &mut *g_lo)
            };
            let mut parts = [a, b];
            sh.algo.interact_with(ev.tick, ev, &mut parts, &ctx, scratch)
        }
        EventKind::Compute => {
            // single-node local phase: one lock, no peers — phased rounds
            // spread n of these per round across all workers
            debug_assert_eq!(ev.nodes.len(), 1, "compute events are 1-node");
            let mut g = sh.nodes[ev.nodes[0]].lock().expect("node lock poisoned");
            let mut parts = [&mut *g];
            sh.algo.interact_with(ev.tick, ev, &mut parts, &ctx, scratch)
        }
        EventKind::Mix => {
            // mixing barrier: lock all participants in ascending node
            // order, then re-borrow in the event's role order
            let mut order: Vec<usize> = ev.nodes.clone();
            order.sort_unstable();
            let mut guards: Vec<MutexGuard<'_, NodeState>> = order
                .iter()
                .map(|&k| sh.nodes[k].lock().expect("node lock poisoned"))
                .collect();
            let mut slots: Vec<Option<&mut NodeState>> =
                guards.iter_mut().map(|g| Some(&mut **g)).collect();
            let mut parts: Vec<&mut NodeState> = ev
                .nodes
                .iter()
                .map(|&k| {
                    let rank = order.binary_search(&k).expect("participant not locked");
                    slots[rank].take().expect("duplicate participant")
                })
                .collect();
            sh.algo.interact_with(ev.tick, ev, &mut parts, &ctx, scratch)
        }
    };
    if outcome.bits > 0 {
        sh.bits.fetch_add(outcome.bits, Ordering::Relaxed);
    }
    if outcome.fallbacks > 0 {
        sh.fallbacks.fetch_add(outcome.fallbacks, Ordering::Relaxed);
    }
}

/// Record a curve point at a chunk barrier (no workers active): consensus
/// and individual models from the algorithm, Γ_t on demand, per-node f64
/// reductions in node-index order.
fn record_point(
    sh: &Shared<'_>,
    t: u64,
    eval_rng: &mut Pcg64,
    track_gamma: bool,
    m: &mut RunMetrics,
) {
    let guards: Vec<MutexGuard<'_, NodeState>> =
        sh.nodes.iter().map(|n| n.lock().expect("node lock poisoned")).collect();
    let states: Vec<&NodeState> = guards.iter().map(|g| &**g).collect();
    let n = states.len();
    let pick = eval_rng.below_usize(n);
    let models = sh.algo.round_metrics(&states, pick);
    let ev = sh.backend.eval(&models.consensus);
    let ind = sh.backend.eval(&models.individual);
    m.final_model = models.consensus;
    let gamma = if track_gamma {
        let live: Vec<Vec<f32>> = states.iter().map(|s| s.params.clone()).collect();
        gamma_potential(&live)
    } else {
        f64::NAN
    };
    let finite: Vec<f64> =
        states.iter().map(|s| s.last_loss).filter(|l| l.is_finite()).collect();
    let train_loss = if finite.is_empty() {
        f64::NAN
    } else {
        finite.iter().sum::<f64>() / finite.len() as f64
    };
    let sim_time = states.iter().map(|s| s.time).fold(0.0, f64::max);
    let epochs = states
        .iter()
        .enumerate()
        .map(|(i, s)| sh.backend.epochs(i, s.steps))
        .sum::<f64>()
        / n as f64;
    m.push(CurvePoint {
        t,
        parallel_time: sh.algo.parallel_time(t, n),
        sim_time,
        epochs,
        train_loss,
        eval_loss: ev.loss,
        eval_acc: ev.accuracy,
        indiv_loss: ind.loss,
        gamma,
        bits: sh.bits.load(Ordering::Relaxed),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AveragingMode, LocalSteps, SwarmSgd};
    use crate::grad::QuadraticOracle;
    use crate::topology::Topology;

    fn quad(n: usize, dim: usize, sigma: f64) -> QuadraticOracle {
        QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 11)
    }

    fn spec(n: usize, t: u64) -> RunSpec {
        RunSpec {
            n,
            events: t,
            lr: LrSchedule::Constant(0.05),
            seed: 9,
            name: "par".into(),
            eval_every: 100,
            track_gamma: true,
        }
    }

    fn graph(n: usize) -> Graph {
        let mut rng = Pcg64::seed(5);
        Graph::build(Topology::Complete, n, &mut rng)
    }

    fn swarm(mode: AveragingMode) -> SwarmSgd {
        SwarmSgd { local_steps: LocalSteps::Fixed(2), mode }
    }

    #[test]
    fn schedule_is_deterministic_and_sequenced() {
        let algo = swarm(AveragingMode::NonBlocking);
        let g = graph(8);
        let scn = Scenario::static_graph(g);
        let mut r1 = Pcg64::stream(9, STREAM_SCHEDULE);
        let mut r2 = Pcg64::stream(9, STREAM_SCHEDULE);
        let a = algo.schedule(8, 500, &scn, &mut r1);
        let b = algo.schedule(8, 500, &scn, &mut r2);
        assert_eq!(a.events, b.events);
        assert_eq!(a.per_node, b.per_node);
        // seq tokens count each node's events in order
        let mut seen = vec![0u64; 8];
        for ev in &a.events {
            assert_ne!(ev.nodes[0], ev.nodes[1]);
            for (&k, &s) in ev.nodes.iter().zip(&ev.seq) {
                assert_eq!(s, seen[k]);
                seen[k] += 1;
            }
        }
        assert_eq!(seen, a.per_node);
        assert_eq!(seen.iter().sum::<u64>(), 1000);
    }

    fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics) {
        assert_eq!(a.curve.len(), b.curve.len());
        for (pa, pb) in a.curve.iter().zip(&b.curve) {
            assert_eq!(pa.t, pb.t);
            assert_eq!(pa.eval_loss.to_bits(), pb.eval_loss.to_bits(), "t={}", pa.t);
            assert_eq!(pa.train_loss.to_bits(), pb.train_loss.to_bits());
            assert_eq!(pa.indiv_loss.to_bits(), pb.indiv_loss.to_bits());
            assert_eq!(pa.gamma.to_bits(), pb.gamma.to_bits());
            assert_eq!(pa.sim_time.to_bits(), pb.sim_time.to_bits());
            assert_eq!(pa.bits, pb.bits);
        }
        assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.quant_fallbacks, b.quant_fallbacks);
        assert_eq!(a.local_steps, b.local_steps);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(a.compute_time_total.to_bits(), b.compute_time_total.to_bits());
        assert_eq!(a.comm_time_total.to_bits(), b.comm_time_total.to_bits());
    }

    #[test]
    fn parallel_matches_serial_all_swarm_modes() {
        let n = 8;
        for mode in [
            AveragingMode::NonBlocking,
            AveragingMode::Blocking,
            AveragingMode::Quantized { bits: 8, eps: 1e-2 },
        ] {
            let algo = swarm(mode);
            let g = graph(n);
            let backend = quad(n, 16, 0.1);
            let cost = CostModel::deterministic(0.4);
            let s = spec(n, 400);
            let serial = run_serial(&algo, &backend, &s, &g, &cost);
            for threads in [2, 4] {
                let par = run_parallel(&algo, &backend, &s, &g, &cost, threads);
                assert_bit_identical(&serial, &par);
            }
        }
    }

    #[test]
    fn serial_converges_on_quadratic() {
        let n = 8;
        let backend = quad(n, 16, 0.1);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.eval(&p).loss - f_star
        };
        let algo = swarm(AveragingMode::NonBlocking);
        let g = graph(n);
        let cost = CostModel::deterministic(0.4);
        let m = run_serial(&algo, &backend, &spec(n, 800), &g, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        assert_eq!(m.interactions, 800);
        assert_eq!(m.local_steps, 800 * 2 * 2);
        assert!(m.sim_time > 0.0);
        assert_eq!(m.executor, "serial");
    }

    #[test]
    fn milestones_cadence() {
        assert_eq!(milestones(10, 0), vec![10]);
        assert_eq!(milestones(10, 4), vec![4, 8, 10]);
        assert_eq!(milestones(8, 4), vec![4, 8]);
        assert!(milestones(0, 4).is_empty());
    }

    #[test]
    fn tick_boundary_maps_ticks_to_event_ends() {
        use crate::coordinator::InteractionSchedule;
        let mut s = InteractionSchedule::new(4);
        s.push_round(&[1; 4], 1); // events 0..=4, tick 0
        s.push_gossip(0, 1, 2, 2, 2); // event 5, tick 1
        s.push_round(&[1; 4], 3); // events 6..=10, tick 2
        assert_eq!(s.ticks, 3);
        assert_eq!(tick_boundary(&s.events, 0), 0);
        assert_eq!(tick_boundary(&s.events, 1), 5);
        assert_eq!(tick_boundary(&s.events, 2), 6);
        assert_eq!(tick_boundary(&s.events, 3), 11);
        assert_eq!(tick_boundary(&s.events, 99), 11);
    }
}
