//! Pairwise averaging primitives shared by all algorithms. (The former
//! `Agent`/`Cluster` state containers are gone — node state now lives in
//! [`super::NodeState`], owned by the executors.)

use crate::quant::{decode, encode, QuantError};

/// In-place midpoint: a ← b ← (a+b)/2 — Algorithm 1's averaging step.
pub fn average_into_both(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        let m = 0.5 * (*x + *y);
        *x = m;
        *y = m;
    }
}

/// The Appendix-F non-blocking update for one endpoint, shared by every
/// algorithm that uses it so all executors stay bit-identical: given the
/// pre-local-phase snapshot `s` and the incoming communication copy `inc`,
/// set `comm ← (s + inc)/2` and `params ← (s + inc)/2 + (params − s)`
/// in place.
pub fn nonblocking_update(params: &mut [f32], comm: &mut [f32], s: &[f32], inc: &[f32]) {
    debug_assert_eq!(params.len(), comm.len());
    debug_assert_eq!(params.len(), s.len());
    debug_assert_eq!(params.len(), inc.len());
    for k in 0..params.len() {
        let avg = 0.5 * (s[k] + inc[k]);
        let delta = params[k] - s[k];
        comm[k] = avg;
        params[k] = avg + delta;
    }
}

/// out ← (x + y)/2 without touching inputs.
pub fn midpoint(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = 0.5 * (a + b);
    }
}

/// Result of a quantized one-way transfer.
pub struct QuantTransfer {
    /// decoded (lattice-rounded) remote model
    pub decoded: Vec<f32>,
    /// bits that crossed the wire (including fallback if any)
    pub bits: u64,
    /// true if the lattice decode failed and we fell back to full precision
    pub fell_back: bool,
}

/// Ship `remote` to a receiver holding `local` through the lattice codec;
/// on checksum failure fall back to full precision (paper: failure happens
/// w.p. O(1/T²) and is handled outside the main bound).
pub fn quantized_transfer(
    remote: &[f32],
    local: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
) -> QuantTransfer {
    let msg = encode(remote, eps, bits, seed);
    match decode(&msg, local) {
        Ok(decoded) => QuantTransfer { decoded, bits: msg.wire_bits(), fell_back: false },
        Err(QuantError::ChecksumMismatch) => QuantTransfer {
            decoded: remote.to_vec(),
            // failed quantized attempt + full-precision resend
            bits: msg.wire_bits() + 32 * remote.len() as u64,
            fell_back: true,
        },
        Err(e) => panic!("quantized_transfer: protocol error: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_into_both_midpoint() {
        let mut a = vec![1.0f32, 3.0];
        let mut b = vec![3.0f32, -1.0];
        average_into_both(&mut a, &mut b);
        assert_eq!(a, vec![2.0, 1.0]);
        assert_eq!(b, vec![2.0, 1.0]);
    }

    #[test]
    fn nonblocking_update_rule() {
        // S = [0, 0], inc = [2, 4], params = S + delta with delta = [1, 1]
        let s = vec![0.0f32, 0.0];
        let mut params = vec![1.0f32, 1.0];
        let mut comm = vec![9.0f32, 9.0];
        let inc = vec![2.0f32, 4.0];
        nonblocking_update(&mut params, &mut comm, &s, &inc);
        assert_eq!(comm, vec![1.0, 2.0]); // (S+inc)/2
        assert_eq!(params, vec![2.0, 3.0]); // (S+inc)/2 + delta
    }

    #[test]
    fn midpoint_is_elementwise_mean() {
        let x = vec![1.0f32, -2.0];
        let y = vec![3.0f32, 2.0];
        let mut out = vec![0.0f32; 2];
        midpoint(&x, &y, &mut out);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    #[test]
    fn quantized_transfer_close_models() {
        let remote: Vec<f32> = (0..512).map(|i| (i as f32) * 1e-4).collect();
        let local: Vec<f32> = remote.iter().map(|v| v + 0.01).collect();
        let t = quantized_transfer(&remote, &local, 1e-3, 8, 9);
        assert!(!t.fell_back);
        assert_eq!(t.bits, 8 * 512 + 160);
        for (d, r) in t.decoded.iter().zip(&remote) {
            assert!((d - r).abs() <= 1e-3 + 1e-6);
        }
    }

    #[test]
    fn quantized_transfer_falls_back_when_far() {
        let remote = vec![0.0f32; 256];
        let local = vec![10.0f32; 256];
        let t = quantized_transfer(&remote, &local, 1e-3, 4, 3);
        assert!(t.fell_back);
        assert_eq!(t.decoded, remote);
        assert!(t.bits > 32 * 256);
    }
}
