//! Agent state + pairwise averaging primitives shared by all algorithms.

use crate::analysis::gamma_potential;
use crate::backend::TrainBackend;
use crate::quant::{decode, encode, QuantError};
use crate::rngx::Pcg64;

/// One decentralized agent (paper §3): a live model copy `X^i` being updated
/// by local SGD, and a communication copy `Y^i` that partners read
/// asynchronously in the non-blocking variant (Appendix F).
pub struct Agent {
    /// live copy X^i
    pub params: Vec<f32>,
    /// optimizer momentum (travels with the live copy; NOT averaged —
    /// matching the paper's implementation where only models are exchanged)
    pub mom: Vec<f32>,
    /// communication copy Y^i = X^i + η·h̃ of the *previous* local batch
    /// (what a partner sees if it reads while we're mid-computation)
    pub comm: Vec<f32>,
    /// local SGD steps performed
    pub steps: u64,
    /// pairwise interactions participated in
    pub interactions: u64,
    /// last observed minibatch loss
    pub last_loss: f64,
    /// private randomness (quantizer seeds, H sampling)
    pub rng: Pcg64,
}

impl Agent {
    fn new(params: Vec<f32>, mom: Vec<f32>, rng: Pcg64) -> Self {
        let comm = params.clone();
        Self { params, mom, comm, steps: 0, interactions: 0, last_loss: f64::NAN, rng }
    }
}

/// The set of agents + convenience ops over them.
pub struct Cluster {
    pub agents: Vec<Agent>,
    pub dim: usize,
}

impl Cluster {
    /// All agents start from the same init (paper: common x₀).
    pub fn init(n: usize, backend: &mut dyn TrainBackend, seed: u64) -> Self {
        let mut root = Pcg64::seed(seed);
        let (p, m) = backend.init(seed as i64);
        let dim = p.len();
        let agents = (0..n)
            .map(|i| Agent::new(p.clone(), m.clone(), root.split(i as u64)))
            .collect();
        Self { agents, dim }
    }

    pub fn n(&self) -> usize {
        self.agents.len()
    }

    /// Mutable access to two distinct agents.
    pub fn pair_mut(&mut self, i: usize, j: usize) -> (&mut Agent, &mut Agent) {
        assert_ne!(i, j);
        if i < j {
            let (a, b) = self.agents.split_at_mut(j);
            (&mut a[i], &mut b[0])
        } else {
            let (a, b) = self.agents.split_at_mut(i);
            (&mut b[0], &mut a[j])
        }
    }

    /// Coordinate-wise mean of live models μ_t.
    pub fn mean_model(&self) -> Vec<f32> {
        let n = self.n() as f64;
        let mut mu = vec![0.0f64; self.dim];
        for a in &self.agents {
            for (s, &v) in mu.iter_mut().zip(&a.params) {
                *s += v as f64;
            }
        }
        mu.into_iter().map(|v| (v / n) as f32).collect()
    }

    /// Γ_t over live models.
    pub fn gamma(&self) -> f64 {
        let models: Vec<Vec<f32>> = self.agents.iter().map(|a| a.params.clone()).collect();
        gamma_potential(&models)
    }

    /// Mean of recent minibatch losses (training-loss proxy).
    pub fn mean_train_loss(&self) -> f64 {
        let vals: Vec<f64> = self
            .agents
            .iter()
            .map(|a| a.last_loss)
            .filter(|l| l.is_finite())
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Total local steps across agents.
    pub fn total_steps(&self) -> u64 {
        self.agents.iter().map(|a| a.steps).sum()
    }
}

/// In-place midpoint: a ← b ← (a+b)/2 — Algorithm 1's averaging step.
pub fn average_into_both(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        let m = 0.5 * (*x + *y);
        *x = m;
        *y = m;
    }
}

/// The Appendix-F non-blocking update for one endpoint, shared by every
/// executor (serial, Poisson, parallel) so they stay bit-identical: given
/// the pre-local-phase snapshot `s` and the incoming communication copy
/// `inc`, set `comm ← (s + inc)/2` and `params ← (s + inc)/2 + (params − s)`
/// in place.
pub fn nonblocking_update(params: &mut [f32], comm: &mut [f32], s: &[f32], inc: &[f32]) {
    debug_assert_eq!(params.len(), comm.len());
    debug_assert_eq!(params.len(), s.len());
    debug_assert_eq!(params.len(), inc.len());
    for k in 0..params.len() {
        let avg = 0.5 * (s[k] + inc[k]);
        let delta = params[k] - s[k];
        comm[k] = avg;
        params[k] = avg + delta;
    }
}

/// out ← (x + y)/2 without touching inputs.
pub fn midpoint(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = 0.5 * (a + b);
    }
}

/// Result of a quantized one-way transfer.
pub struct QuantTransfer {
    /// decoded (lattice-rounded) remote model
    pub decoded: Vec<f32>,
    /// bits that crossed the wire (including fallback if any)
    pub bits: u64,
    /// true if the lattice decode failed and we fell back to full precision
    pub fell_back: bool,
}

/// Ship `remote` to a receiver holding `local` through the lattice codec;
/// on checksum failure fall back to full precision (paper: failure happens
/// w.p. O(1/T²) and is handled outside the main bound).
pub fn quantized_transfer(
    remote: &[f32],
    local: &[f32],
    eps: f32,
    bits: u32,
    seed: u32,
) -> QuantTransfer {
    let msg = encode(remote, eps, bits, seed);
    match decode(&msg, local) {
        Ok(decoded) => QuantTransfer { decoded, bits: msg.wire_bits(), fell_back: false },
        Err(QuantError::ChecksumMismatch) => QuantTransfer {
            decoded: remote.to_vec(),
            // failed quantized attempt + full-precision resend
            bits: msg.wire_bits() + 32 * remote.len() as u64,
            fell_back: true,
        },
        Err(e) => panic!("quantized_transfer: protocol error: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;

    #[test]
    fn init_all_agents_identical() {
        let mut b = QuadraticOracle::new(8, 4, 1.0, 0.5, 2.0, 0.0, 3);
        let c = Cluster::init(4, &mut b, 42);
        assert_eq!(c.n(), 4);
        for a in &c.agents {
            assert_eq!(a.params, c.agents[0].params);
            assert_eq!(a.comm, a.params);
        }
        assert_eq!(c.gamma(), 0.0);
    }

    #[test]
    fn pair_mut_both_orders() {
        let mut b = QuadraticOracle::new(4, 2, 1.0, 1.0, 1.0, 0.0, 1);
        let mut c = Cluster::init(3, &mut b, 7);
        {
            let (a, b2) = c.pair_mut(0, 2);
            a.params[0] = 1.0;
            b2.params[0] = 2.0;
        }
        {
            let (a, b2) = c.pair_mut(2, 0);
            assert_eq!(a.params[0], 2.0);
            assert_eq!(b2.params[0], 1.0);
        }
    }

    #[test]
    fn average_into_both_midpoint() {
        let mut a = vec![1.0f32, 3.0];
        let mut b = vec![3.0f32, -1.0];
        average_into_both(&mut a, &mut b);
        assert_eq!(a, vec![2.0, 1.0]);
        assert_eq!(b, vec![2.0, 1.0]);
    }

    #[test]
    fn mean_model_correct() {
        let mut b = QuadraticOracle::new(2, 2, 1.0, 1.0, 1.0, 0.0, 1);
        let mut c = Cluster::init(2, &mut b, 7);
        c.agents[0].params = vec![0.0, 2.0];
        c.agents[1].params = vec![4.0, 0.0];
        assert_eq!(c.mean_model(), vec![2.0, 1.0]);
        assert!((c.gamma() - 2.0 * (4.0 + 1.0)).abs() < 1e-5);
    }

    #[test]
    fn quantized_transfer_close_models() {
        let remote: Vec<f32> = (0..512).map(|i| (i as f32) * 1e-4).collect();
        let local: Vec<f32> = remote.iter().map(|v| v + 0.01).collect();
        let t = quantized_transfer(&remote, &local, 1e-3, 8, 9);
        assert!(!t.fell_back);
        assert_eq!(t.bits, 8 * 512 + 160);
        for (d, r) in t.decoded.iter().zip(&remote) {
            assert!((d - r).abs() <= 1e-3 + 1e-6);
        }
    }

    #[test]
    fn quantized_transfer_falls_back_when_far() {
        let remote = vec![0.0f32; 256];
        let local = vec![10.0f32; 256];
        let t = quantized_transfer(&remote, &local, 1e-3, 4, 3);
        assert!(t.fell_back);
        assert_eq!(t.decoded, remote);
        assert!(t.bits > 32 * 256);
    }
}
