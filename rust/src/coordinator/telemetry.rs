//! Contention & staleness telemetry for the free-running executor.
//!
//! The replay executors can *simulate* time but never *measure* true
//! asynchrony: their schedules are pre-drawn, so nothing ever actually
//! contends. The free-running executor ([`super::run_freerun`]) is where
//! real threads race on real memory, and this module holds the quantities
//! that only exist there:
//!
//! * **per-interaction staleness** — how many global interactions elapsed
//!   since the partner's model slot was last published (the "version lag"
//!   of the asynchronous-SGD delay analyses, e.g. Even et al.), recorded
//!   into an exact bounded [`StalenessHistogram`];
//! * **slot contention** — seqlock read retries, publish CAS retries, and
//!   dropped best-effort cross-writes ([`FreerunStats`] counters);
//! * **worker activity** — wall-clock busy vs. slot-synchronization time
//!   per worker ([`WorkerActivity`]), plus the run's *real* (not simulated)
//!   interactions/second.
//!
//! Everything here is plain data: workers record locally (no shared
//! counters on the hot path) and the executor merges once at join time.

/// Exact histogram of small non-negative integer observations (staleness
/// is measured in interaction counts, so values are small relative to the
/// run length). Values at or above the bucket capacity land in a single
/// overflow bucket; quantiles falling there report the observed maximum.
#[derive(Clone, Debug)]
pub struct StalenessHistogram {
    /// exact counts for values `0..buckets.len()`
    buckets: Vec<u64>,
    overflow: u64,
    count: u64,
    sum: u128,
    max: u64,
}

impl StalenessHistogram {
    /// Histogram with exact buckets for `0..cap` (cap is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self { buckets: vec![0; cap.max(1)], overflow: 0, count: 0, sum: 0, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        match self.buckets.get_mut(v as usize) {
            Some(b) => *b += 1,
            None => self.overflow += 1,
        }
    }

    /// Fold another histogram in (capacities may differ; the merged
    /// histogram keeps the larger exact range).
    pub fn merge(&mut self, other: &Self) {
        if other.buckets.len() > self.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (b, &c) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += c;
        }
        self.overflow += other.overflow;
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean observed value (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max_observed(&self) -> u64 {
        self.max
    }

    /// Quantile by rank over the recorded values (`q` clamped to [0, 1]).
    /// Returns 0 on an empty histogram; ranks falling into the overflow
    /// bucket report the observed maximum.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (v, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > rank {
                return v as u64;
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Raw parts `(buckets, overflow, count, sum, max)` — what a cluster
    /// worker serializes onto the wire so the coordinator can
    /// [`merge`](Self::merge) histograms across processes exactly.
    pub fn raw_parts(&self) -> (&[u64], u64, u64, u128, u64) {
        (&self.buckets, self.overflow, self.count, self.sum, self.max)
    }

    /// Rebuild from [`raw_parts`](Self::raw_parts) output (the receiving
    /// end of the wire serialization).
    pub fn from_raw(buckets: Vec<u64>, overflow: u64, count: u64, sum: u128, max: u64) -> Self {
        let mut buckets = buckets;
        if buckets.is_empty() {
            buckets.push(0);
        }
        Self { buckets, overflow, count, sum, max }
    }
}

/// One worker's wall-clock activity split: `busy` is time inside
/// interaction bodies (local SGD + averaging), `wait` is time spent in
/// slot synchronization (seqlock reads/retries + publishes). Workers never
/// block on each other, so `wait` measures pure memory contention.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerActivity {
    pub busy_secs: f64,
    pub wait_secs: f64,
    /// interactions this worker initiated
    pub interactions: u64,
}

/// Everything the free-running executor measures that the replay
/// executors cannot, surfaced through
/// [`super::RunMetrics::freerun`].
#[derive(Clone, Debug)]
pub struct FreerunStats {
    /// worker threads the run used
    pub threads: usize,
    /// node shards the run partitioned over
    pub shards: usize,
    /// real wall-clock seconds start-to-join
    pub wall_secs: f64,
    /// real (wall-clock) interactions per second — the throughput number
    /// the paper's non-blocking claim is about
    pub interactions_per_sec: f64,
    /// wire codec the run's mix policy used (`"f32"` | `"lattice"`)
    pub codec: String,
    /// fused merge-kernel implementation the workers' scratch dispatched to
    /// (`"scalar"` | `"simd"`)
    pub kernel: String,
    /// bits the codec put on the simulated wire (the freerun attribution
    /// of `RunMetrics::total_bits`)
    pub wire_bits: u64,
    /// lattice decode failures that fell back to full precision (the
    /// freerun attribution of `RunMetrics::quant_fallbacks`)
    pub wire_fallbacks: u64,
    /// seqlock read retries (reader raced a concurrent slot write)
    pub slot_read_retries: u64,
    /// publish CAS retries by slot owners (racing a cross-write)
    pub slot_publish_retries: u64,
    /// best-effort cross-writes dropped because the slot was held — the
    /// "nobody ever waits" property, counted instead of blocked on
    pub slot_push_conflicts: u64,
    /// per-interaction version lag of the partner snapshot, in global
    /// interaction counts
    pub staleness: StalenessHistogram,
    /// per-worker activity, indexed by worker id
    pub workers: Vec<WorkerActivity>,
    /// roster/storage telemetry of the membership scale engine
    /// ([`crate::membership::run_scale`]); `None` on the dense freerun path
    pub membership: Option<MembershipStats>,
}

/// What the membership scale engine measures on top of the freerun
/// counters: roster flux (joins/leaves/rejections), partner draws that hit
/// vacant slots, and the compact node-store's memory accounting — the
/// bytes-per-node budget the `BENCH_scale` rows track.
#[derive(Clone, Debug)]
pub struct MembershipStats {
    /// roster capacity (slot count) — the configured n
    pub capacity: usize,
    /// live nodes when the run started
    pub live_start: u64,
    /// live nodes when the run ended
    pub live_end: u64,
    /// node arrivals admitted into recycled slots
    pub joins: u64,
    /// node departures (slots vacated)
    pub leaves: u64,
    /// arrivals dropped because no slot was vacant
    pub rejected_joins: u64,
    /// partner draws that hit a vacant (churned-out) slot and re-drew
    pub churn_misses: u64,
    /// claimed events abandoned without an interaction (no live initiator
    /// found, or consumed by a churn transition)
    pub skipped_events: u64,
    /// resident bytes per node the engine accounts for (store record +
    /// per-slot atomics + roster generation + speed rate)
    pub bytes_per_node: u64,
    /// configured bytes-per-node ceiling (0 = unenforced)
    pub node_budget: u64,
    /// nodes whose models escaped the storage lattice to full-precision
    /// side buffers
    pub raw_nodes: u64,
    /// storage decodes that failed the checksum (reference-filled, counted)
    pub decode_failures: u64,
    /// live nodes sampled for the final consensus/loss evaluation
    pub eval_sample: usize,
}

impl FreerunStats {
    /// Total busy seconds across workers.
    pub fn busy_total(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_secs).sum()
    }

    /// Total slot-synchronization seconds across workers.
    pub fn wait_total(&self) -> f64 {
        self.workers.iter().map(|w| w.wait_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = StalenessHistogram::new(16);
        for v in [0u64, 0, 1, 1, 1, 2, 3, 10] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max_observed(), 10);
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.p50(), 1);
        assert_eq!(h.quantile(1.0), 10);
        assert!((h.mean() - 18.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_overflow_reports_max() {
        let mut h = StalenessHistogram::new(4);
        h.record(2);
        h.record(100);
        h.record(200);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_observed(), 200);
        // ranks in the overflow region fall back to the observed max
        assert_eq!(h.quantile(1.0), 200);
        assert_eq!(h.p99(), 200);
        assert_eq!(h.quantile(0.0), 2);
    }

    #[test]
    fn histogram_merge_folds_counts() {
        let mut a = StalenessHistogram::new(8);
        let mut b = StalenessHistogram::new(32);
        a.record(1);
        a.record(20); // overflow for a
        b.record(3);
        b.record(20); // exact for b
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max_observed(), 20);
        assert_eq!(a.p50(), 3);
        assert!((a.mean() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_nan_and_zero() {
        let h = StalenessHistogram::new(8);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert!(h.mean().is_nan());
        assert_eq!(h.max_observed(), 0);
    }

    #[test]
    fn empty_histogram_every_quantile_is_zero() {
        // a worker that executed no interactions merges an empty histogram;
        // every quantile (including the clamped out-of-range ones) must be
        // the 0 sentinel, never a panic or an overflow-bucket max
        let h = StalenessHistogram::new(4);
        for q in [-1.0, 0.0, 0.25, 0.5, 0.99, 1.0, 2.0] {
            assert_eq!(h.quantile(q), 0, "q={q}");
        }
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
    }

    #[test]
    fn single_sample_histogram_reports_that_sample_at_every_quantile() {
        // one observation: rank arithmetic degenerates to (count-1)=0, so
        // every quantile must return the single value — both in the exact
        // range and from the overflow bucket
        for v in [0u64, 3, 500] {
            let mut h = StalenessHistogram::new(8);
            h.record(v);
            assert_eq!(h.count(), 1);
            for q in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(h.quantile(q), v, "v={v} q={q}");
            }
            assert_eq!(h.max_observed(), v);
            assert!((h.mean() - v as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative_across_workers() {
        // the executor folds per-worker histograms in worker order; the
        // result must not depend on that order or grouping, even with
        // mismatched capacities (overflow vs exact buckets)
        let mk = |cap: usize, vals: &[u64]| {
            let mut h = StalenessHistogram::new(cap);
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = || mk(4, &[0, 1, 9]); // 9 overflows cap 4
        let b = || mk(16, &[2, 9, 30]);
        let c = || mk(2, &[1, 1, 700]);
        // (a ⊕ b) ⊕ c
        let mut left = a();
        left.merge(&b());
        left.merge(&c());
        // a ⊕ (b ⊕ c)
        let mut bc = b();
        bc.merge(&c());
        let mut right = a();
        right.merge(&bc);
        // c ⊕ (a ⊕ b): commuted outer order
        let mut ab = a();
        ab.merge(&b());
        let mut comm = c();
        comm.merge(&ab);
        for h in [&left, &right, &comm] {
            assert_eq!(h.count(), 9);
            assert_eq!(h.max_observed(), 700);
            assert!((h.mean() - (0 + 1 + 9 + 2 + 9 + 30 + 1 + 1 + 700) as f64 / 9.0).abs()
                < 1e-12);
        }
        for q in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(left.quantile(q), right.quantile(q), "q={q}");
            assert_eq!(left.quantile(q), comm.quantile(q), "q={q}");
        }
        // merging an empty histogram is the identity
        let mut with_empty = a();
        with_empty.merge(&StalenessHistogram::new(64));
        let base = a();
        assert_eq!(with_empty.count(), base.count());
        assert_eq!(with_empty.p50(), base.p50());
        assert_eq!(with_empty.max_observed(), base.max_observed());
    }

    #[test]
    fn raw_parts_roundtrip_preserves_every_quantile() {
        let mut h = StalenessHistogram::new(8);
        for v in [0u64, 1, 1, 3, 40] {
            h.record(v);
        }
        let (buckets, overflow, count, sum, max) = h.raw_parts();
        let back = StalenessHistogram::from_raw(buckets.to_vec(), overflow, count, sum, max);
        assert_eq!(back.count(), h.count());
        assert_eq!(back.max_observed(), h.max_observed());
        assert!((back.mean() - h.mean()).abs() < 1e-12);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(back.quantile(q), h.quantile(q), "q={q}");
        }
        // merging a reconstructed histogram behaves like the original
        let mut a = StalenessHistogram::new(4);
        a.record(2);
        a.merge(&back);
        assert_eq!(a.count(), 6);
        // empty-bucket reconstruction clamps to the ≥1 capacity invariant
        let e = StalenessHistogram::from_raw(Vec::new(), 0, 0, 0, 0);
        assert_eq!(e.count(), 0);
        assert_eq!(e.quantile(0.5), 0);
    }

    #[test]
    fn stats_totals_sum_workers() {
        let s = FreerunStats {
            threads: 2,
            shards: 4,
            wall_secs: 1.0,
            interactions_per_sec: 100.0,
            codec: "f32".into(),
            kernel: "scalar".into(),
            wire_bits: 0,
            wire_fallbacks: 0,
            slot_read_retries: 0,
            slot_publish_retries: 0,
            slot_push_conflicts: 0,
            staleness: StalenessHistogram::new(4),
            workers: vec![
                WorkerActivity { busy_secs: 1.0, wait_secs: 0.25, interactions: 10 },
                WorkerActivity { busy_secs: 2.0, wait_secs: 0.75, interactions: 20 },
            ],
            membership: None,
        };
        assert!((s.busy_total() - 3.0).abs() < 1e-12);
        assert!((s.wait_total() - 1.0).abs() < 1e-12);
    }
}
