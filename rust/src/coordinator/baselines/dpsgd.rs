//! D-PSGD baseline (Lian et al. [27]): synchronous decentralized SGD.
//! Every round each node takes one SGD step, then the nodes average along a
//! random matching of the interaction graph (a doubly-stochastic, symmetric
//! mixing step — the sequence-of-perfect-matchings gossip model the paper's
//! related-work section describes).
//!
//! Under the phased-event contract each round decomposes into:
//!
//! 1. `n` single-node [`EventKind::Compute`] events — one SGD step per
//!    node, each drawing only from its private stream — that spread across
//!    every worker of the parallel executor;
//! 2. one [`EventKind::Gossip`] event **per matching edge** (the matching
//!    is pre-drawn from the round seed at schedule time — the identical
//!    draw the former monolithic round made at interact time), averaging
//!    the two endpoints; disjoint edges run concurrently;
//! 3. one whole-cluster [`EventKind::Mix`] barrier that settles the round's
//!    synchronous time accounting (everyone meets the slowest, then pays
//!    one exchange latency).
//!
//! The per-edge decomposition is also what makes D-PSGD freerun-eligible:
//! its mixing is pairwise, so it advertises a live-merge
//! [`PairwisePolicy`] (one step per interaction, live-model averaging) and
//! runs on [`run_freerun`](crate::coordinator::run_freerun) as the
//! asynchronous matching-free degradation of the same update rule.

use crate::coordinator::algorithm::{
    barrier_all, pair, step_once, Algorithm, Event, EventKind, EventOutcome,
    InteractionSchedule, NodeState, StepCtx,
};
use crate::coordinator::{
    codec_exchange_average, LocalSteps, MergeScratch, MixPolicy, PairMerge, PairwisePolicy,
    WireCodec,
};
use crate::kernels;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

#[derive(Clone, Copy, Debug)]
pub struct DPsgd {
    /// wire codec for the per-edge matching exchange (`--wire lattice|f32`)
    pub wire: WireCodec,
}

impl Default for DPsgd {
    fn default() -> Self {
        Self { wire: WireCodec::F32 }
    }
}

impl Algorithm for DPsgd {
    fn name(&self) -> &'static str {
        "dpsgd"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        let mut s = InteractionSchedule::new(n);
        for round in 0..events {
            let seed = rng.next_u64();
            for k in 0..n {
                s.push_compute(k, 1, seed);
            }
            // pre-draw the matching from the round seed — bit-for-bit the
            // draw the monolithic round used to make at interact time, so
            // phased schedules replay the identical mixing sequence — over
            // the graph in force at this round's tick
            let mut er = Pcg64::seed(seed);
            for &(u, v) in &scn.graph_at(round).random_matching(&mut er) {
                s.push_pair_mix(u, v, seed);
            }
            s.push_mix((0..n).collect(), seed);
            s.seal_round();
        }
        s
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = MergeScratch::with_kernel(ctx.dim, self.kernel());
        self.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut MergeScratch,
    ) -> EventOutcome {
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        match ev.kind {
            // one SGD step on one node, from its own stream
            EventKind::Compute => {
                step_once(ctx, ev.nodes[0], &mut *parts[0]);
                EventOutcome::default()
            }
            // one matching edge: average the endpoints (disjoint edges of
            // the matching commute, so they run concurrently); the time
            // charge is settled at the round barrier
            EventKind::Gossip => {
                let (a, b) = pair(parts);
                let (bits, fallbacks) = match self.wire {
                    WireCodec::F32 => {
                        kernels::avg_into_both(scratch.kernel, &mut a.params, &mut b.params);
                        (2 * 8 * bytes, 0)
                    }
                    codec => {
                        // both directions of the edge cross the codec; the
                        // decode seeds derive from the round seed plus the
                        // edge's endpoints so every edge is distinct
                        let mut er = Pcg64::seed(
                            ev.seed ^ ((ev.nodes[0] as u64) << 32) ^ (ev.nodes[1] as u64),
                        );
                        let (raw, fb) = codec_exchange_average(a, b, codec, &mut er, scratch);
                        (ctx.cost.scale_bits(raw, ctx.dim), fb)
                    }
                };
                a.comm.copy_from_slice(&a.params);
                b.comm.copy_from_slice(&b.params);
                a.interactions += 1;
                b.interactions += 1;
                EventOutcome { bits, fallbacks }
            }
            // round barrier: the round is synchronous — everyone advances
            // to the slowest node, then pays one exchange latency together
            EventKind::Mix => {
                barrier_all(parts, ctx.cost.exchange_time(bytes));
                EventOutcome::default()
            }
        }
    }

    /// Synchronous rounds: one tick is one round of parallel time.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }

    /// Pairwise mixing makes D-PSGD freerun-eligible: one step per
    /// interaction, live-model averaging against the partner's published
    /// snapshot (the asynchronous degradation of the matching average —
    /// the snapshot *read* still never blocks the partner).
    fn mix_policy(&self) -> Option<Box<dyn MixPolicy>> {
        Some(Box::new(PairwisePolicy {
            steps: LocalSteps::Fixed(1),
            merge: PairMerge::Live,
            wire: self.wire,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    #[test]
    fn dpsgd_converges_on_quadratic() {
        let n = 8;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(2);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let spec = RunSpec {
            n,
            events: 300,
            lr: LrSchedule::Constant(0.05),
            seed: 2,
            name: "dpsgd".into(),
            eval_every: 50,
            track_gamma: true,
        };
        let m = run_serial(&DPsgd::default(), &backend, &spec, &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        // phased rounds still report one interaction per round
        assert_eq!(m.interactions, 300);
        assert_eq!(m.local_steps, 300 * n as u64);
        // models stay concentrated (gossip mixing)
        let gamma_last = m.curve.last().unwrap().gamma;
        assert!(gamma_last.is_finite());
        assert!(gamma_last < 5.0, "gamma={gamma_last}");
    }

    #[test]
    fn dpsgd_lattice_wire_replays_bit_identically_and_saves_bits() {
        // per-edge decode seeds derive from the round seed + the edge's
        // endpoints, so the lattice path replays bit-for-bit at any thread
        // count; matching averages keep neighbors within eps, so it also
        // beats the f32 wire on bits
        use crate::coordinator::{run_parallel, WireCodec};
        let n = 8;
        let backend = QuadraticOracle::new(256, n, 1.0, 0.5, 2.0, 0.05, 3);
        let mut rng = Pcg64::seed(2);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let spec = RunSpec {
            n,
            events: 80,
            lr: LrSchedule::Constant(0.05),
            seed: 2,
            name: "dpsgd-lattice".into(),
            eval_every: 20,
            track_gamma: false,
        };
        let lattice = DPsgd { wire: WireCodec::Lattice { bits: 8, eps: 1e-2 } };
        let serial = run_serial(&lattice, &backend, &spec, &graph, &cost);
        let par = run_parallel(&lattice, &backend, &spec, &graph, &cost, 4);
        assert_eq!(serial.final_eval_loss.to_bits(), par.final_eval_loss.to_bits());
        assert_eq!(serial.total_bits, par.total_bits);
        assert_eq!(serial.quant_fallbacks, par.quant_fallbacks);
        assert_eq!(serial.sim_time.to_bits(), par.sim_time.to_bits());
        assert!(serial.final_eval_loss.is_finite());
        let full = run_serial(&DPsgd::default(), &backend, &spec, &graph, &cost);
        assert!(
            (serial.total_bits as f64) < 0.5 * full.total_bits as f64,
            "lattice {} bits vs f32 {} bits (fallbacks {})",
            serial.total_bits,
            full.total_bits,
            serial.quant_fallbacks
        );
    }

    #[test]
    fn phased_schedule_shape_per_round() {
        // each round: n computes + one gossip event per matching edge + one
        // whole-cluster barrier, all on the round's tick
        let n = 8;
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let mut srng = Pcg64::seed(9);
        let scn = Scenario::static_graph(graph);
        let s = DPsgd::default().schedule(n, 5, &scn, &mut srng);
        assert_eq!(s.ticks, 5);
        let mut cursor = 0usize;
        for round in 0..5u64 {
            // n compute events
            for k in 0..n {
                let ev = &s.events[cursor + k];
                assert_eq!(ev.kind, EventKind::Compute);
                assert_eq!(ev.nodes, vec![k]);
                assert_eq!(ev.tick, round);
            }
            cursor += n;
            // matching edges (complete graph on even n: perfect matching)
            let mut matched = 0usize;
            while s.events[cursor].kind == EventKind::Gossip {
                let ev = &s.events[cursor];
                assert_eq!(ev.nodes.len(), 2);
                assert_eq!(ev.h, vec![0, 0]);
                assert_eq!(ev.tick, round);
                matched += 2;
                cursor += 1;
            }
            assert!(matched > 0 && matched <= n);
            // whole-cluster barrier closes the round
            let mix = &s.events[cursor];
            assert_eq!(mix.kind, EventKind::Mix);
            assert_eq!(mix.nodes, (0..n).collect::<Vec<_>>());
            assert_eq!(mix.tick, round);
            cursor += 1;
        }
        assert_eq!(cursor, s.events.len());
    }
}
