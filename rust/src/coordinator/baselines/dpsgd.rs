//! D-PSGD baseline (Lian et al. [27]): synchronous decentralized SGD.
//! Every round each node takes one SGD step, then the nodes average along a
//! random matching of the interaction graph (a doubly-stochastic, symmetric
//! mixing step — the sequence-of-perfect-matchings gossip model the paper's
//! related-work section describes).

use super::{finalize, record_round_point, step_all, RoundsConfig};
use crate::coordinator::{Cluster, NodeClocks, RunContext, RunMetrics};

pub struct DPsgdRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: RoundsConfig,
}

impl DPsgdRunner {
    pub fn new(cfg: RoundsConfig, ctx: &mut RunContext) -> Self {
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self { clocks: NodeClocks::new(cfg.n), cluster, cfg }
    }

    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let bytes = ctx.cost.wire_bytes(self.cluster.dim);
        for round in 1..=self.cfg.rounds {
            let lr = self.cfg.lr.at(round);
            step_all(&mut self.cluster, ctx, lr, &mut self.clocks);
            // average along a random matching; pairs exchange in parallel,
            // but the round is synchronous: barrier to the slowest, then one
            // exchange latency for everyone matched.
            let matching = ctx.graph.random_matching(ctx.rng);
            for &(u, v) in &matching {
                let (a, b) = self.cluster.pair_mut(u, v);
                crate::coordinator::average_into_both(&mut a.params, &mut b.params);
                a.comm.copy_from_slice(&a.params);
                b.comm.copy_from_slice(&b.params);
                m.total_bits += 2 * 8 * bytes;
            }
            self.clocks.barrier_all(ctx.cost.exchange_time(bytes));
            if (ctx.eval_every > 0 && round % ctx.eval_every == 0) || round == self.cfg.rounds
            {
                record_round_point(&self.cluster, &self.clocks, ctx, round, &mut m, None);
            }
        }
        finalize(&mut m, &self.cluster, &self.clocks, ctx, self.cfg.rounds);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    #[test]
    fn dpsgd_converges_on_quadratic() {
        let n = 8;
        let mut backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let backend_f_star = backend.f_star();
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend_f_star
        };
        let mut rng = Pcg64::seed(2);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 50,
            track_gamma: true,
        };
        let cfg = RoundsConfig::new(n, 300, 0.05, "dpsgd");
        let mut r = DPsgdRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        let gap = (m.final_eval_loss - backend_f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        // models stay concentrated (gossip mixing)
        let gamma_last = m.curve.last().unwrap().gamma;
        assert!(gamma_last.is_finite());
        assert!(gamma_last < 5.0, "gamma={gamma_last}");
    }
}
