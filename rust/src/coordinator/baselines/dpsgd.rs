//! D-PSGD baseline (Lian et al. [27]): synchronous decentralized SGD.
//! Every round each node takes one SGD step, then the nodes average along a
//! random matching of the interaction graph (a doubly-stochastic, symmetric
//! mixing step — the sequence-of-perfect-matchings gossip model the paper's
//! related-work section describes).
//!
//! As an [`Algorithm`], each round is one whole-cluster event: D-PSGD's
//! semantics IS a global barrier, so the event claims every node and the
//! matching is drawn from the event's own seed.

use crate::coordinator::algorithm::{
    barrier_all, pair_at, step_once, Algorithm, Event, EventOutcome, InteractionSchedule,
    NodeState, StepCtx,
};
use crate::coordinator::cluster::average_into_both;
use crate::rngx::Pcg64;
use crate::topology::Graph;

#[derive(Clone, Copy, Debug, Default)]
pub struct DPsgd;

impl Algorithm for DPsgd {
    fn name(&self) -> &'static str {
        "dpsgd"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        let mut s = InteractionSchedule::new(n);
        for _ in 0..events {
            let seed = rng.next_u64();
            s.push((0..n).collect(), vec![1; n], seed);
        }
        s
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        // the matching below indexes `parts` by node id, which requires
        // the identity-ordered whole-cluster events this schedule emits
        debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
        // one SGD step per node, each from its own stream
        for (k, st) in parts.iter_mut().enumerate() {
            step_once(ctx, ev.nodes[k], st);
        }
        // average along a random matching (drawn from the event seed);
        // pairs exchange in parallel, but the round is synchronous:
        // barrier to the slowest, then one exchange latency for everyone
        let mut er = Pcg64::seed(ev.seed);
        let matching = ctx.graph.random_matching(&mut er);
        let mut bits = 0u64;
        for &(u, v) in &matching {
            let (a, b) = pair_at(parts, u, v);
            average_into_both(&mut a.params, &mut b.params);
            a.comm.copy_from_slice(&a.params);
            b.comm.copy_from_slice(&b.params);
            a.interactions += 1;
            b.interactions += 1;
            bits += 2 * 8 * bytes;
        }
        barrier_all(parts, ctx.cost.exchange_time(bytes));
        EventOutcome { bits, fallbacks: 0 }
    }

    /// Synchronous rounds: one event advances parallel time by 1.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::Topology;

    #[test]
    fn dpsgd_converges_on_quadratic() {
        let n = 8;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(2);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let spec = RunSpec {
            n,
            events: 300,
            lr: LrSchedule::Constant(0.05),
            seed: 2,
            name: "dpsgd".into(),
            eval_every: 50,
            track_gamma: true,
        };
        let m = run_serial(&DPsgd, &backend, &spec, &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        // models stay concentrated (gossip mixing)
        let gamma_last = m.curve.last().unwrap().gamma;
        assert!(gamma_last.is_finite());
        assert!(gamma_last < 5.0, "gamma={gamma_last}");
    }
}
