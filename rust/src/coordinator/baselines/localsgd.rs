//! Local SGD baseline [38, 29]: every node runs `h` local steps, then a
//! global model average (the paper's comparison point communicates every
//! 5 steps, following Lin et al. [29]).
//!
//! Under the phased-event contract one communication round is `n`
//! single-node [`EventKind::Compute`] events (`h` local steps each, all
//! randomness from the node's private stream — these spread across every
//! worker) plus one whole-cluster [`EventKind::Mix`] allreduce barrier.

use crate::coordinator::algorithm::{
    barrier_all, local_phase, mean_params, Algorithm, Event, EventKind, EventOutcome,
    InteractionSchedule, NodeState, StepCtx,
};
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

#[derive(Clone, Copy, Debug)]
pub struct LocalSgd {
    /// communication period (local steps per round)
    pub h: u64,
}

impl Algorithm for LocalSgd {
    fn name(&self) -> &'static str {
        "localsgd"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(self.h >= 1, "localsgd needs h >= 1 (the factory rejects h=0)");
        let mut s = InteractionSchedule::new(n);
        let h = vec![self.h; n];
        for _ in 0..events {
            let seed = rng.next_u64();
            s.push_round(&h, seed);
        }
        s
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        match ev.kind {
            // h local steps on one node, on its own stream (the shared
            // burst + per-step compute-charge rule)
            EventKind::Compute => {
                local_phase(ctx, ev.nodes[0], &mut *parts[0], ev.h[0]);
                EventOutcome::default()
            }
            // global model average (shared f64 node-order accumulation) +
            // the allreduce barrier
            EventKind::Mix => {
                let n = parts.len();
                // the node-order accumulation requires the identity-ordered
                // whole-cluster mix this schedule emits
                debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
                let bytes = ctx.cost.wire_bytes(ctx.dim);
                let mu = mean_params(parts.iter().map(|s| s.params.as_slice()), ctx.dim, n);
                for st in parts.iter_mut() {
                    st.params.copy_from_slice(&mu);
                    st.comm.copy_from_slice(&mu);
                    st.interactions += 1;
                }
                barrier_all(parts, ctx.cost.allreduce_time(n, bytes));
                EventOutcome { bits: 2 * 8 * bytes * n as u64, fallbacks: 0 }
            }
            EventKind::Gossip => {
                unreachable!("localsgd schedules phased compute+mix rounds only")
            }
        }
    }

    /// Synchronous rounds: one tick is one round of parallel time.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    #[test]
    fn localsgd_converges_and_communicates_less() {
        let n = 4;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(1);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let spec = RunSpec {
            n,
            events: 60,
            lr: LrSchedule::Constant(0.05),
            seed: 1,
            name: "localsgd".into(),
            eval_every: 20,
            track_gamma: true,
        };
        let m = run_serial(&LocalSgd { h: 5 }, &backend, &spec, &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        // 60 rounds × 5 steps × 4 nodes local steps
        assert_eq!(m.local_steps, 60 * 5 * 4);
        // phased rounds still report one interaction per round
        assert_eq!(m.interactions, 60);
        // after the final average all models agree
        let gamma_last = m.curve.last().unwrap().gamma;
        assert!(gamma_last < 1e-9, "gamma={gamma_last}");
    }
}
