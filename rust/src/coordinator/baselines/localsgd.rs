//! Local SGD baseline [38, 29]: every node runs `h` local steps, then a
//! global model average (the paper's comparison point communicates every
//! 5 steps, following Lin et al. [29]).

use super::{finalize, record_round_point, step_all, RoundsConfig};
use crate::coordinator::{Cluster, NodeClocks, RunContext, RunMetrics};

pub struct LocalSgdRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: RoundsConfig,
}

impl LocalSgdRunner {
    pub fn new(cfg: RoundsConfig, ctx: &mut RunContext) -> Self {
        assert!(cfg.h >= 1);
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self { clocks: NodeClocks::new(cfg.n), cluster, cfg }
    }

    /// `cfg.rounds` counts *communication* rounds; each is `h` local steps +
    /// one global average.
    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let bytes = ctx.cost.wire_bytes(self.cluster.dim);
        for round in 1..=self.cfg.rounds {
            let lr = self.cfg.lr.at(round);
            for _ in 0..self.cfg.h {
                step_all(&mut self.cluster, ctx, lr, &mut self.clocks);
            }
            let mu = self.cluster.mean_model();
            for a in &mut self.cluster.agents {
                a.params.copy_from_slice(&mu);
                a.comm.copy_from_slice(&mu);
            }
            self.clocks.barrier_all(ctx.cost.allreduce_time(self.cfg.n, bytes));
            m.total_bits += 2 * 8 * bytes * self.cfg.n as u64;
            if (ctx.eval_every > 0 && round % ctx.eval_every == 0) || round == self.cfg.rounds
            {
                record_round_point(&self.cluster, &self.clocks, ctx, round, &mut m, None);
            }
        }
        finalize(&mut m, &self.cluster, &self.clocks, ctx, self.cfg.rounds);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    #[test]
    fn localsgd_converges_and_communicates_less() {
        let n = 4;
        let mut backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let backend_f_star = backend.f_star();
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend_f_star
        };
        let mut rng = Pcg64::seed(1);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 20,
            track_gamma: false,
        };
        let mut cfg = RoundsConfig::new(n, 60, 0.05, "localsgd");
        cfg.h = 5;
        let mut r = LocalSgdRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        let gap = (m.final_eval_loss - backend_f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        // 60 rounds × 5 steps × 4 nodes local steps
        assert_eq!(m.local_steps, 60 * 5 * 4);
        // after the final average all models agree
        assert!(r.cluster.gamma() < 1e-9);
    }
}
