//! AD-PSGD baseline (Lian et al. [28]): asynchronous decentralized SGD.
//! Pairwise gossip like SwarmSGD but with H = 1 — one SGD step then an
//! averaging step, every iteration.  Gradient compute overlaps with the
//! node's own sends, but the pairwise averaging itself blocks both
//! endpoints — so every iteration pays compute + exchange, which is exactly
//! the communication-frequency disadvantage SwarmSGD's Figure 4 highlights.

use super::{finalize, RoundsConfig};
use crate::coordinator::{average_into_both, Cluster, NodeClocks, RunContext, RunMetrics};

pub struct AdPsgdRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: RoundsConfig,
}

impl AdPsgdRunner {
    pub fn new(cfg: RoundsConfig, ctx: &mut RunContext) -> Self {
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self { clocks: NodeClocks::new(cfg.n), cluster, cfg }
    }

    /// `cfg.rounds` counts pairwise interactions (same unit as SwarmSGD).
    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let bytes = ctx.cost.wire_bytes(self.cluster.dim);
        for t in 1..=self.cfg.rounds {
            let lr = self.cfg.lr.at(t);
            let (i, j) = ctx.graph.sample_edge(ctx.rng);
            // one local step on each endpoint (AD-PSGD workers never idle)
            let mut comp = [0.0f64; 2];
            for (slot, &node) in [i, j].iter().enumerate() {
                let a = &mut self.cluster.agents[node];
                a.last_loss = ctx.backend.step(node, &mut a.params, &mut a.mom, lr);
                a.steps += 1;
                comp[slot] = ctx.cost.compute_time(&mut a.rng);
            }
            // averaging every step; compute overlapped with communication
            {
                let (a, b) = self.cluster.pair_mut(i, j);
                average_into_both(&mut a.params, &mut b.params);
                a.comm.copy_from_slice(&a.params);
                b.comm.copy_from_slice(&b.params);
            }
            let exch = ctx.cost.exchange_time(bytes);
            // AD-PSGD overlaps gradient compute with its own sends, but the
            // averaging step itself blocks both endpoints (paper Appx B):
            // every iteration pays compute + exchange.
            self.clocks.charge_compute(i, comp[0]);
            self.clocks.charge_compute(j, comp[1]);
            self.clocks.charge_comm(i, exch);
            self.clocks.charge_comm(j, exch);
            self.cluster.agents[i].interactions += 1;
            self.cluster.agents[j].interactions += 1;
            m.total_bits += 2 * 8 * bytes;
            if (ctx.eval_every > 0 && t % ctx.eval_every == 0) || t == self.cfg.rounds {
                super::record_round_point(&self.cluster, &self.clocks, ctx, t, &mut m, None);
            }
        }
        finalize(&mut m, &self.cluster, &self.clocks, ctx, self.cfg.rounds);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    #[test]
    fn adpsgd_converges_on_quadratic() {
        let n = 8;
        let mut backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let backend_f_star = backend.f_star();
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend_f_star
        };
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 100,
            track_gamma: false,
        };
        let cfg = RoundsConfig::new(n, 800, 0.05, "adpsgd");
        let mut r = AdPsgdRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        let gap = (m.final_eval_loss - backend_f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        assert_eq!(m.local_steps, 2 * 800); // one step per endpoint
    }

    #[test]
    fn adpsgd_pays_comm_every_step() {
        // with a big model, AD-PSGD per-step time is dominated by exchange
        let n = 4;
        let mut backend = QuadraticOracle::new(64, n, 1.0, 0.5, 2.0, 0.0, 3);
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        // tiny compute, slow network -> comm dominates
        let cost = CostModel {
            batch_time: 1e-6,
            jitter: 0.0,
            straggler_prob: 0.0,
            bandwidth: 1e3, // 1 KB/s: 64*4 B takes .256 s
            ..CostModel::default()
        };
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 0,
            track_gamma: false,
        };
        let cfg = RoundsConfig::new(n, 100, 0.01, "adpsgd");
        let mut r = AdPsgdRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        // ~100 interactions × 0.256 s spread over 4 nodes ≥ ~6 s at the max
        assert!(m.sim_time > 1.0, "sim_time={}", m.sim_time);
    }
}
