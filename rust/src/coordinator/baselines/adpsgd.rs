//! AD-PSGD baseline (Lian et al. [28]): asynchronous decentralized SGD.
//! Pairwise gossip like SwarmSGD but with H = 1 — one SGD step then an
//! averaging step, every iteration.  Gradient compute overlaps with the
//! node's own sends, but the pairwise averaging itself blocks both
//! endpoints — so every iteration pays compute + exchange, which is exactly
//! the communication-frequency disadvantage SwarmSGD's Figure 4 highlights.
//!
//! As an [`Algorithm`], AD-PSGD schedules 2-node events (uniform random
//! edges), so it parallelizes on the shared-memory executor just like
//! SwarmSGD — the paper's async-baseline comparison on real threads.

use crate::coordinator::algorithm::{
    pair, step_once, Algorithm, Event, EventOutcome, GossipProfile, InteractionSchedule,
    NodeState, StepCtx,
};
use crate::coordinator::cluster::average_into_both;
use crate::coordinator::{AveragingMode, LocalSteps};
use crate::rngx::Pcg64;
use crate::topology::Graph;

#[derive(Clone, Copy, Debug, Default)]
pub struct AdPsgd;

impl Algorithm for AdPsgd {
    fn name(&self) -> &'static str {
        "adpsgd"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        graph: &Graph,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(n >= 2, "gossip needs n >= 2");
        let mut s = InteractionSchedule::new(n);
        for _ in 0..events {
            let (i, j) = graph.sample_edge(rng);
            let seed = rng.next_u64();
            s.push_gossip(i, j, 1, 1, seed);
        }
        s
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        let (ni, nj) = pair(parts);
        // one local step on each endpoint (AD-PSGD workers never idle)
        step_once(ctx, ev.nodes[0], ni);
        step_once(ctx, ev.nodes[1], nj);
        // averaging every step; the averaging blocks both endpoints
        // (paper Appx B): every iteration pays compute + exchange
        average_into_both(&mut ni.params, &mut nj.params);
        ni.comm.copy_from_slice(&ni.params);
        nj.comm.copy_from_slice(&nj.params);
        let exch = ctx.cost.exchange_time(bytes);
        for st in [ni, nj] {
            st.time += exch;
            st.comm_time += exch;
            st.interactions += 1;
        }
        EventOutcome { bits: 2 * 8 * bytes, fallbacks: 0 }
    }

    /// AD-PSGD counts its t axis in interactions, plotted per round like
    /// the paper's baseline tables.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }

    /// Free-running profile: one step per interaction, live-model averaging
    /// against the partner's published snapshot. The snapshot read never
    /// blocks the partner — the `Blocking` tag names the averaging rule.
    fn gossip_profile(&self) -> Option<GossipProfile> {
        Some(GossipProfile {
            local_steps: LocalSteps::Fixed(1),
            mode: AveragingMode::Blocking,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::Topology;

    fn spec(n: usize, t: u64, eval_every: u64) -> RunSpec {
        RunSpec {
            n,
            events: t,
            lr: LrSchedule::Constant(0.05),
            seed: 4,
            name: "adpsgd".into(),
            eval_every,
            track_gamma: false,
        }
    }

    #[test]
    fn adpsgd_converges_on_quadratic() {
        let n = 8;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let m = run_serial(&AdPsgd, &backend, &spec(n, 800, 100), &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        assert_eq!(m.local_steps, 2 * 800); // one step per endpoint
    }

    #[test]
    fn adpsgd_pays_comm_every_step() {
        // with a big model, AD-PSGD per-step time is dominated by exchange
        let n = 4;
        let backend = QuadraticOracle::new(64, n, 1.0, 0.5, 2.0, 0.0, 3);
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        // tiny compute, slow network -> comm dominates
        let cost = CostModel {
            batch_time: 1e-6,
            jitter: 0.0,
            straggler_prob: 0.0,
            bandwidth: 1e3, // 1 KB/s: 64*4 B takes .256 s
            ..CostModel::default()
        };
        let m = run_serial(&AdPsgd, &backend, &spec(n, 100, 0), &graph, &cost);
        // ~100 interactions × 0.256 s spread over 4 nodes ≥ ~6 s at the max
        assert!(m.sim_time > 1.0, "sim_time={}", m.sim_time);
    }
}
