//! AD-PSGD baseline (Lian et al. [28]): asynchronous decentralized SGD.
//! Pairwise gossip like SwarmSGD but with H = 1 — one SGD step then an
//! averaging step, every iteration.  Gradient compute overlaps with the
//! node's own sends, but the pairwise averaging itself blocks both
//! endpoints — so every iteration pays compute + exchange, which is exactly
//! the communication-frequency disadvantage SwarmSGD's Figure 4 highlights.
//!
//! As an [`Algorithm`], AD-PSGD schedules 2-node events (uniform random
//! edges), so it parallelizes on the shared-memory executor just like
//! SwarmSGD — the paper's async-baseline comparison on real threads.

use crate::coordinator::algorithm::{
    pair, step_once, Algorithm, Event, EventOutcome, InteractionSchedule, NodeState, StepCtx,
};
use crate::coordinator::{
    codec_exchange_average, LocalSteps, MergeScratch, MixPolicy, PairMerge, PairwisePolicy,
    WireCodec,
};
use crate::kernels;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

#[derive(Clone, Copy, Debug)]
pub struct AdPsgd {
    /// wire codec for the pairwise exchange (`--wire lattice|f32`);
    /// `F32` reproduces the paper baseline exactly
    pub wire: WireCodec,
}

impl Default for AdPsgd {
    fn default() -> Self {
        Self { wire: WireCodec::F32 }
    }
}

impl Algorithm for AdPsgd {
    fn name(&self) -> &'static str {
        "adpsgd"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(n >= 2, "gossip needs n >= 2");
        let mut s = InteractionSchedule::new(n);
        for t in 0..events {
            let (i, j) = scn.sample_pair(t, rng);
            let seed = rng.next_u64();
            s.push_gossip(i, j, 1, 1, seed);
        }
        s
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = MergeScratch::with_kernel(ctx.dim, self.kernel());
        self.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut MergeScratch,
    ) -> EventOutcome {
        let bytes = ctx.cost.wire_bytes(ctx.dim);
        let (ni, nj) = pair(parts);
        // one local step on each endpoint (AD-PSGD workers never idle)
        step_once(ctx, ev.nodes[0], ni);
        step_once(ctx, ev.nodes[1], nj);
        // averaging every step; the averaging blocks both endpoints
        // (paper Appx B): every iteration pays compute + exchange
        let (bits, fallbacks, exch) = match self.wire {
            WireCodec::F32 => {
                kernels::avg_into_both(scratch.kernel, &mut ni.params, &mut nj.params);
                (2 * 8 * bytes, 0, ctx.cost.exchange_time(bytes))
            }
            codec => {
                let mut er = Pcg64::seed(ev.seed);
                let (raw, fb) = codec_exchange_average(ni, nj, codec, &mut er, scratch);
                let wire = ctx.cost.scale_bits(raw, ctx.dim);
                (wire, fb, ctx.cost.exchange_time(wire.div_ceil(8)))
            }
        };
        ni.comm.copy_from_slice(&ni.params);
        nj.comm.copy_from_slice(&nj.params);
        for st in [ni, nj] {
            st.time += exch;
            st.comm_time += exch;
            st.interactions += 1;
        }
        EventOutcome { bits, fallbacks }
    }

    /// AD-PSGD counts its t axis in interactions, plotted per round like
    /// the paper's baseline tables.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }

    /// Free-running policy: one step per interaction, live-model averaging
    /// against the partner's published snapshot (the snapshot *read* never
    /// blocks the partner), over the algorithm's wire codec.
    fn mix_policy(&self) -> Option<Box<dyn MixPolicy>> {
        Some(Box::new(PairwisePolicy {
            steps: LocalSteps::Fixed(1),
            merge: PairMerge::Live,
            wire: self.wire,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    fn spec(n: usize, t: u64, eval_every: u64) -> RunSpec {
        RunSpec {
            n,
            events: t,
            lr: LrSchedule::Constant(0.05),
            seed: 4,
            name: "adpsgd".into(),
            eval_every,
            track_gamma: false,
        }
    }

    #[test]
    fn adpsgd_converges_on_quadratic() {
        let n = 8;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let m = run_serial(&AdPsgd::default(), &backend, &spec(n, 800, 100), &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        assert_eq!(m.local_steps, 2 * 800); // one step per endpoint
    }

    #[test]
    fn adpsgd_lattice_wire_replays_bit_identically_and_saves_bits() {
        // the per-edge lattice exchange is driven entirely by the event
        // seed, so serial and parallel replay to the bit — and it moves
        // fewer bits than the f32 wire (live models stay within eps)
        use crate::coordinator::run_parallel;
        let n = 8;
        let backend = QuadraticOracle::new(256, n, 1.0, 0.5, 2.0, 0.05, 3);
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let s = spec(n, 300, 100);
        let lattice = AdPsgd { wire: WireCodec::Lattice { bits: 8, eps: 1e-2 } };
        let serial = run_serial(&lattice, &backend, &s, &graph, &cost);
        let par = run_parallel(&lattice, &backend, &s, &graph, &cost, 4);
        assert_eq!(serial.final_eval_loss.to_bits(), par.final_eval_loss.to_bits());
        assert_eq!(serial.total_bits, par.total_bits);
        assert_eq!(serial.quant_fallbacks, par.quant_fallbacks);
        assert_eq!(serial.sim_time.to_bits(), par.sim_time.to_bits());
        assert!(serial.final_eval_loss.is_finite());
        let full = run_serial(&AdPsgd::default(), &backend, &s, &graph, &cost);
        assert!(
            (serial.total_bits as f64) < 0.5 * full.total_bits as f64,
            "lattice {} bits vs f32 {} bits (fallbacks {})",
            serial.total_bits,
            full.total_bits,
            serial.quant_fallbacks
        );
    }

    #[test]
    fn adpsgd_pays_comm_every_step() {
        // with a big model, AD-PSGD per-step time is dominated by exchange
        let n = 4;
        let backend = QuadraticOracle::new(64, n, 1.0, 0.5, 2.0, 0.0, 3);
        let mut rng = Pcg64::seed(4);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        // tiny compute, slow network -> comm dominates
        let cost = CostModel {
            batch_time: 1e-6,
            jitter: 0.0,
            straggler_prob: 0.0,
            bandwidth: 1e3, // 1 KB/s: 64*4 B takes .256 s
            ..CostModel::default()
        };
        let m = run_serial(&AdPsgd::default(), &backend, &spec(n, 100, 0), &graph, &cost);
        // ~100 interactions × 0.256 s spread over 4 nodes ≥ ~6 s at the max
        assert!(m.sim_time > 1.0, "sim_time={}", m.sim_time);
    }
}
