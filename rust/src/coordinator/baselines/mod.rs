//! The comparison systems of the paper's §5 evaluation, each an
//! [`crate::coordinator::Algorithm`] plug-in to the shared executors:
//!
//! | module        | paper baseline                | event shape (per tick)              |
//! |---------------|-------------------------------|-------------------------------------|
//! | [`allreduce`] | (large-batch) data-parallel SGD [16] | n computes + mix barrier     |
//! | [`localsgd`]  | Local SGD [38, 29]            | n computes (h steps) + mix barrier  |
//! | [`dpsgd`]     | D-PSGD [27]                   | n computes + per-edge gossip + mix  |
//! | [`adpsgd`]    | AD-PSGD [28]                  | one pairwise gossip event           |
//! | [`sgp`]       | SGP (push-sum) [5]            | n computes + push-sum mix barrier   |
//!
//! All evaluate on the same cadence as SwarmSGD and charge time from the
//! same [`crate::netmodel::CostModel`] through the per-node clocks in
//! [`crate::coordinator::NodeState`] — so loss-vs-time and time-per-batch
//! comparisons are apples-to-apples, on either executor. Since the
//! phased-event redesign *every* baseline genuinely parallelizes on
//! `--executor parallel`: the asynchronous ones (AD-PSGD) as 2-node gossip
//! events, the synchronous ones as per-node compute events that spread
//! across all workers, with only the round-closing mix event acting as the
//! barrier their semantics requires — and the metrics stay bit-identical
//! to the monolithic whole-cluster rounds they replaced. D-PSGD's
//! per-edge mixing makes it freerun-eligible (a live-merge
//! [`crate::coordinator::PairwisePolicy`]), and SGP freeruns through the
//! weighted-slot [`crate::coordinator::PushSumPolicy`] — push-sum `(x, w)`
//! pairs in the seqlock slots. The pairwise exchanges of adpsgd/dpsgd/sgp
//! honor the [`crate::coordinator::WireCodec`] axis (`--wire lattice|f32`);
//! localsgd/allreduce mix through full-precision collectives and reject
//! the lattice codec with an actionable error.

mod adpsgd;
mod allreduce;
mod dpsgd;
mod localsgd;
mod sgp;

pub use adpsgd::AdPsgd;
pub use allreduce::AllReduce;
pub use dpsgd::DPsgd;
pub use localsgd::LocalSgd;
pub use sgp::Sgp;
