//! The comparison systems of the paper's §5 evaluation, each an
//! [`crate::coordinator::Algorithm`] plug-in to the shared executors:
//!
//! | module        | paper baseline                | event shape                  |
//! |---------------|-------------------------------|------------------------------|
//! | [`allreduce`] | (large-batch) data-parallel SGD [16] | whole-cluster round   |
//! | [`localsgd`]  | Local SGD [38, 29]            | whole-cluster round (h steps)|
//! | [`dpsgd`]     | D-PSGD [27]                   | whole-cluster round + matching|
//! | [`adpsgd`]    | AD-PSGD [28]                  | pairwise gossip event        |
//! | [`sgp`]       | SGP (push-sum) [5]            | whole-cluster push round     |
//!
//! All evaluate on the same cadence as SwarmSGD and charge time from the
//! same [`crate::netmodel::CostModel`] through the per-node clocks in
//! [`crate::coordinator::NodeState`] — so loss-vs-time and time-per-batch
//! comparisons are apples-to-apples, on either executor. The asynchronous
//! baselines (AD-PSGD) schedule 2-node events and genuinely parallelize on
//! `--executor parallel`; the synchronous ones schedule whole-cluster
//! events, because their semantics IS a global barrier per round.

mod adpsgd;
mod allreduce;
mod dpsgd;
mod localsgd;
mod sgp;

pub use adpsgd::AdPsgd;
pub use allreduce::AllReduce;
pub use dpsgd::DPsgd;
pub use localsgd::LocalSgd;
pub use sgp::Sgp;
