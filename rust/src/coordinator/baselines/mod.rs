//! The comparison systems of the paper's §5 evaluation:
//!
//! | module        | paper baseline                | communication pattern        |
//! |---------------|-------------------------------|------------------------------|
//! | [`allreduce`] | (large-batch) data-parallel SGD [16] | global allreduce / step |
//! | [`localsgd`]  | Local SGD [38, 29]            | global average every H steps |
//! | [`dpsgd`]     | D-PSGD [27]                   | matching average / step      |
//! | [`adpsgd`]    | AD-PSGD [28]                  | pairwise average / step      |
//! | [`sgp`]       | SGP (push-sum) [5]            | directed push / step         |
//!
//! All reuse [`super::Cluster`] and [`super::NodeClocks`], evaluate the mean
//! (or de-biased) model on the same cadence as SwarmSGD, and charge time
//! from the same [`crate::netmodel::CostModel`] — so loss-vs-time and
//! time-per-batch comparisons are apples-to-apples.

mod adpsgd;
mod allreduce;
mod dpsgd;
mod localsgd;
mod sgp;

pub use adpsgd::AdPsgdRunner;
pub use allreduce::AllReduceRunner;
pub use dpsgd::DPsgdRunner;
pub use localsgd::LocalSgdRunner;
pub use sgp::SgpRunner;

use super::{Cluster, LrSchedule, NodeClocks, RunContext, RunMetrics};
use crate::backend::TrainBackend;

/// Shared configuration for the round-based baselines.
#[derive(Clone, Debug)]
pub struct RoundsConfig {
    pub n: usize,
    /// synchronous rounds (each round = 1 local step per node, except
    /// LocalSGD which takes `h` steps per communication round)
    pub rounds: u64,
    pub lr: LrSchedule,
    pub seed: u64,
    pub name: String,
    /// LocalSGD communication period (ignored by the others)
    pub h: u64,
}

impl RoundsConfig {
    pub fn new(n: usize, rounds: u64, lr: f32, name: &str) -> Self {
        Self {
            n,
            rounds,
            lr: LrSchedule::Constant(lr),
            seed: 0x5EED,
            name: name.to_string(),
            h: 5,
        }
    }
}

/// Record one curve point for a round-based run (shared by all baselines).
pub(crate) fn record_round_point(
    cluster: &Cluster,
    clocks: &NodeClocks,
    ctx: &mut RunContext,
    round: u64,
    metrics: &mut RunMetrics,
    mean_override: Option<&[f32]>,
) {
    let mu_owned;
    let mu: &[f32] = match mean_override {
        Some(m) => m,
        None => {
            mu_owned = cluster.mean_model();
            &mu_owned
        }
    };
    let ev = ctx.backend.eval(mu);
    let pick = ctx.rng.below_usize(cluster.n());
    let ind = ctx.backend.eval(&cluster.agents[pick].params);
    let gamma = if ctx.track_gamma { cluster.gamma() } else { f64::NAN };
    let n = cluster.n() as f64;
    let epochs =
        (0..cluster.n()).map(|i| ctx.backend.epochs(i)).sum::<f64>() / n;
    metrics.push(super::CurvePoint {
        t: round,
        parallel_time: round as f64,
        sim_time: clocks.max_time(),
        epochs,
        train_loss: cluster.mean_train_loss(),
        eval_loss: ev.loss,
        eval_acc: ev.accuracy,
        indiv_loss: ind.loss,
        gamma,
        bits: metrics.total_bits,
    });
}

/// Finalize aggregate fields common to all round-based runners.
pub(crate) fn finalize(
    metrics: &mut RunMetrics,
    cluster: &Cluster,
    clocks: &NodeClocks,
    ctx: &mut RunContext,
    rounds: u64,
) {
    metrics.interactions = rounds;
    metrics.local_steps = cluster.total_steps();
    metrics.sim_time = clocks.max_time();
    metrics.compute_time_total = clocks.compute_total;
    metrics.comm_time_total = clocks.comm_total;
    metrics.epochs =
        (0..cluster.n()).map(|i| ctx.backend.epochs(i)).sum::<f64>() / cluster.n() as f64;
    if let Some(p) = metrics.curve.last() {
        metrics.final_eval_loss = p.eval_loss;
        metrics.final_eval_acc = p.eval_acc;
    }
}

/// One local SGD step for every node; returns the max per-node compute time
/// (the synchronous-round critical path).
pub(crate) fn step_all(
    cluster: &mut Cluster,
    ctx: &mut RunContext,
    lr: f32,
    clocks: &mut NodeClocks,
) -> f64 {
    let mut max_t: f64 = 0.0;
    for i in 0..cluster.n() {
        let a = &mut cluster.agents[i];
        a.last_loss = ctx.backend.step(i, &mut a.params, &mut a.mom, lr);
        a.steps += 1;
        let dt = ctx.cost.compute_time(&mut a.rng);
        clocks.charge_compute(i, dt);
        max_t = max_t.max(dt);
    }
    max_t
}

#[allow(unused_imports)]
pub(crate) use crate::backend::EvalResult;

#[allow(dead_code)]
fn _assert_backend_obj_safe(_: &mut dyn TrainBackend) {}
