//! Data-parallel (large-batch) SGD baseline [16]: every round each node
//! takes one local step from the common model and a global allreduce makes
//! all models exactly equal again.  (Averaging post-step models from a
//! common start is algebraically identical to averaging gradients for
//! SGD+momentum when momenta follow the same trajectory, which they do
//! here — all agents stay in lock-step.)

use super::{finalize, record_round_point, step_all, RoundsConfig};
use crate::coordinator::{Cluster, NodeClocks, RunContext, RunMetrics};

pub struct AllReduceRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: RoundsConfig,
}

impl AllReduceRunner {
    pub fn new(cfg: RoundsConfig, ctx: &mut RunContext) -> Self {
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self { clocks: NodeClocks::new(cfg.n), cluster, cfg }
    }

    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let d = self.cluster.dim;
        let bytes = ctx.cost.wire_bytes(d);
        for round in 1..=self.cfg.rounds {
            let lr = self.cfg.lr.at(round);
            step_all(&mut self.cluster, ctx, lr, &mut self.clocks);
            // global model average (== gradient allreduce)
            let mu = self.cluster.mean_model();
            for a in &mut self.cluster.agents {
                a.params.copy_from_slice(&mu);
                a.comm.copy_from_slice(&mu);
            }
            self.clocks.barrier_all(ctx.cost.allreduce_time(self.cfg.n, bytes));
            // ring allreduce moves ~2·(n−1)/n·bytes per node
            m.total_bits += (2 * (self.cfg.n as u64 - 1) / self.cfg.n as u64)
                .max(1)
                * 8
                * bytes
                * self.cfg.n as u64;
            if (ctx.eval_every > 0 && round % ctx.eval_every == 0) || round == self.cfg.rounds
            {
                record_round_point(&self.cluster, &self.clocks, ctx, round, &mut m, None);
            }
        }
        finalize(&mut m, &self.cluster, &self.clocks, ctx, self.cfg.rounds);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LrSchedule;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    #[test]
    fn allreduce_keeps_models_identical_and_converges() {
        let n = 4;
        let mut backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let backend_f_star = backend.f_star();
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend_f_star
        };
        let mut rng = Pcg64::seed(1);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 50,
            track_gamma: true,
        };
        let cfg = RoundsConfig {
            lr: LrSchedule::Constant(0.05),
            ..RoundsConfig::new(n, 200, 0.05, "allreduce")
        };
        let mut r = AllReduceRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        // models identical after every round
        assert!(r.cluster.gamma() < 1e-9);
        let gap = (m.final_eval_loss - backend_f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        assert!(m.sim_time > 0.0);
        assert_eq!(m.local_steps, 200 * n as u64);
    }
}
