//! Data-parallel (large-batch) SGD baseline [16]: every round each node
//! takes one local step from the common model and a global allreduce makes
//! all models exactly equal again.  (Averaging post-step models from a
//! common start is algebraically identical to averaging gradients for
//! SGD+momentum when momenta follow the same trajectory, which they do
//! here — all agents stay in lock-step.)
//!
//! Under the phased-event contract one round is `n` single-node
//! [`EventKind::Compute`] events (one step each, spread across every
//! worker) plus one whole-cluster [`EventKind::Mix`] allreduce barrier.

use crate::coordinator::algorithm::{
    barrier_all, mean_params, step_once, Algorithm, Event, EventKind, EventOutcome,
    InteractionSchedule, NodeState, StepCtx,
};
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

#[derive(Clone, Copy, Debug, Default)]
pub struct AllReduce;

impl Algorithm for AllReduce {
    fn name(&self) -> &'static str {
        "allreduce"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        let mut s = InteractionSchedule::new(n);
        let h = vec![1; n];
        for _ in 0..events {
            let seed = rng.next_u64();
            s.push_round(&h, seed);
        }
        s
    }

    fn interact(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        match ev.kind {
            // one SGD step on one node, from its own stream
            EventKind::Compute => {
                step_once(ctx, ev.nodes[0], &mut *parts[0]);
                EventOutcome::default()
            }
            // global model average (== gradient allreduce; shared f64
            // node-order helper) + the ring-allreduce barrier
            EventKind::Mix => {
                let n = parts.len();
                debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
                let bytes = ctx.cost.wire_bytes(ctx.dim);
                let mu = mean_params(parts.iter().map(|s| s.params.as_slice()), ctx.dim, n);
                for st in parts.iter_mut() {
                    st.params.copy_from_slice(&mu);
                    st.comm.copy_from_slice(&mu);
                    st.interactions += 1;
                }
                barrier_all(parts, ctx.cost.allreduce_time(n, bytes));
                // ring allreduce moves ~2·(n−1)/n·bytes per node
                let bits = (2 * (n as u64 - 1) / n as u64).max(1) * 8 * bytes * n as u64;
                EventOutcome { bits, fallbacks: 0 }
            }
            EventKind::Gossip => {
                unreachable!("allreduce schedules phased compute+mix rounds only")
            }
        }
    }

    /// Synchronous rounds: one tick is one round of parallel time.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    #[test]
    fn allreduce_keeps_models_identical_and_converges() {
        let n = 4;
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let mut rng = Pcg64::seed(1);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.1);
        let spec = RunSpec {
            n,
            events: 200,
            lr: LrSchedule::Constant(0.05),
            seed: 1,
            name: "allreduce".into(),
            eval_every: 50,
            track_gamma: true,
        };
        let m = run_serial(&AllReduce, &backend, &spec, &graph, &cost);
        // models identical after every round
        let gamma_last = m.curve.last().unwrap().gamma;
        assert!(gamma_last < 1e-9, "gamma={gamma_last}");
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        assert!(m.sim_time > 0.0);
        assert_eq!(m.local_steps, 200 * n as u64);
        // phased rounds still report one interaction per round
        assert_eq!(m.interactions, 200);
    }
}
