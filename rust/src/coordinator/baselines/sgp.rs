//! SGP baseline (Assran et al. [5]): Stochastic Gradient Push.
//!
//! Push-sum over a directed gossip: each node holds a value `x` and a
//! weight `w` (init 1).  Per round, after one SGD step on its de-biased
//! model `z = x/w`, node `i` halves `(x, w)` and pushes one half to a
//! uniformly chosen out-neighbor; incoming shares are accumulated.  The
//! de-biased models converge to consensus while Σx and Σw are conserved —
//! push-sum's defining invariant (tested below).  Run with overlap factor 1
//! as the paper configures SGP.

use super::{finalize, record_round_point, RoundsConfig};
use crate::coordinator::{Cluster, NodeClocks, RunContext, RunMetrics};

pub struct SgpRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    /// push-sum weights w_i
    pub weights: Vec<f64>,
    cfg: RoundsConfig,
}

impl SgpRunner {
    pub fn new(cfg: RoundsConfig, ctx: &mut RunContext) -> Self {
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self {
            clocks: NodeClocks::new(cfg.n),
            weights: vec![1.0; cfg.n],
            cluster,
            cfg,
        }
    }

    /// De-biased model of node i: z_i = x_i / w_i.
    pub fn debiased(&self, i: usize) -> Vec<f32> {
        let w = self.weights[i] as f32;
        self.cluster.agents[i].params.iter().map(|&v| v / w).collect()
    }

    /// Weighted mean model Σx / Σw (the consensus target).
    pub fn consensus_model(&self) -> Vec<f32> {
        let wsum: f64 = self.weights.iter().sum();
        let d = self.cluster.dim;
        let mut acc = vec![0.0f64; d];
        for a in &self.cluster.agents {
            for (s, &v) in acc.iter_mut().zip(&a.params) {
                *s += v as f64;
            }
        }
        acc.into_iter().map(|v| (v / wsum) as f32).collect()
    }

    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let bytes = ctx.cost.wire_bytes(self.cluster.dim);
        let n = self.cfg.n;
        let mut inbox_x: Vec<Vec<f32>> = vec![vec![0.0; self.cluster.dim]; n];
        let mut inbox_w = vec![0.0f64; n];
        for round in 1..=self.cfg.rounds {
            let lr = self.cfg.lr.at(round);
            // SGD step on the de-biased model, then re-bias the update
            let mut max_comp: f64 = 0.0;
            for i in 0..n {
                let w = self.weights[i] as f32;
                let mut z = self.debiased(i);
                let a = &mut self.cluster.agents[i];
                a.last_loss = ctx.backend.step(i, &mut z, &mut a.mom, lr);
                a.steps += 1;
                for (x, &zv) in a.params.iter_mut().zip(&z) {
                    *x = zv * w;
                }
                max_comp = max_comp.max(ctx.cost.compute_time(&mut a.rng));
            }
            for i in 0..n {
                self.clocks.charge_compute(i, max_comp); // synchronous round
            }
            // push phase: halve and send to one random out-neighbor
            for ib in inbox_x.iter_mut() {
                ib.iter_mut().for_each(|v| *v = 0.0);
            }
            inbox_w.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..n {
                let dst = ctx.graph.sample_neighbor(i, ctx.rng);
                let a = &self.cluster.agents[i];
                for (s, &v) in inbox_x[dst].iter_mut().zip(&a.params) {
                    *s += 0.5 * v;
                }
                inbox_w[dst] += 0.5 * self.weights[i];
                m.total_bits += 8 * bytes + 64; // x halves + weight scalar
            }
            for i in 0..n {
                let a = &mut self.cluster.agents[i];
                for (x, &add) in a.params.iter_mut().zip(&inbox_x[i]) {
                    *x = 0.5 * *x + add;
                }
                self.weights[i] = 0.5 * self.weights[i] + inbox_w[i];
                a.comm.copy_from_slice(&a.params);
            }
            self.clocks.barrier_all(ctx.cost.p2p_time(bytes));
            if (ctx.eval_every > 0 && round % ctx.eval_every == 0) || round == self.cfg.rounds
            {
                let mu = self.consensus_model();
                record_round_point(&self.cluster, &self.clocks, ctx, round, &mut m, Some(&mu));
            }
        }
        finalize(&mut m, &self.cluster, &self.clocks, ctx, self.cfg.rounds);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    fn setup(
        n: usize,
    ) -> (QuadraticOracle, Graph, CostModel, Pcg64) {
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let mut rng = Pcg64::seed(8);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        (backend, graph, CostModel::deterministic(0.1), rng)
    }

    #[test]
    fn push_sum_conserves_mass() {
        let n = 6;
        let (mut backend, graph, cost, mut rng) = setup(n);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 0,
            track_gamma: false,
        };
        let cfg = RoundsConfig {
            lr: crate::coordinator::LrSchedule::Constant(0.0), // no SGD: pure gossip
            ..RoundsConfig::new(n, 50, 0.0, "sgp")
        };
        let mut r = SgpRunner::new(cfg, &mut ctx);
        // perturb one node so consensus is non-trivial
        r.cluster.agents[0].params[0] = 6.0;
        let x_sum_before: f64 = r
            .cluster
            .agents
            .iter()
            .map(|a| a.params[0] as f64)
            .sum();
        let w_sum_before: f64 = r.weights.iter().sum();
        let _ = r.run(&mut ctx);
        let x_sum_after: f64 =
            r.cluster.agents.iter().map(|a| a.params[0] as f64).sum();
        let w_sum_after: f64 = r.weights.iter().sum();
        assert!((x_sum_before - x_sum_after).abs() < 1e-3);
        assert!((w_sum_before - w_sum_after).abs() < 1e-9);
        // and de-biased values reached consensus
        let z0 = r.debiased(0)[0];
        for i in 1..n {
            assert!((r.debiased(i)[0] - z0).abs() < 1e-3);
        }
    }

    #[test]
    fn sgp_converges_on_quadratic() {
        let n = 8;
        let (mut backend, graph, cost, mut rng) = setup(n);
        let backend_f_star = backend.f_star();
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend_f_star
        };
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 50,
            track_gamma: false,
        };
        let cfg = RoundsConfig::new(n, 300, 0.05, "sgp");
        let mut r = SgpRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        let gap = (m.final_eval_loss - backend_f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
    }
}
