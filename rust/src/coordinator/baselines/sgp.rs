//! SGP baseline (Assran et al. [5]): Stochastic Gradient Push.
//!
//! Push-sum over a directed gossip: each node holds a value `x` and a
//! weight `w` (init 1).  Per round, after one SGD step on its de-biased
//! model `z = x/w`, node `i` halves `(x, w)` and pushes one half to a
//! uniformly chosen out-neighbor; incoming shares are accumulated.  The
//! de-biased models converge to consensus while Σx and Σw are conserved —
//! push-sum's defining invariant (tested below).  Run with overlap factor 1
//! as the paper configures SGP.
//!
//! Under the phased-event contract one round is `n` single-node
//! [`EventKind::Compute`] events (the de-biased SGD step, all randomness
//! from the node's private stream) plus one whole-cluster
//! [`EventKind::Mix`] event that performs the push phase. SGP charges the
//! round *max* compute time to everyone (synchronous rounds), so each
//! compute event parks its drawn time in [`NodeState::pending_compute`]
//! and the mix barrier settles it. The push targets are drawn from the
//! round seed; each node's inbox is its `inbox` scratch, so the round
//! allocates only the n-vector of weight shares.
//! [`Algorithm::round_metrics`] is overridden: curves evaluate the
//! de-biased consensus Σx/Σw, and the individual model is z = x/w.

use crate::coordinator::algorithm::{
    barrier_all, pair_at, Algorithm, Event, EventKind, EventOutcome, InteractionSchedule,
    NodeState, RoundModels, StepCtx,
};
use crate::coordinator::{LocalSteps, MergeScratch, MixPolicy, PushSumPolicy, WireCodec};
use crate::kernels;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

#[derive(Clone, Copy, Debug)]
pub struct Sgp {
    /// wire codec the pushed halves cross (`--wire lattice|f32`)
    pub wire: WireCodec,
}

impl Default for Sgp {
    fn default() -> Self {
        Self { wire: WireCodec::F32 }
    }
}

impl Algorithm for Sgp {
    fn name(&self) -> &'static str {
        "sgp"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        _scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        // the push targets are graph-constrained at interact time
        // (`ctx.graph.sample_neighbor` in the Mix phase — an out-neighbor
        // draw on directed scenarios), so the schedule itself is just the
        // round skeleton
        let mut s = InteractionSchedule::new(n);
        let h = vec![1; n];
        for _ in 0..events {
            let seed = rng.next_u64();
            s.push_round(&h, seed);
        }
        s
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = MergeScratch::with_kernel(ctx.dim, self.kernel());
        self.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut MergeScratch,
    ) -> EventOutcome {
        match ev.kind {
            // SGD step on the de-biased model z = x/w, then re-bias the
            // update. The compute-time draw is parked: the round is
            // synchronous, so everyone pays the round max at the barrier.
            EventKind::Compute => {
                let st = &mut *parts[0];
                let agent = ev.nodes[0];
                let w = st.weight as f32;
                for (z, &x) in st.snap.iter_mut().zip(&st.params) {
                    *z = x / w;
                }
                st.last_loss =
                    ctx.backend.step(agent, &mut st.snap, &mut st.mom, ctx.lr, &mut st.rng);
                st.steps += 1;
                for (x, &z) in st.params.iter_mut().zip(&st.snap) {
                    *x = z * w;
                }
                st.pending_compute = ctx.cost.compute_time(&mut st.rng);
                EventOutcome::default()
            }
            // the push-sum phase: settle the round-max compute charge,
            // halve-and-push to one random out-neighbor each, absorb,
            // barrier on the p2p cost
            EventKind::Mix => {
                let n = parts.len();
                // the push targets below index `parts` by node id, which
                // requires the identity-ordered whole-cluster mix this
                // schedule emits
                debug_assert!(ev.nodes.iter().enumerate().all(|(k, &v)| k == v));
                let bytes = ctx.cost.wire_bytes(ctx.dim);
                let mut er = Pcg64::seed(ev.seed);
                let max_comp =
                    parts.iter().map(|s| s.pending_compute).fold(0.0, f64::max);
                for st in parts.iter_mut() {
                    st.time += max_comp;
                    st.compute += max_comp;
                    st.pending_compute = 0.0;
                }
                // push phase: halve and send to one random out-neighbor;
                // inboxes are the receivers' `inbox` scratch buffers
                for st in parts.iter_mut() {
                    st.inbox.iter_mut().for_each(|v| *v = 0.0);
                }
                let mut inbox_w = vec![0.0f64; n];
                let mut bits = 0u64;
                let mut fallbacks = 0u64;
                // codec seeds come from a sibling stream so the F32 path's
                // push-target draws stay bit-identical to the golden rounds
                let mut cr = Pcg64::seed(ev.seed ^ 0x5EED_C0DE_C0DE_0001);
                for k in 0..n {
                    let dst = ctx.graph.sample_neighbor(ev.nodes[k], &mut er);
                    inbox_w[dst] += 0.5 * parts[k].weight;
                    let (src, dstst) = pair_at(parts, k, dst);
                    match self.wire {
                        WireCodec::F32 => {
                            for (s, &v) in dstst.inbox.iter_mut().zip(&src.params) {
                                *s += 0.5 * v;
                            }
                            bits += 8 * bytes + 64; // x halves + weight scalar
                        }
                        WireCodec::Lattice { bits: qbits, eps } => {
                            // the pushed x crosses the codec, decoded
                            // against the receiver's own x and pre-halved,
                            // in one fused traversal into the scratch buffer
                            let (b, fb) = kernels::lattice_take_half_into(
                                scratch.kernel,
                                &src.params,
                                &dstst.params,
                                eps,
                                qbits,
                                cr.next_u32(),
                                &mut scratch.publish[..ctx.dim],
                            );
                            for (s, &v) in
                                dstst.inbox.iter_mut().zip(&scratch.publish[..ctx.dim])
                            {
                                *s += v;
                            }
                            bits += ctx.cost.scale_bits(b, ctx.dim) + 64;
                            fallbacks += fb as u64;
                        }
                    }
                }
                // absorb: x ← x/2 + inbox, w ← w/2 + inbox_w
                for (k, st) in parts.iter_mut().enumerate() {
                    for (x, &add) in st.params.iter_mut().zip(&st.inbox) {
                        *x = 0.5 * *x + add;
                    }
                    st.weight = 0.5 * st.weight + inbox_w[k];
                    st.comm.copy_from_slice(&st.params);
                    st.interactions += 1;
                }
                barrier_all(parts, ctx.cost.p2p_time(bytes));
                EventOutcome { bits, fallbacks }
            }
            EventKind::Gossip => {
                unreachable!("sgp schedules phased compute+mix rounds only")
            }
        }
    }

    /// Synchronous rounds: one tick is one round of parallel time.
    fn parallel_time(&self, t: u64, _n: usize) -> f64 {
        t as f64
    }

    /// Push-sum *does* freerun — through weighted slots: every node
    /// publishes its `(x, w)` pair, the initiator runs one de-biased SGD
    /// step on `z = x/w` and takes half of the partner's published offer
    /// on both lanes (cross-writing the remaining half back). Because `x`
    /// and `w` always undergo the same linear ops, `Σx/Σw` stays a
    /// consistent consensus estimate under staleness and dropped
    /// cross-writes — the policy that moves SGP off the freerun refusal
    /// list.
    fn mix_policy(&self) -> Option<Box<dyn MixPolicy>> {
        Some(Box::new(PushSumPolicy { steps: LocalSteps::Fixed(1), wire: self.wire }))
    }

    /// Curves evaluate push-sum's de-biased quantities: the weighted
    /// consensus Σx/Σw and the picked node's z = x/w.
    fn round_metrics(&self, states: &[&NodeState], pick: usize) -> RoundModels {
        let wsum: f64 = states.iter().map(|s| s.weight).sum();
        let dim = states.first().map_or(0, |s| s.params.len());
        let mut acc = vec![0.0f64; dim];
        for s in states {
            for (a, &v) in acc.iter_mut().zip(&s.params) {
                *a += v as f64;
            }
        }
        let consensus = acc.into_iter().map(|v| (v / wsum) as f32).collect();
        let w = states[pick].weight as f32;
        let individual = states[pick].params.iter().map(|&v| v / w).collect();
        RoundModels { consensus, individual }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    fn setup(n: usize) -> (QuadraticOracle, Graph, CostModel) {
        let backend = QuadraticOracle::new(8, n, 1.0, 0.5, 2.0, 0.05, 3);
        let mut rng = Pcg64::seed(8);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        (backend, graph, CostModel::deterministic(0.1))
    }

    fn spec(n: usize, t: u64, lr: f32) -> RunSpec {
        RunSpec {
            n,
            events: t,
            lr: LrSchedule::Constant(lr),
            seed: 8,
            name: "sgp".into(),
            eval_every: 50,
            track_gamma: false,
        }
    }

    #[test]
    fn push_sum_conserves_mass() {
        // lr=0: pure gossip. The consensus model must equal the initial
        // common model exactly in expectation — and the de-biased curve
        // must stay at the initial loss (mass conservation).
        let n = 6;
        let (backend, graph, cost) = setup(n);
        let (p0, _) = backend.init();
        let init_loss = backend.eval(&p0).loss;
        let m = run_serial(&Sgp::default(), &backend, &spec(n, 50, 0.0), &graph, &cost);
        // with no gradient steps, Σx/Σw stays the common x₀ forever
        let final_loss = m.final_eval_loss;
        assert!(
            (final_loss - init_loss).abs() < 1e-6 * init_loss.abs().max(1.0),
            "consensus drifted: {init_loss} -> {final_loss}"
        );
    }

    #[test]
    fn sgp_lattice_wire_replays_bit_identically() {
        // push decode seeds come from a per-round sibling stream, so the
        // lattice push phase replays bit-for-bit at any thread count. (No
        // bit-savings assertion: pushed halves are decoded against the
        // receiver's x, whose push-sum weight may differ, so fallbacks are
        // workload-dependent — they are counted, and must replay exactly.)
        use crate::coordinator::run_parallel;
        let n = 8;
        let (backend, graph, cost) = setup(n);
        let lattice = Sgp { wire: crate::coordinator::WireCodec::Lattice { bits: 8, eps: 1e-2 } };
        let s = spec(n, 120, 0.05);
        let serial = run_serial(&lattice, &backend, &s, &graph, &cost);
        let par = run_parallel(&lattice, &backend, &s, &graph, &cost, 4);
        assert_eq!(serial.final_eval_loss.to_bits(), par.final_eval_loss.to_bits());
        assert_eq!(serial.total_bits, par.total_bits);
        assert_eq!(serial.quant_fallbacks, par.quant_fallbacks);
        assert_eq!(serial.sim_time.to_bits(), par.sim_time.to_bits());
        assert!(serial.final_eval_loss.is_finite());
        assert!(serial.total_bits > 0);
    }

    #[test]
    fn sgp_converges_on_quadratic() {
        let n = 8;
        let (backend, graph, cost) = setup(n);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let m = run_serial(&Sgp::default(), &backend, &spec(n, 300, 0.05), &graph, &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.15, "normalized gap {gap}");
        // phased rounds: interactions still count rounds, steps count nodes
        assert_eq!(m.interactions, 300);
        assert_eq!(m.local_steps, 300 * n as u64);
    }
}
