//! The open free-running capability API: [`MixPolicy`], [`SlotPayload`],
//! and the first-class [`WireCodec`] axis.
//!
//! PR 3 admitted algorithms to the free-running executor through a closed
//! two-field `GossipProfile` struct (local-step distribution + averaging
//! mode), which hardcoded three orthogonal decisions at once: what a node
//! *publishes* (always a plain model snapshot), how an initiator *merges*
//! a stale partner snapshot (one of the three SwarmSGD averaging modes),
//! and whether the snapshot crosses the simulated wire *quantized* (an
//! executor-level constant keyed off the averaging mode). That closed
//! struct is why SGP's push-sum was locked out of freerun: push-sum's
//! published value is a weighted pair `(x, w)`, not a model.
//!
//! This module replaces the struct with an object-safe trait an algorithm
//! returns from [`Algorithm::mix_policy`]. A policy owns four axes:
//!
//! 1. **Slot payload** ([`SlotPayload`], selected via [`PayloadKind`]) —
//!    the value a node publishes into its seqlock slot: [`PlainModel`]
//!    (`dim` lanes) or [`PushSumWeighted`] (`dim + 1` lanes, push-sum
//!    weight in the last lane). The payload trait carries the
//!    encode/decode/merge hooks the executor and policies share, and
//!    `ModelSlot` in [`super::freerun`] is generic over it.
//! 2. **Merge rule** ([`MixPolicy::merge`]) — what the initiator does with
//!    a possibly-stale partner snapshot. Subsumes the old `AveragingMode`
//!    dispatch: live averaging, the Appendix-F non-blocking update, or
//!    push-sum's take-half weight flow.
//! 3. **Local-step policy** ([`MixPolicy::draw_steps`] +
//!    [`MixPolicy::local_phase`]) — how much local work one interaction
//!    performs, and on which model view (SGP steps on the de-biased
//!    `z = x/w`).
//! 4. **Wire codec** ([`WireCodec`]) — whether model lanes cross the
//!    simulated wire lattice-quantized or at full precision. CLI-selectable
//!    per algorithm (`--wire lattice|f32`) and honored by *all three*
//!    executors; bits and decode-fallbacks are attributed through
//!    [`EventOutcome`] and `FreerunStats`.
//!
//! # Implementing a toy policy
//!
//! Any object-safe implementation admits an algorithm to
//! [`run_freerun`](super::run_freerun). A minimal policy that performs no
//! local work and pulls the initiator 25% toward the partner snapshot:
//!
//! ```
//! use swarm_sgd::coordinator::{
//!     EventOutcome, MergeScratch, MixPolicy, NodeState, PayloadKind, StepCtx,
//!     WireCodec,
//! };
//! use swarm_sgd::rngx::Pcg64;
//!
//! struct PullQuarter;
//!
//! impl MixPolicy for PullQuarter {
//!     fn payload(&self) -> PayloadKind {
//!         PayloadKind::Plain
//!     }
//!     fn wire(&self) -> WireCodec {
//!         WireCodec::F32
//!     }
//!     fn draw_steps(&self, _rng: &mut Pcg64) -> u64 {
//!         0
//!     }
//!     fn local_phase(&self, _ctx: &StepCtx<'_>, _node: usize, _st: &mut NodeState, _h: u64) {}
//!     fn merge(
//!         &self,
//!         _ctx: &StepCtx<'_>,
//!         _node: usize,
//!         st: &mut NodeState,
//!         scratch: &mut MergeScratch,
//!         _rng: &mut Pcg64,
//!     ) -> EventOutcome {
//!         for (p, &s) in st.params.iter_mut().zip(scratch.snapshot.iter()) {
//!             *p += 0.25 * (s - *p);
//!         }
//!         st.comm.copy_from_slice(&st.params);
//!         scratch.publish.copy_from_slice(&st.params);
//!         scratch.cross.copy_from_slice(&st.params);
//!         EventOutcome { bits: 32 * scratch.publish.len() as u64, fallbacks: 0 }
//!     }
//! }
//! ```
//!
//! [`Algorithm::mix_policy`]: super::Algorithm::mix_policy

use super::algorithm::{local_phase, EventOutcome, NodeState, StepCtx};
use super::cluster::quantized_transfer;
use super::swarm::LocalSteps;
use crate::kernels::{self, Kernel};
use crate::rngx::Pcg64;

/// How model lanes cross the simulated wire — the quantization axis,
/// CLI-selectable per algorithm (`--wire lattice|f32`) and honored by all
/// three executors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WireCodec {
    /// full-precision f32 lanes
    F32,
    /// lattice-quantized lanes (Appendix G): `bits` per coordinate against
    /// an `eps`-grid, with a counted full-precision fallback when the
    /// decode distance criterion fails
    Lattice { bits: u32, eps: f32 },
}

impl WireCodec {
    /// Selector name, as written on the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            WireCodec::F32 => "f32",
            WireCodec::Lattice { .. } => "lattice",
        }
    }

    /// Decode `model` lanes in place, as received by a node holding
    /// `reference`; returns `(raw wire bits, fell_back)`. `F32` is the
    /// identity at 32 bits/lane; `Lattice` round-trips the lattice codec
    /// (fallback bits included when the decode fails). Raw bits are before
    /// any `CostModel::scale_bits` wire-size override scaling.
    pub fn decode_in_place(
        &self,
        model: &mut [f32],
        reference: &[f32],
        seed: u32,
    ) -> (u64, bool) {
        match *self {
            WireCodec::F32 => (32 * model.len() as u64, false),
            WireCodec::Lattice { bits, eps } => {
                let tr = quantized_transfer(model, reference, eps, bits, seed);
                model.copy_from_slice(&tr.decoded);
                (tr.bits, tr.fell_back)
            }
        }
    }

    /// Allocation-free [`WireCodec::decode_in_place`]: decode `remote` as
    /// received by a node holding `reference` into `out` through the fused
    /// kernel path (one traversal, no `Vec`). Bit-identical to the
    /// two-pass `decode_in_place` on both codecs and both kernels.
    pub fn decode_into(
        &self,
        kernel: Kernel,
        remote: &[f32],
        reference: &[f32],
        seed: u32,
        out: &mut [f32],
    ) -> (u64, bool) {
        match *self {
            WireCodec::F32 => {
                out.copy_from_slice(remote);
                (32 * remote.len() as u64, false)
            }
            WireCodec::Lattice { bits, eps } => {
                kernels::lattice_decode_into(kernel, remote, reference, eps, bits, seed, out)
            }
        }
    }
}

/// Per-worker reusable merge buffers — the allocation-free path into the
/// fused kernels ([`crate::kernels`]).
///
/// One scratch is created per executor worker (or per serial run), sized to
/// the policy's payload lanes, and threaded through every
/// [`MixPolicy::merge`] / [`Algorithm::interact_with`] call so the merge
/// hot path allocates **zero** per interaction (asserted by
/// `tests/merge_no_alloc.rs`). It also carries the selected [`Kernel`] so
/// merge bodies dispatch without re-plumbing an extra argument.
///
/// ```
/// use swarm_sgd::coordinator::MergeScratch;
/// use swarm_sgd::kernels::Kernel;
///
/// let mut s = MergeScratch::with_kernel(4, Kernel::Simd);
/// assert_eq!(s.publish.len(), 4);
/// assert_eq!(s.kernel, Kernel::Simd);
/// s.ensure(6); // grows for a larger payload, never shrinks
/// assert_eq!(s.snapshot.len(), 6);
/// ```
///
/// [`Algorithm::interact_with`]: super::Algorithm::interact_with
#[derive(Clone, Debug)]
pub struct MergeScratch {
    /// the initiator's own published payload (own-slot sync reads land
    /// here for [`MixPolicy::absorb_own_slot`])
    pub own: Vec<f32>,
    /// the partner's possibly-stale payload snapshot
    pub snapshot: Vec<f32>,
    /// the payload republished into the initiator's slot
    pub publish: Vec<f32>,
    /// the payload best-effort cross-written into the partner's slot
    pub cross: Vec<f32>,
    /// the fused-kernel implementation merges dispatch to
    pub kernel: Kernel,
}

impl MergeScratch {
    /// Scratch for `lanes`-wide payloads with the default scalar kernel.
    pub fn new(lanes: usize) -> Self {
        Self::with_kernel(lanes, Kernel::Scalar)
    }

    /// Scratch for `lanes`-wide payloads dispatching to `kernel`.
    pub fn with_kernel(lanes: usize, kernel: Kernel) -> Self {
        MergeScratch {
            own: vec![0.0; lanes],
            snapshot: vec![0.0; lanes],
            publish: vec![0.0; lanes],
            cross: vec![0.0; lanes],
            kernel,
        }
    }

    /// Grow all buffers to at least `lanes` (no-op when already large
    /// enough — the amortized-zero-allocation reuse path).
    pub fn ensure(&mut self, lanes: usize) {
        if self.snapshot.len() < lanes {
            self.own.resize(lanes, 0.0);
            self.snapshot.resize(lanes, 0.0);
            self.publish.resize(lanes, 0.0);
            self.cross.resize(lanes, 0.0);
        }
    }
}

/// One endpoint of a codec exchange, fused: average `mine` with the
/// decoded `remote` into `out` in a single traversal. `F32` averages the
/// models directly; `Lattice` runs the fused quantize-average kernel.
/// The operand order (`0.5 * (mine + decoded)`) matches the historical
/// per-endpoint update exactly.
fn fused_codec_avg(
    codec: WireCodec,
    kernel: Kernel,
    remote: &[f32],
    mine: &[f32],
    seed: u32,
    out: &mut [f32],
) -> (u64, bool) {
    match codec {
        WireCodec::F32 => {
            kernels::avg_into(kernel, mine, remote, out);
            (32 * remote.len() as u64, false)
        }
        WireCodec::Lattice { bits, eps } => {
            kernels::lattice_qavg_into(kernel, remote, mine, eps, bits, seed, out)
        }
    }
}

/// Two-way codec exchange + live averaging for one gossip edge — the
/// shared lattice path of the AD-PSGD and D-PSGD replay interact bodies:
/// both incoming copies cross the codec (each decoded against the
/// receiver's live model), then each endpoint averages with what it
/// decoded — fused into one traversal per endpoint through `scratch`.
/// Returns raw (pre-`scale_bits`) wire bits and the fallback count.
/// Callers derive `er` deterministically from the event seed so the
/// exchange replays bit-identically on any executor.
pub fn codec_exchange_average(
    a: &mut NodeState,
    b: &mut NodeState,
    codec: WireCodec,
    er: &mut Pcg64,
    scratch: &mut MergeScratch,
) -> (u64, u64) {
    // seeds drawn unconditionally, in the historical order, before any
    // codec dispatch — the replay RNG stream must not depend on the codec
    let seed_a = er.next_u32();
    let seed_b = er.next_u32();
    let kern = scratch.kernel;
    let dim = a.params.len();
    let (b1, f1) =
        fused_codec_avg(codec, kern, &b.params, &a.params, seed_a, &mut scratch.publish[..dim]);
    let (b2, f2) =
        fused_codec_avg(codec, kern, &a.params, &b.params, seed_b, &mut scratch.cross[..dim]);
    a.params.copy_from_slice(&scratch.publish[..dim]);
    b.params.copy_from_slice(&scratch.cross[..dim]);
    (b1 + b2, (f1 as u64) + (f2 as u64))
}

/// Which [`SlotPayload`] layout a policy publishes — the executor
/// dispatches its generic slot machinery on this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// [`PlainModel`]: `dim` lanes
    Plain,
    /// [`PushSumWeighted`]: `dim + 1` lanes (weight in the last lane)
    PushSumWeighted,
}

/// The value one freerun model slot publishes, as flat f32 lanes, with the
/// encode/decode/merge hooks the executor and policies share. `ModelSlot`
/// in [`super::freerun`] is generic over this trait, so the slot layout is
/// part of the policy contract rather than a hardcoded `Vec<f32>` model
/// snapshot.
pub trait SlotPayload: Send + Sync + 'static {
    /// lanes beyond the model (0 for plain models, 1 for the push-sum
    /// weight)
    const AUX_LANES: usize;

    /// total f32 lanes one payload occupies at model dimension `dim`
    fn lanes(dim: usize) -> usize {
        dim + Self::AUX_LANES
    }

    /// **encode**: a node's publishable value from its live model view and
    /// push-sum weight
    fn encode(params: &[f32], weight: f64, out: &mut [f32]);

    /// **merge**: lane-wise payload-algebra midpoint `into ← (into+other)/2`
    /// — the symmetric pairwise mixing step in payload space (for weighted
    /// pairs this merges `x` and `w` by the *same* linear rule, which is
    /// push-sum's defining invariant)
    fn mix_into(into: &mut [f32], other: &[f32]) {
        debug_assert_eq!(into.len(), other.len());
        for (a, &b) in into.iter_mut().zip(other) {
            *a = 0.5 * (*a + b);
        }
    }

    /// **decode**: the consensus model an evaluation snapshot of raw
    /// payloads represents (mean model, or push-sum's de-biased Σx/Σw)
    fn consensus(snaps: &[Vec<f32>], dim: usize) -> Vec<f32>;

    /// **decode**: one payload's individual (de-biased) model
    fn individual(payload: &[f32], dim: usize) -> Vec<f32>;
}

/// Plain-model payload: the node's communication copy, `dim` lanes.
#[derive(Clone, Copy, Debug)]
pub struct PlainModel;

impl SlotPayload for PlainModel {
    const AUX_LANES: usize = 0;

    fn encode(params: &[f32], _weight: f64, out: &mut [f32]) {
        out.copy_from_slice(params);
    }

    fn consensus(snaps: &[Vec<f32>], dim: usize) -> Vec<f32> {
        super::algorithm::mean_params(snaps.iter().map(|v| &v[..dim]), dim, snaps.len())
    }

    fn individual(payload: &[f32], dim: usize) -> Vec<f32> {
        payload[..dim].to_vec()
    }
}

/// Push-sum weighted pair `(x, w)`: `dim` model lanes plus the weight in
/// the last lane. Because `x` and `w` always undergo the *same* linear
/// ops — halving takes, absorbs, or lane-wise midpoints
/// ([`SlotPayload::mix_into`]) — the de-biased ratio `x/w` stays a
/// consistent consensus estimate even when best-effort cross-writes drop
/// — the property that admits SGP to the free-running executor.
#[derive(Clone, Copy, Debug)]
pub struct PushSumWeighted;

impl SlotPayload for PushSumWeighted {
    const AUX_LANES: usize = 1;

    fn encode(params: &[f32], weight: f64, out: &mut [f32]) {
        let (model, aux) = out.split_at_mut(params.len());
        model.copy_from_slice(params);
        aux[0] = weight as f32;
    }

    /// De-biased weighted consensus Σx/Σw over the published pairs.
    fn consensus(snaps: &[Vec<f32>], dim: usize) -> Vec<f32> {
        let wsum: f64 = snaps.iter().map(|s| s[dim] as f64).sum();
        let mut acc = vec![0.0f64; dim];
        for s in snaps {
            for (a, &v) in acc.iter_mut().zip(&s[..dim]) {
                *a += v as f64;
            }
        }
        acc.into_iter().map(|v| (v / wsum) as f32).collect()
    }

    fn individual(payload: &[f32], dim: usize) -> Vec<f32> {
        let w = payload[dim];
        payload[..dim].iter().map(|&x| x / w).collect()
    }
}

/// How the free-running executor drives one initiator-side interaction.
/// Object-safe; returned by [`Algorithm::mix_policy`](super::Algorithm::mix_policy)
/// iff the algorithm has free-running (initiator-decomposable) semantics.
///
/// The executor's per-interaction protocol is fixed; the policy fills in
/// the four axes (see the [module docs](self)):
///
/// 1. iff [`MixPolicy::needs_own_slot_sync`], the executor seqlock-reads
///    the initiator's *own* slot into `scratch.own` and hands it to
///    [`MixPolicy::absorb_own_slot`] — policies whose slot is the
///    canonical value between rings (push-sum: cross-writers take mass
///    out of it) sync their state here; plain-model policies skip the
///    read entirely (their state is canonical);
/// 2. `h = draw_steps(rng)` — pre-draw the local-step count;
/// 3. `local_phase(ctx, node, st, h)` — the initiator's local work;
/// 4. the executor seqlock-reads the partner's slot (never blocking the
///    partner) into `scratch.snapshot`;
/// 5. `merge(ctx, node, st, scratch, rng)` — decode `scratch.snapshot`
///    through [`MixPolicy::wire`] and apply the merge rule to the
///    initiator's state via the fused kernels (`scratch.kernel`), fill
///    `scratch.publish` (the payload for the initiator's own slot) and
///    `scratch.cross` (the payload for the partner's slot), and return
///    the wire accounting;
/// 6. the executor publishes `scratch.publish` into the initiator's slot
///    and best-effort cross-writes `scratch.cross` into the partner's
///    slot (dropped and counted on conflict — nobody ever waits).
///
/// All buffers live in one per-worker [`MergeScratch`], so the protocol
/// allocates nothing per interaction.
pub trait MixPolicy: Send + Sync {
    /// Slot payload layout this policy publishes.
    fn payload(&self) -> PayloadKind;

    /// The codec model lanes cross the simulated wire through.
    fn wire(&self) -> WireCodec;

    /// Pre-draw the initiator's local-step count for one interaction.
    fn draw_steps(&self, rng: &mut Pcg64) -> u64;

    /// Whether the executor must read the initiator's own slot and call
    /// [`MixPolicy::absorb_own_slot`] before each interaction. Policies
    /// whose cross-writes mutate the published value (push-sum takes)
    /// return true; plain-model policies default to false and keep the
    /// own-read off the hot path (so their slot-read telemetry stays
    /// comparable to the pre-`MixPolicy` executor).
    fn needs_own_slot_sync(&self) -> bool {
        false
    }

    /// Sync the initiator's state from its own published slot at ring
    /// time. A node's state only changes during its own rings, so for
    /// policies whose cross-writes *mutate* the published value (push-sum
    /// takes), the slot is the canonical pair and must be absorbed before
    /// the local phase. Only called when [`MixPolicy::needs_own_slot_sync`]
    /// is true; the default is a no-op.
    fn absorb_own_slot(&self, st: &mut NodeState, own: &[f32], dim: usize) {
        let _ = (st, own, dim);
    }

    /// The initiator's local phase: `h` pre-drawn SGD steps on whatever
    /// model view the policy steps (plain params, or SGP's de-biased
    /// `z = x/w`), charging compute time to the state's clock.
    fn local_phase(&self, ctx: &StepCtx<'_>, node: usize, st: &mut NodeState, h: u64);

    /// The merge rule against the partner's possibly-stale payload in
    /// `scratch.snapshot` (`lanes` long). Must update the initiator's
    /// state, fill `scratch.publish` (the payload republished into the
    /// initiator's slot) and `scratch.cross` (the payload best-effort
    /// cross-written into the partner's slot — the pair average for
    /// symmetric policies, the remaining half-offer for push-sum takes),
    /// charge exchange time, and return the wire bits/fallbacks (the
    /// codec's accounting). Implementations dispatch the decode + merge
    /// traversal to the fused kernels selected by `scratch.kernel`.
    fn merge(
        &self,
        ctx: &StepCtx<'_>,
        node: usize,
        st: &mut NodeState,
        scratch: &mut MergeScratch,
        rng: &mut Pcg64,
    ) -> EventOutcome;
}

/// Merge rule of a plain-model pairwise policy — what `AveragingMode`
/// meant to the free-running executor, minus the quantization axis (now
/// [`WireCodec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairMerge {
    /// average live models (the AD-PSGD / Algorithm-1 rule; the snapshot
    /// *read* still never blocks anyone)
    Live,
    /// the Appendix-F non-blocking update against the pre-phase snapshot
    NonBlocking,
}

/// The pairwise-gossip policy family: plain-model slots, configurable
/// local steps, live or non-blocking merge, any wire codec. Covers swarm,
/// poisson, adpsgd, and dpsgd's freerun degradation.
#[derive(Clone, Copy, Debug)]
pub struct PairwisePolicy {
    pub steps: LocalSteps,
    pub merge: PairMerge,
    pub wire: WireCodec,
}

impl MixPolicy for PairwisePolicy {
    fn payload(&self) -> PayloadKind {
        PayloadKind::Plain
    }

    fn wire(&self) -> WireCodec {
        self.wire
    }

    fn draw_steps(&self, rng: &mut Pcg64) -> u64 {
        self.steps.sample(rng)
    }

    fn local_phase(&self, ctx: &StepCtx<'_>, node: usize, st: &mut NodeState, h: u64) {
        local_phase(ctx, node, st, h);
    }

    fn merge(
        &self,
        ctx: &StepCtx<'_>,
        _node: usize,
        st: &mut NodeState,
        scratch: &mut MergeScratch,
        rng: &mut Pcg64,
    ) -> EventOutcome {
        let dim = ctx.dim;
        let full_bytes = ctx.cost.wire_bytes(dim);
        // seed drawn unconditionally before codec dispatch (replay streams
        // must not depend on the codec)
        let seed = rng.next_u32();
        let kern = scratch.kernel;
        let MergeScratch { snapshot, publish, cross, .. } = scratch;
        // fused decode + pair-average in one traversal; the lattice
        // reference is the merge rule's own local view
        let reference: &[f32] = match self.merge {
            PairMerge::Live => &st.params,
            PairMerge::NonBlocking => &st.snap,
        };
        let (raw_bits, fell_back) = match self.wire {
            WireCodec::F32 => {
                kernels::avg_into(kern, reference, &snapshot[..dim], &mut publish[..dim]);
                (32 * dim as u64, false)
            }
            WireCodec::Lattice { bits, eps } => kernels::lattice_qavg_into(
                kern,
                &snapshot[..dim],
                reference,
                eps,
                bits,
                seed,
                &mut publish[..dim],
            ),
        };
        let (exch, bits) = match self.wire {
            WireCodec::F32 => (ctx.cost.exchange_time(full_bytes), 2 * 8 * full_bytes),
            WireCodec::Lattice { bits, .. } => {
                // quantized pull + the symmetric cross-write payload
                let push_bits = dim as u64 * bits as u64 + 160;
                let wire = ctx.cost.scale_bits(raw_bits + push_bits, dim);
                (ctx.cost.exchange_time(wire.div_ceil(8)), wire)
            }
        };
        match self.merge {
            PairMerge::Live => st.params.copy_from_slice(&publish[..dim]),
            PairMerge::NonBlocking => {
                // comm ← (S + inc)/2, params ← comm + (params − S)
                for k in 0..dim {
                    st.params[k] = publish[k] + (st.params[k] - st.snap[k]);
                }
            }
        }
        st.comm.copy_from_slice(&publish[..dim]);
        // symmetric policy: the cross-write ships the same pair average
        // (Algorithm 2's X' update on both endpoints)
        cross[..dim].copy_from_slice(&publish[..dim]);
        st.time += exch;
        st.comm_time += exch;
        EventOutcome { bits, fallbacks: fell_back as u64 }
    }
}

/// SGP's weighted-slot policy — the asynchronous **take-half** analogue of
/// push-sum that admits SGP to the free-running executor:
///
/// * every slot publishes a push-sum pair `(x, w)` ([`PushSumWeighted`]);
///   between a node's own rings, initiators *take mass out of* its slot,
///   so the slot is the canonical pair and the owner re-absorbs it at
///   ring time ([`MixPolicy::absorb_own_slot`]);
/// * one interaction: the initiator runs its de-biased SGD step(s) on
///   `z = x/w`, reads the partner's offer `(x', w')`, keeps half of it —
///   `(x, w) ← (x + x'/2, w + w'/2)` — and cross-writes the remaining
///   half `(x'/2, w'/2)` back into the partner's slot.
///
/// Mass `(Σx, Σw)` is conserved exactly when the cross-write lands; when
/// it drops (or races a republish) both lanes distort *identically*, so
/// every pair remains a nonnegative combination of the initial pairs with
/// equal coefficients on `x` and `w` — the push-sum invariant that keeps
/// the de-biased `Σx/Σw` (and each `z = x/w`) a consistent consensus
/// estimate under staleness, drops, and arbitrary interleaving. Unlike a
/// symmetric midpoint rule (under which every weight would stay pinned at
/// exactly 1 and the weighted machinery would be vacuous), the take-half
/// flow makes the weights genuinely non-trivial, as in the synchronous
/// push phase.
#[derive(Clone, Copy, Debug)]
pub struct PushSumPolicy {
    pub steps: LocalSteps,
    pub wire: WireCodec,
}

impl MixPolicy for PushSumPolicy {
    fn payload(&self) -> PayloadKind {
        PayloadKind::PushSumWeighted
    }

    fn wire(&self) -> WireCodec {
        self.wire
    }

    fn draw_steps(&self, rng: &mut Pcg64) -> u64 {
        self.steps.sample(rng)
    }

    /// Takes mutate the published pair in place, so the slot is canonical
    /// between rings and the owner must re-absorb it.
    fn needs_own_slot_sync(&self) -> bool {
        true
    }

    /// The slot is canonical between rings (takes halve it in place), so
    /// the owner syncs its state from it before doing any local work.
    fn absorb_own_slot(&self, st: &mut NodeState, own: &[f32], dim: usize) {
        st.params.copy_from_slice(&own[..dim]);
        st.weight = own[dim] as f64;
    }

    /// SGD on the de-biased model `z = x/w`, then re-bias — SGP's compute
    /// rule, charged immediately (freerun has no round barrier to park
    /// compute time against).
    fn local_phase(&self, ctx: &StepCtx<'_>, node: usize, st: &mut NodeState, h: u64) {
        let w = st.weight as f32;
        for (z, &x) in st.snap.iter_mut().zip(&st.params) {
            *z = x / w;
        }
        st.last_loss =
            ctx.backend.step_burst(node, &mut st.snap, &mut st.mom, ctx.lr, h, &mut st.rng);
        st.steps += h;
        for (x, &z) in st.params.iter_mut().zip(&st.snap) {
            *x = z * w;
        }
        let mut comp = 0.0;
        for _ in 0..h {
            comp += ctx.cost.compute_time(&mut st.rng);
        }
        st.time += comp;
        st.compute += comp;
    }

    fn merge(
        &self,
        ctx: &StepCtx<'_>,
        _node: usize,
        st: &mut NodeState,
        scratch: &mut MergeScratch,
        rng: &mut Pcg64,
    ) -> EventOutcome {
        let dim = ctx.dim;
        let full_bytes = ctx.cost.wire_bytes(dim);
        // seed drawn unconditionally before codec dispatch
        let seed = rng.next_u32();
        let kern = scratch.kernel;
        let MergeScratch { snapshot, publish, cross, .. } = scratch;
        // fused decode + take-half in one traversal: the offer's model
        // lanes cross the codec (x-scale against x-scale, decoded against
        // the initiator's params); the weight lane is a full-precision
        // scalar either way
        let (raw_bits, fell_back) = match self.wire {
            WireCodec::F32 => {
                kernels::half_into(kern, &snapshot[..dim], &mut cross[..dim]);
                (32 * dim as u64, false)
            }
            WireCodec::Lattice { bits, eps } => kernels::lattice_take_half_into(
                kern,
                &snapshot[..dim],
                &st.params,
                eps,
                bits,
                seed,
                &mut cross[..dim],
            ),
        };
        let (exch, bits) = match self.wire {
            // pulled offer + returned half-offer: one model each way plus
            // the weight scalars
            WireCodec::F32 => {
                (ctx.cost.exchange_time(full_bytes + 8), 2 * (8 * full_bytes + 64))
            }
            WireCodec::Lattice { bits, .. } => {
                let push_bits = dim as u64 * bits as u64 + 160;
                let wire = ctx.cost.scale_bits(raw_bits + push_bits, dim) + 2 * 64;
                (ctx.cost.exchange_time(wire.div_ceil(8)), wire)
            }
        };
        // the kernel already halved the model lanes into `cross`; halve
        // the weight lane, keep the half-offer, and cross-write the rest
        cross[dim] = 0.5 * snapshot[dim];
        for (x, &half) in st.params.iter_mut().zip(&cross[..dim]) {
            *x += half;
        }
        st.weight += cross[dim] as f64;
        PushSumWeighted::encode(&st.params, st.weight, &mut publish[..dim + 1]);
        st.comm.copy_from_slice(&st.params);
        st.time += exch;
        st.comm_time += exch;
        EventOutcome { bits, fallbacks: fell_back as u64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_names_and_f32_identity() {
        assert_eq!(WireCodec::F32.name(), "f32");
        assert_eq!(WireCodec::Lattice { bits: 8, eps: 1e-2 }.name(), "lattice");
        let mut model = vec![1.0f32, -2.0, 3.0];
        let reference = vec![0.0f32; 3];
        let (bits, fb) = WireCodec::F32.decode_in_place(&mut model, &reference, 7);
        assert_eq!(model, vec![1.0, -2.0, 3.0]);
        assert_eq!(bits, 96);
        assert!(!fb);
    }

    #[test]
    fn lattice_codec_roundtrips_close_models() {
        let remote: Vec<f32> = (0..512).map(|i| i as f32 * 1e-4).collect();
        let reference: Vec<f32> = remote.iter().map(|v| v + 0.01).collect();
        let mut lanes = remote.clone();
        let codec = WireCodec::Lattice { bits: 8, eps: 1e-3 };
        let (bits, fb) = codec.decode_in_place(&mut lanes, &reference, 9);
        assert!(!fb);
        assert_eq!(bits, 8 * 512 + 160);
        for (d, r) in lanes.iter().zip(&remote) {
            assert!((d - r).abs() <= 1e-3 + 1e-6);
        }
    }

    #[test]
    fn plain_payload_encode_consensus_individual() {
        assert_eq!(PlainModel::lanes(4), 4);
        let mut out = vec![0.0f32; 2];
        PlainModel::encode(&[1.0, 3.0], 99.0, &mut out); // weight ignored
        assert_eq!(out, vec![1.0, 3.0]);
        let snaps = vec![vec![0.0f32, 2.0], vec![4.0, 0.0]];
        assert_eq!(PlainModel::consensus(&snaps, 2), vec![2.0, 1.0]);
        assert_eq!(PlainModel::individual(&snaps[1], 2), vec![4.0, 0.0]);
    }

    #[test]
    fn weighted_payload_debiases_by_the_weight_lane() {
        assert_eq!(PushSumWeighted::lanes(4), 5);
        let mut out = vec![0.0f32; 3];
        PushSumWeighted::encode(&[2.0, 4.0], 0.5, &mut out);
        assert_eq!(out, vec![2.0, 4.0, 0.5]);
        // individual de-biases: x/w
        assert_eq!(PushSumWeighted::individual(&out, 2), vec![4.0, 8.0]);
        // consensus: Σx/Σw — two pairs encoding the same z must agree
        let snaps = vec![vec![2.0f32, 4.0, 0.5], vec![6.0, 12.0, 1.5]];
        assert_eq!(PushSumWeighted::consensus(&snaps, 2), vec![4.0, 8.0]);
    }

    #[test]
    fn mix_into_is_lanewise_midpoint_for_both_payloads() {
        let mut a = vec![1.0f32, 3.0, 1.0];
        let b = vec![3.0f32, -1.0, 0.5];
        PushSumWeighted::mix_into(&mut a, &b);
        assert_eq!(a, vec![2.0, 1.0, 0.75]);
        let mut p = vec![0.0f32, 2.0];
        PlainModel::mix_into(&mut p, &[4.0, 2.0]);
        assert_eq!(p, vec![2.0, 2.0]);
    }

    #[test]
    fn pairwise_policy_reports_its_axes() {
        let p = PairwisePolicy {
            steps: LocalSteps::Fixed(3),
            merge: PairMerge::NonBlocking,
            wire: WireCodec::Lattice { bits: 8, eps: 1e-2 },
        };
        assert_eq!(p.payload(), PayloadKind::Plain);
        assert_eq!(p.wire().name(), "lattice");
        let mut rng = Pcg64::seed(1);
        assert_eq!(p.draw_steps(&mut rng), 3);
        let ps = PushSumPolicy { steps: LocalSteps::Fixed(1), wire: WireCodec::F32 };
        assert_eq!(ps.payload(), PayloadKind::PushSumWeighted);
        assert_eq!(ps.wire().name(), "f32");
    }

    /// A minimal merge context over the deterministic quadratic oracle.
    fn ctx_fixture(
        dim: usize,
        n: usize,
    ) -> (crate::grad::QuadraticOracle, crate::topology::Graph, crate::netmodel::CostModel)
    {
        let backend = crate::grad::QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, 0.0, 3);
        let mut rng = Pcg64::seed(5);
        let graph =
            crate::topology::Graph::build(crate::topology::Topology::Complete, n, &mut rng);
        (backend, graph, crate::netmodel::CostModel::deterministic(0.1))
    }

    #[test]
    fn push_sum_take_half_merge_conserves_paired_mass() {
        let (dim, n) = (2, 4);
        let (backend, graph, cost) = ctx_fixture(dim, n);
        let ctx = StepCtx { backend: &backend, cost: &cost, graph: &graph, lr: 0.0, dim, n };
        let policy = PushSumPolicy { steps: LocalSteps::Fixed(1), wire: WireCodec::F32 };
        let mut st = NodeState::new(vec![2.0, 4.0], vec![0.0; 2], Pcg64::seed(1));
        // partner offer (x', w') = ([4, 8], 2) — same de-biased z as ours
        let mut scratch = MergeScratch::new(3);
        scratch.snapshot.copy_from_slice(&[4.0, 8.0, 2.0]);
        let mut rng = Pcg64::seed(9);
        let before = st.time;
        let out = policy.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
        // the initiator keeps half the offer on BOTH lanes...
        assert_eq!(st.params, vec![4.0, 8.0]); // 2 + 4/2, 4 + 8/2
        assert!((st.weight - 2.0).abs() < 1e-9); // 1 + 2/2
        assert_eq!(scratch.publish, vec![4.0, 8.0, 2.0]);
        // ...and returns the remaining half-offer as the cross-write
        assert_eq!(scratch.cross, vec![2.0, 4.0, 1.0]);
        // mass before (own + offer) == mass after (publish + cross), lanes
        // paired — and the de-biased z is unchanged (offer had the same z)
        assert_eq!(PushSumWeighted::individual(&scratch.publish, dim), vec![2.0, 4.0]);
        assert_eq!(PushSumWeighted::individual(&scratch.cross, dim), vec![2.0, 4.0]);
        assert!(out.bits > 0);
        assert_eq!(out.fallbacks, 0);
        assert!(st.time > before, "exchange time must be charged");
    }

    #[test]
    fn push_sum_absorb_own_slot_syncs_state_from_the_canonical_pair() {
        let policy = PushSumPolicy { steps: LocalSteps::Fixed(1), wire: WireCodec::F32 };
        let mut st = NodeState::new(vec![9.0, 9.0], vec![0.0; 2], Pcg64::seed(1));
        // an initiator took mass from our slot since our last ring
        let own = vec![1.0f32, 2.0, 0.25];
        policy.absorb_own_slot(&mut st, &own, 2);
        assert_eq!(st.params, vec![1.0, 2.0]);
        assert!((st.weight - 0.25).abs() < 1e-9);
    }

    #[test]
    fn pairwise_nonblocking_merge_matches_the_appendix_f_update() {
        // same scenario as cluster::nonblocking_update's unit test:
        // S = [0, 0], params = S + delta with delta = [1, 1], inc = [2, 4]
        let (dim, n) = (2, 4);
        let (backend, graph, cost) = ctx_fixture(dim, n);
        let ctx = StepCtx { backend: &backend, cost: &cost, graph: &graph, lr: 0.0, dim, n };
        let policy = PairwisePolicy {
            steps: LocalSteps::Fixed(1),
            merge: PairMerge::NonBlocking,
            wire: WireCodec::F32,
        };
        let mut st = NodeState::new(vec![1.0, 1.0], vec![0.0; 2], Pcg64::seed(1));
        st.snap.copy_from_slice(&[0.0, 0.0]);
        let mut scratch = MergeScratch::new(2);
        scratch.snapshot.copy_from_slice(&[2.0, 4.0]);
        let mut rng = Pcg64::seed(9);
        policy.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
        assert_eq!(scratch.publish, vec![1.0, 2.0]); // (S + inc)/2
        assert_eq!(st.comm, vec![1.0, 2.0]);
        assert_eq!(st.params, vec![2.0, 3.0]); // (S + inc)/2 + delta
        assert_eq!(
            scratch.cross, scratch.publish,
            "symmetric policy cross-writes the pair average"
        );
    }

    #[test]
    fn pairwise_live_merge_averages_live_models() {
        let (dim, n) = (2, 4);
        let (backend, graph, cost) = ctx_fixture(dim, n);
        let ctx = StepCtx { backend: &backend, cost: &cost, graph: &graph, lr: 0.0, dim, n };
        let policy = PairwisePolicy {
            steps: LocalSteps::Fixed(1),
            merge: PairMerge::Live,
            wire: WireCodec::F32,
        };
        let mut st = NodeState::new(vec![1.0, 3.0], vec![0.0; 2], Pcg64::seed(1));
        let mut scratch = MergeScratch::new(2);
        scratch.snapshot.copy_from_slice(&[3.0, -1.0]);
        let mut rng = Pcg64::seed(9);
        policy.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
        assert_eq!(st.params, vec![2.0, 1.0]);
        assert_eq!(scratch.publish, vec![2.0, 1.0]);
        assert_eq!(scratch.cross, scratch.publish);
    }

    #[test]
    fn decode_into_matches_decode_in_place_on_both_codecs() {
        let remote: Vec<f32> = (0..300).map(|i| i as f32 * 1e-4).collect();
        let reference: Vec<f32> = remote.iter().map(|v| v + 0.01).collect();
        for codec in [WireCodec::F32, WireCodec::Lattice { bits: 8, eps: 1e-3 }] {
            let mut in_place = remote.clone();
            let want = codec.decode_in_place(&mut in_place, &reference, 11);
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let mut out = vec![0.0f32; remote.len()];
                let got = codec.decode_into(kernel, &remote, &reference, 11, &mut out);
                assert_eq!(out, in_place, "{codec:?} {kernel:?}");
                assert_eq!(got, want, "{codec:?} {kernel:?}");
            }
        }
    }

    #[test]
    fn merge_is_bit_identical_across_kernels() {
        // the same merge through scalar and simd scratches must agree
        // exactly — the property that lets replay executors select simd
        let (dim, n) = (67, 4); // not a multiple of the lane width
        let (backend, graph, cost) = ctx_fixture(dim, n);
        let ctx = StepCtx { backend: &backend, cost: &cost, graph: &graph, lr: 0.0, dim, n };
        let policy = PairwisePolicy {
            steps: LocalSteps::Fixed(1),
            merge: PairMerge::NonBlocking,
            wire: WireCodec::Lattice { bits: 8, eps: 1e-2 },
        };
        let params: Vec<f32> = (0..dim).map(|i| i as f32 * 1e-3).collect();
        let offer: Vec<f32> = params.iter().map(|v| v + 5e-3).collect();
        let mut results = Vec::new();
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let mut st = NodeState::new(params.clone(), vec![0.0; dim], Pcg64::seed(1));
            st.snap.copy_from_slice(&params);
            let mut scratch = MergeScratch::with_kernel(dim, kernel);
            scratch.snapshot.copy_from_slice(&offer);
            let mut rng = Pcg64::seed(9);
            let out = policy.merge(&ctx, 0, &mut st, &mut scratch, &mut rng);
            results.push((st.params.clone(), scratch.publish.clone(), out.bits));
        }
        assert_eq!(results[0], results[1]);
    }
}
