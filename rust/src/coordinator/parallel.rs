//! Shared-memory parallel SwarmSGD executor — Algorithm 2 executed, not
//! simulated.
//!
//! The serial [`super::SwarmRunner`] walks the paper's interaction sequence
//! one pairwise gossip at a time through a discrete-event loop. This module
//! runs the same process on N real worker threads over shared node state,
//! so "non-blocking pairwise averaging" is carried out by genuinely
//! concurrent interactions:
//!
//! * **Per-node state** lives in `Mutex<NodeState>`; an interaction locks
//!   only the endpoint it is currently updating.
//! * **Blocking mode (Alg. 1)** takes both endpoint locks in ascending node
//!   order (a global lock order, so rendezvous pairs cannot deadlock) and
//!   holds them across the whole interaction — the rendezvous semantics.
//! * **Non-blocking / quantized modes (Alg. 2 / Appendices F–G)** never hold
//!   two locks: each node's communication copy `X'` is published into a
//!   lock-free double-buffered [`CommSlot`] (seqlock: version counter +
//!   two buffers, flipped by an atomic), and partners read it without
//!   touching the owner's lock — the paper's "nobody waits" property.
//!
//! # Replay determinism
//!
//! A parallel run is **bit-identical** to a serial replay of the same seed,
//! by construction rather than by luck:
//!
//! 1. The whole interaction sequence (edges, local-step counts H_i, and
//!    quantizer seeds) is pre-drawn by [`Schedule::generate`] from a
//!    dedicated [`Pcg64::stream`] — it does not depend on execution order.
//! 2. All node-local randomness (gradient noise, compute-time jitter) comes
//!    from that node's own `Pcg64::stream`, consumed in the node's schedule
//!    order.
//! 3. Workers claim interactions from a global cursor but **commit in
//!    dependency order**: interaction t runs only after both endpoints have
//!    finished all of their earlier scheduled interactions. The dataflow
//!    DAG — and therefore every f32 operation and operand — is fixed by the
//!    schedule, so any thread interleaving computes the same bits.
//!
//! [`run_replay_serial`] executes the identical schedule in plain program
//! order; `tests/parallel_executor.rs` asserts metric-for-metric bit
//! equality against multi-threaded runs, and CI enforces it on every PR.
//!
//! Deadlock freedom: the blocking mode uses ordered two-lock acquisition;
//! the dependency wait cannot cycle because the lowest unfinished schedule
//! index always has both dependencies satisfied (induction over t).

use super::cluster::{average_into_both, nonblocking_update, quantized_transfer};
use super::engine::NodeClocks;
use super::metrics::{CurvePoint, RunMetrics};
use super::swarm::{AveragingMode, LocalSteps, SwarmConfig};
use crate::analysis::gamma_potential;
use crate::backend::SyncBackend;
use crate::netmodel::CostModel;
use crate::rngx::Pcg64;
use crate::topology::Graph;
use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Stream tags for the executor's deterministic sub-RNGs (arbitrary,
/// distinct; node streams use `STREAM_NODE_BASE + node`).
const STREAM_SCHEDULE: u64 = 0x5EED_5C8E_D01E_0001;
const STREAM_EVAL: u64 = 0x5EED_E7A1_0000_0002;
const STREAM_NODE_BASE: u64 = 0x5EED_40DE_0000_0003;

/// One pre-drawn pairwise interaction of the global schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interaction {
    /// initiator endpoint (pays the exchange in non-blocking modes)
    pub i: usize,
    /// partner endpoint
    pub j: usize,
    /// local-step counts for each endpoint
    pub hi: u64,
    pub hj: u64,
    /// lattice-quantizer seeds for the i←j and j←i transfers
    pub seed_ij: u32,
    pub seed_ji: u32,
    /// this is endpoint i's `seq_i`-th interaction (0-based) — the
    /// dependency token workers wait on
    pub seq_i: u64,
    pub seq_j: u64,
}

/// The full pre-drawn interaction sequence of one run. Everything stochastic
/// about *who* interacts, *how many* local steps they take, and *which*
/// quantizer hashes they use is fixed here, before any thread starts — the
/// first pillar of the replay-determinism contract.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub steps: Vec<Interaction>,
    /// total interactions per node (seq_* counters end at these values)
    pub per_node: Vec<u64>,
}

impl Schedule {
    pub fn generate(cfg: &SwarmConfig, graph: &Graph) -> Self {
        let mut rng = Pcg64::stream(cfg.seed, STREAM_SCHEDULE);
        let mut per_node = vec![0u64; cfg.n];
        let mut steps = Vec::with_capacity(cfg.interactions as usize);
        for _ in 0..cfg.interactions {
            let (i, j) = graph.sample_edge(&mut rng);
            let (hi, hj) = match cfg.local_steps {
                LocalSteps::Fixed(h) => (h, h),
                LocalSteps::Geometric(h) => (rng.geometric(h), rng.geometric(h)),
            };
            let seed_ij = rng.next_u32();
            let seed_ji = rng.next_u32();
            steps.push(Interaction {
                i,
                j,
                hi,
                hj,
                seed_ij,
                seed_ji,
                seq_i: per_node[i],
                seq_j: per_node[j],
            });
            per_node[i] += 1;
            per_node[j] += 1;
        }
        Self { steps, per_node }
    }
}

/// Lock-free double-buffered communication-copy slot (seqlock).
///
/// In this executor the per-node dependency order guarantees a slot is
/// never written while being read (a node has at most one enabled
/// interaction, which is the only writer, and readers are interactions of
/// the partner — also serialized against it). The seqlock protocol is
/// defense in depth for that invariant breaking (e.g. a future
/// free-running mode): writers mark the version odd, fill the inactive
/// buffer, then flip; readers copy and retry on any version change, with
/// fences ordering the buffer accesses against the version stores.
struct CommSlot {
    /// odd = write in progress; `(seq >> 1) & 1` = active buffer index
    seq: AtomicU64,
    buf: [UnsafeCell<Vec<f32>>; 2],
}

// Safety: buffer contents are only written by the slot's unique active
// interaction (dependency order) and reads validate the version counter
// around the copy; the atomic `seq` stores/fences provide the necessary
// release/acquire edges.
unsafe impl Sync for CommSlot {}

impl CommSlot {
    fn new(init: &[f32]) -> Self {
        Self {
            seq: AtomicU64::new(0),
            buf: [UnsafeCell::new(init.to_vec()), UnsafeCell::new(init.to_vec())],
        }
    }

    /// Publish a fresh communication copy (caller is the node's unique
    /// enabled interaction).
    fn publish(&self, data: &[f32]) {
        let s = self.seq.load(Ordering::Relaxed);
        debug_assert_eq!(s & 1, 0, "concurrent CommSlot writers");
        self.seq.store(s.wrapping_add(1), Ordering::Relaxed);
        // buffer writes must not become visible before the odd mark
        fence(Ordering::SeqCst);
        let idx = (((s >> 1) + 1) & 1) as usize;
        unsafe { (*self.buf[idx].get()).copy_from_slice(data) };
        self.seq.store(s.wrapping_add(2), Ordering::Release);
    }

    /// Copy the current communication copy into `out` (lock-free).
    fn read_into(&self, out: &mut [f32]) {
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let idx = ((s1 >> 1) & 1) as usize;
            out.copy_from_slice(unsafe { &*self.buf[idx].get() });
            // the copy must complete before the validating re-read
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                return;
            }
        }
    }
}

/// Everything one simulated node owns. Guarded by its own mutex; the
/// simulated clock and cost totals live here too, so the hot path touches
/// no shared mutable accounting (merged once, in node-index order, at the
/// end — keeping f64 sums replay-exact).
struct NodeState {
    params: Vec<f32>,
    mom: Vec<f32>,
    /// communication copy X' (also mirrored into the lock-free slot)
    comm: Vec<f32>,
    /// snapshot S of `params` taken before the current local phase
    snap: Vec<f32>,
    /// per-node stream: gradient noise + compute-time jitter
    rng: Pcg64,
    steps: u64,
    interactions: u64,
    last_loss: f64,
    /// simulated clock (seconds)
    time: f64,
    compute: f64,
    comm_time: f64,
}

/// Shared run state visible to every worker.
struct Shared<'a, B: SyncBackend + ?Sized> {
    backend: &'a B,
    cost: &'a CostModel,
    cfg: &'a SwarmConfig,
    schedule: &'a [Interaction],
    nodes: Vec<Mutex<NodeState>>,
    slots: Vec<CommSlot>,
    /// completed-interaction count per node (the dependency tokens)
    done: Vec<AtomicU64>,
    /// global schedule cursor (next unclaimed interaction index)
    cursor: AtomicU64,
    bits: AtomicU64,
    fallbacks: AtomicU64,
    /// set when a worker panics so dependency spins stay live
    abort: AtomicBool,
    dim: usize,
}

/// Flags `abort` if the owning thread unwinds, so sibling workers spinning
/// on a dependency from the dead thread exit instead of hanging.
struct AbortGuard<'a>(&'a AtomicBool);

impl Drop for AbortGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

/// Run SwarmSGD on `threads` worker threads over shared node state.
///
/// Evaluation points are chunk barriers: workers drain the schedule up to
/// each milestone (multiples of `eval_every`, plus the end), then the
/// calling thread records a [`CurvePoint`] exactly as the serial runner
/// would. `threads == 1` degenerates to the serial replay path.
pub fn run_parallel<B: SyncBackend + ?Sized>(
    cfg: &SwarmConfig,
    threads: usize,
    graph: &Graph,
    cost: &CostModel,
    backend: &B,
    eval_every: u64,
    track_gamma: bool,
) -> RunMetrics {
    run_schedule(cfg, threads.max(1), graph, cost, backend, eval_every, track_gamma, "parallel")
}

/// Serially replay the exact schedule a parallel run with the same
/// [`SwarmConfig`] executes. Metrics are bit-identical to [`run_parallel`]
/// at any thread count — the executor's testable oracle.
pub fn run_replay_serial<B: SyncBackend + ?Sized>(
    cfg: &SwarmConfig,
    graph: &Graph,
    cost: &CostModel,
    backend: &B,
    eval_every: u64,
    track_gamma: bool,
) -> RunMetrics {
    run_schedule(cfg, 1, graph, cost, backend, eval_every, track_gamma, "serial-replay")
}

#[allow(clippy::too_many_arguments)]
fn run_schedule<B: SyncBackend + ?Sized>(
    cfg: &SwarmConfig,
    threads: usize,
    graph: &Graph,
    cost: &CostModel,
    backend: &B,
    eval_every: u64,
    track_gamma: bool,
    label: &str,
) -> RunMetrics {
    assert!(cfg.n >= 2, "gossip needs n >= 2");
    assert_eq!(cfg.n, graph.n(), "config n must match graph");
    let schedule = Schedule::generate(cfg, graph);
    let dim = backend.dim();
    let (p0, m0) = backend.common_init();
    assert_eq!(p0.len(), dim, "backend dim() must match its init vector");
    let nodes: Vec<Mutex<NodeState>> = (0..cfg.n)
        .map(|k| {
            Mutex::new(NodeState {
                params: p0.clone(),
                mom: m0.clone(),
                comm: p0.clone(),
                snap: vec![0.0; dim],
                rng: Pcg64::stream(cfg.seed, STREAM_NODE_BASE + k as u64),
                steps: 0,
                interactions: 0,
                last_loss: f64::NAN,
                time: 0.0,
                compute: 0.0,
                comm_time: 0.0,
            })
        })
        .collect();
    let sh = Shared {
        backend,
        cost,
        cfg,
        schedule: &schedule.steps,
        nodes,
        slots: (0..cfg.n).map(|_| CommSlot::new(&p0)).collect(),
        done: (0..cfg.n).map(|_| AtomicU64::new(0)).collect(),
        cursor: AtomicU64::new(0),
        bits: AtomicU64::new(0),
        fallbacks: AtomicU64::new(0),
        abort: AtomicBool::new(false),
        dim,
    };
    let mut eval_rng = Pcg64::stream(cfg.seed, STREAM_EVAL);
    let mut m = RunMetrics::new(&cfg.name);
    if threads == 1 {
        let mut inc_i = vec![0.0f32; dim];
        let mut inc_j = vec![0.0f32; dim];
        for end in milestones(cfg.interactions, eval_every) {
            chunk_serial(&sh, end, &mut inc_i, &mut inc_j);
            record_point(&sh, end, &mut eval_rng, track_gamma, &mut m);
        }
    } else {
        for end in milestones(cfg.interactions, eval_every) {
            chunk_parallel(&sh, end, threads);
            record_point(&sh, end, &mut eval_rng, track_gamma, &mut m);
        }
    }
    let Shared { nodes, bits, fallbacks, .. } = sh;
    let states: Vec<NodeState> = nodes
        .into_iter()
        .map(|n| n.into_inner().expect("node lock poisoned"))
        .collect();
    let clocks = NodeClocks::from_parts(
        states.iter().map(|s| s.time).collect(),
        states.iter().map(|s| s.compute).sum(),
        states.iter().map(|s| s.comm_time).sum(),
    );
    m.interactions = cfg.interactions;
    m.local_steps = states.iter().map(|s| s.steps).sum();
    m.sim_time = clocks.max_time();
    m.compute_time_total = clocks.compute_total;
    m.comm_time_total = clocks.comm_total;
    m.total_bits = bits.into_inner();
    m.quant_fallbacks = fallbacks.into_inner();
    m.executor = label.to_string();
    m.threads = threads;
    if let Some(p) = m.curve.last() {
        m.final_eval_loss = p.eval_loss;
        m.final_eval_acc = p.eval_acc;
    }
    m
}

/// Chunk ends: every multiple of `eval_every` in `(0, total)`, then `total`
/// (matching the serial runner's `at_eval || t == total` cadence).
fn milestones(total: u64, eval_every: u64) -> Vec<u64> {
    let mut v = Vec::new();
    if total == 0 {
        return v;
    }
    if eval_every > 0 {
        let mut next = eval_every;
        while next < total {
            v.push(next);
            next += eval_every;
        }
    }
    v.push(total);
    v
}

/// Drain schedule indices `[cursor, end)` on `threads` scoped workers.
fn chunk_parallel<B: SyncBackend + ?Sized>(sh: &Shared<'_, B>, end: u64, threads: usize) {
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _guard = AbortGuard(&sh.abort);
                let mut inc_i = vec![0.0f32; sh.dim];
                let mut inc_j = vec![0.0f32; sh.dim];
                loop {
                    let t = sh.cursor.fetch_add(1, Ordering::Relaxed);
                    if t >= end {
                        break;
                    }
                    let it = sh.schedule[t as usize];
                    if !wait_deps(sh, &it) {
                        break;
                    }
                    execute_interaction(sh, t, &it, &mut inc_i, &mut inc_j);
                    // this worker is the unique owner of both endpoints here
                    sh.done[it.i].store(it.seq_i + 1, Ordering::Release);
                    sh.done[it.j].store(it.seq_j + 1, Ordering::Release);
                }
            });
        }
    });
    // indices over-claimed past `end` were abandoned; hand them to the
    // next chunk
    sh.cursor.store(end, Ordering::Relaxed);
}

/// The `threads == 1` replay path: plain program order, no spawning.
fn chunk_serial<B: SyncBackend + ?Sized>(
    sh: &Shared<'_, B>,
    end: u64,
    inc_i: &mut [f32],
    inc_j: &mut [f32],
) {
    loop {
        let t = sh.cursor.load(Ordering::Relaxed);
        if t >= end {
            break;
        }
        sh.cursor.store(t + 1, Ordering::Relaxed);
        let it = sh.schedule[t as usize];
        // program order trivially satisfies the dependency order
        execute_interaction(sh, t, &it, inc_i, inc_j);
        sh.done[it.i].store(it.seq_i + 1, Ordering::Relaxed);
        sh.done[it.j].store(it.seq_j + 1, Ordering::Relaxed);
    }
}

/// Spin until both endpoints of `it` have completed all earlier scheduled
/// interactions. Returns false if the run is aborting (sibling panic).
fn wait_deps<B: SyncBackend + ?Sized>(sh: &Shared<'_, B>, it: &Interaction) -> bool {
    let mut spins = 0u32;
    while sh.done[it.i].load(Ordering::Acquire) != it.seq_i
        || sh.done[it.j].load(Ordering::Acquire) != it.seq_j
    {
        if sh.abort.load(Ordering::Relaxed) {
            return false;
        }
        spins = spins.wrapping_add(1);
        if spins % 64 == 0 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
    }
    true
}

/// Execute one scheduled interaction (both endpoints), per the configured
/// averaging mode. `t` is the 0-based schedule index.
fn execute_interaction<B: SyncBackend + ?Sized>(
    sh: &Shared<'_, B>,
    t: u64,
    it: &Interaction,
    inc_i: &mut [f32],
    inc_j: &mut [f32],
) {
    // the serial runner numbers interactions from 1
    let lr = sh.cfg.lr.at(t + 1);
    let full_bytes = sh.cost.wire_bytes(sh.dim);
    match sh.cfg.mode {
        AveragingMode::Blocking => {
            // ordered two-lock acquisition: ascending node index
            let (lo, hi) = (it.i.min(it.j), it.i.max(it.j));
            let mut g_lo = sh.nodes[lo].lock().expect("node lock poisoned");
            let mut g_hi = sh.nodes[hi].lock().expect("node lock poisoned");
            let (ni, nj) = if lo == it.i {
                (&mut *g_lo, &mut *g_hi)
            } else {
                (&mut *g_hi, &mut *g_lo)
            };
            local_phase(sh.backend, sh.cost, it.i, ni, lr, it.hi);
            local_phase(sh.backend, sh.cost, it.j, nj, lr, it.hj);
            average_into_both(&mut ni.params, &mut nj.params);
            ni.comm.copy_from_slice(&ni.params);
            nj.comm.copy_from_slice(&nj.params);
            sh.slots[it.i].publish(&ni.comm);
            sh.slots[it.j].publish(&nj.comm);
            // rendezvous: both wait for the later endpoint, both pay the NIC
            let exch = sh.cost.exchange_time(full_bytes);
            let done = ni.time.max(nj.time) + exch;
            ni.time = done;
            nj.time = done;
            ni.comm_time += exch;
            nj.comm_time += exch;
            ni.interactions += 1;
            nj.interactions += 1;
            sh.bits.fetch_add(2 * 8 * full_bytes, Ordering::Relaxed);
        }
        mode => {
            // --- local phases, each endpoint under its own lock only ---
            {
                let mut g = sh.nodes[it.i].lock().expect("node lock poisoned");
                local_phase(sh.backend, sh.cost, it.i, &mut g, lr, it.hi);
            }
            {
                let mut g = sh.nodes[it.j].lock().expect("node lock poisoned");
                local_phase(sh.backend, sh.cost, it.j, &mut g, lr, it.hj);
            }
            // --- read both communication copies BEFORE either update
            // (matches the serial runner); lock-free seqlock reads ---
            sh.slots[it.j].read_into(inc_i); // incoming for i: X'_j
            sh.slots[it.i].read_into(inc_j); // incoming for j: X'_i
            let quant = match mode {
                AveragingMode::Quantized { bits, eps } => Some((bits, eps)),
                _ => None,
            };
            // --- endpoint updates: nobody ever takes the partner's lock.
            // j first, so i's guard can also absorb the initiator's
            // exchange charge (which needs both wire-bit counts) without a
            // third lock acquisition on the hot path ---
            let wire_j = {
                let mut g = sh.nodes[it.j].lock().expect("node lock poisoned");
                endpoint_update(sh, it.j, &mut g, inc_j, quant, it.seed_ji)
            };
            let add_bits = {
                let mut g = sh.nodes[it.i].lock().expect("node lock poisoned");
                let st = &mut *g;
                let wire = wire_j + endpoint_update(sh, it.i, st, inc_i, quant, it.seed_ij);
                // time/bit accounting: the initiator pays the exchange
                let (exch, add_bits) = match quant {
                    None => (sh.cost.exchange_time(full_bytes), 2 * 8 * full_bytes),
                    Some(_) => {
                        let wire_bits = sh.cost.scale_bits(wire, sh.dim);
                        (sh.cost.exchange_time(wire_bits.div_ceil(8)), wire_bits)
                    }
                };
                st.time += exch;
                st.comm_time += exch;
                add_bits
            };
            sh.bits.fetch_add(add_bits, Ordering::Relaxed);
        }
    }
}

/// One endpoint's local-SGD phase: snapshot S, run `h` steps drawing all
/// randomness from the node's own stream, charge compute time.
fn local_phase<B: SyncBackend + ?Sized>(
    backend: &B,
    cost: &CostModel,
    agent: usize,
    st: &mut NodeState,
    lr: f32,
    h: u64,
) {
    st.snap.copy_from_slice(&st.params);
    let mut last = f64::NAN;
    for _ in 0..h {
        last = backend.step_with(agent, &mut st.params, &mut st.mom, lr, &mut st.rng);
    }
    st.last_loss = last;
    st.steps += h;
    let mut comp = 0.0;
    for _ in 0..h {
        comp += cost.compute_time(&mut st.rng);
    }
    st.time += comp;
    st.compute += comp;
}

/// Apply the Appendix-F update to one endpoint (caller holds its lock):
/// optional lattice decode of the incoming copy against the node's
/// snapshot, the averaging rule, then publish the fresh communication
/// copy. Returns wire bits consumed (0 when not quantizing).
fn endpoint_update<B: SyncBackend + ?Sized>(
    sh: &Shared<'_, B>,
    node: usize,
    st: &mut NodeState,
    inc: &mut [f32],
    quant: Option<(u32, f32)>,
    seed: u32,
) -> u64 {
    let mut wire = 0u64;
    if let Some((bits, eps)) = quant {
        let tr = quantized_transfer(inc, &st.snap, eps, bits, seed);
        wire = tr.bits;
        if tr.fell_back {
            sh.fallbacks.fetch_add(1, Ordering::Relaxed);
        }
        inc.copy_from_slice(&tr.decoded);
    }
    nonblocking_update(&mut st.params, &mut st.comm, &st.snap, inc);
    sh.slots[node].publish(&st.comm);
    st.interactions += 1;
    wire
}

/// Record a curve point at a chunk barrier (no workers active). Mirrors the
/// serial runner's bookkeeping: μ_t in f64 node-index order, an eval-stream
/// individual pick, Γ_t on demand.
fn record_point<B: SyncBackend + ?Sized>(
    sh: &Shared<'_, B>,
    t: u64,
    eval_rng: &mut Pcg64,
    track_gamma: bool,
    m: &mut RunMetrics,
) {
    let guards: Vec<std::sync::MutexGuard<'_, NodeState>> =
        sh.nodes.iter().map(|n| n.lock().expect("node lock poisoned")).collect();
    let n = guards.len();
    let mut acc = vec![0.0f64; sh.dim];
    for g in &guards {
        for (s, &v) in acc.iter_mut().zip(&g.params) {
            *s += v as f64;
        }
    }
    let mu: Vec<f32> = acc.into_iter().map(|v| (v / n as f64) as f32).collect();
    let ev = sh.backend.eval_at(&mu);
    let pick = eval_rng.below_usize(n);
    let ind = sh.backend.eval_at(&guards[pick].params);
    let gamma = if track_gamma {
        let models: Vec<Vec<f32>> = guards.iter().map(|g| g.params.clone()).collect();
        gamma_potential(&models)
    } else {
        f64::NAN
    };
    let finite: Vec<f64> =
        guards.iter().map(|g| g.last_loss).filter(|l| l.is_finite()).collect();
    let train_loss = if finite.is_empty() {
        f64::NAN
    } else {
        finite.iter().sum::<f64>() / finite.len() as f64
    };
    let sim_time = guards.iter().map(|g| g.time).fold(0.0, f64::max);
    m.push(CurvePoint {
        t,
        parallel_time: t as f64 / n as f64,
        sim_time,
        epochs: 0.0,
        train_loss,
        eval_loss: ev.loss,
        eval_acc: ev.accuracy,
        indiv_loss: ind.loss,
        gamma,
        bits: sh.bits.load(Ordering::Relaxed),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LrSchedule;
    use crate::grad::QuadraticOracle;
    use crate::topology::Topology;

    fn quad(n: usize, dim: usize, sigma: f64) -> QuadraticOracle {
        QuadraticOracle::new(dim, n, 1.0, 0.5, 2.0, sigma, 11)
    }

    fn cfg(n: usize, t: u64, mode: AveragingMode) -> SwarmConfig {
        SwarmConfig {
            n,
            local_steps: LocalSteps::Fixed(2),
            mode,
            lr: LrSchedule::Constant(0.05),
            interactions: t,
            seed: 9,
            name: "par".into(),
        }
    }

    fn graph(n: usize) -> Graph {
        let mut rng = Pcg64::seed(5);
        Graph::build(Topology::Complete, n, &mut rng)
    }

    #[test]
    fn schedule_is_deterministic_and_sequenced() {
        let c = cfg(8, 500, AveragingMode::NonBlocking);
        let g = graph(8);
        let a = Schedule::generate(&c, &g);
        let b = Schedule::generate(&c, &g);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.per_node, b.per_node);
        // seq tokens count each node's interactions in order
        let mut seen = vec![0u64; 8];
        for it in &a.steps {
            assert_ne!(it.i, it.j);
            assert_eq!(it.seq_i, seen[it.i]);
            assert_eq!(it.seq_j, seen[it.j]);
            seen[it.i] += 1;
            seen[it.j] += 1;
        }
        assert_eq!(seen, a.per_node);
        assert_eq!(seen.iter().sum::<u64>(), 1000);
    }

    #[test]
    fn comm_slot_roundtrip_flips_buffers() {
        let s = CommSlot::new(&[1.0, 2.0]);
        let mut out = vec![0.0f32; 2];
        s.read_into(&mut out);
        assert_eq!(out, [1.0, 2.0]);
        s.publish(&[3.0, 4.0]);
        s.read_into(&mut out);
        assert_eq!(out, [3.0, 4.0]);
        s.publish(&[5.0, 6.0]);
        s.read_into(&mut out);
        assert_eq!(out, [5.0, 6.0]);
    }

    fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics) {
        assert_eq!(a.curve.len(), b.curve.len());
        for (pa, pb) in a.curve.iter().zip(&b.curve) {
            assert_eq!(pa.t, pb.t);
            assert_eq!(pa.eval_loss.to_bits(), pb.eval_loss.to_bits(), "t={}", pa.t);
            assert_eq!(pa.train_loss.to_bits(), pb.train_loss.to_bits());
            assert_eq!(pa.indiv_loss.to_bits(), pb.indiv_loss.to_bits());
            assert_eq!(pa.gamma.to_bits(), pb.gamma.to_bits());
            assert_eq!(pa.sim_time.to_bits(), pb.sim_time.to_bits());
            assert_eq!(pa.bits, pb.bits);
        }
        assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.quant_fallbacks, b.quant_fallbacks);
        assert_eq!(a.local_steps, b.local_steps);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(a.compute_time_total.to_bits(), b.compute_time_total.to_bits());
        assert_eq!(a.comm_time_total.to_bits(), b.comm_time_total.to_bits());
    }

    #[test]
    fn parallel_matches_serial_replay_all_modes() {
        let n = 8;
        for mode in [
            AveragingMode::NonBlocking,
            AveragingMode::Blocking,
            AveragingMode::Quantized { bits: 8, eps: 1e-2 },
        ] {
            let c = cfg(n, 400, mode);
            let g = graph(n);
            let backend = quad(n, 16, 0.1);
            let cost = CostModel::deterministic(0.4);
            let serial = run_replay_serial(&c, &g, &cost, &backend, 100, true);
            for threads in [2, 4] {
                let par = run_parallel(&c, threads, &g, &cost, &backend, 100, true);
                assert_bit_identical(&serial, &par);
            }
        }
    }

    #[test]
    fn replay_converges_on_quadratic() {
        let n = 8;
        let backend = quad(n, 16, 0.1);
        let f_star = backend.f_star();
        let gap0 = {
            let (p, _) = backend.common_init();
            backend.eval_at(&p).loss - f_star
        };
        let c = cfg(n, 800, AveragingMode::NonBlocking);
        let g = graph(n);
        let cost = CostModel::deterministic(0.4);
        let m = run_replay_serial(&c, &g, &cost, &backend, 100, false);
        let gap = (m.final_eval_loss - f_star) / gap0;
        assert!(gap < 0.1, "normalized gap {gap}");
        assert_eq!(m.interactions, 800);
        assert_eq!(m.local_steps, 800 * 2 * 2);
        assert!(m.sim_time > 0.0);
        assert_eq!(m.executor, "serial-replay");
    }

    #[test]
    fn milestones_cadence_matches_serial_runner() {
        assert_eq!(milestones(10, 0), vec![10]);
        assert_eq!(milestones(10, 4), vec![4, 8, 10]);
        assert_eq!(milestones(8, 4), vec![4, 8]);
        assert!(milestones(0, 4).is_empty());
    }
}
