//! The free-running executor (`--executor freerun`): OS-thread workers
//! over node *shards*, live Poisson clocks, and non-blocking model slots.
//!
//! The two replay executors ([`super::run_serial`] / [`super::run_parallel`])
//! drain a pre-drawn schedule, which makes them bit-replayable — and makes
//! it impossible for them to *measure* the thing the paper actually claims:
//! that non-blocking gossip wins on wall-clock because nobody ever waits.
//! This executor drops the schedule entirely:
//!
//! * **Sharded workers** — `n` nodes are partitioned into `S` shards and
//!   the shards are dealt round-robin to `K` OS threads, so `n ≫ cores`
//!   runs without one-thread-per-node. A worker *owns* its nodes outright
//!   (no locks on node state, ever); everything cross-worker flows through
//!   the slots.
//! * **Live Poisson clocks** — each worker keeps a clock heap over its own
//!   nodes (exponential inter-arrival at the node's [`Scenario`] rate;
//!   rate 1 under uniform speeds, the paper's §2 model). When a node
//!   rings, the worker picks a uniform random neighbor in the scenario's
//!   active graph stage *at that moment* and runs the interaction —
//!   partners are chosen on the fly, not replayed. Each worker executes
//!   an event quota proportional to the nodes it owns, so per-node
//!   initiation rates follow the scenario's speed model even when the
//!   shard deal is uneven or workers run at different speeds.
//! * **Non-blocking model slots** — every node publishes its
//!   [`SlotPayload`] into a seqlock-style versioned double buffer
//!   (`ModelSlot`, generic over the payload: [`PlainModel`] snapshots
//!   for the pairwise policies, push-sum `(x, w)` [`PushSumWeighted`]
//!   pairs for SGP). An initiator seqlock-reads the partner's slot (a
//!   possibly-stale snapshot; the partner is **never** delayed), hands it
//!   to its algorithm's [`MixPolicy`] — which decodes the model lanes
//!   through its [`WireCodec`](super::WireCodec) (`--wire lattice|f32`),
//!   applies its merge rule, and produces two payloads: one republished
//!   into the initiator's own slot, one best-effort cross-written into
//!   the partner's slot (the pair average under the symmetric policies —
//!   Algorithm 2's X' update — or the remaining half-offer under
//!   push-sum's take-half flow). If the cross-write CAS loses a race it
//!   is *dropped and counted*, not waited on. Policies whose cross-writes
//!   mutate the published value (push-sum) re-absorb their own slot at
//!   ring time, so the slot is the canonical pair between rings.
//!
//! # Contract split
//!
//! `serial`/`parallel` are **bit-replayable**; `freerun` is
//! **throughput-faithful but non-replayable** — thread interleaving is real,
//! so two runs of the same seed differ in the bits. Tests against this
//! executor must be statistical (tolerance-based convergence, telemetry
//! invariants), never bit-equality. What freerun gives back is telemetry
//! the replay executors cannot produce ([`super::telemetry`]): real
//! interactions/sec, per-interaction staleness (version-lag) histograms,
//! seqlock retry counts, per-worker busy/wait splits, and the codec's
//! wire-bit/fallback attribution, surfaced in [`RunMetrics::freerun`].
//!
//! Only algorithms with free-running semantics run here — those return a
//! [`MixPolicy`] from [`Algorithm::mix_policy`]: the pairwise-mixing
//! algorithms (`swarm`, `poisson`, `adpsgd`, `dpsgd`) over plain-model
//! slots, and — since the `MixPolicy` redesign — `sgp` over weighted
//! `(x, w)` slots. Baselines whose mixing is an irreducible global mean
//! (`localsgd`, `allreduce`) refuse.

use super::algorithm::{Algorithm, NodeState, StepCtx};
use super::executor::{milestones, RunSpec};
use super::metrics::{CurvePoint, RunMetrics};
use super::policy::{MergeScratch, MixPolicy, PayloadKind, PlainModel, PushSumWeighted, SlotPayload};
use super::telemetry::{FreerunStats, StalenessHistogram, WorkerActivity};
use super::LrSchedule;
use crate::analysis::gamma_potential;
use crate::backend::Backend;
use crate::netmodel::CostModel;
use crate::obs::{self, ObsOptions, Sampler, SpanKind, TraceDrain, TraceRing};
use crate::rngx::Pcg64;
use crate::scenario::Scenario;
use crate::topology::Graph;
use std::cell::UnsafeCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::time::Instant;

/// Stream tags for the executor's sub-RNGs (disjoint from the replay
/// executors' tags; worker streams use `STREAM_WORKER_BASE + worker`).
const STREAM_EVAL: u64 = 0x5EED_F4EE_0000_0001;
const STREAM_WORKER_BASE: u64 = 0x5EED_F4EE_0000_0010;
const STREAM_NODE_BASE: u64 = 0x5EED_F4EE_0000_1000;

/// Seqlock-style versioned double buffer holding one node's published
/// [`SlotPayload`] plus the global interaction count at publish time (the
/// staleness stamp). Generic over the payload, so the slot layout (plain
/// `dim`-lane models vs `dim + 1`-lane push-sum pairs) is part of the
/// policy contract rather than a hardcoded model snapshot. Readers never
/// block writers and vice versa; multiple writers are arbitrated by a CAS
/// on the odd bit, and the best-effort cross-write path simply gives up
/// (and is counted) when it loses that race. Crate-visible: the cluster
/// executor ([`crate::cluster`]) reuses the same slot for its per-process
/// node mirrors, so in-process and cross-process gossip share one
/// publish/read protocol.
pub(crate) struct ModelSlot<P: SlotPayload> {
    /// odd = write in progress; `(seq >> 1) & 1` = active buffer index
    seq: AtomicU64,
    buf: [UnsafeCell<Vec<f32>>; 2],
    /// global interaction count at publish, aligned with `buf`
    stamp: [AtomicU64; 2],
    _payload: PhantomData<P>,
}

// Safety: a buffer is only written while the writer holds the odd seq mark
// (exclusive via compare_exchange), and readers validate the version
// counter around their copy, retrying on any change; the seq stores and
// fences provide the release/acquire edges. Same protocol as PR 1's
// CommSlot, extended with CAS writer arbitration and a publish stamp.
unsafe impl<P: SlotPayload> Sync for ModelSlot<P> {}

impl<P: SlotPayload> ModelSlot<P> {
    /// Slot initialized with the payload encoding of the common init model
    /// (push-sum weight 1).
    pub(crate) fn new(params: &[f32]) -> Self {
        let mut lanes = vec![0.0f32; P::lanes(params.len())];
        P::encode(params, 1.0, &mut lanes);
        Self {
            seq: AtomicU64::new(0),
            buf: [UnsafeCell::new(lanes.clone()), UnsafeCell::new(lanes)],
            stamp: [AtomicU64::new(0), AtomicU64::new(0)],
            _payload: PhantomData,
        }
    }

    /// One publish attempt; false if another writer holds the slot.
    pub(crate) fn try_publish(&self, data: &[f32], stamp: u64) -> bool {
        let s = self.seq.load(Ordering::Relaxed);
        if s & 1 == 1 {
            return false;
        }
        if self
            .seq
            .compare_exchange(s, s.wrapping_add(1), Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return false;
        }
        let idx = (((s >> 1) + 1) & 1) as usize;
        unsafe { (*self.buf[idx].get()).copy_from_slice(data) };
        self.stamp[idx].store(stamp, Ordering::Relaxed);
        self.seq.store(s.wrapping_add(2), Ordering::Release);
        true
    }

    /// Publish, spinning out any concurrent cross-writer (owners must
    /// succeed). Returns the CAS retries burned.
    pub(crate) fn publish(&self, data: &[f32], stamp: u64) -> u64 {
        let mut retries = 0;
        while !self.try_publish(data, stamp) {
            retries += 1;
            std::hint::spin_loop();
        }
        retries
    }

    /// Seqlock read of the current payload into `out`; returns the publish
    /// stamp and the retries burned racing concurrent writes.
    pub(crate) fn read_into(&self, out: &mut [f32]) -> (u64, u64) {
        let mut retries = 0;
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                retries += 1;
                std::hint::spin_loop();
                continue;
            }
            let idx = ((s1 >> 1) & 1) as usize;
            out.copy_from_slice(unsafe { &*self.buf[idx].get() });
            let stamp = self.stamp[idx].load(Ordering::Relaxed);
            // the copy must complete before the validating re-read
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                return (stamp, retries);
            }
            retries += 1;
        }
    }
}

/// Shared run state visible to every worker and the evaluation monitor.
struct FreeShared<'a, P: SlotPayload> {
    backend: &'a dyn Backend,
    cost: &'a CostModel,
    scn: &'a Scenario,
    lr: LrSchedule,
    policy: &'a dyn MixPolicy,
    /// fused merge-kernel implementation every worker's scratch dispatches to
    kernel: crate::kernels::Kernel,
    slots: Vec<ModelSlot<P>>,
    /// next unclaimed global event index
    claimed: AtomicU64,
    /// completed interactions — the staleness clock
    done: AtomicU64,
    bits: AtomicU64,
    fallbacks: AtomicU64,
    total: u64,
    dim: usize,
    n: usize,
    /// live-metrics sinks, allocated only when `--metrics-out` is active
    /// (the hot loop pays one branch when absent)
    live: Option<LiveStats>,
}

/// Shared wait-free sinks the workers publish into when live metrics are
/// on: a log2 staleness histogram for p50/p99 gauges plus contention
/// counters. The *exact* per-worker [`StalenessHistogram`]s still merge at
/// join — this is the coarser live view, not a replacement.
#[derive(Default)]
struct LiveStats {
    staleness: obs::AtomicHistogram,
    read_retries: AtomicU64,
    publish_retries: AtomicU64,
    push_conflicts: AtomicU64,
}

/// f64-ordered clock-heap entry (same shape as the Poisson scheduler's).
#[derive(PartialEq)]
struct Tick {
    at: f64,
    /// index into the worker's owned-node vector
    ix: usize,
}

impl Eq for Tick {}
impl PartialOrd for Tick {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Tick {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.partial_cmp(&other.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// What one worker hands back at join time.
struct WorkerResult {
    states: Vec<(usize, NodeState)>,
    activity: WorkerActivity,
    read_retries: u64,
    publish_retries: u64,
    push_conflicts: u64,
    staleness: StalenessHistogram,
}

/// Periodic Prometheus snapshot writer for `--metrics-out`: run-level
/// series re-derived from the shared atomics and appended to the file at
/// [`obs::METRICS_CADENCE`] by the evaluation monitor thread.
struct FreerunMetricsExport {
    file: std::fs::File,
    registry: obs::MetricsRegistry,
    ips: obs::Gauge,
    p50: obs::Gauge,
    p99: obs::Gauge,
    interactions: obs::Counter,
    bits: obs::Counter,
    fallbacks: obs::Counter,
    read_retries: obs::Counter,
    publish_retries: obs::Counter,
    push_conflicts: obs::Counter,
    last: Instant,
    last_done: u64,
}

impl FreerunMetricsExport {
    fn create(path: &str) -> Result<FreerunMetricsExport, String> {
        let file = std::fs::File::create(path)
            .map_err(|e| format!("cannot create metrics file '{path}': {e}"))?;
        let registry = obs::MetricsRegistry::new();
        Ok(FreerunMetricsExport {
            ips: registry.gauge("swarm_interactions_per_sec", "throughput over the last cadence"),
            p50: registry.gauge("swarm_staleness_p50", "live staleness p50 (log2 bucket bound)"),
            p99: registry.gauge("swarm_staleness_p99", "live staleness p99 (log2 bucket bound)"),
            interactions: registry.counter("swarm_interactions_total", "interactions completed"),
            bits: registry.counter("swarm_wire_bits_total", "cumulative bits on the wire"),
            fallbacks: registry.counter("swarm_wire_fallbacks_total", "codec fallbacks"),
            read_retries: registry.counter("swarm_slot_read_retries_total", "seqlock read retries"),
            publish_retries: registry
                .counter("swarm_slot_publish_retries_total", "slot publish retries"),
            push_conflicts: registry
                .counter("swarm_push_conflicts_total", "cross-writes dropped to a held slot"),
            file,
            registry,
            last: Instant::now(),
            last_done: 0,
        })
    }

    /// Refresh the registry from the shared run state and append one
    /// snapshot, rate-limited to the cadence unless `force`d (final flush).
    fn tick<P: SlotPayload>(&mut self, sh: &FreeShared<'_, P>, force: bool) {
        if !force && self.last.elapsed() < obs::METRICS_CADENCE {
            return;
        }
        let now = Instant::now();
        let done = sh.done.load(Ordering::Relaxed);
        let dt = now.duration_since(self.last).as_secs_f64().max(1e-9);
        self.ips.set(done.saturating_sub(self.last_done) as f64 / dt);
        self.interactions.set(done);
        self.bits.set(sh.bits.load(Ordering::Relaxed));
        self.fallbacks.set(sh.fallbacks.load(Ordering::Relaxed));
        if let Some(lv) = &sh.live {
            self.p50.set(lv.staleness.quantile(0.5) as f64);
            self.p99.set(lv.staleness.quantile(0.99) as f64);
            self.read_retries.set(lv.read_retries.load(Ordering::Relaxed));
            self.publish_retries.set(lv.publish_retries.load(Ordering::Relaxed));
            self.push_conflicts.set(lv.push_conflicts.load(Ordering::Relaxed));
        }
        self.last = now;
        self.last_done = done;
        if let Err(e) = obs::metrics::append_snapshot(&mut self.file, &self.registry) {
            obs::log::warn("freerun", format_args!("metrics append failed: {e}"));
        }
    }
}

/// Run `spec.events` free-running gossip interactions on `threads` workers
/// over `shards` node shards (`--executor freerun --threads K --shards S`).
///
/// Non-replayable by contract (see the module docs); returns the usual
/// [`RunMetrics`] plus [`RunMetrics::freerun`] telemetry.
///
/// # Panics
///
/// Panics if the algorithm does not return a [`MixPolicy`] (baselines
/// whose mixing is an irreducible global mean — localsgd, allreduce —
/// have no free-running semantics). The CLI checks this up front.
pub fn run_freerun(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    graph: &Graph,
    cost: &CostModel,
    threads: usize,
    shards: usize,
) -> RunMetrics {
    run_freerun_with_obs(algo, backend, spec, graph, cost, threads, shards, &ObsOptions::default())
}

/// [`run_freerun`] with observability switches: per-worker trace rings
/// (drained into [`RunMetrics::trace`]) and periodic Prometheus snapshots
/// to `obs.metrics_out`. `ObsOptions::default()` is everything-off and
/// byte-for-byte the [`run_freerun`] hot path.
pub fn run_freerun_with_obs(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    graph: &Graph,
    cost: &CostModel,
    threads: usize,
    shards: usize,
    obs: &ObsOptions,
) -> RunMetrics {
    let scn = Scenario::static_graph(graph.clone());
    run_freerun_scenario(algo, backend, spec, &scn, cost, threads, shards, obs)
}

/// Scenario-aware free-running entry point: like [`run_freerun_with_obs`]
/// but taking the whole [`Scenario`] (topology stages, per-node speed
/// classes) instead of a single static graph. Partner draws honor the
/// graph stage active at each event's global index, and each node's
/// Poisson clock runs at its scenario rate, so speed classes turn into
/// *structural* stragglers: slow nodes ring less often, their slots go
/// stale, and the staleness histogram shows it. A uniform static-graph
/// scenario is byte-for-byte the [`run_freerun_with_obs`] hot path.
#[allow(clippy::too_many_arguments)]
pub fn run_freerun_scenario(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    scn: &Scenario,
    cost: &CostModel,
    threads: usize,
    shards: usize,
    obs: &ObsOptions,
) -> RunMetrics {
    let policy = algo.mix_policy().unwrap_or_else(|| {
        panic!(
            "--executor freerun requires a MixPolicy (freerun-eligible: swarm, \
             poisson, adpsgd, dpsgd, sgp); '{}' mixes through an irreducible \
             global mean",
            algo.name()
        )
    });
    // the slot machinery is monomorphized per payload layout
    match policy.payload() {
        PayloadKind::Plain => freerun_with::<PlainModel>(
            algo,
            policy.as_ref(),
            backend,
            spec,
            scn,
            cost,
            threads,
            shards,
            obs,
        ),
        PayloadKind::PushSumWeighted => freerun_with::<PushSumWeighted>(
            algo,
            policy.as_ref(),
            backend,
            spec,
            scn,
            cost,
            threads,
            shards,
            obs,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn freerun_with<P: SlotPayload>(
    algo: &dyn Algorithm,
    policy: &dyn MixPolicy,
    backend: &dyn Backend,
    spec: &RunSpec,
    scn: &Scenario,
    cost: &CostModel,
    threads: usize,
    shards: usize,
    obs: &ObsOptions,
) -> RunMetrics {
    assert!(spec.n >= 2, "gossip needs n >= 2");
    assert_eq!(spec.n, scn.n(), "spec n must match the scenario's graph");
    assert!(threads >= 1, "freerun needs at least one worker thread");
    let shards = shards.clamp(1, spec.n);
    let n = spec.n;
    let dim = backend.dim();
    let (p0, m0) = backend.init();
    assert_eq!(p0.len(), dim, "backend dim() must match its init vector");

    // deal node k to shard k % S, shard s to worker s % K
    let mut owned: Vec<Vec<(usize, NodeState)>> = (0..threads).map(|_| Vec::new()).collect();
    for k in 0..n {
        let st = NodeState::new(
            p0.clone(),
            m0.clone(),
            Pcg64::stream(spec.seed, STREAM_NODE_BASE + k as u64),
        );
        owned[(k % shards) % threads].push((k, st));
    }
    let sh = FreeShared {
        backend,
        cost,
        scn,
        lr: spec.lr,
        policy,
        kernel: algo.kernel(),
        slots: (0..n).map(|_| ModelSlot::<P>::new(&p0)).collect(),
        claimed: AtomicU64::new(0),
        done: AtomicU64::new(0),
        bits: AtomicU64::new(0),
        fallbacks: AtomicU64::new(0),
        total: spec.events,
        dim,
        n,
        live: obs.metrics_out.as_ref().map(|_| LiveStats::default()),
    };
    // staleness is measured in global interaction counts; lags beyond a few
    // multiples of n land in the overflow bucket (quantiles then report max)
    let staleness_cap = (8 * n).max(1024);

    // each worker executes an event quota proportional to the nodes it
    // owns, so per-node initiation rates stay uniform (the rate-1 Poisson
    // model) even when the shard deal is uneven (shards % threads != 0) or
    // workers run at different speeds
    let quotas: Vec<u64> = {
        let counts: Vec<u64> = owned.iter().map(|v| v.len() as u64).collect();
        let mut q: Vec<u64> = counts
            .iter()
            .map(|&c| (spec.events as u128 * c as u128 / n as u128) as u64)
            .collect();
        let mut leftover = spec.events - q.iter().sum::<u64>();
        let mut w = 0usize;
        while leftover > 0 {
            if counts[w] > 0 {
                q[w] += 1;
                leftover -= 1;
            }
            w = (w + 1) % threads;
        }
        q
    };

    let mut m = RunMetrics::new(&spec.name);
    let mut eval_rng = Pcg64::stream(spec.seed, STREAM_EVAL);
    let marks = milestones(spec.events, spec.eval_every);
    // all but the final milestone are recorded live from non-blocking slot
    // snapshots; the final point is computed exactly from the joined states
    let live_marks = &marks[..marks.len().saturating_sub(1)];

    // observability: one trace ring per worker (empty when tracing is off —
    // `record` is then a single branch), plus the periodic Prometheus
    // snapshot writer for `--metrics-out`
    let trace_epoch = Instant::now();
    let rings: Vec<TraceRing> =
        (0..threads).map(|_| TraceRing::with_epoch(obs.trace_capacity, trace_epoch)).collect();
    let sample_rate = obs.sample_rate();
    let mut export = match &obs.metrics_out {
        Some(path) => match FreerunMetricsExport::create(path) {
            Ok(e) => Some(e),
            Err(err) => {
                obs::log::warn("freerun", format_args!("{err}; live metrics disabled"));
                None
            }
        },
        None => None,
    };

    let started = Instant::now();
    let results: Vec<WorkerResult> = std::thread::scope(|scope| {
        let shref = &sh;
        let seed = spec.seed;
        let ringref = &rings;
        let handles: Vec<_> = owned
            .into_iter()
            .enumerate()
            .map(|(wid, nodes)| {
                let quota = quotas[wid];
                let sampler = Sampler::new(sample_rate, seed.wrapping_add(wid as u64));
                scope.spawn(move || {
                    worker_loop(
                        shref,
                        nodes,
                        wid,
                        seed,
                        staleness_cap,
                        quota,
                        &ringref[wid],
                        sampler,
                    )
                })
            })
            .collect();
        // evaluation monitor: snapshots the published slots without ever
        // stopping the workers — the free-running analogue of eval
        // barriers. Best-effort by contract: a run that drains faster than
        // the sampling loop records fewer live points (only the final
        // exact point is guaranteed), and nothing is recorded at d ≥ total
        // (the exact final point covers the end).
        let mut next = 0usize;
        while !handles.iter().all(|h| h.is_finished()) {
            if let Some(ex) = export.as_mut() {
                ex.tick(&sh, false);
            }
            let d = sh.done.load(Ordering::Acquire);
            if next < live_marks.len() && d >= live_marks[next] && d < sh.total {
                m.push(slot_point(&sh, algo, d, spec.track_gamma, &mut eval_rng));
                while next < live_marks.len() && live_marks[next] <= d {
                    next += 1;
                }
            } else {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("freerun worker panicked"))
            .collect()
    });
    let wall_secs = started.elapsed().as_secs_f64();
    if let Some(ex) = export.as_mut() {
        ex.tick(&sh, true);
    }
    if obs.tracing() {
        m.trace = Some(TraceDrain::from_rings(&rings));
    }

    // merge worker-local telemetry and reassemble the node states
    let mut staleness = StalenessHistogram::new(staleness_cap);
    let mut workers: Vec<WorkerActivity> = Vec::with_capacity(threads);
    let (mut read_retries, mut publish_retries, mut push_conflicts) = (0u64, 0u64, 0u64);
    let mut tagged: Vec<(usize, NodeState)> = Vec::with_capacity(n);
    for r in results {
        staleness.merge(&r.staleness);
        workers.push(r.activity);
        read_retries += r.read_retries;
        publish_retries += r.publish_retries;
        push_conflicts += r.push_conflicts;
        tagged.extend(r.states);
    }
    tagged.sort_by_key(|&(k, _)| k);
    let states: Vec<NodeState> = tagged.into_iter().map(|(_, s)| s).collect();
    debug_assert_eq!(states.len(), n);

    // exact final evaluation point from the joined states
    {
        let refs: Vec<&NodeState> = states.iter().collect();
        let pick = eval_rng.below_usize(n);
        let models = algo.round_metrics(&refs, pick);
        let ev = backend.eval(&models.consensus);
        let ind = backend.eval(&models.individual);
        m.final_model = models.consensus;
        let gamma = if spec.track_gamma {
            let live: Vec<Vec<f32>> = states.iter().map(|s| s.params.clone()).collect();
            gamma_potential(&live)
        } else {
            f64::NAN
        };
        let finite: Vec<f64> =
            states.iter().map(|s| s.last_loss).filter(|l| l.is_finite()).collect();
        let train_loss = if finite.is_empty() {
            f64::NAN
        } else {
            finite.iter().sum::<f64>() / finite.len() as f64
        };
        m.push(CurvePoint {
            t: spec.events,
            parallel_time: algo.parallel_time(spec.events, n),
            sim_time: states.iter().map(|s| s.time).fold(0.0, f64::max),
            epochs: states
                .iter()
                .enumerate()
                .map(|(i, s)| backend.epochs(i, s.steps))
                .sum::<f64>()
                / n as f64,
            train_loss,
            eval_loss: ev.loss,
            eval_acc: ev.accuracy,
            indiv_loss: ind.loss,
            gamma,
            bits: sh.bits.load(Ordering::Relaxed),
        });
    }

    let total_bits = sh.bits.into_inner();
    let quant_fallbacks = sh.fallbacks.into_inner();
    m.finalize(
        &states,
        backend,
        spec.events,
        total_bits,
        quant_fallbacks,
        "freerun",
        threads,
        algo.kernel().name(),
    );
    m.freerun = Some(FreerunStats {
        threads,
        shards,
        wall_secs,
        interactions_per_sec: spec.events as f64 / wall_secs.max(1e-9),
        codec: policy.wire().name().to_string(),
        kernel: algo.kernel().name().to_string(),
        wire_bits: total_bits,
        wire_fallbacks: quant_fallbacks,
        slot_read_retries: read_retries,
        slot_publish_retries: publish_retries,
        slot_push_conflicts: push_conflicts,
        staleness,
        workers,
        membership: None,
    });
    m
}

/// One worker: execute its event quota (proportional to the nodes it
/// owns), ringing own nodes off the local Poisson heap and running
/// initiator-side interactions against slot snapshots through the
/// algorithm's [`MixPolicy`]. The global `claimed` counter only sequences
/// event indices (for the lr schedule); it never redistributes work, so
/// per-node initiation rates stay uniform regardless of worker speed or
/// shard-deal imbalance.
fn worker_loop<P: SlotPayload>(
    sh: &FreeShared<'_, P>,
    mut owned: Vec<(usize, NodeState)>,
    wid: usize,
    seed: u64,
    staleness_cap: usize,
    quota: u64,
    ring: &TraceRing,
    mut sampler: Sampler,
) -> WorkerResult {
    let mut res = WorkerResult {
        states: Vec::new(),
        activity: WorkerActivity::default(),
        read_retries: 0,
        publish_retries: 0,
        push_conflicts: 0,
        staleness: StalenessHistogram::new(staleness_cap),
    };
    if owned.is_empty() || quota == 0 {
        res.states = owned;
        return res;
    }
    let mut rng = Pcg64::stream(seed, STREAM_WORKER_BASE + wid as u64);
    // each owned node's clock runs at its scenario rate (1.0 under uniform
    // speeds — the legacy rate-1 Poisson model, byte-identical draws)
    let mut heap: BinaryHeap<Reverse<Tick>> = BinaryHeap::new();
    for ix in 0..owned.len() {
        heap.push(Reverse(Tick { at: rng.exponential(sh.scn.rate(owned[ix].0)), ix }));
    }
    let lanes = P::lanes(sh.dim);
    // worker-local merge scratch: the node's own published payload, the
    // partner snapshot, and the two payloads the policy produces (its own
    // republish and the partner cross-write) — one bundle, allocated once,
    // reused for every interaction this worker runs
    let mut scratch = MergeScratch::with_kernel(lanes, sh.kernel);
    // only slot-canonical policies (push-sum takes) pay the own-slot read;
    // plain-model policies keep the PR 3 hot path and telemetry semantics
    let sync_own = sh.policy.needs_own_slot_sync();
    let tracing = ring.enabled();
    for _ in 0..quota {
        let t = sh.claimed.fetch_add(1, Ordering::Relaxed);
        debug_assert!(t < sh.total, "worker quotas must sum to the event budget");
        // sampling decision up front so a skipped interaction costs one
        // branch, not a clock read
        let traced = tracing && sampler.hit();
        let started = Instant::now();
        let mut sync_secs = 0.0f64;
        let Reverse(Tick { at, ix }) = heap.pop().expect("non-empty worker heap");
        let node = owned[ix].0;
        let st = &mut owned[ix].1;
        // the node rings: sync from its own published slot (canonical for
        // policies whose cross-writes mutate it — push-sum takes), then
        // pick a partner *now* and draw the local phase
        if sync_own {
            let t0 = Instant::now();
            let (_, own_retries) = sh.slots[node].read_into(&mut scratch.own);
            sync_secs += t0.elapsed().as_secs_f64();
            res.read_retries += own_retries;
            sh.policy.absorb_own_slot(st, &scratch.own, sh.dim);
        }
        // partner draw honors the graph stage active at this event's
        // global index (static scenarios resolve to the one graph)
        let graph = sh.scn.graph_at(t);
        let partner = graph.sample_neighbor(node, &mut rng);
        let h = sh.policy.draw_steps(&mut rng);
        let ctx = StepCtx {
            backend: sh.backend,
            cost: sh.cost,
            graph,
            lr: sh.lr.at(t + 1),
            dim: sh.dim,
            n: sh.n,
        };
        let tc = if traced { ring.now_ns() } else { 0 };
        sh.policy.local_phase(&ctx, node, st, h);
        if traced {
            ring.span(SpanKind::Compute, wid as u32, tc, h);
        }
        // non-blocking snapshot of the partner's published payload
        let t0 = Instant::now();
        let (stamp, retries) = sh.slots[partner].read_into(&mut scratch.snapshot);
        sync_secs += t0.elapsed().as_secs_f64();
        res.read_retries += retries;
        if traced && retries > 0 {
            ring.record(SpanKind::SlotRetry, wid as u32, ring.now_ns(), 0, retries);
        }
        let lag = sh.done.load(Ordering::Relaxed).saturating_sub(stamp);
        res.staleness.record(lag);
        // the policy's merge rule, initiator side only — the partner is
        // never touched, let alone delayed. The wire codec's accounting
        // comes back through the EventOutcome.
        let tm = if traced { ring.now_ns() } else { 0 };
        let outcome = sh.policy.merge(&ctx, node, st, &mut scratch, &mut rng);
        if traced {
            ring.span(SpanKind::Merge, wid as u32, tm, outcome.bits);
        }
        st.interactions += 1;
        sh.bits.fetch_add(outcome.bits, Ordering::Relaxed);
        if outcome.fallbacks > 0 {
            sh.fallbacks.fetch_add(outcome.fallbacks, Ordering::Relaxed);
        }
        // republish our payload; best-effort cross-write of the policy's
        // partner payload (the pair average for symmetric policies, the
        // remaining half-offer for push-sum takes) into the partner's
        // slot — dropped and counted if the slot is held
        let stamp_now = sh.done.load(Ordering::Relaxed);
        let t1 = Instant::now();
        let tp = if traced { ring.now_ns() } else { 0 };
        let pub_retries = sh.slots[node].publish(&scratch.publish, stamp_now);
        res.publish_retries += pub_retries;
        let conflicted = !sh.slots[partner].try_publish(&scratch.cross, stamp_now);
        if conflicted {
            res.push_conflicts += 1;
        }
        if traced {
            ring.span(SpanKind::Publish, wid as u32, tp, partner as u64);
            if pub_retries > 0 {
                ring.record(SpanKind::SlotRetry, wid as u32, ring.now_ns(), 0, pub_retries);
            }
        }
        sync_secs += t1.elapsed().as_secs_f64();
        if let Some(lv) = &sh.live {
            lv.staleness.record(lag);
            if retries > 0 {
                lv.read_retries.fetch_add(retries, Ordering::Relaxed);
            }
            if pub_retries > 0 {
                lv.publish_retries.fetch_add(pub_retries, Ordering::Relaxed);
            }
            if conflicted {
                lv.push_conflicts.fetch_add(1, Ordering::Relaxed);
            }
        }
        // re-arm this node's Poisson clock at its scenario rate
        heap.push(Reverse(Tick { at: at + rng.exponential(sh.scn.rate(node)), ix }));
        sh.done.fetch_add(1, Ordering::Release);
        let dt = started.elapsed().as_secs_f64();
        res.activity.busy_secs += (dt - sync_secs).max(0.0);
        res.activity.wait_secs += sync_secs;
        res.activity.interactions += 1;
    }
    res.states = owned;
    res
}

/// A live curve point from non-blocking slot snapshots: consensus and
/// individual models are decoded from the *published* payloads through the
/// [`SlotPayload`] hooks (push-sum slots de-bias by Σx/Σw); the workers
/// are not stopped, so per-node clocks and losses are unavailable — those
/// fields are NaN.
fn slot_point<P: SlotPayload>(
    sh: &FreeShared<'_, P>,
    algo: &dyn Algorithm,
    t: u64,
    track_gamma: bool,
    eval_rng: &mut Pcg64,
) -> CurvePoint {
    let mut snaps: Vec<Vec<f32>> = Vec::with_capacity(sh.n);
    let mut buf = vec![0.0f32; P::lanes(sh.dim)];
    for slot in &sh.slots {
        slot.read_into(&mut buf);
        snaps.push(buf.clone());
    }
    let consensus = P::consensus(&snaps, sh.dim);
    let pick = eval_rng.below_usize(sh.n);
    let ev = sh.backend.eval(&consensus);
    let ind = sh.backend.eval(&P::individual(&snaps[pick], sh.dim));
    let gamma = if track_gamma {
        let models: Vec<Vec<f32>> =
            snaps.iter().map(|s| P::individual(s, sh.dim)).collect();
        gamma_potential(&models)
    } else {
        f64::NAN
    };
    CurvePoint {
        t,
        parallel_time: algo.parallel_time(t, sh.n),
        sim_time: f64::NAN,
        epochs: f64::NAN,
        train_loss: f64::NAN,
        eval_loss: ev.loss,
        eval_acc: ev.accuracy,
        indiv_loss: ind.loss,
        gamma,
        bits: sh.bits.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_roundtrips_data_and_stamp() {
        let s = ModelSlot::<PlainModel>::new(&[1.0, 2.0]);
        let mut out = vec![0.0f32; 2];
        let (stamp, _) = s.read_into(&mut out);
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(stamp, 0);
        assert_eq!(s.publish(&[3.0, 4.0], 7), 0);
        let (stamp, _) = s.read_into(&mut out);
        assert_eq!(out, vec![3.0, 4.0]);
        assert_eq!(stamp, 7);
    }

    #[test]
    fn slot_sequential_publishes_always_succeed() {
        let s = ModelSlot::<PlainModel>::new(&[0.0]);
        assert!(s.try_publish(&[1.0], 1));
        assert!(s.try_publish(&[2.0], 2));
        let mut out = vec![0.0f32];
        let (stamp, _) = s.read_into(&mut out);
        assert_eq!(out, vec![2.0]);
        assert_eq!(stamp, 2);
    }

    #[test]
    fn weighted_slot_carries_the_weight_lane() {
        // a push-sum slot is dim + 1 lanes; a fresh one encodes weight 1
        let s = ModelSlot::<PushSumWeighted>::new(&[2.0, 4.0]);
        let mut out = vec![0.0f32; 3];
        let (stamp, _) = s.read_into(&mut out);
        assert_eq!(out, vec![2.0, 4.0, 1.0]);
        assert_eq!(stamp, 0);
        // publishing a halved pair round-trips intact
        assert!(s.try_publish(&[1.0, 2.0, 0.5], 3));
        let (stamp, _) = s.read_into(&mut out);
        assert_eq!(out, vec![1.0, 2.0, 0.5]);
        assert_eq!(stamp, 3);
        assert_eq!(PushSumWeighted::individual(&out, 2), vec![2.0, 4.0]);
    }

    #[test]
    fn slot_concurrent_reads_see_consistent_pairs() {
        // hammer one slot from a writer and several readers: every read
        // must return one of the published (data, stamp) pairs intact
        let dim = 64;
        let s = ModelSlot::<PlainModel>::new(&vec![0.0f32; dim]);
        let writes = 2_000u64;
        std::thread::scope(|scope| {
            let sref = &s;
            scope.spawn(move || {
                for v in 1..=writes {
                    let data = vec![v as f32; dim];
                    sref.publish(&data, v);
                }
            });
            for _ in 0..3 {
                scope.spawn(move || {
                    let mut out = vec![0.0f32; dim];
                    for _ in 0..2_000 {
                        let (stamp, _) = sref.read_into(&mut out);
                        let v = out[0];
                        assert!(out.iter().all(|&x| x == v), "torn read");
                        assert_eq!(stamp, v as u64, "stamp/data pair mixed");
                    }
                });
            }
        });
    }

    #[test]
    fn tick_heap_orders_by_time() {
        let mut heap: BinaryHeap<Reverse<Tick>> = BinaryHeap::new();
        heap.push(Reverse(Tick { at: 2.0, ix: 0 }));
        heap.push(Reverse(Tick { at: 0.5, ix: 1 }));
        heap.push(Reverse(Tick { at: 1.0, ix: 2 }));
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|Reverse(t)| t.ix))
            .collect();
        assert_eq!(order, vec![1, 2, 0]);
    }
}
