//! The `Algorithm` plug-in API — one engine, many training processes.
//!
//! PR 2's redesign: SwarmSGD's three averaging modes and all five §5
//! baselines (AD-PSGD, D-PSGD, SGP, local SGD, allreduce SGD) implement one
//! object-safe trait, and both executors ([`super::run_serial`] /
//! [`super::run_parallel`]) are generic drivers over
//! `&dyn Algorithm × &dyn Backend`. The decomposition follows the
//! observation (Even et al., "Asynchronous SGD on Graphs"; DIGEST) that all
//! of these methods are instances of one scheduled-interaction process:
//!
//! 1. **Schedule** — the algorithm pre-draws its full [`InteractionSchedule`]
//!    from a dedicated RNG stream: a sequence of [`Event`]s, each naming its
//!    [`EventKind`], participating nodes, pre-drawn local-step counts, and
//!    an event-local randomness seed. Gossip algorithms emit 2-node
//!    [`EventKind::Gossip`] events; synchronous round-based algorithms emit
//!    *phased* rounds — `n` independent single-node [`EventKind::Compute`]
//!    events (each node's local SGD phase, drawing only from its private
//!    stream) closed by an [`EventKind::Mix`] barrier — so their compute
//!    phases spread across all workers and only the mixing step is a
//!    barrier.
//! 2. **Interact** — the executor grants the event exclusive access to its
//!    participants' [`NodeState`]s (locks taken in ascending node order →
//!    deadlock-free) and the algorithm applies its update rule, charging
//!    simulated time to the per-node clocks carried in the states.
//! 3. **Round metrics** — at evaluation barriers the algorithm maps raw
//!    node states to the models the paper's curves evaluate (mean model for
//!    most; SGP overrides with its de-biased push-sum consensus).
//!
//! Because every event's participant set and every draw of randomness is
//! fixed before any thread starts, and node-local noise comes from each
//! node's private [`Pcg64::stream`], a parallel run at any thread count is
//! bit-identical to the serial program-order replay — the same
//! replay-determinism contract PR 1 established for SwarmSGD, now holding
//! for every algorithm.

use crate::backend::Backend;
use crate::netmodel::CostModel;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;
use crate::topology::Graph;

/// The scheduling/locking class of one [`Event`] — what the executors
/// dispatch on (exhaustively, so adding a kind is a compile error at every
/// dispatch site rather than a silently misrouted event).
///
/// * `Gossip` — an independent 2-node pairwise interaction: the executor
///   takes the two participants' locks in ascending node order (the
///   allocation-free fast path). Gossip *algorithms* schedule one per
///   logical tick; D-PSGD schedules its per-matching-edge mixing steps as
///   in-round `Gossip` events sharing the round's tick.
/// * `Compute` — a single-node local phase (one lock, no peers): one node's
///   SGD burst inside a phased synchronous round, drawing only from that
///   node's private RNG stream. `n` of these per round run concurrently
///   across all workers.
/// * `Mix` — a multi-node mixing/barrier phase closing a synchronous
///   round; the executor locks all participants in ascending node order.
///   The schedule's `seq` dependency tokens wire every compute (and
///   in-round gossip) event before the round's mix event.
///
/// # Examples
///
/// A phased synchronous round is `n` `Compute` events plus one `Mix`
/// barrier, all sharing one logical tick:
///
/// ```
/// use swarm_sgd::coordinator::{EventKind, InteractionSchedule};
///
/// let mut s = InteractionSchedule::new(3);
/// s.push_round(&[5, 5, 5], 0xABCD); // 5 local steps per node, round seed
/// assert_eq!(s.events.len(), 4); // 3 computes + 1 mix
/// assert!(s.events[..3].iter().all(|e| e.kind == EventKind::Compute));
/// assert_eq!(s.events[3].kind, EventKind::Mix);
/// assert!(s.events.iter().all(|e| e.tick == 0));
/// assert_eq!(s.ticks, 1); // one logical round
/// // the mix event waits on every compute via the seq tokens
/// assert_eq!(s.events[3].seq, vec![1, 1, 1]);
/// ```
///
/// Gossip events are one per tick:
///
/// ```
/// use swarm_sgd::coordinator::{EventKind, InteractionSchedule};
///
/// let mut s = InteractionSchedule::new(4);
/// s.push_gossip(0, 2, 3, 3, 7);
/// s.push_gossip(1, 2, 3, 3, 8);
/// assert_eq!(s.events[1].kind, EventKind::Gossip);
/// assert_eq!(s.events[1].tick, 1);
/// assert_eq!(s.ticks, 2);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// independent 2-node pairwise interaction (`[initiator, partner]`)
    Gossip,
    /// single-node local compute phase of a phased synchronous round
    Compute,
    /// multi-node mixing barrier closing a phased synchronous round
    Mix,
}

/// One pre-drawn event of the global schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    /// scheduling/locking class — executors dispatch on this, never on
    /// participant arity
    pub kind: EventKind,
    /// participating nodes in *role* order (gossip: `[initiator, partner]`;
    /// compute: `[node]`; mix: `0..n`). The executor grants exclusive
    /// access to these states, passed to [`Algorithm::interact`] in the
    /// same order.
    pub nodes: Vec<usize>,
    /// pre-drawn local-step counts, aligned with `nodes` (0 for pure
    /// mixing events)
    pub h: Vec<u64>,
    /// event-local randomness (quantizer hashes, matchings, push targets):
    /// algorithms derive a deterministic `Pcg64::seed(seed)` from it.
    /// Every event of one phased round shares the round's seed.
    pub seed: u64,
    /// per-participant dependency tokens, aligned with `nodes`: this event
    /// is participant `k`'s `seq[k]`-th event (0-based) — what parallel
    /// workers wait on
    pub seq: Vec<u64>,
    /// logical time this event belongs to: the gossip interaction index,
    /// or the synchronous round. Drives the lr schedule, the parallel-time
    /// axis, and eval milestones — so a phased round's `n + 1` events cost
    /// one tick, exactly like the monolithic round they replaced.
    pub tick: u64,
}

/// The full pre-drawn event sequence of one run. Everything stochastic
/// about *who* interacts and *how much* local work they do is fixed here,
/// before any thread starts — the first pillar of replay determinism.
#[derive(Clone, Debug, Default)]
pub struct InteractionSchedule {
    pub events: Vec<Event>,
    /// total events per node (seq tokens end at these values)
    pub per_node: Vec<u64>,
    /// total logical ticks: gossip interactions or synchronous rounds.
    /// `RunSpec::events` counts ticks, and events are in non-decreasing
    /// tick order, so executors map tick milestones to event boundaries.
    pub ticks: u64,
}

impl InteractionSchedule {
    pub fn new(n: usize) -> Self {
        Self { events: Vec::new(), per_node: vec![0; n], ticks: 0 }
    }

    /// Append one event at the current tick, assigning its per-participant
    /// sequence tokens. Participants must be distinct (the executor takes
    /// one lock each).
    fn append(&mut self, kind: EventKind, nodes: Vec<usize>, h: Vec<u64>, seed: u64) {
        debug_assert_eq!(nodes.len(), h.len());
        debug_assert!(
            {
                let mut sorted = nodes.clone();
                sorted.sort_unstable();
                sorted.windows(2).all(|w| w[0] != w[1])
            },
            "duplicate participant in event"
        );
        let seq: Vec<u64> = nodes.iter().map(|&k| self.per_node[k]).collect();
        for &k in &nodes {
            self.per_node[k] += 1;
        }
        self.events.push(Event { kind, nodes, h, seed, seq, tick: self.ticks });
    }

    /// Append one standalone 2-node [`EventKind::Gossip`] interaction
    /// (`h_i`/`h_j` pre-drawn local steps) occupying its own logical tick.
    pub fn push_gossip(&mut self, i: usize, j: usize, h_i: u64, h_j: u64, seed: u64) {
        self.append(EventKind::Gossip, vec![i, j], vec![h_i, h_j], seed);
        self.ticks += 1;
    }

    /// Append one single-node [`EventKind::Compute`] phase to the round
    /// under construction (the tick advances only at [`Self::seal_round`]).
    pub fn push_compute(&mut self, node: usize, h: u64, seed: u64) {
        self.append(EventKind::Compute, vec![node], vec![h], seed);
    }

    /// Append one pairwise mixing edge to the round under construction —
    /// scheduled as [`EventKind::Gossip`] (it *is* an independent 2-node
    /// event; disjoint edges of a matching run concurrently) but sharing
    /// the round's tick. D-PSGD's per-edge neighbor averaging.
    pub fn push_pair_mix(&mut self, i: usize, j: usize, seed: u64) {
        self.append(EventKind::Gossip, vec![i, j], vec![0, 0], seed);
    }

    /// Append one [`EventKind::Mix`] barrier over `nodes` to the round
    /// under construction. The `seq` tokens make it wait for every earlier
    /// event of each participant — compute → mix ordering by construction.
    pub fn push_mix(&mut self, nodes: Vec<usize>, seed: u64) {
        let h = vec![0; nodes.len()];
        self.append(EventKind::Mix, nodes, h, seed);
    }

    /// Close the round under construction: advance the logical tick.
    pub fn seal_round(&mut self) {
        self.ticks += 1;
    }

    /// Append one complete phased synchronous round: one `Compute` event
    /// per node (`h[k]` local steps) followed by one whole-cluster `Mix`
    /// barrier, all sharing one logical tick and one round seed.
    pub fn push_round(&mut self, h: &[u64], seed: u64) {
        let n = self.per_node.len();
        debug_assert_eq!(h.len(), n, "one local-step count per node");
        for (k, &hk) in h.iter().enumerate() {
            self.push_compute(k, hk, seed);
        }
        self.push_mix((0..n).collect(), seed);
        self.seal_round();
    }
}

/// Everything one node owns: model copies, its private RNG stream, and its
/// simulated clock/accounting. The executor guards each in its own mutex;
/// algorithms receive exclusive borrows of the event's participants.
pub struct NodeState {
    /// live model copy X^i
    pub params: Vec<f32>,
    /// optimizer momentum (travels with the live copy; NOT averaged —
    /// matching the paper's implementation where only models are exchanged)
    pub mom: Vec<f32>,
    /// communication copy X' that partners read (Appendix F)
    pub comm: Vec<f32>,
    /// scratch: snapshot S of `params` before the current local phase
    pub snap: Vec<f32>,
    /// scratch: incoming model buffer (gossip) / push-sum inbox (SGP)
    pub inbox: Vec<f32>,
    /// push-sum weight w_i (SGP); 1.0 and untouched elsewhere
    pub weight: f64,
    /// private stream: gradient noise, batch draws, compute-time jitter
    pub rng: Pcg64,
    /// local SGD steps performed
    pub steps: u64,
    /// events participated in
    pub interactions: u64,
    /// last observed minibatch loss
    pub last_loss: f64,
    /// compute-time drawn during a `Compute` phase but not yet charged —
    /// synchronous algorithms that charge the round *max* (SGP) park the
    /// draw here and settle it at the round's `Mix` barrier
    pub pending_compute: f64,
    /// simulated clock (seconds)
    pub time: f64,
    /// simulated seconds spent computing
    pub compute: f64,
    /// simulated seconds spent communicating
    pub comm_time: f64,
}

impl NodeState {
    pub fn new(params: Vec<f32>, mom: Vec<f32>, rng: Pcg64) -> Self {
        let dim = params.len();
        Self {
            comm: params.clone(),
            snap: vec![0.0; dim],
            inbox: vec![0.0; dim],
            params,
            mom,
            weight: 1.0,
            rng,
            steps: 0,
            interactions: 0,
            last_loss: f64::NAN,
            pending_compute: 0.0,
            time: 0.0,
            compute: 0.0,
            comm_time: 0.0,
        }
    }
}

/// Per-event context handed to [`Algorithm::interact`].
pub struct StepCtx<'a> {
    pub backend: &'a dyn Backend,
    pub cost: &'a CostModel,
    pub graph: &'a Graph,
    /// learning rate at this event (from the run's [`super::LrSchedule`])
    pub lr: f32,
    /// model dimension d
    pub dim: usize,
    /// cluster size n
    pub n: usize,
}

/// What one event consumed (merged into [`super::RunMetrics`] totals).
#[derive(Clone, Copy, Debug, Default)]
pub struct EventOutcome {
    /// bits that crossed the wire
    pub bits: u64,
    /// lattice-decode failures that fell back to full precision
    pub fallbacks: u64,
}

/// The models an evaluation barrier measures.
pub struct RoundModels {
    /// consensus model evaluated as μ_t (mean by default; SGP: Σx/Σw)
    pub consensus: Vec<f32>,
    /// one node's individual model (paper §5 compares μ vs individual)
    pub individual: Vec<f32>,
}

/// A decentralized training algorithm as a plug-in to the executors.
///
/// Object-safe by design: the CLI, figure harnesses, and both executors
/// hold `Box<dyn Algorithm>` / `&dyn Algorithm`.
pub trait Algorithm: Sync {
    /// Short identifier (`"swarm"`, `"adpsgd"`, …).
    fn name(&self) -> &'static str;

    /// Pre-draw the complete event sequence for a run of `events` events on
    /// `n` nodes. All randomness must come from `rng` (the executor hands a
    /// dedicated schedule stream), never from global state. Gossip pairs
    /// come from the scenario ([`Scenario::sample_pair`] /
    /// [`Scenario::sample_partner`] at the event's tick), so partner draws
    /// honor the configured topology, its time schedule, and the per-node
    /// speed classes — and under the default scenario they consume `rng`
    /// byte-identically to the historical uniform-complete draws.
    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule;

    /// Execute one event. `parts` are exclusive borrows of the event's
    /// participant states, aligned with `ev.nodes`; `t` is the event's
    /// 0-based logical tick (`ev.tick`: the gossip interaction index, or
    /// the synchronous round the event belongs to). Dispatch on `ev.kind`
    /// for phased schedules. Charge simulated time to the states' clocks
    /// and return the wire accounting.
    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome;

    /// [`Algorithm::interact`] with a caller-provided per-worker
    /// [`super::MergeScratch`] — the allocation-free entry point every
    /// executor calls. The default forwards to [`Algorithm::interact`]
    /// (correct for algorithms whose interact bodies never touch the
    /// scratch); the quantized-merge algorithms override this with their
    /// real body and turn `interact` into a compatibility wrapper that
    /// builds a transient scratch.
    fn interact_with(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut super::MergeScratch,
    ) -> EventOutcome {
        let _ = scratch;
        self.interact(t, ev, parts, ctx)
    }

    /// The fused merge kernel this algorithm's interactions dispatch to —
    /// the `--kernel` axis. Default scalar; [`make_algorithm`] wraps the
    /// algorithm when another kernel is selected, and the executors read
    /// this once per run to size their per-worker
    /// [`super::MergeScratch`]es and tag [`super::RunMetrics`].
    fn kernel(&self) -> crate::kernels::Kernel {
        crate::kernels::Kernel::Scalar
    }

    /// The paper's parallel-time axis for event count `t`: gossip events
    /// advance it by 1/n (default); synchronous rounds by 1.
    fn parallel_time(&self, t: u64, n: usize) -> f64 {
        t as f64 / n as f64
    }

    /// Map node states to the models an evaluation barrier measures.
    /// Default: coordinate-wise mean of live models + node `pick`'s params.
    fn round_metrics(&self, states: &[&NodeState], pick: usize) -> RoundModels {
        RoundModels {
            consensus: mean_model(states),
            individual: states[pick].params.clone(),
        }
    }

    /// Free-running mix policy: `Some` iff the algorithm has free-running
    /// semantics on [`super::run_freerun`] — its mixing decomposes into
    /// initiator-driven interactions against published slot payloads. The
    /// pairwise gossip algorithms (swarm, poisson, adpsgd, dpsgd) return a
    /// plain-model [`super::PairwisePolicy`]; SGP returns the weighted-slot
    /// [`super::PushSumPolicy`] (push-sum `(x, w)` pairs). Default `None`
    /// (irreducibly global mixing: localsgd's and allreduce's global mean).
    fn mix_policy(&self) -> Option<Box<dyn super::MixPolicy>> {
        None
    }
}

/// Coordinate-wise f64 mean over `n` parameter slices, accumulated in
/// iteration (node-index) order — the single definition every averaging
/// site shares so consensus math stays bit-identical across serial runs,
/// parallel runs, and the synchronous baselines' in-event allreduce.
pub fn mean_params<'a, I: IntoIterator<Item = &'a [f32]>>(
    models: I,
    dim: usize,
    n: usize,
) -> Vec<f32> {
    let mut acc = vec![0.0f64; dim];
    for m in models {
        for (a, &v) in acc.iter_mut().zip(m) {
            *a += v as f64;
        }
    }
    acc.into_iter().map(|v| (v / n as f64) as f32).collect()
}

/// Coordinate-wise mean of live models μ_t.
pub fn mean_model(states: &[&NodeState]) -> Vec<f32> {
    let dim = states.first().map_or(0, |s| s.params.len());
    mean_params(states.iter().map(|s| s.params.as_slice()), dim, states.len())
}

/// One endpoint's local-SGD phase, shared by the gossip algorithms:
/// snapshot S, `h` steps drawing all randomness from the node's own stream,
/// compute-time charge.
pub fn local_phase(ctx: &StepCtx<'_>, agent: usize, st: &mut NodeState, h: u64) {
    st.snap.copy_from_slice(&st.params);
    st.last_loss =
        ctx.backend.step_burst(agent, &mut st.params, &mut st.mom, ctx.lr, h, &mut st.rng);
    st.steps += h;
    let mut comp = 0.0;
    for _ in 0..h {
        comp += ctx.cost.compute_time(&mut st.rng);
    }
    st.time += comp;
    st.compute += comp;
}

/// One single SGD step + its compute-time charge for a node — the H=1
/// counterpart of [`local_phase`], shared by the per-step baselines so the
/// charging rule has exactly one definition. (SGP steps on a de-biased
/// copy and charges the round max instead, so it keeps its own body.)
pub fn step_once(ctx: &StepCtx<'_>, agent: usize, st: &mut NodeState) {
    st.last_loss = ctx.backend.step(agent, &mut st.params, &mut st.mom, ctx.lr, &mut st.rng);
    st.steps += 1;
    let dt = ctx.cost.compute_time(&mut st.rng);
    st.time += dt;
    st.compute += dt;
}

/// Synchronous-round barrier over the event's participants: everyone
/// advances to the participant max, then pays `cost` together.
pub fn barrier_all(parts: &mut [&mut NodeState], cost: f64) {
    let meet = parts.iter().map(|s| s.time).fold(0.0, f64::max);
    let done = meet + cost;
    for st in parts.iter_mut() {
        st.time = done;
        st.comm_time += cost;
    }
}

/// Exclusive borrows of participants `u` and `v` (distinct positions).
pub fn pair_at<'a>(
    parts: &'a mut [&mut NodeState],
    u: usize,
    v: usize,
) -> (&'a mut NodeState, &'a mut NodeState) {
    assert_ne!(u, v);
    if u < v {
        let (a, b) = parts.split_at_mut(v);
        (&mut *a[u], &mut *b[0])
    } else {
        let (a, b) = parts.split_at_mut(u);
        (&mut *b[0], &mut *a[v])
    }
}

/// The two participants of a gossip event, in role order.
pub(crate) fn pair<'a>(
    parts: &'a mut [&mut NodeState],
) -> (&'a mut NodeState, &'a mut NodeState) {
    debug_assert_eq!(parts.len(), 2);
    pair_at(parts, 0, 1)
}

/// Knobs for [`make_algorithm`] that are not universal across algorithms.
#[derive(Clone, Copy, Debug)]
pub struct AlgoOptions {
    /// SwarmSGD local-step distribution (fixed H vs geometric)
    pub local_steps: super::LocalSteps,
    /// SwarmSGD averaging mode (blocking / non-blocking / quantized)
    pub mode: super::AveragingMode,
    /// Local-SGD communication period
    pub h_localsgd: u64,
    /// wire codec (`--wire lattice|f32`) — how model payloads cross the
    /// simulated wire, on every executor. `mode = quantized` implies the
    /// lattice codec for swarm/poisson; for the other pairwise-mixing
    /// algorithms this is the only quantization switch.
    pub wire: super::WireCodec,
    /// fused merge kernel (`--kernel scalar|simd`) — which implementation
    /// the decode + merge traversals dispatch to. Both are bit-exact, so
    /// this is a pure performance axis, valid on every executor.
    pub kernel: crate::kernels::Kernel,
}

impl Default for AlgoOptions {
    fn default() -> Self {
        Self {
            local_steps: super::LocalSteps::Fixed(2),
            mode: super::AveragingMode::NonBlocking,
            h_localsgd: 5,
            wire: super::WireCodec::F32,
            kernel: crate::kernels::Kernel::Scalar,
        }
    }
}

/// All `--algorithm` selector values, in paper order.
pub const ALGORITHM_NAMES: &[&str] =
    &["swarm", "poisson", "adpsgd", "dpsgd", "sgp", "localsgd", "allreduce"];

/// SwarmSGD's effective averaging mode once the wire-codec axis is folded
/// in: `--wire lattice` turns the non-blocking merge into the quantized
/// variant (which *is* non-blocking + lattice wire), and is rejected for
/// the blocking rendezvous, whose live-model average has no snapshot to
/// quantize against. Precedence: `mode=quantized` keeps the lattice codec
/// even under the default `wire=f32` (the two spell the same thing, and
/// an explicit `--wire f32` is indistinguishable from the default) — full
/// precision is selected with `mode=nonblocking`, as documented in the
/// CLI usage.
fn swarm_mode(opts: &AlgoOptions) -> Result<super::AveragingMode, String> {
    use super::{AveragingMode, WireCodec};
    match (opts.mode, opts.wire) {
        (m, WireCodec::F32) => Ok(m),
        (AveragingMode::Blocking, WireCodec::Lattice { .. }) => Err(
            "--wire lattice pairs with the non-blocking merge (mode=blocking \
             averages live models at a rendezvous, with no snapshot to decode \
             against): use mode=nonblocking, or drop --wire lattice"
                .to_string(),
        ),
        (_, WireCodec::Lattice { bits, eps }) => Ok(AveragingMode::Quantized { bits, eps }),
    }
}

/// Actionable rejection for `--wire lattice` on algorithms whose mixing is
/// a full-precision collective rather than a pairwise exchange.
fn reject_lattice(name: &str, opts: &AlgoOptions) -> Result<(), String> {
    if let super::WireCodec::Lattice { .. } = opts.wire {
        return Err(format!(
            "{name} mixes through a full-precision collective (global mean), \
             so the lattice wire codec does not apply: drop --wire lattice, \
             or pick a pairwise-mixing algorithm (swarm|poisson|adpsgd|dpsgd|sgp)"
        ));
    }
    Ok(())
}

/// Delegating wrapper [`make_algorithm`] applies when a non-default kernel
/// is selected, so the algorithm structs themselves stay kernel-free (their
/// literal constructors — used all over the tests — don't change). Only
/// [`Algorithm::kernel`] is overridden; everything else forwards.
struct WithKernel {
    inner: Box<dyn Algorithm>,
    kernel: crate::kernels::Kernel,
}

impl Algorithm for WithKernel {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        self.inner.schedule(n, events, scn, rng)
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = super::MergeScratch::with_kernel(ctx.dim, self.kernel);
        self.inner.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut super::MergeScratch,
    ) -> EventOutcome {
        self.inner.interact_with(t, ev, parts, ctx, scratch)
    }

    fn kernel(&self) -> crate::kernels::Kernel {
        self.kernel
    }

    fn parallel_time(&self, t: u64, n: usize) -> f64 {
        self.inner.parallel_time(t, n)
    }

    fn round_metrics(&self, states: &[&NodeState], pick: usize) -> RoundModels {
        self.inner.round_metrics(states, pick)
    }

    fn mix_policy(&self) -> Option<Box<dyn super::MixPolicy>> {
        self.inner.mix_policy()
    }
}

/// Build an algorithm by its `--algorithm` selector name.
pub fn make_algorithm(name: &str, opts: &AlgoOptions) -> Result<Box<dyn Algorithm>, String> {
    use super::baselines::{AdPsgd, AllReduce, DPsgd, LocalSgd, Sgp};
    use super::{PoissonSwarm, SwarmSgd};
    let algo: Box<dyn Algorithm> = match name {
        "swarm" => {
            Box::new(SwarmSgd { local_steps: opts.local_steps, mode: swarm_mode(opts)? })
        }
        "poisson" => Box::new(PoissonSwarm::new(opts.local_steps, swarm_mode(opts)?)),
        "adpsgd" => Box::new(AdPsgd { wire: opts.wire }),
        "dpsgd" => Box::new(DPsgd { wire: opts.wire }),
        "sgp" => Box::new(Sgp { wire: opts.wire }),
        "localsgd" => {
            reject_lattice("localsgd", opts)?;
            if opts.h_localsgd == 0 {
                return Err(
                    "localsgd needs a communication period h >= 1 (got h=0): \
                     pass --set h=5 for the paper's period, or any positive \
                     integer"
                        .to_string(),
                );
            }
            Box::new(LocalSgd { h: opts.h_localsgd })
        }
        "allreduce" => {
            reject_lattice("allreduce", opts)?;
            Box::new(AllReduce)
        }
        other => {
            return Err(format!(
                "unknown algorithm '{other}' (known: {})",
                ALGORITHM_NAMES.join("|")
            ))
        }
    };
    Ok(if opts.kernel == crate::kernels::Kernel::Scalar {
        algo
    } else {
        Box::new(WithKernel { inner: algo, kernel: opts.kernel })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_state(vals: &[f32]) -> NodeState {
        NodeState::new(vals.to_vec(), vec![0.0; vals.len()], Pcg64::seed(1))
    }

    #[test]
    fn schedule_push_assigns_sequence_tokens() {
        let mut s = InteractionSchedule::new(4);
        s.push_gossip(0, 1, 2, 2, 7);
        s.push_gossip(1, 3, 1, 1, 8);
        s.push_mix(vec![0, 1, 2, 3], 9);
        s.seal_round();
        assert_eq!(s.events[0].seq, vec![0, 0]);
        assert_eq!(s.events[1].seq, vec![1, 0]);
        assert_eq!(s.events[2].seq, vec![1, 2, 0, 1]);
        assert_eq!(s.per_node, vec![2, 3, 1, 2]);
        assert_eq!(s.ticks, 3);
        assert_eq!(
            s.events.iter().map(|e| e.tick).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn phased_round_wires_compute_before_mix() {
        let n = 3;
        let mut s = InteractionSchedule::new(n);
        s.push_round(&[2, 2, 2], 11);
        s.push_round(&[2, 2, 2], 12);
        assert_eq!(s.events.len(), 2 * (n + 1));
        assert_eq!(s.ticks, 2);
        for r in 0..2 {
            let base = r * (n + 1);
            for k in 0..n {
                let ev = &s.events[base + k];
                assert_eq!(ev.kind, EventKind::Compute);
                assert_eq!(ev.nodes, vec![k]);
                assert_eq!(ev.h, vec![2]);
                assert_eq!(ev.tick, r as u64);
            }
            let mix = &s.events[base + n];
            assert_eq!(mix.kind, EventKind::Mix);
            assert_eq!(mix.nodes, (0..n).collect::<Vec<_>>());
            assert_eq!(mix.tick, r as u64);
            // the mix waits for every compute of its round
            let expect: Vec<u64> = (0..n).map(|_| (2 * r + 1) as u64).collect();
            assert_eq!(mix.seq, expect);
        }
        // events are in non-decreasing tick order (the executors'
        // milestone mapping relies on this)
        assert!(s.events.windows(2).all(|w| w[0].tick <= w[1].tick));
    }

    #[test]
    fn pair_mix_shares_round_tick() {
        let mut s = InteractionSchedule::new(4);
        s.push_compute(0, 1, 5);
        s.push_compute(1, 1, 5);
        s.push_compute(2, 1, 5);
        s.push_compute(3, 1, 5);
        s.push_pair_mix(0, 2, 5);
        s.push_pair_mix(1, 3, 5);
        s.push_mix(vec![0, 1, 2, 3], 5);
        s.seal_round();
        assert_eq!(s.ticks, 1);
        assert!(s.events.iter().all(|e| e.tick == 0));
        assert_eq!(s.events[4].kind, EventKind::Gossip);
        assert_eq!(s.events[4].nodes, vec![0, 2]);
        assert_eq!(s.events[4].h, vec![0, 0]);
        // edge (0,2) depends on computes of 0 and 2 only
        assert_eq!(s.events[4].seq, vec![1, 1]);
        // the barrier is every node's third event
        assert_eq!(s.events[6].seq, vec![2, 2, 2, 2]);
    }

    #[test]
    fn mean_model_is_f64_accumulated() {
        let a = dummy_state(&[0.0, 2.0]);
        let b = dummy_state(&[4.0, 0.0]);
        let mu = mean_model(&[&a, &b]);
        assert_eq!(mu, vec![2.0, 1.0]);
    }

    #[test]
    fn barrier_advances_to_max_plus_cost() {
        let mut a = dummy_state(&[0.0]);
        let mut b = dummy_state(&[0.0]);
        a.time = 1.0;
        b.time = 3.0;
        {
            let mut parts = [&mut a, &mut b];
            barrier_all(&mut parts, 0.5);
        }
        assert_eq!(a.time, 3.5);
        assert_eq!(b.time, 3.5);
        assert_eq!(a.comm_time, 0.5);
    }

    #[test]
    fn pair_at_returns_role_order() {
        let mut a = dummy_state(&[1.0]);
        let mut b = dummy_state(&[2.0]);
        let mut c = dummy_state(&[3.0]);
        let mut parts = [&mut a, &mut b, &mut c];
        let (x, y) = pair_at(&mut parts, 2, 0);
        assert_eq!(x.params[0], 3.0);
        assert_eq!(y.params[0], 1.0);
    }

    #[test]
    fn factory_knows_all_names() {
        let opts = AlgoOptions::default();
        for name in ALGORITHM_NAMES {
            let a = make_algorithm(name, &opts).unwrap();
            assert_eq!(a.name(), *name);
        }
        assert!(make_algorithm("nope", &opts).is_err());
    }

    #[test]
    fn factory_folds_wire_codec_into_the_algorithms() {
        use crate::coordinator::{AveragingMode, WireCodec};
        let lattice = AlgoOptions {
            wire: WireCodec::Lattice { bits: 6, eps: 1e-2 },
            ..AlgoOptions::default()
        };
        // pairwise-mixing algorithms accept the lattice wire
        for name in ["swarm", "poisson", "adpsgd", "dpsgd", "sgp"] {
            assert!(make_algorithm(name, &lattice).is_ok(), "{name}");
        }
        // full-precision-collective baselines reject it with an actionable
        // message
        for name in ["localsgd", "allreduce"] {
            let err = make_algorithm(name, &lattice).unwrap_err();
            assert!(err.contains("drop --wire lattice"), "{name}: unhelpful error: {err}");
        }
        // blocking rendezvous averaging has no snapshot to quantize against
        let blocking_lattice =
            AlgoOptions { mode: AveragingMode::Blocking, ..lattice };
        let err = make_algorithm("swarm", &blocking_lattice).unwrap_err();
        assert!(err.contains("mode=nonblocking"), "unhelpful error: {err}");
        // f32 wire (the default) never restricts anything
        for name in ALGORITHM_NAMES {
            assert!(make_algorithm(name, &AlgoOptions::default()).is_ok(), "{name}");
        }
    }

    #[test]
    fn factory_wraps_non_default_kernels_transparently() {
        use crate::kernels::Kernel;
        let opts = AlgoOptions::default();
        for name in ALGORITHM_NAMES {
            let a = make_algorithm(name, &opts).unwrap();
            assert_eq!(a.kernel(), Kernel::Scalar, "{name}");
        }
        let simd = AlgoOptions { kernel: Kernel::Simd, ..AlgoOptions::default() };
        for name in ALGORITHM_NAMES {
            let a = make_algorithm(name, &simd).unwrap();
            assert_eq!(a.kernel(), Kernel::Simd, "{name}");
            assert_eq!(a.name(), *name, "the kernel wrapper must stay transparent");
        }
    }

    #[test]
    fn factory_rejects_zero_localsgd_period() {
        let opts = AlgoOptions { h_localsgd: 0, ..AlgoOptions::default() };
        let err = make_algorithm("localsgd", &opts).unwrap_err();
        assert!(err.contains("h >= 1"), "unhelpful error: {err}");
        // other algorithms ignore the localsgd period entirely
        assert!(make_algorithm("swarm", &opts).is_ok());
        assert!(make_algorithm("dpsgd", &opts).is_ok());
    }
}
