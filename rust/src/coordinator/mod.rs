//! L3 — the SwarmSGD coordinator (the paper's system contribution).
//!
//! * [`swarm`] — Algorithm 1 (blocking), Algorithm 2 (non-blocking,
//!   Appendix F) and the quantized variant (Appendix G), with fixed or
//!   geometric local-step counts.
//! * [`baselines`] — the comparison systems of §5: AD-PSGD, D-PSGD, SGP,
//!   local SGD, and (large-batch) allreduce SGD.
//! * [`engine`] — per-node simulated clocks + the event accounting that
//!   turns the logical interaction sequence into the paper's time axes
//!   (DESIGN.md §2: the discrete-event stand-in for Piz Daint).
//! * [`cluster`] — shared agent state (live/communication model copies) and
//!   pairwise averaging primitives.
//! * [`metrics`] — loss curves, Γ_t, bits-on-wire, comm/compute splits.
//! * [`parallel`] — the shared-memory multi-threaded executor: per-node
//!   locks + lock-free communication slots, with a deterministic schedule
//!   that makes any parallel run serially replayable bit-for-bit.

pub mod baselines;
mod cluster;
mod engine;
mod metrics;
mod parallel;
mod poisson;
mod swarm;

pub use cluster::{
    average_into_both, midpoint, nonblocking_update, quantized_transfer, Agent, Cluster,
};
pub use engine::NodeClocks;
pub use metrics::{CurvePoint, RunMetrics};
pub use parallel::{run_parallel, run_replay_serial, Interaction, Schedule};
pub use poisson::PoissonRunner;
pub use swarm::{AveragingMode, LocalSteps, SwarmConfig, SwarmRunner};

use crate::backend::TrainBackend;
use crate::netmodel::CostModel;
use crate::rngx::Pcg64;
use crate::topology::Graph;

/// Learning-rate schedule (paper §5: identical to sequential SGD per model;
/// annealed at 1/3 and 2/3 of training for the vision recipes).
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant(f32),
    /// base lr, annealed ×0.1 at 1/3 and 2/3 of `total` progress
    StepDecay { base: f32, total: u64 },
    /// η = n/√T — the theory rate of Theorems 4.1/4.2
    Theory { n: usize, t: u64 },
}

impl LrSchedule {
    pub fn at(&self, progress: u64) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::StepDecay { base, total } => {
                let frac = progress as f64 / total.max(1) as f64;
                if frac < 1.0 / 3.0 {
                    base
                } else if frac < 2.0 / 3.0 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
            LrSchedule::Theory { n, t } => (n as f64 / (t as f64).sqrt()) as f32,
        }
    }
}

/// Everything a runner needs, bundled to keep signatures sane.
pub struct RunContext<'a> {
    pub backend: &'a mut dyn TrainBackend,
    pub graph: &'a Graph,
    pub cost: &'a CostModel,
    pub rng: &'a mut Pcg64,
    /// evaluate the mean model every this many interactions (0 = never)
    pub eval_every: u64,
    /// record Γ_t at eval points
    pub track_gamma: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_variants() {
        let c = LrSchedule::Constant(0.1);
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(1000), 0.1);

        let s = LrSchedule::StepDecay { base: 0.3, total: 300 };
        assert_eq!(s.at(0), 0.3);
        assert!((s.at(150) - 0.03).abs() < 1e-6);
        assert!((s.at(299) - 0.003).abs() < 1e-6);

        let t = LrSchedule::Theory { n: 4, t: 1600 };
        assert!((t.at(0) - 0.1).abs() < 1e-7); // 4/40
    }
}
