//! L3 — the coordinator: one engine, every algorithm (PR 2's redesign).
//!
//! The module is organized as an **Algorithm × Backend × Executor** matrix:
//!
//! * [`algorithm`] — the object-safe [`Algorithm`] trait ( `schedule` /
//!   `interact` / `round_metrics`), [`NodeState`], the pre-drawn
//!   [`InteractionSchedule`] of typed [`EventKind`] events (`Gossip` /
//!   `Compute` / `Mix` — synchronous rounds are *phased* into per-node
//!   compute events plus a mix barrier, so every algorithm parallelizes),
//!   and the [`make_algorithm`] factory behind the CLI's `--algorithm`
//!   selector.
//! * [`swarm`] — SwarmSGD: Algorithm 1 (blocking), Algorithm 2
//!   (non-blocking, Appendix F) and the quantized variant (Appendix G),
//!   with fixed or geometric local-step counts.
//! * [`poisson`] — the same process scheduled by literal Poisson clocks
//!   (paper §2's equivalence, testable on the schedule).
//! * [`baselines`] — the comparison systems of §5: AD-PSGD, D-PSGD, SGP,
//!   local SGD, and (large-batch) allreduce SGD — the round-based four
//!   schedule phased rounds (per-node `Compute` events + a `Mix` barrier;
//!   D-PSGD additionally decomposes its matching average into per-edge
//!   gossip events, which makes it freerun-eligible).
//! * [`executor`] — [`run_serial`] (program-order reference) and
//!   [`run_parallel`] (shared-memory worker threads), generic over
//!   `&dyn Algorithm × &dyn Backend`, with the PR-1 replay-determinism
//!   contract extended to every algorithm.
//! * [`freerun`] — [`run_freerun`], the third executor: no schedule at all.
//!   Sharded OS-thread workers, live per-worker Poisson clocks, and
//!   non-blocking seqlock model slots — throughput-faithful, measured, and
//!   deliberately **non-replayable** (the contract split is documented in
//!   that module and in `lib.rs`).
//! * [`policy`] — the open free-running capability API: the object-safe
//!   [`MixPolicy`] trait ([`Algorithm::mix_policy`]) owning the slot
//!   payload ([`SlotPayload`]: plain models or push-sum `(x, w)` pairs),
//!   the merge rule, the local-step policy, and the first-class
//!   [`WireCodec`] quantization axis (`--wire lattice|f32`, honored on all
//!   three executors). Replaced PR 3's closed `GossipProfile` struct and
//!   admitted SGP to freerun via weighted slots. Merge bodies run through
//!   the fused quantize-average kernels of [`crate::kernels`]
//!   (`--kernel scalar|simd`), fed by a per-worker allocation-free
//!   [`MergeScratch`].
//! * [`telemetry`] — what only the free-running executor can measure:
//!   staleness histograms, seqlock retry counts, per-worker busy/wait,
//!   and the codec's wire-bit/fallback attribution.
//! * [`cluster`] — pairwise averaging primitives shared by the algorithms.
//! * [`engine`] — per-node simulated clocks merged into the paper's time
//!   axes.
//! * [`metrics`] — loss curves, Γ_t, bits-on-wire, comm/compute splits.

mod algorithm;
pub mod baselines;
mod cluster;
mod engine;
mod executor;
pub mod freerun;
mod metrics;
mod poisson;
pub mod policy;
mod swarm;
pub mod telemetry;

pub use algorithm::{
    barrier_all, local_phase, make_algorithm, mean_model, mean_params, pair_at, step_once,
    AlgoOptions, Algorithm, Event, EventKind, EventOutcome, InteractionSchedule, NodeState,
    RoundModels, StepCtx, ALGORITHM_NAMES,
};
pub use cluster::{average_into_both, midpoint, nonblocking_update, quantized_transfer};
pub use engine::NodeClocks;
pub use executor::{
    run_parallel, run_parallel_scenario, run_serial, run_serial_scenario, RunSpec,
};
pub use freerun::{run_freerun, run_freerun_scenario, run_freerun_with_obs};
pub use metrics::{CurvePoint, RunMetrics};
pub use poisson::PoissonSwarm;
pub use crate::kernels::Kernel;
pub use policy::{
    codec_exchange_average, MergeScratch, MixPolicy, PairMerge, PairwisePolicy, PayloadKind,
    PlainModel, PushSumPolicy, PushSumWeighted, SlotPayload, WireCodec,
};
pub use swarm::{AveragingMode, LocalSteps, SwarmSgd};
pub use telemetry::{FreerunStats, MembershipStats, StalenessHistogram, WorkerActivity};

/// Learning-rate schedule (paper §5: identical to sequential SGD per model;
/// annealed at 1/3 and 2/3 of training for the vision recipes).
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant(f32),
    /// base lr, annealed ×0.1 at 1/3 and 2/3 of `total` progress
    StepDecay { base: f32, total: u64 },
    /// η = n/√T — the theory rate of Theorems 4.1/4.2
    Theory { n: usize, t: u64 },
}

impl LrSchedule {
    pub fn at(&self, progress: u64) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::StepDecay { base, total } => {
                let frac = progress as f64 / total.max(1) as f64;
                if frac < 1.0 / 3.0 {
                    base
                } else if frac < 2.0 / 3.0 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
            LrSchedule::Theory { n, t } => (n as f64 / (t as f64).sqrt()) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_variants() {
        let c = LrSchedule::Constant(0.1);
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(1000), 0.1);

        let s = LrSchedule::StepDecay { base: 0.3, total: 300 };
        assert_eq!(s.at(0), 0.3);
        assert!((s.at(150) - 0.03).abs() < 1e-6);
        assert!((s.at(299) - 0.003).abs() < 1e-6);

        let t = LrSchedule::Theory { n: 4, t: 1600 };
        assert!((t.at(0) - 0.1).abs() < 1e-7); // 4/40
    }
}
