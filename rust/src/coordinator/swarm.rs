//! SwarmSGD — Algorithms 1 & 2 and the quantized variant, faithful to the
//! paper's update rules, as an [`Algorithm`] plug-in:
//!
//! **Blocking (Alg. 1)**: sample edge (i,j); each endpoint runs `H` local
//! SGD steps on its live model; both set `X ← (X_i + X_j)/2`.
//!
//! **Non-blocking (Alg. 2 / Appendix F)**: partners exchange *communication
//! copies* `X' = X_{p+1/2}` — the averaged model from the node's previous
//! interaction, **missing** its in-flight local-gradient batch — so nobody
//! waits:
//! ```text
//!   S_i = X_i;  X_i ← H_i local steps;  Δ_i = X_i − S_i
//!   X_i ← (S_i + X_j')/2 + Δ_i          (and symmetrically for j)
//!   X_i' ← (S_i + X_j')/2               (next round's communication copy)
//! ```
//!
//! **Quantized (Appendix G)**: same as non-blocking, but the incoming copy
//! crosses the wire through the lattice codec; decode failures (distance
//! criterion violated) fall back to full precision and are counted.
//!
//! Local step counts are fixed (`H`) or geometric with mean `H` — the two
//! regimes of Theorems 4.2 and 4.1 respectively.

use super::algorithm::{
    local_phase, pair, Algorithm, Event, EventOutcome, InteractionSchedule, NodeState, StepCtx,
};
use super::policy::MergeScratch;
use crate::kernels;
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

/// Distribution of the number of local SGD steps between interactions.
#[derive(Clone, Copy, Debug)]
pub enum LocalSteps {
    /// exactly H steps (Theorem 4.2 regime)
    Fixed(u64),
    /// geometric with mean H — Poisson interaction clocks (Theorem 4.1)
    Geometric(f64),
}

impl LocalSteps {
    pub fn mean(&self) -> f64 {
        match *self {
            LocalSteps::Fixed(h) => h as f64,
            LocalSteps::Geometric(h) => h,
        }
    }

    pub(crate) fn sample(&self, rng: &mut Pcg64) -> u64 {
        match *self {
            LocalSteps::Fixed(h) => h,
            LocalSteps::Geometric(h) => rng.geometric(h),
        }
    }
}

/// How the pairwise averaging step is performed.
#[derive(Clone, Copy, Debug)]
pub enum AveragingMode {
    /// Algorithm 1: rendezvous, average live models.
    Blocking,
    /// Algorithm 2: average against stale communication copies.
    NonBlocking,
    /// Appendix G: non-blocking + lattice-quantized exchange.
    Quantized { bits: u32, eps: f32 },
}

/// SwarmSGD as an [`Algorithm`]: uniform random edges, `H` local steps per
/// endpoint, pairwise averaging per the configured mode.
#[derive(Clone, Copy, Debug)]
pub struct SwarmSgd {
    pub local_steps: LocalSteps,
    pub mode: AveragingMode,
}

impl SwarmSgd {
    pub fn nonblocking(h: u64) -> Self {
        Self { local_steps: LocalSteps::Fixed(h), mode: AveragingMode::NonBlocking }
    }

    /// The pairwise interaction body, shared with [`super::PoissonSwarm`]
    /// (which differs only in how the edge sequence is scheduled). The
    /// decode + average traversals run through the fused kernels selected
    /// by `scratch.kernel`, with `scratch.publish` as the per-endpoint
    /// average buffer — zero allocation per interaction.
    pub(crate) fn interact_pair(
        &self,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut MergeScratch,
    ) -> EventOutcome {
        let (ni, nj) = pair(parts);
        local_phase(ctx, ev.nodes[0], ni, ev.h[0]);
        local_phase(ctx, ev.nodes[1], nj, ev.h[1]);
        let full_bytes = ctx.cost.wire_bytes(ctx.dim);
        let kern = scratch.kernel;
        let outcome = match self.mode {
            AveragingMode::Blocking => {
                kernels::avg_into_both(kern, &mut ni.params, &mut nj.params);
                ni.comm.copy_from_slice(&ni.params);
                nj.comm.copy_from_slice(&nj.params);
                // rendezvous: both wait for the later endpoint, both pay
                // the NIC (Alg. 1 blocks)
                let exch = ctx.cost.exchange_time(full_bytes);
                let done = ni.time.max(nj.time) + exch;
                ni.time = done;
                nj.time = done;
                ni.comm_time += exch;
                nj.comm_time += exch;
                EventOutcome { bits: 2 * 8 * full_bytes, fallbacks: 0 }
            }
            mode => {
                // read both communication copies BEFORE either update
                ni.inbox.copy_from_slice(&nj.comm);
                nj.inbox.copy_from_slice(&ni.comm);
                let quant = match mode {
                    AveragingMode::Quantized { bits, eps } => Some((bits, eps)),
                    _ => None,
                };
                // event-local randomness: the two one-way quantizer seeds
                let mut er = Pcg64::seed(ev.seed);
                let seed_i = er.next_u32(); // for i's incoming (from j)
                let seed_j = er.next_u32(); // for j's incoming (from i)
                let mut fallbacks = 0u64;
                let wire = endpoint_update(
                    ni,
                    quant,
                    seed_i,
                    &mut fallbacks,
                    kern,
                    &mut scratch.publish[..ctx.dim],
                ) + endpoint_update(
                    nj,
                    quant,
                    seed_j,
                    &mut fallbacks,
                    kern,
                    &mut scratch.publish[..ctx.dim],
                );
                // time/bit accounting: the initiator pays the exchange;
                // the partner is not delayed (the "nobody waits" property)
                let (exch, bits) = match quant {
                    None => (ctx.cost.exchange_time(full_bytes), 2 * 8 * full_bytes),
                    Some(_) => {
                        let wire_bits = ctx.cost.scale_bits(wire, ctx.dim);
                        (ctx.cost.exchange_time(wire_bits.div_ceil(8)), wire_bits)
                    }
                };
                ni.time += exch;
                ni.comm_time += exch;
                EventOutcome { bits, fallbacks }
            }
        };
        ni.interactions += 1;
        nj.interactions += 1;
        outcome
    }
}

/// Apply the Appendix-F update to one endpoint in a single fused traversal:
/// decode the incoming copy (in `st.inbox`) against the node's snapshot and
/// average it with the snapshot into `avg` (`(S + X')/2`, one pass through
/// the selected kernel), then replay the delta rule — `X' ← avg`,
/// `X ← avg + (X − S)` — bit-identically to the historical
/// `quantized_transfer` + `nonblocking_update` pair. Returns wire bits
/// consumed (0 when not quantizing).
fn endpoint_update(
    st: &mut NodeState,
    quant: Option<(u32, f32)>,
    seed: u32,
    fallbacks: &mut u64,
    kern: kernels::Kernel,
    avg: &mut [f32],
) -> u64 {
    let mut wire = 0u64;
    match quant {
        None => kernels::avg_into(kern, &st.snap, &st.inbox, avg),
        Some((bits, eps)) => {
            let (b, fb) =
                kernels::lattice_qavg_into(kern, &st.inbox, &st.snap, eps, bits, seed, avg);
            wire = b;
            if fb {
                *fallbacks += 1;
            }
        }
    }
    for k in 0..avg.len() {
        let delta = st.params[k] - st.snap[k];
        st.comm[k] = avg[k];
        st.params[k] = avg[k] + delta;
    }
    wire
}

impl Algorithm for SwarmSgd {
    fn name(&self) -> &'static str {
        "swarm"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(n >= 2, "gossip needs n >= 2");
        let mut s = InteractionSchedule::new(n);
        for t in 0..events {
            // scenario-constrained pair: the graph in force at tick t, with
            // rate-weighted initiators under a speed class (the uniform
            // default is the historical edge draw, bit-for-bit)
            let (i, j) = scn.sample_pair(t, rng);
            let hi = self.local_steps.sample(rng);
            let hj = self.local_steps.sample(rng);
            let seed = rng.next_u64();
            s.push_gossip(i, j, hi, hj, seed);
        }
        s
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = MergeScratch::with_kernel(ctx.dim, self.kernel());
        self.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut MergeScratch,
    ) -> EventOutcome {
        self.interact_pair(ev, parts, ctx, scratch)
    }

    /// All three averaging modes have free-running semantics: plain-model
    /// slots, with the quantized variant decomposed into its two real axes
    /// (non-blocking merge + lattice wire codec).
    fn mix_policy(&self) -> Option<Box<dyn super::MixPolicy>> {
        use super::{PairMerge, PairwisePolicy, WireCodec};
        let (merge, wire) = match self.mode {
            AveragingMode::Blocking => (PairMerge::Live, WireCodec::F32),
            AveragingMode::NonBlocking => (PairMerge::NonBlocking, WireCodec::F32),
            AveragingMode::Quantized { bits, eps } => {
                (PairMerge::NonBlocking, WireCodec::Lattice { bits, eps })
            }
        };
        Some(Box::new(PairwisePolicy { steps: self.local_steps, merge, wire }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    fn graph(n: usize) -> Graph {
        let mut rng = Pcg64::seed(5);
        Graph::build(Topology::Complete, n, &mut rng)
    }

    fn spec(n: usize, t: u64) -> RunSpec {
        RunSpec {
            n,
            events: t,
            lr: LrSchedule::Constant(0.05),
            seed: 1,
            name: "test".into(),
            eval_every: 100,
            track_gamma: true,
        }
    }

    fn run_mode(mode: AveragingMode, h: LocalSteps) -> (crate::coordinator::RunMetrics, f64) {
        let n = 8;
        let backend = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.1, 11);
        let f_star = backend.f_star();
        let gap0 = {
            use crate::backend::Backend;
            let (p, _) = backend.init();
            backend.full_loss(&p) - f_star
        };
        let algo = SwarmSgd { local_steps: h, mode };
        let cost = CostModel::deterministic(0.4);
        let m = run_serial(&algo, &backend, &spec(n, 800), &graph(n), &cost);
        let gap = (m.final_eval_loss - f_star) / gap0;
        (m, gap)
    }

    #[test]
    fn blocking_converges_on_quadratic() {
        let (_, gap) = run_mode(AveragingMode::Blocking, LocalSteps::Fixed(2));
        assert!(gap < 0.1, "normalized gap {gap}");
    }

    #[test]
    fn nonblocking_converges_on_quadratic() {
        let (_, gap) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(2));
        assert!(gap < 0.1, "normalized gap {gap}");
    }

    #[test]
    fn geometric_steps_converge() {
        let (m, gap) = run_mode(AveragingMode::NonBlocking, LocalSteps::Geometric(3.0));
        assert!(gap < 0.1, "normalized gap {gap}");
        // geometric sampling actually produced variable counts
        assert!(m.local_steps > 0);
    }

    #[test]
    fn quantized_converges_and_saves_bits() {
        // larger model so the O(log T) header amortizes (paper: d >> log T)
        let n = 8;
        let run = |mode: AveragingMode| {
            let backend = QuadraticOracle::new(256, n, 1.0, 0.5, 2.0, 0.05, 21);
            let f_star = backend.f_star();
            let gap0 = {
                use crate::backend::Backend;
                let (p, _) = backend.init();
                backend.full_loss(&p) - f_star
            };
            let algo = SwarmSgd { local_steps: LocalSteps::Fixed(2), mode };
            let cost = CostModel::deterministic(0.4);
            let m = run_serial(&algo, &backend, &spec(n, 800), &graph(n), &cost);
            ((m.final_eval_loss - f_star) / gap0, m)
        };
        let (gap, mq) = run(AveragingMode::Quantized { bits: 8, eps: 1e-2 });
        let (_, mf) = run(AveragingMode::NonBlocking);
        assert!(gap < 0.1, "normalized gap {gap}");
        assert!(
            (mq.total_bits as f64) < 0.5 * mf.total_bits as f64,
            "quantized {} vs full {} (fallbacks {})",
            mq.total_bits,
            mf.total_bits,
            mq.quant_fallbacks
        );
    }

    #[test]
    fn gamma_stays_bounded() {
        let (m, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(4));
        let gammas: Vec<f64> =
            m.curve.iter().map(|p| p.gamma).filter(|g| g.is_finite()).collect();
        assert!(!gammas.is_empty());
        // potential must not blow up over the run (Lemma F.3: bounded in t)
        let first = gammas[0];
        let last = *gammas.last().unwrap();
        assert!(last < 100.0 * first.max(1e-3), "Γ grew: {first} -> {last}");
    }

    #[test]
    fn nonblocking_is_faster_than_blocking_in_sim_time() {
        let (mb, _) = run_mode(AveragingMode::Blocking, LocalSteps::Fixed(2));
        let (mn, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(2));
        assert!(
            mn.sim_time < mb.sim_time,
            "non-blocking {} should beat blocking {}",
            mn.sim_time,
            mb.sim_time
        );
    }

    #[test]
    fn interactions_and_steps_accounted() {
        let (m, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(3));
        assert_eq!(m.interactions, 800);
        assert_eq!(m.local_steps, 800 * 2 * 3); // two endpoints × H
        assert!(m.total_bits > 0);
        assert!(m.sim_time > 0.0);
    }
}
