//! SwarmSGD — Algorithms 1 & 2 and the quantized variant, faithful to the
//! paper's update rules:
//!
//! **Blocking (Alg. 1)**: sample edge (i,j); each endpoint runs `H` local
//! SGD steps on its live model; both set `X ← (X_i + X_j)/2`.
//!
//! **Non-blocking (Alg. 2 / Appendix F)**: partners exchange *communication
//! copies* `X' = X_{p+1/2}` — the averaged model from the node's previous
//! interaction, **missing** its in-flight local-gradient batch — so nobody
//! waits:
//! ```text
//!   S_i = X_i;  X_i ← H_i local steps;  Δ_i = X_i − S_i
//!   X_i ← (S_i + X_j')/2 + Δ_i          (and symmetrically for j)
//!   X_i' ← (S_i + X_j')/2               (next round's communication copy)
//! ```
//!
//! **Quantized (Appendix G)**: same as non-blocking, but the incoming copy
//! crosses the wire through the lattice codec; decode failures (distance
//! criterion violated) fall back to full precision and are counted.
//!
//! Local step counts are fixed (`H`) or geometric with mean `H` — the two
//! regimes of Theorems 4.2 and 4.1 respectively.

use super::cluster::{nonblocking_update, quantized_transfer, Cluster};
use super::engine::NodeClocks;
use super::metrics::{CurvePoint, RunMetrics};
use super::{LrSchedule, RunContext};

/// Distribution of the number of local SGD steps between interactions.
#[derive(Clone, Copy, Debug)]
pub enum LocalSteps {
    /// exactly H steps (Theorem 4.2 regime)
    Fixed(u64),
    /// geometric with mean H — Poisson interaction clocks (Theorem 4.1)
    Geometric(f64),
}

impl LocalSteps {
    pub fn mean(&self) -> f64 {
        match *self {
            LocalSteps::Fixed(h) => h as f64,
            LocalSteps::Geometric(h) => h,
        }
    }

    fn sample(&self, rng: &mut crate::rngx::Pcg64) -> u64 {
        match *self {
            LocalSteps::Fixed(h) => h,
            LocalSteps::Geometric(h) => rng.geometric(h),
        }
    }
}

/// How the pairwise averaging step is performed.
#[derive(Clone, Copy, Debug)]
pub enum AveragingMode {
    /// Algorithm 1: rendezvous, average live models.
    Blocking,
    /// Algorithm 2: average against stale communication copies.
    NonBlocking,
    /// Appendix G: non-blocking + lattice-quantized exchange.
    Quantized { bits: u32, eps: f32 },
}

/// Full SwarmSGD run configuration.
#[derive(Clone, Debug)]
pub struct SwarmConfig {
    pub n: usize,
    pub local_steps: LocalSteps,
    pub mode: AveragingMode,
    pub lr: LrSchedule,
    /// total pairwise interactions T
    pub interactions: u64,
    pub seed: u64,
    pub name: String,
}

impl SwarmConfig {
    pub fn basic(n: usize, h: u64, lr: f32, interactions: u64) -> Self {
        Self {
            n,
            local_steps: LocalSteps::Fixed(h),
            mode: AveragingMode::NonBlocking,
            lr: LrSchedule::Constant(lr),
            interactions,
            seed: 0x5EED,
            name: "swarm".into(),
        }
    }
}

/// Executes SwarmSGD over a [`RunContext`]; owns the agents and clocks.
pub struct SwarmRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: SwarmConfig,
    // scratch buffers (no allocation on the interaction hot path)
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    comm_a: Vec<f32>,
    comm_b: Vec<f32>,
}

impl SwarmRunner {
    pub fn new(cfg: SwarmConfig, ctx: &mut RunContext) -> Self {
        assert_eq!(cfg.n, ctx.graph.n(), "config n must match graph");
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        let dim = cluster.dim;
        Self {
            clocks: NodeClocks::new(cfg.n),
            cluster,
            cfg,
            scratch_a: vec![0.0; dim],
            scratch_b: vec![0.0; dim],
            comm_a: vec![0.0; dim],
            comm_b: vec![0.0; dim],
        }
    }

    /// Run to completion, returning the metrics record.
    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let total = self.cfg.interactions;
        for t in 1..=total {
            self.interact(ctx, t, &mut m);
            let at_eval = ctx.eval_every > 0 && t % ctx.eval_every == 0;
            if at_eval || t == total {
                self.record_point(ctx, t, &mut m);
            }
        }
        m.interactions = total;
        m.local_steps = self.cluster.total_steps();
        m.sim_time = self.clocks.max_time();
        m.compute_time_total = self.clocks.compute_total;
        m.comm_time_total = self.clocks.comm_total;
        m.epochs = self.mean_epochs(ctx);
        m.executor = "serial".into();
        if let Some(p) = m.curve.last() {
            m.final_eval_loss = p.eval_loss;
            m.final_eval_acc = p.eval_acc;
        }
        m
    }

    fn mean_epochs(&self, ctx: &mut RunContext) -> f64 {
        (0..self.cfg.n).map(|i| ctx.backend.epochs(i)).sum::<f64>() / self.cfg.n as f64
    }

    /// One step of the paper's process: sample an edge, run local steps on
    /// both endpoints, average per the configured mode, charge time.
    fn interact(&mut self, ctx: &mut RunContext, t: u64, m: &mut RunMetrics) {
        let (i, j) = ctx.graph.sample_edge(ctx.rng);
        let lr = self.cfg.lr.at(t);
        let hi = self.cfg.local_steps.sample(ctx.rng);
        let hj = self.cfg.local_steps.sample(ctx.rng);
        let d = self.cluster.dim;
        let full_bytes = ctx.cost.wire_bytes(d);

        // --- local SGD phases (both endpoints) ---
        // S_k snapshots for the non-blocking delta
        self.scratch_a.copy_from_slice(&self.cluster.agents[i].params);
        self.scratch_b.copy_from_slice(&self.cluster.agents[j].params);
        let mut comp_i = 0.0;
        let mut comp_j = 0.0;
        {
            let a = &mut self.cluster.agents[i];
            a.last_loss = ctx.backend.step_burst(i, &mut a.params, &mut a.mom, lr, hi);
            a.steps += hi;
            for _ in 0..hi {
                comp_i += ctx.cost.compute_time(&mut a.rng);
            }
        }
        {
            let a = &mut self.cluster.agents[j];
            a.last_loss = ctx.backend.step_burst(j, &mut a.params, &mut a.mom, lr, hj);
            a.steps += hj;
            for _ in 0..hj {
                comp_j += ctx.cost.compute_time(&mut a.rng);
            }
        }
        self.clocks.charge_compute(i, comp_i);
        self.clocks.charge_compute(j, comp_j);

        // --- averaging phase ---
        match self.cfg.mode {
            AveragingMode::Blocking => {
                let (ai, aj) = self.cluster.pair_mut(i, j);
                super::cluster::average_into_both(&mut ai.params, &mut aj.params);
                ai.comm.copy_from_slice(&ai.params);
                aj.comm.copy_from_slice(&aj.params);
                // both models cross the wire; rendezvous (Alg. 1 blocks)
                self.clocks.rendezvous(i, j, ctx.cost.exchange_time(full_bytes));
                m.total_bits += 2 * 8 * full_bytes;
            }
            AveragingMode::NonBlocking => {
                self.nonblocking_average(i, j, None, ctx, m);
                // initiator pays the exchange; partner is not delayed
                self.clocks.charge_comm(i, ctx.cost.exchange_time(full_bytes));
                m.total_bits += 2 * 8 * full_bytes;
            }
            AveragingMode::Quantized { bits, eps } => {
                let q = Some((bits, eps));
                let raw_bits = self.nonblocking_average(i, j, q, ctx, m);
                let wire_bits = ctx.cost.scale_bits(raw_bits, d);
                let bytes = wire_bits.div_ceil(8);
                self.clocks.charge_comm(i, ctx.cost.exchange_time(bytes));
                m.total_bits += wire_bits;
            }
        }
        self.cluster.agents[i].interactions += 1;
        self.cluster.agents[j].interactions += 1;
    }

    /// Appendix-F averaging. `scratch_a`/`scratch_b` hold S_i/S_j on entry.
    /// Returns total wire bits when quantizing (0 otherwise — the caller
    /// accounts full precision itself).
    fn nonblocking_average(
        &mut self,
        i: usize,
        j: usize,
        quant: Option<(u32, f32)>,
        _ctx: &mut RunContext,
        m: &mut RunMetrics,
    ) -> u64 {
        let mut wire = 0u64;
        // read both communication copies BEFORE either write (into scratch —
        // no allocation on the hot path)
        self.comm_a.copy_from_slice(&self.cluster.agents[i].comm);
        self.comm_b.copy_from_slice(&self.cluster.agents[j].comm);
        let seed_ij = self.cluster.agents[i].rng.next_u32();
        let seed_ji = self.cluster.agents[j].rng.next_u32();

        // incoming copy for i (from j) and for j (from i), possibly quantized
        // (yi = comm_a, yj = comm_b)
        if let Some((bits, eps)) = quant {
            // receiver's reference is its own snapshot S (closest local
            // state to the sender under the Γ bound)
            let ti = quantized_transfer(&self.comm_b, &self.scratch_a, eps, bits, seed_ij);
            let tj = quantized_transfer(&self.comm_a, &self.scratch_b, eps, bits, seed_ji);
            wire += ti.bits + tj.bits;
            m.quant_fallbacks += u64::from(ti.fell_back) + u64::from(tj.fell_back);
            self.comm_b.copy_from_slice(&ti.decoded);
            self.comm_a.copy_from_slice(&tj.decoded);
        }

        // X_i ← (S_i + inc)/2 + Δ_i ;  comm_i ← (S_i + inc)/2
        {
            let a = &mut self.cluster.agents[i];
            nonblocking_update(&mut a.params, &mut a.comm, &self.scratch_a, &self.comm_b);
        }
        {
            let a = &mut self.cluster.agents[j];
            nonblocking_update(&mut a.params, &mut a.comm, &self.scratch_b, &self.comm_a);
        }
        wire
    }

    fn record_point(&mut self, ctx: &mut RunContext, t: u64, m: &mut RunMetrics) {
        let mu = self.cluster.mean_model();
        let ev = ctx.backend.eval(&mu);
        // an arbitrary individual model (paper compares μ vs individual)
        let pick = ctx.rng.below_usize(self.cfg.n);
        let ind = ctx.backend.eval(&self.cluster.agents[pick].params);
        let gamma = if ctx.track_gamma { self.cluster.gamma() } else { f64::NAN };
        m.push(CurvePoint {
            t,
            parallel_time: t as f64 / self.cfg.n as f64,
            sim_time: self.clocks.max_time(),
            epochs: self.mean_epochs(ctx),
            train_loss: self.cluster.mean_train_loss(),
            eval_loss: ev.loss,
            eval_acc: ev.accuracy,
            indiv_loss: ind.loss,
            gamma,
            bits: m.total_bits,
        });
    }

    /// The mean model after training (what gets deployed).
    pub fn mean_model(&self) -> Vec<f32> {
        self.cluster.mean_model()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    fn ctx_parts(
        n: usize,
    ) -> (QuadraticOracle, Graph, CostModel, Pcg64) {
        let backend = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.1, 11);
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        (backend, graph, CostModel::deterministic(0.4), Pcg64::seed(6))
    }

    fn run_mode(mode: AveragingMode, h: LocalSteps) -> (RunMetrics, f64) {
        let n = 8;
        let (mut backend, graph, cost, mut rng) = ctx_parts(n);
        // initial suboptimality gap f(x0) − f*
        let gap0 = {
            use crate::backend::TrainBackend;
            let (p, _) = backend.init(0);
            backend.full_loss(&p) - backend.f_star()
        };
        let f_star = backend.f_star();
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 100,
            track_gamma: true,
        };
        let cfg = SwarmConfig {
            n,
            local_steps: h,
            mode,
            lr: LrSchedule::Constant(0.05),
            interactions: 800,
            seed: 1,
            name: "test".into(),
        };
        let mut runner = SwarmRunner::new(cfg, &mut ctx);
        let m = runner.run(&mut ctx);
        // return metrics + the normalized final gap (f(μ_T) − f*)/(f(x₀) − f*)
        let gap = (m.final_eval_loss - f_star) / gap0;
        (m, gap)
    }

    #[test]
    fn blocking_converges_on_quadratic() {
        let (_, gap) = run_mode(AveragingMode::Blocking, LocalSteps::Fixed(2));
        assert!(gap < 0.1, "normalized gap {gap}");
    }

    #[test]
    fn nonblocking_converges_on_quadratic() {
        let (_, gap) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(2));
        assert!(gap < 0.1, "normalized gap {gap}");
    }

    #[test]
    fn geometric_steps_converge() {
        let (m, gap) = run_mode(AveragingMode::NonBlocking, LocalSteps::Geometric(3.0));
        assert!(gap < 0.1, "normalized gap {gap}");
        // geometric sampling actually produced variable counts
        assert!(m.local_steps > 0);
    }

    #[test]
    fn quantized_converges_and_saves_bits() {
        // larger model so the O(log T) header amortizes (paper: d >> log T)
        let n = 8;
        let run = |mode: AveragingMode| {
            let mut backend = QuadraticOracle::new(256, n, 1.0, 0.5, 2.0, 0.05, 21);
            let f_star = backend.f_star();
            let gap0 = {
                use crate::backend::TrainBackend;
                let (p, _) = backend.init(0);
                backend.full_loss(&p) - f_star
            };
            let mut rng = Pcg64::seed(9);
            let graph = Graph::build(Topology::Complete, n, &mut rng);
            let cost = CostModel::deterministic(0.4);
            let mut ctx = RunContext {
                backend: &mut backend,
                graph: &graph,
                cost: &cost,
                rng: &mut rng,
                eval_every: 200,
                track_gamma: false,
            };
            let cfg = SwarmConfig {
                n,
                local_steps: LocalSteps::Fixed(2),
                mode,
                lr: LrSchedule::Constant(0.05),
                interactions: 800,
                seed: 1,
                name: "q".into(),
            };
            let mut r = SwarmRunner::new(cfg, &mut ctx);
            let m = r.run(&mut ctx);
            ((m.final_eval_loss - f_star) / gap0, m)
        };
        let (gap, mq) = run(AveragingMode::Quantized { bits: 8, eps: 1e-2 });
        let (_, mf) = run(AveragingMode::NonBlocking);
        assert!(gap < 0.1, "normalized gap {gap}");
        assert!(
            (mq.total_bits as f64) < 0.5 * mf.total_bits as f64,
            "quantized {} vs full {} (fallbacks {})",
            mq.total_bits,
            mf.total_bits,
            mq.quant_fallbacks
        );
    }

    #[test]
    fn gamma_stays_bounded() {
        let (m, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(4));
        let gammas: Vec<f64> =
            m.curve.iter().map(|p| p.gamma).filter(|g| g.is_finite()).collect();
        assert!(!gammas.is_empty());
        // potential must not blow up over the run (Lemma F.3: bounded in t)
        let first = gammas[0];
        let last = *gammas.last().unwrap();
        assert!(last < 100.0 * first.max(1e-3), "Γ grew: {first} -> {last}");
    }

    #[test]
    fn nonblocking_is_faster_than_blocking_in_sim_time() {
        let (mb, _) = run_mode(AveragingMode::Blocking, LocalSteps::Fixed(2));
        let (mn, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(2));
        assert!(
            mn.sim_time < mb.sim_time,
            "non-blocking {} should beat blocking {}",
            mn.sim_time,
            mb.sim_time
        );
    }

    #[test]
    fn interactions_and_steps_accounted() {
        let (m, _) = run_mode(AveragingMode::NonBlocking, LocalSteps::Fixed(3));
        assert_eq!(m.interactions, 800);
        assert_eq!(m.local_steps, 800 * 2 * 3); // two endpoints × H
        assert!(m.total_bits > 0);
        assert!(m.sim_time > 0.0);
    }
}
