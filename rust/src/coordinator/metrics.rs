//! Run metrics: loss/accuracy curves on all the paper's axes
//! (interactions, parallel time, simulated seconds, epochs, bits).

use super::algorithm::NodeState;
use super::engine::NodeClocks;
use super::telemetry::FreerunStats;
use crate::backend::Backend;

/// One evaluation point along a run.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// interactions (gossip) or rounds (synchronous baselines)
    pub t: u64,
    /// parallel time = t / n
    pub parallel_time: f64,
    /// simulated wall-clock seconds (cost-model)
    pub sim_time: f64,
    /// mean fractional data epochs per agent
    pub epochs: f64,
    /// mean recent minibatch training loss
    pub train_loss: f64,
    /// held-out loss of the mean model μ_t
    pub eval_loss: f64,
    /// held-out accuracy of the mean model (NaN if not applicable)
    pub eval_acc: f64,
    /// held-out loss of a uniformly chosen *individual* model
    /// (paper §5: "the real average ... is usually more accurate than an
    /// arbitrary model, but not significantly")
    pub indiv_loss: f64,
    /// Γ_t potential (NaN if not tracked)
    pub gamma: f64,
    /// cumulative bits on the wire
    pub bits: u64,
}

/// Aggregated result of one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub name: String,
    pub curve: Vec<CurvePoint>,
    pub interactions: u64,
    pub local_steps: u64,
    pub total_bits: u64,
    pub sim_time: f64,
    pub compute_time_total: f64,
    pub comm_time_total: f64,
    /// quantizer checksum failures that fell back to full precision
    pub quant_fallbacks: u64,
    /// final evaluation
    pub final_eval_loss: f64,
    pub final_eval_acc: f64,
    /// consensus (deployable) model at the last evaluation point
    pub final_model: Vec<f32>,
    /// mean data epochs per agent at the end
    pub epochs: f64,
    /// which executor produced this run ("serial" | "parallel" | "freerun")
    pub executor: String,
    /// worker threads the executor ran with (serial runs report 1)
    pub threads: usize,
    /// fused merge-kernel implementation the run dispatched to
    /// ("scalar" | "simd") — tags bench rows with the `--kernel` axis
    pub kernel: String,
    /// contention/staleness telemetry — only the free-running executor
    /// produces it; `None` for the replay executors
    pub freerun: Option<FreerunStats>,
    /// drained trace events when the run executed with tracing enabled
    /// (`--trace-out` via [`crate::obs::ObsOptions`]); the CLI serializes
    /// this into Chrome trace-event JSON
    pub trace: Option<crate::obs::TraceDrain>,
}

impl RunMetrics {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Self::default() }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.curve.push(p);
    }

    /// Fill the aggregate tail every executor shares, from the final node
    /// states: totals (steps, bits, fallbacks), per-node f64 clock
    /// reductions in node-index order (bit-identical across executors),
    /// epochs, the executor and kernel tags, and the final eval from the
    /// last curve point. Call after the last curve point is pushed.
    pub(super) fn finalize(
        &mut self,
        states: &[NodeState],
        backend: &dyn Backend,
        total: u64,
        total_bits: u64,
        quant_fallbacks: u64,
        executor: &str,
        threads: usize,
        kernel: &str,
    ) {
        let clocks = NodeClocks::from_parts(
            states.iter().map(|s| s.time).collect(),
            states.iter().map(|s| s.compute).sum(),
            states.iter().map(|s| s.comm_time).sum(),
        );
        self.interactions = total;
        self.local_steps = states.iter().map(|s| s.steps).sum();
        self.sim_time = clocks.max_time();
        self.compute_time_total = clocks.compute_total;
        self.comm_time_total = clocks.comm_total;
        self.total_bits = total_bits;
        self.quant_fallbacks = quant_fallbacks;
        self.epochs = states
            .iter()
            .enumerate()
            .map(|(i, s)| backend.epochs(i, s.steps))
            .sum::<f64>()
            / states.len().max(1) as f64;
        self.executor = executor.to_string();
        self.threads = threads;
        self.kernel = kernel.to_string();
        if let Some(p) = self.curve.last() {
            self.final_eval_loss = p.eval_loss;
            self.final_eval_acc = p.eval_acc;
        }
    }

    /// Average communication seconds per local step per node — the y-axis of
    /// the paper's Figure 4 (above the 0.4 s compute base).
    pub fn comm_per_step(&self, n: usize) -> f64 {
        if self.local_steps == 0 {
            return 0.0;
        }
        let _ = n;
        self.comm_time_total / self.local_steps as f64
    }

    /// Best (lowest) eval loss seen along the curve. NaN entries are
    /// skipped (a NaN operand would poison a plain min fold); returns NaN
    /// only when no finite point exists.
    pub fn best_eval_loss(&self) -> f64 {
        let best = self
            .curve
            .iter()
            .map(|p| p.eval_loss)
            .filter(|l| l.is_finite())
            .fold(f64::INFINITY, f64::min);
        if best.is_finite() {
            best
        } else {
            f64::NAN
        }
    }

    /// Best accuracy seen along the curve. The quadratic oracle emits NaN
    /// accuracy (no accuracy notion); those entries must not poison the max
    /// fold. Returns NaN when the curve has no finite accuracy at all.
    pub fn best_eval_acc(&self) -> f64 {
        let mut best = f64::NAN;
        for a in self.curve.iter().map(|p| p.eval_acc).filter(|a| a.is_finite()) {
            if best.is_nan() || a > best {
                best = a;
            }
        }
        best
    }

    /// First simulated time at which eval loss ≤ target (None if never).
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss.is_finite() && p.eval_loss <= target)
            .map(|p| p.sim_time)
    }

    /// Throughput: local steps per simulated second.
    pub fn steps_per_sec(&self) -> f64 {
        if self.sim_time > 0.0 {
            self.local_steps as f64 / self.sim_time
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(t: u64, loss: f64, time: f64) -> CurvePoint {
        CurvePoint {
            t,
            parallel_time: t as f64,
            sim_time: time,
            epochs: 0.0,
            train_loss: loss,
            eval_loss: loss,
            eval_acc: 1.0 - loss,
            indiv_loss: loss,
            gamma: f64::NAN,
            bits: 0,
        }
    }

    #[test]
    fn best_and_time_to_loss() {
        let mut m = RunMetrics::new("x");
        m.push(pt(0, 1.0, 0.0));
        m.push(pt(10, 0.5, 1.0));
        m.push(pt(20, 0.7, 2.0));
        assert_eq!(m.best_eval_loss(), 0.5);
        assert_eq!(m.time_to_loss(0.6), Some(1.0));
        assert_eq!(m.time_to_loss(0.1), None);
        assert!((m.best_eval_acc() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nan_eval_entries_do_not_poison_best_folds() {
        // regression: the quadratic oracle emits NaN accuracy for every
        // point (and a curve can contain NaN losses from divergent runs);
        // best_* must skip them instead of folding NaN through min/max
        let mut m = RunMetrics::new("nan");
        let mut a = pt(0, 1.0, 0.0);
        a.eval_acc = f64::NAN;
        let mut b = pt(10, f64::NAN, 1.0);
        b.eval_acc = 0.75;
        let mut c = pt(20, 0.4, 2.0);
        c.eval_acc = f64::NAN;
        m.push(a);
        m.push(b);
        m.push(c);
        assert_eq!(m.best_eval_loss(), 0.4);
        assert_eq!(m.best_eval_acc(), 0.75);

        // all-NaN curves report NaN, not ±∞/0.0 sentinels
        let mut all_nan = RunMetrics::new("allnan");
        let mut p = pt(0, f64::NAN, 0.0);
        p.eval_acc = f64::NAN;
        all_nan.push(p);
        assert!(all_nan.best_eval_loss().is_nan());
        assert!(all_nan.best_eval_acc().is_nan());
        assert!(RunMetrics::new("empty").best_eval_loss().is_nan());
        assert!(RunMetrics::new("empty").best_eval_acc().is_nan());
    }

    #[test]
    fn throughput() {
        let mut m = RunMetrics::new("x");
        m.local_steps = 100;
        m.sim_time = 50.0;
        assert_eq!(m.steps_per_sec(), 2.0);
        m.comm_time_total = 25.0;
        assert_eq!(m.comm_per_step(4), 0.25);
    }
}
