//! Per-node simulated clocks — the discrete-event core (DESIGN.md S10).
//!
//! The logical algorithm (who interacts with whom, in what order) follows
//! the paper's model exactly: a uniformly random edge per step.  This module
//! supplies the *time* axis: each node owns a clock; compute and
//! communication charges advance it; rendezvous semantics differ between
//! blocking (clocks synchronize at the interaction) and non-blocking (the
//! partner is not delayed).  "Parallel time" = interactions / n is also
//! tracked for the theory figures.

/// Simulated per-node clocks (seconds) plus aggregate accounting.
#[derive(Clone, Debug)]
pub struct NodeClocks {
    t: Vec<f64>,
    /// total seconds spent computing across nodes
    pub compute_total: f64,
    /// total seconds spent communicating across nodes
    pub comm_total: f64,
}

impl NodeClocks {
    pub fn new(n: usize) -> Self {
        Self { t: vec![0.0; n], compute_total: 0.0, comm_total: 0.0 }
    }

    /// Reassemble clocks from per-node recordings — used by the parallel
    /// executor, which accounts time inside each node's state (no shared
    /// mutable clock on the hot path) and merges once at the end. Callers
    /// must reduce the per-node totals in node-index order so the f64 sums
    /// are bit-identical to a serial replay.
    pub fn from_parts(t: Vec<f64>, compute_total: f64, comm_total: f64) -> Self {
        Self { t, compute_total, comm_total }
    }

    pub fn n(&self) -> usize {
        self.t.len()
    }

    pub fn get(&self, i: usize) -> f64 {
        self.t[i]
    }

    /// Charge compute time to node `i`.
    pub fn charge_compute(&mut self, i: usize, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t[i] += dt;
        self.compute_total += dt;
    }

    /// Charge communication time to node `i`.
    pub fn charge_comm(&mut self, i: usize, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t[i] += dt;
        self.comm_total += dt;
    }

    /// Blocking rendezvous: both nodes wait for the later one, then both pay
    /// the exchange; returns the completion time.
    pub fn rendezvous(&mut self, i: usize, j: usize, exchange: f64) -> f64 {
        let meet = self.t[i].max(self.t[j]);
        // waiting is idle time (charged to neither bucket, but clocks move)
        let done = meet + exchange;
        self.comm_total += exchange * 2.0; // both endpoints occupy their NIC
        self.t[i] = done;
        self.t[j] = done;
        done
    }

    /// Synchronous-round barrier: everyone advances to the global max, then
    /// pays `cost` together (allreduce / matching round). Returns new time.
    pub fn barrier_all(&mut self, cost: f64) -> f64 {
        let meet = self.t.iter().cloned().fold(0.0, f64::max);
        let done = meet + cost;
        self.comm_total += cost * self.t.len() as f64;
        for t in &mut self.t {
            *t = done;
        }
        done
    }

    /// Global simulated time = the furthest-ahead node (what a wall clock
    /// at the job level would read once everything drains).
    pub fn max_time(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Median node time — robust "cluster progress" measure for async runs.
    pub fn median_time(&self) -> f64 {
        let mut v = self.t.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut c = NodeClocks::new(3);
        c.charge_compute(0, 1.0);
        c.charge_comm(0, 0.5);
        c.charge_compute(1, 2.0);
        assert_eq!(c.get(0), 1.5);
        assert_eq!(c.get(1), 2.0);
        assert_eq!(c.get(2), 0.0);
        assert_eq!(c.compute_total, 3.0);
        assert_eq!(c.comm_total, 0.5);
    }

    #[test]
    fn rendezvous_synchronizes() {
        let mut c = NodeClocks::new(2);
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 3.0);
        let done = c.rendezvous(0, 1, 0.25);
        assert_eq!(done, 3.25);
        assert_eq!(c.get(0), 3.25);
        assert_eq!(c.get(1), 3.25);
    }

    #[test]
    fn barrier_includes_stragglers() {
        let mut c = NodeClocks::new(4);
        c.charge_compute(2, 5.0);
        let done = c.barrier_all(1.0);
        assert_eq!(done, 6.0);
        assert!((0..4).all(|i| c.get(i) == 6.0));
    }

    #[test]
    fn median_vs_max() {
        let mut c = NodeClocks::new(4);
        c.charge_compute(0, 10.0);
        assert_eq!(c.max_time(), 10.0);
        assert_eq!(c.median_time(), 0.0);
    }
}
