//! Per-node simulated clocks — the aggregate time view (DESIGN.md S10).
//!
//! The executors account time inside each [`super::NodeState`] (no shared
//! mutable clock on any hot path); this type reassembles those per-node
//! recordings into the paper's aggregate time axes once a run finishes.
//! The charging rules themselves (rendezvous max, synchronous barriers,
//! initiator-pays exchanges) live with the algorithms — see
//! [`super::barrier_all`] and the per-algorithm `interact` impls.

/// Simulated per-node clocks (seconds) plus aggregate accounting.
#[derive(Clone, Debug)]
pub struct NodeClocks {
    t: Vec<f64>,
    /// total seconds spent computing across nodes
    pub compute_total: f64,
    /// total seconds spent communicating across nodes
    pub comm_total: f64,
}

impl NodeClocks {
    /// Reassemble clocks from per-node recordings. Callers must reduce the
    /// per-node totals in node-index order so the f64 sums are bit-identical
    /// between serial and parallel executions.
    pub fn from_parts(t: Vec<f64>, compute_total: f64, comm_total: f64) -> Self {
        Self { t, compute_total, comm_total }
    }

    pub fn n(&self) -> usize {
        self.t.len()
    }

    pub fn get(&self, i: usize) -> f64 {
        self.t[i]
    }

    /// Global simulated time = the furthest-ahead node (what a wall clock
    /// at the job level would read once everything drains).
    pub fn max_time(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Median node time — robust "cluster progress" measure for async runs.
    pub fn median_time(&self) -> f64 {
        let mut v = self.t.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_parts_reassembles() {
        let c = NodeClocks::from_parts(vec![1.5, 2.0, 0.0], 3.0, 0.5);
        assert_eq!(c.n(), 3);
        assert_eq!(c.get(0), 1.5);
        assert_eq!(c.get(1), 2.0);
        assert_eq!(c.compute_total, 3.0);
        assert_eq!(c.comm_total, 0.5);
    }

    #[test]
    fn median_vs_max() {
        let c = NodeClocks::from_parts(vec![10.0, 0.0, 0.0, 0.0], 10.0, 0.0);
        assert_eq!(c.max_time(), 10.0);
        assert_eq!(c.median_time(), 0.0);
    }
}
