//! Poisson-clock scheduling — the paper's §2 model states that uniform
//! random edge sampling is equivalent to "random times given by a clock of
//! Poisson rate" per node (the asynchronous gossip model of Boyd et al.
//! [10]).
//!
//! Under the `Algorithm` API this is purely a *scheduling policy*: the
//! event queue semantics (each node rings at rate 1 and wakes a uniform
//! neighbor) live in `PoissonSwarm`'s `schedule`, while the interaction
//! body is delegated verbatim to [`SwarmSgd`]. The equivalence is therefore
//! testable on the schedule itself — the induced edge distribution must be
//! uniform on E for regular graphs — and training results must
//! statistically match the edge-sampling scheduler.

use super::algorithm::{Algorithm, Event, EventOutcome, InteractionSchedule, NodeState, StepCtx};
use super::swarm::{AveragingMode, LocalSteps, SwarmSgd};
use crate::rngx::Pcg64;
use crate::scenario::Scenario;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64-ordered entry for the clock heap.
#[derive(PartialEq)]
struct Ring {
    at: f64,
    node: usize,
}

impl Eq for Ring {}
impl PartialOrd for Ring {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ring {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.partial_cmp(&other.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// SwarmSGD driven by literal Poisson clocks instead of uniform edge draws.
#[derive(Clone, Copy, Debug)]
pub struct PoissonSwarm {
    inner: SwarmSgd,
}

impl PoissonSwarm {
    pub fn new(local_steps: LocalSteps, mode: AveragingMode) -> Self {
        Self { inner: SwarmSgd { local_steps, mode } }
    }
}

impl Algorithm for PoissonSwarm {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn schedule(
        &self,
        n: usize,
        events: u64,
        scn: &Scenario,
        rng: &mut Pcg64,
    ) -> InteractionSchedule {
        assert!(n >= 2, "gossip needs n >= 2");
        let mut s = InteractionSchedule::new(n);
        let mut heap: BinaryHeap<Reverse<Ring>> = BinaryHeap::new();
        // every node's clock rings at its scenario rate (1 under uniform
        // speeds — the same exponential(1.0) draw as always, bit-for-bit;
        // a speed class makes stragglers *structural*: a slow node's clock
        // is slow for the whole run)
        for node in 0..n {
            let dt = rng.exponential(scn.rate(node));
            heap.push(Reverse(Ring { at: dt, node }));
        }
        for t in 0..events {
            let Reverse(Ring { at, node: i }) = heap.pop().expect("heap never empty");
            // initiator wakes and picks a uniform random neighbor in the
            // graph in force at this tick
            let j = scn.sample_partner(i, t, rng);
            let hi = self.inner.local_steps.sample(rng);
            let hj = self.inner.local_steps.sample(rng);
            let seed = rng.next_u64();
            s.push_gossip(i, j, hi, hj, seed);
            // re-arm i's Poisson clock
            let dt = rng.exponential(scn.rate(i));
            heap.push(Reverse(Ring { at: at + dt, node: i }));
        }
        s
    }

    fn interact(
        &self,
        t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
    ) -> EventOutcome {
        let mut scratch = super::MergeScratch::with_kernel(ctx.dim, self.kernel());
        self.interact_with(t, ev, parts, ctx, &mut scratch)
    }

    fn interact_with(
        &self,
        _t: u64,
        ev: &Event,
        parts: &mut [&mut NodeState],
        ctx: &StepCtx<'_>,
        scratch: &mut super::MergeScratch,
    ) -> EventOutcome {
        self.inner.interact_pair(ev, parts, ctx, scratch)
    }

    /// Same policy as [`SwarmSgd`] — the free-running executor *is* the
    /// literal per-node Poisson-clock runtime this scheduler simulates.
    fn mix_policy(&self) -> Option<Box<dyn super::MixPolicy>> {
        self.inner.mix_policy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_serial, LrSchedule, RunSpec, SwarmSgd};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::topology::{Graph, Topology};

    fn algo() -> PoissonSwarm {
        PoissonSwarm::new(LocalSteps::Fixed(2), AveragingMode::NonBlocking)
    }

    #[test]
    fn poisson_clock_induces_uniform_edges() {
        // paper §2: Poisson clocks + uniform neighbor choice on a regular
        // graph ≡ uniform edge sampling. χ²-ish check over K8's 28 edges,
        // applied directly to the pre-drawn schedule.
        let n = 8;
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let mut srng = Pcg64::stream(1, 77);
        let scn = Scenario::static_graph(graph.clone());
        let sched = algo().schedule(n, 28_000, &scn, &mut srng);
        let mut counts = std::collections::HashMap::new();
        for ev in &sched.events {
            let (i, j) = (ev.nodes[0], ev.nodes[1]);
            *counts.entry((i.min(j), i.max(j))).or_insert(0u64) += 1;
        }
        assert_eq!(counts.len(), 28, "all edges must fire");
        let mean = 1000.0;
        for &c in counts.values() {
            assert!(
                (c as f64 - mean).abs() < 5.0 * mean.sqrt() + 30.0,
                "edge count {c} far from uniform mean {mean}"
            );
        }
    }

    #[test]
    fn poisson_converges_like_edge_sampling() {
        let n = 8;
        let backend = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.1, 11);
        let f_star = backend.f_star();
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.4);
        let spec = RunSpec {
            n,
            events: 1200,
            lr: LrSchedule::Constant(0.05),
            seed: 1,
            name: "poisson".into(),
            eval_every: 150,
            track_gamma: false,
        };
        let mp = run_serial(&algo(), &backend, &spec, &graph, &cost);
        let edge = SwarmSgd {
            local_steps: LocalSteps::Fixed(2),
            mode: AveragingMode::NonBlocking,
        };
        let me = run_serial(&edge, &backend, &spec, &graph, &cost);
        let gap_p = (mp.final_eval_loss - f_star).max(1e-9);
        let gap_e = (me.final_eval_loss - f_star).max(1e-9);
        let ratio = gap_p / gap_e;
        assert!(
            (0.2..5.0).contains(&ratio),
            "poisson gap {gap_p} vs edge-sampling gap {gap_e}"
        );
    }
}
