//! Event-driven Poisson-clock runner — the paper's §2 model states that
//! uniform random edge sampling is equivalent to "random times given by a
//! clock of Poisson rate" per node (the asynchronous gossip model of
//! Boyd et al. [10]). This runner implements the Poisson-clock semantics
//! *literally* with an event queue (each node rings at rate 1 and wakes a
//! uniform neighbor), so the equivalence is testable rather than assumed:
//! the induced edge distribution must be uniform on E for regular graphs,
//! and training results must statistically match the edge-sampling runner.

use super::cluster::Cluster;
use super::engine::NodeClocks;
use super::metrics::{CurvePoint, RunMetrics};
use super::swarm::{LocalSteps, SwarmConfig};
use super::RunContext;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64 ordered for the event heap.
#[derive(PartialEq)]
struct Event {
    at: f64,
    node: usize,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.partial_cmp(&other.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Poisson-clock SwarmSGD (non-blocking averaging only — the natural pair
/// for asynchronous clocks). Interactions stop after `cfg.interactions`.
pub struct PoissonRunner {
    pub cluster: Cluster,
    pub clocks: NodeClocks,
    cfg: SwarmConfig,
    /// per-edge interaction counts (for the equivalence test)
    pub edge_counts: std::collections::HashMap<(usize, usize), u64>,
}

impl PoissonRunner {
    pub fn new(cfg: SwarmConfig, ctx: &mut RunContext) -> Self {
        let cluster = Cluster::init(cfg.n, ctx.backend, cfg.seed);
        Self {
            clocks: NodeClocks::new(cfg.n),
            cluster,
            cfg,
            edge_counts: std::collections::HashMap::new(),
        }
    }

    pub fn run(&mut self, ctx: &mut RunContext) -> RunMetrics {
        let mut m = RunMetrics::new(&self.cfg.name);
        let n = self.cfg.n;
        let d = self.cluster.dim;
        let full_bytes = ctx.cost.wire_bytes(d);
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        // every node's clock rings at rate 1 (arbitrary time unit)
        for node in 0..n {
            let dt = ctx.rng.exponential(1.0);
            heap.push(Reverse(Event { at: dt, node }));
        }
        let mut t = 0u64;
        let mut scratch_i = vec![0.0f32; d];
        let mut scratch_j = vec![0.0f32; d];
        while t < self.cfg.interactions {
            let Reverse(Event { at, node: i }) = heap.pop().expect("heap never empty");
            // initiator wakes and picks a uniform random neighbor
            let j = ctx.graph.sample_neighbor(i, ctx.rng);
            t += 1;
            let key = (i.min(j), i.max(j));
            *self.edge_counts.entry(key).or_insert(0) += 1;
            let lr = self.cfg.lr.at(t);
            let (hi, hj) = match self.cfg.local_steps {
                LocalSteps::Fixed(h) => (h, h),
                LocalSteps::Geometric(h) => (ctx.rng.geometric(h), ctx.rng.geometric(h)),
            };
            // local phases
            scratch_i.copy_from_slice(&self.cluster.agents[i].params);
            scratch_j.copy_from_slice(&self.cluster.agents[j].params);
            for (node, h) in [(i, hi), (j, hj)] {
                let ag = &mut self.cluster.agents[node];
                ag.last_loss = ctx.backend.step_burst(node, &mut ag.params, &mut ag.mom, lr, h);
                ag.steps += h;
                let mut comp = 0.0;
                for _ in 0..h {
                    comp += ctx.cost.compute_time(&mut ag.rng);
                }
                self.clocks.charge_compute(node, comp);
            }
            // non-blocking averaging (Appendix F), same update as SwarmRunner
            let comm_i = self.cluster.agents[i].comm.clone();
            let comm_j = self.cluster.agents[j].comm.clone();
            for (node, s, inc) in [(i, &scratch_i, &comm_j), (j, &scratch_j, &comm_i)] {
                let a = &mut self.cluster.agents[node];
                super::cluster::nonblocking_update(&mut a.params, &mut a.comm, s, inc);
                a.interactions += 1;
            }
            self.clocks.charge_comm(i, ctx.cost.exchange_time(full_bytes));
            m.total_bits += 2 * 8 * full_bytes;
            // re-arm i's Poisson clock
            let dt = ctx.rng.exponential(1.0);
            heap.push(Reverse(Event { at: at + dt, node: i }));
            // metrics
            if (ctx.eval_every > 0 && t % ctx.eval_every == 0) || t == self.cfg.interactions {
                let mu = self.cluster.mean_model();
                let ev = ctx.backend.eval(&mu);
                let gamma = if ctx.track_gamma { self.cluster.gamma() } else { f64::NAN };
                m.push(CurvePoint {
                    t,
                    parallel_time: t as f64 / n as f64,
                    sim_time: self.clocks.max_time(),
                    epochs: 0.0,
                    train_loss: self.cluster.mean_train_loss(),
                    eval_loss: ev.loss,
                    eval_acc: ev.accuracy,
                    indiv_loss: f64::NAN,
                    gamma,
                    bits: m.total_bits,
                });
            }
        }
        m.interactions = self.cfg.interactions;
        m.local_steps = self.cluster.total_steps();
        m.sim_time = self.clocks.max_time();
        m.compute_time_total = self.clocks.compute_total;
        m.comm_time_total = self.clocks.comm_total;
        if let Some(p) = m.curve.last() {
            m.final_eval_loss = p.eval_loss;
            m.final_eval_acc = p.eval_acc;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AveragingMode, LrSchedule};
    use crate::grad::QuadraticOracle;
    use crate::netmodel::CostModel;
    use crate::rngx::Pcg64;
    use crate::topology::{Graph, Topology};

    fn run_poisson(t: u64) -> (RunMetrics, PoissonRunner) {
        let n = 8;
        let mut backend = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.1, 11);
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.4);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: (t / 8).max(1),
            track_gamma: true,
        };
        let cfg = SwarmConfig {
            n,
            local_steps: LocalSteps::Fixed(2),
            mode: AveragingMode::NonBlocking,
            lr: LrSchedule::Constant(0.05),
            interactions: t,
            seed: 1,
            name: "poisson".into(),
        };
        let mut r = PoissonRunner::new(cfg, &mut ctx);
        let m = r.run(&mut ctx);
        (m, r)
    }

    #[test]
    fn poisson_clock_induces_uniform_edges() {
        // paper §2: Poisson clocks + uniform neighbor choice on a regular
        // graph ≡ uniform edge sampling. χ²-ish check over K8's 28 edges.
        let (_, r) = run_poisson(28_000);
        let counts: Vec<u64> = r.edge_counts.values().copied().collect();
        assert_eq!(counts.len(), 28, "all edges must fire");
        let mean = 1000.0;
        for &c in &counts {
            assert!(
                (c as f64 - mean).abs() < 5.0 * mean.sqrt() + 30.0,
                "edge count {c} far from uniform mean {mean}"
            );
        }
    }

    #[test]
    fn poisson_converges_like_edge_sampling() {
        let (m, _) = run_poisson(1200);
        // same oracle/config via the edge-sampling SwarmRunner
        let n = 8;
        let mut backend = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.1, 11);
        let f_star = backend.f_star();
        let mut rng = Pcg64::seed(5);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(0.4);
        let mut ctx = RunContext {
            backend: &mut backend,
            graph: &graph,
            cost: &cost,
            rng: &mut rng,
            eval_every: 0,
            track_gamma: false,
        };
        let cfg = SwarmConfig {
            n,
            local_steps: LocalSteps::Fixed(2),
            mode: AveragingMode::NonBlocking,
            lr: LrSchedule::Constant(0.05),
            interactions: 1200,
            seed: 1,
            name: "edge".into(),
        };
        let edge = crate::coordinator::SwarmRunner::new(cfg, &mut ctx).run(&mut ctx);
        let gap_p = (m.final_eval_loss - f_star).max(1e-9);
        let gap_e = (edge.final_eval_loss - f_star).max(1e-9);
        let ratio = gap_p / gap_e;
        assert!(
            (0.2..5.0).contains(&ratio),
            "poisson gap {gap_p} vs edge-sampling gap {gap_e}"
        );
    }
}
