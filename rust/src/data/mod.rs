//! Synthetic workloads + sharding — the data substrate (DESIGN.md §2).
//!
//! The paper trains on CIFAR-10/ImageNet/WMT17; those are unavailable here
//! (repro band 0/5), so we synthesize workloads with the same *shape*:
//! labelled vectors (MLP), labelled images from a Gaussian mixture (CNN),
//! and a Markov-chain token stream with power-law vocabulary (transformer
//! LM).  Sharding partitions the data per agent (iid shuffle, plus the
//! non-iid by-label and Dirichlet partitions that exercise the Theorem 4.2
//! regime); minibatches are then drawn from a shard via the shared
//! [`draw_batch_indices`] / [`draw_token_batch`] rules, uniformly with
//! replacement from the *caller's* RNG — the stateless sampling the
//! unified backend contract requires for bit-exact replay.

mod corpus;
mod shard;
mod synth;

pub use corpus::{draw_token_batch, MarkovCorpus};
pub use shard::{dirichlet_shards, draw_batch_indices, iid_shards, label_shards};
pub use synth::{GaussianMixture, ImageDataset, VectorDataset};

/// A host-side minibatch, ready to be wrapped into PJRT literals.
#[derive(Clone, Debug)]
pub enum Batch {
    /// x: f32 features (row-major), y: i32 labels
    Dense { x: Vec<f32>, y: Vec<i32> },
    /// x: i32 token ids, y: i32 next-token targets
    Tokens { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Dense { y, .. } | Batch::Tokens { y, .. } => y,
        }
    }

    /// Number of examples (Dense) — tokens batches report windows.
    pub fn len_labels(&self) -> usize {
        self.labels().len()
    }
}
