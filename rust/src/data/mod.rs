//! Synthetic workloads + sharding — the data substrate (DESIGN.md §2).
//!
//! The paper trains on CIFAR-10/ImageNet/WMT17; those are unavailable here
//! (repro band 0/5), so we synthesize workloads with the same *shape*:
//! labelled vectors (MLP), labelled images from a Gaussian mixture (CNN),
//! and a Markov-chain token stream with power-law vocabulary (transformer
//! LM).  Sharding follows the paper's §5 training process: re-shuffle and
//! partition per epoch (iid), plus the non-iid partitions (by-label,
//! Dirichlet) that exercise the Theorem 4.2 regime.

mod corpus;
mod shard;
mod synth;

pub use corpus::{MarkovCorpus, TokenBatcher};
pub use shard::{dirichlet_shards, iid_shards, label_shards, ShardIter};
pub use synth::{GaussianMixture, ImageDataset, VectorDataset};

/// A host-side minibatch, ready to be wrapped into PJRT literals.
#[derive(Clone, Debug)]
pub enum Batch {
    /// x: f32 features (row-major), y: i32 labels
    Dense { x: Vec<f32>, y: Vec<i32> },
    /// x: i32 token ids, y: i32 next-token targets
    Tokens { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Dense { y, .. } | Batch::Tokens { y, .. } => y,
        }
    }

    /// Number of examples (Dense) — tokens batches report windows.
    pub fn len_labels(&self) -> usize {
        self.labels().len()
    }
}
