//! Gaussian-mixture synthetic datasets (vector + image variants).

use super::Batch;
use crate::rngx::Pcg64;

/// Shared generator: `classes` Gaussian blobs in `dim` dimensions with
/// controllable separation (higher = easier task).
pub struct GaussianMixture {
    pub dim: usize,
    pub classes: usize,
    means: Vec<f32>, // classes × dim
    noise: f32,
}

impl GaussianMixture {
    pub fn new(dim: usize, classes: usize, separation: f32, noise: f32, rng: &mut Pcg64) -> Self {
        let scale = separation / (dim as f32).sqrt();
        let means = (0..classes * dim)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        Self { dim, classes, means, noise }
    }

    /// Sample one example: (features, label).
    pub fn sample(&self, rng: &mut Pcg64) -> (Vec<f32>, i32) {
        let c = rng.below_usize(self.classes);
        let x = (0..self.dim)
            .map(|j| self.means[c * self.dim + j] + rng.normal() as f32 * self.noise)
            .collect();
        (x, c as i32)
    }
}

/// Materialized labelled-vector dataset (the MLP workload).
pub struct VectorDataset {
    pub dim: usize,
    pub classes: usize,
    pub x: Vec<f32>, // n × dim row-major
    pub y: Vec<i32>,
}

impl VectorDataset {
    pub fn generate(n: usize, dim: usize, classes: usize, separation: f32, rng: &mut Pcg64) -> Self {
        let gm = GaussianMixture::new(dim, classes, separation, 1.0, rng);
        Self::from_mixture(&gm, n, rng)
    }

    /// Sample from an existing mixture (so train/test share the task).
    pub fn from_mixture(gm: &GaussianMixture, n: usize, rng: &mut Pcg64) -> Self {
        let mut x = Vec::with_capacity(n * gm.dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let (xi, yi) = gm.sample(rng);
            x.extend_from_slice(&xi);
            y.push(yi);
        }
        Self { dim: gm.dim, classes: gm.classes, x, y }
    }

    /// Train/test pair drawn from the SAME mixture.
    pub fn generate_split(
        n_train: usize,
        n_test: usize,
        dim: usize,
        classes: usize,
        separation: f32,
        rng: &mut Pcg64,
    ) -> (Self, Self) {
        let gm = GaussianMixture::new(dim, classes, separation, 1.0, rng);
        (Self::from_mixture(&gm, n_train, rng), Self::from_mixture(&gm, n_test, rng))
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Gather examples at `idxs` into a dense batch.
    pub fn batch(&self, idxs: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(idxs.len() * self.dim);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
            y.push(self.y[i]);
        }
        Batch::Dense { x, y }
    }
}

/// Labelled-image dataset (the CNN workload): per-class spatial templates
/// (low-frequency blobs) + pixel noise — a CIFAR-shaped stand-in.
pub struct ImageDataset {
    pub hw: usize,
    pub chans: usize,
    pub classes: usize,
    pub x: Vec<f32>, // n × hw × hw × chans (NHWC)
    pub y: Vec<i32>,
}

impl ImageDataset {
    pub fn generate(
        n: usize,
        hw: usize,
        chans: usize,
        classes: usize,
        separation: f32,
        rng: &mut Pcg64,
    ) -> Self {
        let templates = Self::templates(hw, chans, classes, separation, rng);
        Self::from_templates(&templates, n, hw, chans, classes, rng)
    }

    /// Train/test pair sharing the SAME class templates.
    pub fn generate_split(
        n_train: usize,
        n_test: usize,
        hw: usize,
        chans: usize,
        classes: usize,
        separation: f32,
        rng: &mut Pcg64,
    ) -> (Self, Self) {
        let t = Self::templates(hw, chans, classes, separation, rng);
        (
            Self::from_templates(&t, n_train, hw, chans, classes, rng),
            Self::from_templates(&t, n_test, hw, chans, classes, rng),
        )
    }

    /// Class templates: sums of random low-frequency cosines per channel.
    fn templates(
        hw: usize,
        chans: usize,
        classes: usize,
        separation: f32,
        rng: &mut Pcg64,
    ) -> Vec<f32> {
        let mut templates = vec![0.0f32; classes * hw * hw * chans];
        for c in 0..classes {
            for ch in 0..chans {
                for _ in 0..3 {
                    let fx = 1.0 + rng.below(3) as f32;
                    let fy = 1.0 + rng.below(3) as f32;
                    let px = rng.f32() * std::f32::consts::TAU;
                    let py = rng.f32() * std::f32::consts::TAU;
                    let amp = separation * (0.5 + rng.f32());
                    for r in 0..hw {
                        for q in 0..hw {
                            let v = amp
                                * ((fx * r as f32 / hw as f32 * std::f32::consts::TAU + px).cos()
                                    + (fy * q as f32 / hw as f32 * std::f32::consts::TAU + py)
                                        .cos());
                            templates[((c * hw + r) * hw + q) * chans + ch] += v * 0.5;
                        }
                    }
                }
            }
        }
        templates
    }

    fn from_templates(
        templates: &[f32],
        n: usize,
        hw: usize,
        chans: usize,
        classes: usize,
        rng: &mut Pcg64,
    ) -> Self {
        let img_sz = hw * hw * chans;
        let mut x = Vec::with_capacity(n * img_sz);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below_usize(classes);
            for j in 0..img_sz {
                x.push(templates[c * img_sz + j] + rng.normal() as f32 * 1.0);
            }
            y.push(c as i32);
        }
        Self { hw, chans, classes, x, y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn batch(&self, idxs: &[usize]) -> Batch {
        let img_sz = self.hw * self.hw * self.chans;
        let mut x = Vec::with_capacity(idxs.len() * img_sz);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(&self.x[i * img_sz..(i + 1) * img_sz]);
            y.push(self.y[i]);
        }
        Batch::Dense { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_dataset_shapes() {
        let mut rng = Pcg64::seed(1);
        let d = VectorDataset::generate(100, 8, 4, 3.0, &mut rng);
        assert_eq!(d.len(), 100);
        assert_eq!(d.x.len(), 800);
        assert!(d.y.iter().all(|&y| (0..4).contains(&y)));
    }

    #[test]
    fn vector_classes_are_separable() {
        // a nearest-centroid classifier must beat chance by a wide margin
        let mut rng = Pcg64::seed(2);
        let d = VectorDataset::generate(2000, 16, 4, 4.0, &mut rng);
        // estimate centroids from the data
        let mut cent = vec![0.0f64; 4 * 16];
        let mut cnt = [0usize; 4];
        for i in 0..d.len() {
            let c = d.y[i] as usize;
            cnt[c] += 1;
            for j in 0..16 {
                cent[c * 16 + j] += d.x[i * 16 + j] as f64;
            }
        }
        for c in 0..4 {
            for j in 0..16 {
                cent[c * 16 + j] /= cnt[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let mut best = (f64::MAX, 0);
            for c in 0..4 {
                let dist: f64 = (0..16)
                    .map(|j| (d.x[i * 16 + j] as f64 - cent[c * 16 + j]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.8, "nearest-centroid acc={acc}");
    }

    #[test]
    fn image_dataset_shapes() {
        let mut rng = Pcg64::seed(3);
        let d = ImageDataset::generate(50, 8, 3, 5, 2.0, &mut rng);
        assert_eq!(d.x.len(), 50 * 8 * 8 * 3);
        assert_eq!(d.len(), 50);
        let b = d.batch(&[0, 7, 12]);
        match b {
            Batch::Dense { x, y } => {
                assert_eq!(x.len(), 3 * 8 * 8 * 3);
                assert_eq!(y.len(), 3);
            }
            _ => panic!("expected dense batch"),
        }
    }

    #[test]
    fn batch_gathers_right_rows() {
        let mut rng = Pcg64::seed(4);
        let d = VectorDataset::generate(10, 4, 2, 3.0, &mut rng);
        if let Batch::Dense { x, y } = d.batch(&[3, 5]) {
            assert_eq!(&x[0..4], &d.x[12..16]);
            assert_eq!(&x[4..8], &d.x[20..24]);
            assert_eq!(y, vec![d.y[3], d.y[5]]);
        } else {
            panic!();
        }
    }
}
