//! Synthetic Markov-chain token corpus — the WMT17 stand-in for the LM task.
//!
//! A first-order Markov source with sparse, power-law-weighted transitions:
//! learnable structure (a transformer can push the loss well below
//! `log(vocab)` toward the chain's conditional entropy) without any external
//! data.  Every agent gets a contiguous shard of the stream, mirroring the
//! paper's per-epoch partitioning.

use super::Batch;
use crate::rngx::Pcg64;

/// Token stream + its generator parameters.
pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
    /// per-state successor lists (succ, weight) used for entropy estimation
    trans: Vec<Vec<(usize, f64)>>,
}

impl MarkovCorpus {
    /// Build a chain with `branch` likely successors per state and sample
    /// `len` tokens.
    pub fn generate(vocab: usize, len: usize, branch: usize, rng: &mut Pcg64) -> Self {
        assert!(vocab >= 2 && branch >= 1);
        let mut trans: Vec<Vec<(usize, f64)>> = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let succ = rng.sample_indices(vocab, branch.min(vocab));
            // power-law weights 1/k over the chosen successors + smoothing
            let mut row: Vec<(usize, f64)> = succ
                .into_iter()
                .enumerate()
                .map(|(k, s)| (s, 1.0 / (k + 1) as f64))
                .collect();
            let total: f64 = row.iter().map(|(_, w)| w).sum();
            for e in &mut row {
                e.1 = 0.9 * e.1 / total; // 10% mass smoothed over full vocab
            }
            trans.push(row);
        }
        let mut tokens = Vec::with_capacity(len);
        let mut state = rng.below_usize(vocab);
        for _ in 0..len {
            tokens.push(state as i32);
            state = if rng.bernoulli(0.9) {
                let row = &trans[state];
                let weights: Vec<f64> = row.iter().map(|(_, w)| *w).collect();
                row[rng.categorical(&weights)].0
            } else {
                rng.below_usize(vocab)
            };
        }
        Self { vocab, tokens, trans }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Entropy rate of the chain in nats — the LM loss floor.
    pub fn conditional_entropy(&self) -> f64 {
        let v = self.vocab as f64;
        let mut h = 0.0;
        for row in &self.trans {
            let mut hs = 0.0;
            let smooth = 0.1 / v;
            let mut structured = vec![smooth; self.vocab];
            for &(s, w) in row {
                structured[s] += w; // w already scaled to 0.9 total
            }
            for p in structured {
                if p > 0.0 {
                    hs -= p * p.ln();
                }
            }
            h += hs;
        }
        h / v // states are ~uniform under the 10% teleport smoothing
    }
}

/// Sample one (x, y) next-token batch of `batch` windows from a token
/// shard, y shifted by one, drawing window starts from the caller's RNG —
/// the stateless token-side counterpart of
/// [`super::draw_batch_indices`], shared so every token backend consumes
/// node streams identically.
pub fn draw_token_batch(shard: &[i32], seq: usize, batch: usize, rng: &mut Pcg64) -> Batch {
    assert!(shard.len() > seq + 1, "shard too small for seq={seq}");
    // valid window starts are 0..=len-seq-1 (y is shifted by 1)
    let max_start = shard.len() - seq - 1;
    let mut x = Vec::with_capacity(batch * seq);
    let mut y = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let s = rng.below_usize(max_start + 1);
        x.extend_from_slice(&shard[s..s + seq]);
        y.extend_from_slice(&shard[s + 1..s + seq + 1]);
    }
    Batch::Tokens { x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_range() {
        let mut rng = Pcg64::seed(1);
        let c = MarkovCorpus::generate(64, 10_000, 4, &mut rng);
        assert_eq!(c.len(), 10_000);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn corpus_has_structure() {
        // bigram predictability: most-likely-successor accuracy far above 1/V
        let mut rng = Pcg64::seed(2);
        let c = MarkovCorpus::generate(32, 50_000, 3, &mut rng);
        let mut counts = vec![[0u32; 32]; 32];
        for w in c.tokens.windows(2) {
            counts[w[0] as usize][w[1] as usize] += 1;
        }
        let mut correct = 0u32;
        let mut total = 0u32;
        for w in c.tokens.windows(2) {
            let pred = counts[w[0] as usize]
                .iter()
                .enumerate()
                .max_by_key(|(_, c)| **c)
                .unwrap()
                .0;
            if pred == w[1] as usize {
                correct += 1;
            }
            total += 1;
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.2, "bigram acc={acc}, chance={}", 1.0 / 32.0);
    }

    #[test]
    fn entropy_below_uniform() {
        let mut rng = Pcg64::seed(3);
        let c = MarkovCorpus::generate(64, 1000, 4, &mut rng);
        let h = c.conditional_entropy();
        assert!(h < (64f64).ln(), "H={h} >= ln V");
        assert!(h > 0.5, "H={h} suspiciously low");
    }

    #[test]
    fn token_batch_shapes_and_shift() {
        let mut rng = Pcg64::seed(4);
        let c = MarkovCorpus::generate(16, 5000, 3, &mut rng);
        let mut brng = Pcg64::seed(9);
        let batch = draw_token_batch(&c.tokens, 8, 4, &mut brng);
        if let Batch::Tokens { x, y } = batch {
            assert_eq!(x.len(), 32);
            assert_eq!(y.len(), 32);
            // y is x shifted within each window — check via corpus lookup
            // (x window is contiguous in the corpus, so x[1..] == y[..-1])
            for w in 0..4 {
                assert_eq!(&x[w * 8 + 1..(w + 1) * 8], &y[w * 8..(w + 1) * 8 - 1]);
            }
        } else {
            panic!("expected token batch");
        }
        // same stream → same batch (the replay contract at the data layer)
        let one = draw_token_batch(&c.tokens, 8, 4, &mut Pcg64::seed(9));
        let two = draw_token_batch(&c.tokens, 8, 4, &mut Pcg64::seed(9));
        match (one, two) {
            (Batch::Tokens { x: a, .. }, Batch::Tokens { x: b, .. }) => assert_eq!(a, b),
            _ => panic!("expected token batches"),
        }
    }
}
