//! Dataset sharding across agents: iid, pathological by-label, and
//! Dirichlet-skewed — the paper's §5 protocol (iid re-partitioning) plus the
//! non-iid regimes of Theorem 4.2 / Appendix H.

use crate::rngx::Pcg64;

/// Shuffle and split `n` examples into `agents` near-equal shards.
pub fn iid_shards(n: usize, agents: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(agents >= 1 && n >= agents);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    split_even(&idx, agents)
}

/// Sort by label and hand out contiguous chunks — maximal label skew.
pub fn label_shards(labels: &[i32], agents: usize) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..labels.len()).collect();
    idx.sort_by_key(|&i| labels[i]);
    split_even(&idx, agents)
}

/// Dirichlet(α) label-distribution skew (standard federated-learning
/// protocol): for each class, split its examples across agents with
/// Dirichlet-sampled proportions. Small α → heavy skew, large α → ~iid.
pub fn dirichlet_shards(
    labels: &[i32],
    agents: usize,
    alpha: f64,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    let classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &y) in labels.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    let mut shards = vec![Vec::new(); agents];
    for mut members in by_class {
        rng.shuffle(&mut members);
        let props = rng.dirichlet(alpha, agents);
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (a, p) in props.iter().enumerate() {
            acc += p;
            let end = if a + 1 == agents {
                members.len()
            } else {
                ((members.len() as f64) * acc).round() as usize
            }
            .min(members.len());
            shards[a].extend_from_slice(&members[start..end]);
            start = end;
        }
    }
    // guarantee non-empty shards (move one element from the largest)
    for a in 0..agents {
        if shards[a].is_empty() {
            let donor = (0..agents).max_by_key(|&b| shards[b].len()).unwrap();
            let x = shards[donor].pop().expect("donor shard empty");
            shards[a].push(x);
        }
    }
    shards
}

fn split_even(idx: &[usize], agents: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / agents;
    let extra = n % agents;
    let mut out = Vec::with_capacity(agents);
    let mut start = 0;
    for a in 0..agents {
        let len = base + usize::from(a < extra);
        out.push(idx[start..start + len].to_vec());
        start += len;
    }
    out
}

/// Draw one minibatch of example indices from a shard, uniformly with
/// replacement, consuming exactly `batch` outputs from the caller's RNG.
///
/// This is THE batch-selection rule of the unified backend contract: every
/// oracle and the PJRT path call it (or mirror it for non-index data), so
/// all backends consume a node's private stream identically — a pillar of
/// the executors' replay-determinism guarantee.
pub fn draw_batch_indices(shard: &[usize], batch: usize, rng: &mut Pcg64) -> Vec<usize> {
    assert!(!shard.is_empty(), "empty shard");
    (0..batch).map(|_| shard[rng.below_usize(shard.len())]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_shards_partition() {
        let mut rng = Pcg64::seed(1);
        let shards = iid_shards(103, 8, &mut rng);
        assert_eq!(shards.len(), 8);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().all(|&s| s == 12 || s == 13));
    }

    #[test]
    fn label_shards_are_skewed() {
        let labels: Vec<i32> = (0..100).map(|i| (i / 25) as i32).collect();
        let shards = label_shards(&labels, 4);
        // each shard should be single-label
        for s in &shards {
            let l0 = labels[s[0]];
            assert!(s.iter().all(|&i| labels[i] == l0));
        }
    }

    #[test]
    fn dirichlet_partition_and_coverage() {
        let mut rng = Pcg64::seed(2);
        let labels: Vec<i32> = (0..500).map(|i| (i % 10) as i32).collect();
        for alpha in [0.1, 1.0, 100.0] {
            let shards = dirichlet_shards(&labels, 8, alpha, &mut rng);
            let mut all: Vec<usize> = shards.concat();
            all.sort_unstable();
            assert_eq!(all.len(), 500, "alpha={alpha}");
            all.dedup();
            assert_eq!(all.len(), 500);
            assert!(shards.iter().all(|s| !s.is_empty()));
        }
    }

    #[test]
    fn dirichlet_alpha_controls_skew() {
        let mut rng = Pcg64::seed(3);
        let labels: Vec<i32> = (0..2000).map(|i| (i % 10) as i32).collect();
        let skew = |alpha: f64, rng: &mut Pcg64| -> f64 {
            let shards = dirichlet_shards(&labels, 10, alpha, rng);
            // average per-shard max-class proportion
            shards
                .iter()
                .map(|s| {
                    let mut c = [0usize; 10];
                    for &i in s {
                        c[labels[i] as usize] += 1;
                    }
                    *c.iter().max().unwrap() as f64 / s.len().max(1) as f64
                })
                .sum::<f64>()
                / 10.0
        };
        let low = skew(0.05, &mut rng);
        let high = skew(100.0, &mut rng);
        assert!(low > high + 0.2, "low-alpha skew {low} vs high-alpha {high}");
    }

    #[test]
    fn draw_batch_indices_is_uniform_and_replayable() {
        let shard: Vec<usize> = (100..110).collect();
        let mut a = Pcg64::seed(4);
        let mut b = Pcg64::seed(4);
        let da = draw_batch_indices(&shard, 64, &mut a);
        let db = draw_batch_indices(&shard, 64, &mut b);
        assert_eq!(da, db, "same stream must draw the same batch");
        assert_eq!(da.len(), 64);
        assert!(da.iter().all(|i| shard.contains(i)));
        // with replacement: 64 draws from 10 values must repeat something
        let mut uniq = da.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 10);
    }
}
