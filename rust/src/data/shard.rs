//! Dataset sharding across agents: iid, pathological by-label, and
//! Dirichlet-skewed — the paper's §5 protocol (iid re-partitioning) plus the
//! non-iid regimes of Theorem 4.2 / Appendix H.

use crate::rngx::Pcg64;

/// Shuffle and split `n` examples into `agents` near-equal shards.
pub fn iid_shards(n: usize, agents: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(agents >= 1 && n >= agents);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    split_even(&idx, agents)
}

/// Sort by label and hand out contiguous chunks — maximal label skew.
pub fn label_shards(labels: &[i32], agents: usize) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..labels.len()).collect();
    idx.sort_by_key(|&i| labels[i]);
    split_even(&idx, agents)
}

/// Dirichlet(α) label-distribution skew (standard federated-learning
/// protocol): for each class, split its examples across agents with
/// Dirichlet-sampled proportions. Small α → heavy skew, large α → ~iid.
pub fn dirichlet_shards(
    labels: &[i32],
    agents: usize,
    alpha: f64,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    let classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &y) in labels.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    let mut shards = vec![Vec::new(); agents];
    for mut members in by_class {
        rng.shuffle(&mut members);
        let props = rng.dirichlet(alpha, agents);
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (a, p) in props.iter().enumerate() {
            acc += p;
            let end = if a + 1 == agents {
                members.len()
            } else {
                ((members.len() as f64) * acc).round() as usize
            }
            .min(members.len());
            shards[a].extend_from_slice(&members[start..end]);
            start = end;
        }
    }
    // guarantee non-empty shards (move one element from the largest)
    for a in 0..agents {
        if shards[a].is_empty() {
            let donor = (0..agents).max_by_key(|&b| shards[b].len()).unwrap();
            let x = shards[donor].pop().expect("donor shard empty");
            shards[a].push(x);
        }
    }
    shards
}

fn split_even(idx: &[usize], agents: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / agents;
    let extra = n % agents;
    let mut out = Vec::with_capacity(agents);
    let mut start = 0;
    for a in 0..agents {
        let len = base + usize::from(a < extra);
        out.push(idx[start..start + len].to_vec());
        start += len;
    }
    out
}

/// Cycles through a shard with per-epoch reshuffling (paper §5: "at the
/// beginning of each epoch, we re-shuffle the dataset").
pub struct ShardIter {
    shard: Vec<usize>,
    pos: usize,
    rng: Pcg64,
    pub epochs_done: u64,
}

impl ShardIter {
    pub fn new(shard: Vec<usize>, mut rng: Pcg64) -> Self {
        assert!(!shard.is_empty());
        let mut s = shard;
        rng.shuffle(&mut s);
        Self { shard: s, pos: 0, rng, epochs_done: 0 }
    }

    /// Next `k` example indices (wrapping + reshuffling at epoch end).
    pub fn next_indices(&mut self, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            if self.pos == self.shard.len() {
                self.rng.shuffle(&mut self.shard);
                self.pos = 0;
                self.epochs_done += 1;
            }
            out.push(self.shard[self.pos]);
            self.pos += 1;
        }
        out
    }

    /// Fractional epochs consumed.
    pub fn epochs(&self) -> f64 {
        self.epochs_done as f64 + self.pos as f64 / self.shard.len() as f64
    }

    pub fn len(&self) -> usize {
        self.shard.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shard.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_shards_partition() {
        let mut rng = Pcg64::seed(1);
        let shards = iid_shards(103, 8, &mut rng);
        assert_eq!(shards.len(), 8);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().all(|&s| s == 12 || s == 13));
    }

    #[test]
    fn label_shards_are_skewed() {
        let labels: Vec<i32> = (0..100).map(|i| (i / 25) as i32).collect();
        let shards = label_shards(&labels, 4);
        // each shard should be single-label
        for s in &shards {
            let l0 = labels[s[0]];
            assert!(s.iter().all(|&i| labels[i] == l0));
        }
    }

    #[test]
    fn dirichlet_partition_and_coverage() {
        let mut rng = Pcg64::seed(2);
        let labels: Vec<i32> = (0..500).map(|i| (i % 10) as i32).collect();
        for alpha in [0.1, 1.0, 100.0] {
            let shards = dirichlet_shards(&labels, 8, alpha, &mut rng);
            let mut all: Vec<usize> = shards.concat();
            all.sort_unstable();
            assert_eq!(all.len(), 500, "alpha={alpha}");
            all.dedup();
            assert_eq!(all.len(), 500);
            assert!(shards.iter().all(|s| !s.is_empty()));
        }
    }

    #[test]
    fn dirichlet_alpha_controls_skew() {
        let mut rng = Pcg64::seed(3);
        let labels: Vec<i32> = (0..2000).map(|i| (i % 10) as i32).collect();
        let skew = |alpha: f64, rng: &mut Pcg64| -> f64 {
            let shards = dirichlet_shards(&labels, 10, alpha, rng);
            // average per-shard max-class proportion
            shards
                .iter()
                .map(|s| {
                    let mut c = [0usize; 10];
                    for &i in s {
                        c[labels[i] as usize] += 1;
                    }
                    *c.iter().max().unwrap() as f64 / s.len().max(1) as f64
                })
                .sum::<f64>()
                / 10.0
        };
        let low = skew(0.05, &mut rng);
        let high = skew(100.0, &mut rng);
        assert!(low > high + 0.2, "low-alpha skew {low} vs high-alpha {high}");
    }

    #[test]
    fn shard_iter_visits_everything_each_epoch() {
        let it_shard: Vec<usize> = (0..10).collect();
        let mut it = ShardIter::new(it_shard, Pcg64::seed(4));
        let first: Vec<usize> = it.next_indices(10);
        let mut sorted = first.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
        assert_eq!(it.epochs_done, 0);
        it.next_indices(1);
        assert_eq!(it.epochs_done, 1);
        assert!(it.epochs() > 1.0);
    }
}
