//! The generation-stamped node roster: which slots are live, and which
//! incarnation of a node occupies each slot.
//!
//! A slot's generation is a monotonically increasing counter whose parity
//! encodes liveness: **odd = live, even = vacant** (the same parity trick
//! as the model-slot seqlock, but per node lifetime instead of per write).
//! Every join/leave transition bumps the generation by one, so the pair
//! `(slot, generation)` uniquely names one incarnation of one node — a
//! recycled slot can never alias a departed node's identity, which is what
//! lets joiners derive fresh RNG streams and lets stale cross-writes be
//! recognized as harmless. Only the worker that owns a slot range
//! transitions its slots, so transitions need no CAS loops; readers on
//! other workers see liveness through a single acquire load.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Parsed `--churn join:<rate>,leave:<rate>` spec. Rates are per-node
/// event weights in the engine's competition sampler: with uniform speeds,
/// each initiated interaction is accompanied by ~`join` expected node
/// arrivals and ~`leave` expected departures per live node (small rates;
/// the exact competition is documented on the scale engine).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChurnSpec {
    /// arrival weight (new nodes claim recycled slots)
    pub join: f64,
    /// departure weight (live nodes vacate their slots)
    pub leave: f64,
}

impl ChurnSpec {
    /// The fixed-roster spec (both rates zero).
    pub fn none() -> Self {
        Self::default()
    }

    /// Whether any churn process is switched on.
    pub fn active(&self) -> bool {
        self.join > 0.0 || self.leave > 0.0
    }

    /// Parse `join:<rate>,leave:<rate>` (either part optional, any order;
    /// the empty string is the fixed roster). Negative or non-finite rates
    /// are rejected with an actionable error.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut spec = Self::none();
        let s = s.trim();
        if s.is_empty() {
            return Ok(spec);
        }
        for part in s.split(',') {
            let part = part.trim();
            let (key, val) = part.split_once(':').ok_or_else(|| {
                format!(
                    "bad churn '{s}': each part must be join:<rate> or \
                     leave:<rate> (e.g. join:0.001,leave:0.001)"
                )
            })?;
            let field = match key.trim() {
                "join" => &mut spec.join,
                "leave" => &mut spec.leave,
                k => {
                    return Err(format!(
                        "unknown churn part '{k}' in '{s}' (known: join, leave)"
                    ))
                }
            };
            let rate: f64 = val.trim().parse().map_err(|_| {
                format!("bad churn rate '{val}' in '{s}': want a number, e.g. join:0.001")
            })?;
            if !rate.is_finite() || rate < 0.0 {
                return Err(format!(
                    "churn {} rate must be finite and >= 0, got {val}; omit \
                     the part (or the --churn flag) to run a fixed roster",
                    key.trim()
                ));
            }
            *field = rate;
        }
        Ok(spec)
    }
}

impl std::fmt::Display for ChurnSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "join:{},leave:{}", self.join, self.leave)
    }
}

/// The roster proper: one generation counter per slot plus global flux
/// counters. See the module docs for the parity protocol.
pub struct Roster {
    gen: Box<[AtomicU32]>,
    live: AtomicU64,
    joins: AtomicU64,
    leaves: AtomicU64,
    rejected: AtomicU64,
}

impl Roster {
    /// Roster with `capacity` slots, the first `live_prefix` of which start
    /// live at generation 1 (the initial cohort); the rest start vacant.
    pub fn new(capacity: usize, live_prefix: usize) -> Self {
        assert!(live_prefix <= capacity, "live prefix exceeds capacity");
        let gen = (0..capacity)
            .map(|i| AtomicU32::new(u32::from(i < live_prefix)))
            .collect();
        Self {
            gen,
            live: AtomicU64::new(live_prefix as u64),
            joins: AtomicU64::new(0),
            leaves: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.gen.len()
    }

    /// Current generation of `slot` (odd = live).
    #[inline]
    pub fn generation(&self, slot: usize) -> u32 {
        self.gen[slot].load(Ordering::Acquire)
    }

    #[inline]
    pub fn is_live(&self, slot: usize) -> bool {
        self.generation(slot) & 1 == 1
    }

    /// Owner-only: transition a vacant slot to live. Returns the new (odd)
    /// generation stamping this incarnation.
    pub fn admit(&self, slot: usize) -> u32 {
        let g = self.gen[slot].fetch_add(1, Ordering::AcqRel) + 1;
        debug_assert!(g & 1 == 1, "admit on an already-live slot {slot}");
        self.live.fetch_add(1, Ordering::Relaxed);
        self.joins.fetch_add(1, Ordering::Relaxed);
        g
    }

    /// Owner-only: transition a live slot to vacant. Returns the new
    /// (even) generation.
    pub fn retire(&self, slot: usize) -> u32 {
        let g = self.gen[slot].fetch_add(1, Ordering::AcqRel) + 1;
        debug_assert!(g & 1 == 0, "retire on a vacant slot {slot}");
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.leaves.fetch_add(1, Ordering::Relaxed);
        g
    }

    /// Count a join that found no vacant slot (the roster is at capacity).
    pub fn reject_join(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn live_count(&self) -> u64 {
        self.live.load(Ordering::Relaxed)
    }

    pub fn joins(&self) -> u64 {
        self.joins.load(Ordering::Relaxed)
    }

    pub fn leaves(&self) -> u64 {
        self.leaves.load(Ordering::Relaxed)
    }

    pub fn rejected_joins(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_parse_accepts_both_orders_and_partial_specs() {
        assert_eq!(ChurnSpec::parse("").unwrap(), ChurnSpec::none());
        assert!(!ChurnSpec::parse("").unwrap().active());
        let c = ChurnSpec::parse("join:0.01,leave:0.02").unwrap();
        assert_eq!(c, ChurnSpec { join: 0.01, leave: 0.02 });
        let c = ChurnSpec::parse("leave:0.02, join:0.01").unwrap();
        assert_eq!(c, ChurnSpec { join: 0.01, leave: 0.02 });
        let c = ChurnSpec::parse("join:0.5").unwrap();
        assert_eq!(c, ChurnSpec { join: 0.5, leave: 0.0 });
        assert!(c.active());
    }

    #[test]
    fn churn_parse_rejects_bad_specs_with_actionable_errors() {
        let e = ChurnSpec::parse("join:-0.1").unwrap_err();
        assert!(e.contains(">= 0"), "{e}");
        assert!(e.contains("--churn"), "{e}");
        let e = ChurnSpec::parse("leave:nan").unwrap_err();
        assert!(e.contains("finite"), "{e}");
        let e = ChurnSpec::parse("jion:0.1").unwrap_err();
        assert!(e.contains("known: join, leave"), "{e}");
        let e = ChurnSpec::parse("join=0.1").unwrap_err();
        assert!(e.contains("join:<rate>"), "{e}");
        let e = ChurnSpec::parse("join:lots").unwrap_err();
        assert!(e.contains("want a number"), "{e}");
    }

    #[test]
    fn roster_transitions_keep_parity_and_counts() {
        let r = Roster::new(4, 3);
        assert_eq!(r.capacity(), 4);
        assert_eq!(r.live_count(), 3);
        assert!(r.is_live(0) && r.is_live(2) && !r.is_live(3));
        let g = r.retire(1);
        assert_eq!(g, 2);
        assert!(!r.is_live(1));
        assert_eq!(r.live_count(), 2);
        let g = r.admit(1);
        assert_eq!(g, 3);
        assert!(r.is_live(1));
        assert_eq!(r.live_count(), 3);
        assert_eq!(r.joins(), 1);
        assert_eq!(r.leaves(), 1);
        r.reject_join();
        assert_eq!(r.rejected_joins(), 1);
    }

    #[test]
    fn recycled_slots_never_alias_prior_generations() {
        // (slot, generation) pairs are unique across incarnations: the
        // generation strictly increases through every retire/admit cycle
        let r = Roster::new(1, 1);
        let mut seen = std::collections::HashSet::new();
        assert!(seen.insert(r.generation(0)));
        for _ in 0..100 {
            assert!(seen.insert(r.retire(0)));
            assert!(seen.insert(r.admit(0)));
        }
        assert_eq!(r.generation(0), 201);
    }
}
