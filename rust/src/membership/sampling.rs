//! Shard-local partner sampling: O(1) procedural neighbor draws with no
//! global graph structure and no shared RNG.
//!
//! The scenario engine materializes a [`Graph`] (edge + adjacency lists),
//! which is exactly right up to tens of thousands of nodes and exactly
//! wrong at a million: a complete graph's edge list alone is ~2 TB. The
//! structured families the paper's analysis actually uses (complete, ring,
//! torus, hypercube) all admit *formula* neighbor sampling, so [`ProcGraph`]
//! keeps them procedural above [`MATERIALIZE_MAX`] nodes and falls back to
//! a materialized table below it (where arbitrary families, including the
//! spectrally-certified expander, stay available). Expanders survive the
//! procedural cutover as random **circulant** graphs — generator 1 keeps
//! them connected (a ring layer), the remaining seed-derived generators
//! spread mass like the random-regular family; the spectral certificate
//! itself only runs at materialized sizes (see [`Graph::expander`]).
//!
//! Every worker samples with its own [`Pcg64`] stream against this shared
//! read-only structure, so partner draws contend on nothing.

use crate::rngx::Pcg64;
use crate::topology::{Graph, Topology};

/// Largest n at which a topology is materialized into a [`Graph`] table;
/// above this only the procedural families resolve.
pub const MATERIALIZE_MAX: usize = 1 << 16;

/// Stream tag for graph construction (materialized tables and circulant
/// generator draws), disjoint from the worker/node stream tags.
const STREAM_MEMBER_GRAPH: u64 = 0x5EED_3CA1_0000_0002;

/// A neighbor sampler that is either a closed-form formula (large n) or a
/// materialized [`Graph`] table (small n).
#[derive(Clone, Debug)]
pub enum ProcGraph {
    /// K_n: any other node.
    Complete { n: usize },
    /// C_n: ±1 around the cycle.
    Ring { n: usize },
    /// side × side torus: one of the four grid directions.
    Torus { side: usize },
    /// Q_bits: flip one coordinate bit.
    Hypercube { bits: u32 },
    /// Circulant graph on Z_n with connection set `gens` ∪ `-gens`
    /// (the procedural expander surrogate).
    Circulant { n: usize, gens: Vec<usize> },
    /// Materialized adjacency table (small n; any family).
    Table(Graph),
}

impl ProcGraph {
    /// Resolve `topo` at `n` nodes. Below [`MATERIALIZE_MAX`] every family
    /// materializes (seeded from `seed`); above it the structured families
    /// go procedural and the table-only families (random-regular,
    /// powerlaw) fail with an actionable error.
    pub fn resolve(topo: Topology, n: usize, seed: u64) -> Result<Self, String> {
        topo.validate(n)?;
        if n <= MATERIALIZE_MAX {
            let mut rng = Pcg64::stream(seed, STREAM_MEMBER_GRAPH);
            return Ok(ProcGraph::Table(Graph::build(topo, n, &mut rng)));
        }
        Ok(match topo {
            Topology::Complete => ProcGraph::Complete { n },
            Topology::Ring => ProcGraph::Ring { n },
            Topology::Torus => {
                ProcGraph::Torus { side: (n as f64).sqrt().round() as usize }
            }
            Topology::Hypercube => ProcGraph::Hypercube { bits: n.trailing_zeros() },
            Topology::Expander(r) => {
                // r/2 circulant generator layers; gens[0] = 1 pins
                // connectivity, the rest are seed-derived and distinct in
                // [2, n/2) so g and n-g never coincide
                let mut rng = Pcg64::stream(seed, STREAM_MEMBER_GRAPH);
                let layers = (r / 2).max(1);
                let mut gens = vec![1usize];
                while gens.len() < layers {
                    let g = 2 + rng.below_usize(n / 2 - 2);
                    if !gens.contains(&g) {
                        gens.push(g);
                    }
                }
                ProcGraph::Circulant { n, gens }
            }
            Topology::RandomRegular(_) | Topology::PowerLaw(_) => {
                return Err(format!(
                    "topology needs a materialized edge table, which is \
                     infeasible at n={n} (> {MATERIALIZE_MAX}); use complete, \
                     ring, torus, hypercube, or expander<r> in the scale regime"
                ));
            }
        })
    }

    /// Node count.
    pub fn n(&self) -> usize {
        match self {
            ProcGraph::Complete { n }
            | ProcGraph::Ring { n }
            | ProcGraph::Circulant { n, .. } => *n,
            ProcGraph::Torus { side } => side * side,
            ProcGraph::Hypercube { bits } => 1usize << bits,
            ProcGraph::Table(g) => g.n(),
        }
    }

    /// Degree of the procedural families (max degree for tables) — sizing
    /// hint for dead-partner retry budgets.
    pub fn degree_hint(&self) -> usize {
        match self {
            ProcGraph::Complete { n } => n - 1,
            ProcGraph::Ring { .. } => 2,
            ProcGraph::Torus { .. } => 4,
            ProcGraph::Hypercube { bits } => *bits as usize,
            ProcGraph::Circulant { gens, .. } => 2 * gens.len(),
            ProcGraph::Table(g) => (0..g.n()).map(|u| g.degree(u)).max().unwrap_or(0),
        }
    }

    /// Sample a uniform neighbor of `u`. O(1) for the procedural families;
    /// table lookup otherwise.
    #[inline]
    pub fn sample_neighbor(&self, u: usize, rng: &mut Pcg64) -> usize {
        match self {
            ProcGraph::Complete { n } => {
                let j = rng.below_usize(n - 1);
                if j >= u {
                    j + 1
                } else {
                    j
                }
            }
            ProcGraph::Ring { n } => {
                if rng.bernoulli(0.5) {
                    (u + 1) % n
                } else {
                    (u + n - 1) % n
                }
            }
            ProcGraph::Torus { side } => {
                let (r, c) = (u / side, u % side);
                match rng.below(4) {
                    0 => r * side + (c + 1) % side,
                    1 => r * side + (c + side - 1) % side,
                    2 => ((r + 1) % side) * side + c,
                    _ => ((r + side - 1) % side) * side + c,
                }
            }
            ProcGraph::Hypercube { bits } => u ^ (1usize << rng.below(*bits as u64)),
            ProcGraph::Circulant { n, gens } => {
                let g = gens[rng.below_usize(gens.len())];
                if rng.bernoulli(0.5) {
                    (u + g) % n
                } else {
                    (u + n - g) % n
                }
            }
            ProcGraph::Table(g) => g.sample_neighbor(u, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_neighbors(pg: &ProcGraph, samples: usize) {
        let n = pg.n();
        let mut rng = Pcg64::seed(5);
        for _ in 0..samples {
            let u = rng.below_usize(n);
            let v = pg.sample_neighbor(u, &mut rng);
            assert!(v < n, "neighbor {v} out of range (n={n})");
            assert_ne!(v, u, "self-loop from {u}");
        }
    }

    #[test]
    fn small_n_materializes_a_table() {
        let pg = ProcGraph::resolve(Topology::Ring, 16, 7).unwrap();
        assert!(matches!(pg, ProcGraph::Table(_)));
        assert_eq!(pg.n(), 16);
        assert_eq!(pg.degree_hint(), 2);
        check_neighbors(&pg, 200);
    }

    #[test]
    fn procedural_families_stay_in_range_above_the_cutover() {
        let n = MATERIALIZE_MAX * 4; // 262144: square AND a power of two
        for topo in [
            Topology::Complete,
            Topology::Ring,
            Topology::Torus,
            Topology::Hypercube,
            Topology::Expander(8),
        ] {
            let pg = ProcGraph::resolve(topo, n, 7).unwrap();
            assert!(
                !matches!(pg, ProcGraph::Table(_)),
                "{topo:?} should be procedural at n={n}"
            );
            assert_eq!(pg.n(), n, "{topo:?}");
            check_neighbors(&pg, 500);
        }
    }

    #[test]
    fn table_only_families_fail_actionably_above_the_cutover() {
        let e = ProcGraph::resolve(Topology::RandomRegular(4), MATERIALIZE_MAX * 2, 7)
            .unwrap_err();
        assert!(e.contains("expander"), "{e}");
        let e =
            ProcGraph::resolve(Topology::PowerLaw(2), MATERIALIZE_MAX * 2, 7).unwrap_err();
        assert!(e.contains("infeasible"), "{e}");
    }

    #[test]
    fn complete_neighbor_draw_covers_all_and_skips_self() {
        let pg = ProcGraph::Complete { n: 8 };
        let mut rng = Pcg64::seed(1);
        let mut hit = [0u32; 8];
        for _ in 0..4000 {
            hit[pg.sample_neighbor(3, &mut rng)] += 1;
        }
        assert_eq!(hit[3], 0);
        for (v, &h) in hit.iter().enumerate() {
            if v != 3 {
                assert!(h > 300, "neighbor {v} undersampled: {h}");
            }
        }
    }

    #[test]
    fn circulant_uses_its_generator_set() {
        let pg = ProcGraph::Circulant { n: 1000, gens: vec![1, 17, 243] };
        assert_eq!(pg.degree_hint(), 6);
        let mut rng = Pcg64::seed(2);
        for _ in 0..500 {
            let v = pg.sample_neighbor(10, &mut rng);
            let d = (v as i64 - 10).rem_euclid(1000);
            let d = d.min(1000 - d) as usize;
            assert!([1, 17, 243].contains(&d), "offset {d} not a generator");
        }
    }

    #[test]
    fn expander_resolution_is_deterministic_per_seed() {
        let n = MATERIALIZE_MAX * 2;
        let a = ProcGraph::resolve(Topology::Expander(8), n, 11).unwrap();
        let b = ProcGraph::resolve(Topology::Expander(8), n, 11).unwrap();
        let (ProcGraph::Circulant { gens: ga, .. }, ProcGraph::Circulant { gens: gb, .. }) =
            (&a, &b)
        else {
            panic!("expected circulant expander surrogate");
        };
        assert_eq!(ga, gb);
        assert_eq!(ga.len(), 4);
        assert_eq!(ga[0], 1);
    }
}
