//! The membership subsystem: node roster, compact node-state storage, and
//! the free-running **scale engine** that makes n ∈ {10k, 100k, 1M}
//! runnable on one box.
//!
//! The paper's population model is an *open* crowd of cheap, transient
//! nodes — "scalability to hundreds of nodes, [tolerating] node and
//! message failures" — but every executor before this subsystem assumed a
//! fixed roster of densely-materialized node states, which caps n at the
//! tens of thousands and rules out churn entirely. The subsystem owns the
//! three pieces that change that, each usable on its own:
//!
//! * [`roster`] — **who exists**: a generation-stamped slot roster
//!   ([`Roster`]) whose parity protocol makes `(slot, generation)` a
//!   unique incarnation identity (recycled slots never alias departed
//!   nodes), plus the parsed [`ChurnSpec`] join/leave process.
//! * [`store`] — **where state lives**: the [`NodeStore`] arena keeps each
//!   node's model lattice-encoded against the initial model (the same
//!   codec the wire uses, reused as a *storage* codec — ~200 bytes/node at
//!   d=64 vs ~1 KB dense), under the freerun seqlock protocol, with a
//!   sticky full-precision escape for models that drift out of lattice
//!   range.
//! * [`sampling`] — **who meets whom**: [`ProcGraph`] resolves the overlay
//!   to O(1) closed-form neighbor draws above the materialize cutover
//!   (complete / ring / torus / hypercube / circulant-expander), so
//!   partner sampling holds no global graph and contends on nothing.
//! * [`engine`] — the [`run_scale`] executor composing the three: freerun
//!   semantics (checkout → local phase → snapshot merge → commit) over
//!   compact records, with live churn, per-worker RNG streams, an
//!   enforced bytes-per-node budget, and roster/storage telemetry in
//!   [`MembershipStats`](crate::coordinator::MembershipStats).
//!
//! The dense executors are untouched: below the scale regime they remain
//! the replayable (serial/parallel) and measured (freerun) reference
//! paths; `lib.rs` documents where the regime boundary sits and the CLI
//! routes `--executor freerun` here when n or churn demands it.

pub mod engine;
pub mod roster;
pub mod sampling;
pub mod store;

pub use engine::{run_scale, ScaleOptions};
pub use roster::{ChurnSpec, Roster};
pub use sampling::{ProcGraph, MATERIALIZE_MAX};
pub use store::{NodeMeta, NodeStore, STORE_BITS, STORE_EPS};
