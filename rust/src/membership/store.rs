//! Compact node-state storage: per-node models parked lattice-encoded in
//! one flat arena, materialized into worker scratch only while touched.
//!
//! A dense f32 model at d=64 is 256 bytes/node *per copy*, and the freerun
//! executor keeps three (params in the worker's `NodeState`, the published
//! slot's double buffer) — ~1 KB/node before counting momentum. The store
//! replaces all of that with **one** record per node holding the model as
//! a 16-bit lattice payload (the same codec the wire uses, reused as a
//! storage codec against a frozen reference model) plus a small header:
//!
//! ```text
//! offset  field                            width
//! 0       rng state (Pcg64 raw)            16
//! 16      payload checksum                 8
//! 24      local SGD steps                  8
//! 32      stochastic-rounding seed         4
//! 36      last minibatch loss (f32)        4
//! 40      raw-escape flag                  1
//! 41..48  padding                          —
//! 48      lattice payload                  ceil(d·16/8)
//! ```
//!
//! At d=64 that is 176 bytes/node (48 + 128), ~200 with the per-slot
//! seqlock/stamp/escape words — the bytes-per-node budget the scale bench
//! tracks. Quantization noise from re-encoding on every commit is unbiased
//! stochastic rounding at `STORE_EPS` (fresh seed per commit), far below
//! the gradient noise of any workload the paper considers.
//!
//! **Concurrency** is the freerun `ModelSlot` seqlock, single-buffered:
//! an odd sequence number marks a write in progress; readers copy out the
//! record bytes, then validate the sequence was stable across the copy and
//! retry otherwise (same protocol and safety argument as `ModelSlot`,
//! without the double buffer — a torn copy is always detected and
//! discarded, never decoded). Owners `commit` full records (spinning on
//! the rare cross-write race); partners `try_push` payload-only updates
//! best-effort, preserving the owner's RNG/step header fields.
//!
//! **Raw escape**: the lattice codec is exact only while the model stays
//! within `(M/2 − 1)·ε` of the reference in every coordinate (~±32.7 at
//! the default 16-bit/1e-3 grid). A commit that would violate the
//! criterion flips the node to a lazily-allocated full-precision side
//! buffer instead (sticky, counted in [`NodeStore::raw_nodes`]) — nothing
//! ever decodes garbage, and well-behaved runs never allocate one.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, Ordering};

use crate::quant;

/// Bits per coordinate of the storage lattice (M = 2^16 residues).
pub const STORE_BITS: u32 = 16;
/// Storage lattice resolution: ±(M/2−1)·ε ≈ ±32.7 of headroom around the
/// reference model, quantization error ≤ 1e-3 per coordinate.
pub const STORE_EPS: f32 = 1e-3;

const OFF_RNG: usize = 0;
const OFF_CHECKSUM: usize = 16;
const OFF_STEPS: usize = 24;
const OFF_SEED: usize = 32;
const OFF_LOSS: usize = 36;
const OFF_FLAG: usize = 40;
const HEADER: usize = 48;

/// Per-coordinate deviation from the reference at which a commit escapes
/// to the raw side buffer: one grid step inside the decode criterion
/// `(M/2 − 1)·ε`, so encode-side rounding can never push a stored model
/// across the exactness boundary.
const ESCAPE_DEV: f32 = ((1u32 << STORE_BITS) / 2 - 2) as f32 * STORE_EPS;

/// Header fields returned by a node checkout.
#[derive(Clone, Copy, Debug)]
pub struct NodeMeta {
    /// global interaction count at the record's last write (staleness base)
    pub stamp: u64,
    /// the node's private RNG stream, resumable via `Pcg64::from_raw_state`
    pub rng_state: u128,
    /// local SGD steps performed so far
    pub steps: u64,
    /// last observed minibatch loss (NaN until the first local phase)
    pub last_loss: f32,
    /// seqlock read retries this checkout paid
    pub retries: u64,
}

/// The arena. One record per slot; see module docs for layout and
/// protocol. Safe to share across worker threads (`Sync` below).
pub struct NodeStore {
    arena: UnsafeCell<Box<[u8]>>,
    seq: Box<[AtomicU64]>,
    stamp: Box<[AtomicU64]>,
    /// lazily-allocated full-precision escape buffers (null = lattice)
    raw: Box<[AtomicPtr<f32>]>,
    reference: Vec<f32>,
    dim: usize,
    stride: usize,
    payload: usize,
    raw_nodes: AtomicU64,
    decode_failures: AtomicU64,
}

// SAFETY: all arena access goes through the per-slot seqlock (`seq`):
// writers hold the odd sequence while mutating a record, readers copy the
// record out and validate the sequence was even and unchanged across the
// copy, discarding torn snapshots. Raw escape buffers are published once
// via CAS and mutated only under the same slot's seqlock. This is the
// `ModelSlot` safety argument with one buffer instead of two.
unsafe impl Sync for NodeStore {}

impl NodeStore {
    /// Arena for `capacity` nodes of model dimension `reference.len()`,
    /// every record zeroed (callers seed real state before first read).
    /// `reference` is the frozen decode reference — the initial model.
    pub fn new(capacity: usize, reference: Vec<f32>) -> Self {
        let dim = reference.len();
        assert!(dim > 0, "node store needs a non-empty reference model");
        let payload = quant::payload_bytes(dim, STORE_BITS);
        let stride = (HEADER + payload).div_ceil(8) * 8;
        Self {
            arena: UnsafeCell::new(vec![0u8; capacity * stride].into_boxed_slice()),
            seq: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            stamp: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            raw: (0..capacity).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            reference,
            dim,
            stride,
            payload,
            raw_nodes: AtomicU64::new(0),
            decode_failures: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.seq.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The frozen decode reference (the initial model).
    pub fn reference(&self) -> &[f32] {
        &self.reference
    }

    /// Packed payload length in bytes — the scratch size
    /// [`NodeStore::read_node`] / [`NodeStore::commit`] require.
    pub fn payload_len(&self) -> usize {
        self.payload
    }

    /// Resident bytes per node this store accounts for: the record stride
    /// plus the per-slot seqlock, stamp, and escape-pointer words. (The
    /// engine adds its own per-node roster/rate overheads on top.)
    pub fn bytes_per_node(&self) -> usize {
        Self::record_bytes(self.dim)
    }

    /// [`NodeStore::bytes_per_node`] without a store — what a budget gate
    /// checks *before* committing to the arena allocation.
    pub fn record_bytes(dim: usize) -> usize {
        let payload = quant::payload_bytes(dim, STORE_BITS);
        (HEADER + payload).div_ceil(8) * 8 + 8 + 8 + 8
    }

    /// Total arena bytes (records only).
    pub fn arena_bytes(&self) -> usize {
        self.capacity() * self.stride
    }

    /// Nodes that escaped to full-precision side buffers.
    pub fn raw_nodes(&self) -> u64 {
        self.raw_nodes.load(Ordering::Relaxed)
    }

    /// Checksum-verified decodes that failed (impossible while commits
    /// respect the escape criterion; counted, reference-filled).
    pub fn decode_failures(&self) -> u64 {
        self.decode_failures.load(Ordering::Relaxed)
    }

    #[inline]
    fn rec_ptr(&self, slot: usize) -> *mut u8 {
        debug_assert!(slot < self.capacity(), "slot {slot} out of range");
        // SAFETY: in-bounds offset into the arena allocation; the returned
        // pointer is only dereferenced under the slot's seqlock protocol
        unsafe { (*self.arena.get()).as_mut_ptr().add(slot * self.stride) }
    }

    /// Consistent snapshot of a record: decoded params into `out`, header
    /// fields in the returned [`NodeMeta`]. Used both for owner checkouts
    /// and partner snapshots; never blocks writers, retries torn reads.
    /// `payload_scratch` must be [`NodeStore::payload_len`] bytes.
    pub fn read_node(
        &self,
        slot: usize,
        out: &mut [f32],
        payload_scratch: &mut [u8],
    ) -> NodeMeta {
        assert_eq!(out.len(), self.dim, "read_node: output buffer length");
        assert_eq!(payload_scratch.len(), self.payload, "read_node: payload scratch");
        let mut header = [0u8; HEADER];
        let mut retries: u64 = 0;
        let (stamp, is_raw) = loop {
            let s1 = self.seq[slot].load(Ordering::Acquire);
            if s1 & 1 == 1 {
                retries += 1;
                std::hint::spin_loop();
                continue;
            }
            let p = self.rec_ptr(slot);
            // SAFETY: seqlock-validated copy (see Sync impl note); a torn
            // copy is detected below and retried
            unsafe {
                std::ptr::copy_nonoverlapping(p, header.as_mut_ptr(), HEADER);
            }
            let is_raw = header[OFF_FLAG] != 0;
            if is_raw {
                let rp = self.raw[slot].load(Ordering::Acquire);
                debug_assert!(!rp.is_null(), "raw flag set without a buffer");
                // SAFETY: published once, freed only on drop; contents are
                // seqlock-consistent like the arena record
                unsafe {
                    std::ptr::copy_nonoverlapping(rp, out.as_mut_ptr(), self.dim);
                }
            } else {
                // SAFETY: as above
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        p.add(HEADER),
                        payload_scratch.as_mut_ptr(),
                        self.payload,
                    );
                }
            }
            let st = self.stamp[slot].load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if self.seq[slot].load(Ordering::Relaxed) == s1 {
                break (st, is_raw);
            }
            retries += 1;
        };
        let checksum =
            u64::from_le_bytes(header[OFF_CHECKSUM..OFF_CHECKSUM + 8].try_into().unwrap());
        let seed = u32::from_le_bytes(header[OFF_SEED..OFF_SEED + 4].try_into().unwrap());
        if !is_raw {
            // decode outside the critical window — the copy is consistent
            let ok = quant::decode_slice(
                payload_scratch,
                STORE_BITS,
                STORE_EPS,
                seed,
                checksum,
                &self.reference,
                out,
            )
            .is_ok();
            if !ok {
                self.decode_failures.fetch_add(1, Ordering::Relaxed);
                out.copy_from_slice(&self.reference);
            }
        }
        NodeMeta {
            stamp,
            rng_state: u128::from_le_bytes(header[OFF_RNG..OFF_RNG + 16].try_into().unwrap()),
            steps: u64::from_le_bytes(header[OFF_STEPS..OFF_STEPS + 8].try_into().unwrap()),
            last_loss: f32::from_le_bytes(header[OFF_LOSS..OFF_LOSS + 4].try_into().unwrap()),
            retries,
        }
    }

    /// Owner commit: write the full record (params + RNG/steps/loss
    /// header), spinning out the rare cross-write race. Returns the CAS
    /// retry count.
    #[allow(clippy::too_many_arguments)]
    pub fn commit(
        &self,
        slot: usize,
        params: &[f32],
        rng_state: u128,
        steps: u64,
        last_loss: f32,
        stamp: u64,
        seed: u32,
        payload_scratch: &mut [u8],
    ) -> u64 {
        let mut retries = 0u64;
        loop {
            match self.write(
                slot,
                params,
                Some((rng_state, steps, last_loss)),
                stamp,
                seed,
                payload_scratch,
            ) {
                true => return retries,
                false => {
                    retries += 1;
                    std::hint::spin_loop();
                }
            }
        }
    }

    /// Best-effort cross-write of a partner payload: params only, the
    /// owner's RNG/steps/loss header is preserved. Returns `false`
    /// (dropping the write, never blocking) when the slot is held.
    pub fn try_push(
        &self,
        slot: usize,
        params: &[f32],
        stamp: u64,
        seed: u32,
        payload_scratch: &mut [u8],
    ) -> bool {
        self.write(slot, params, None, stamp, seed, payload_scratch)
    }

    /// One seqlock write attempt; `header` carries owner-only fields.
    fn write(
        &self,
        slot: usize,
        params: &[f32],
        header: Option<(u128, u64, f32)>,
        stamp: u64,
        seed: u32,
        payload_scratch: &mut [u8],
    ) -> bool {
        assert_eq!(params.len(), self.dim, "write: params length");
        assert_eq!(payload_scratch.len(), self.payload, "write: payload scratch");
        // escape is sticky: once a node has a raw buffer it stays raw, so
        // reads never race a lattice↔raw mode flip mid-incarnation
        let escaped = !self.raw[slot].load(Ordering::Acquire).is_null()
            || params
                .iter()
                .zip(&self.reference)
                .any(|(x, r)| !(x - r).abs().is_finite() || (x - r).abs() >= ESCAPE_DEV);
        // encode (or allocate the escape buffer) outside the critical
        // window, keeping the write hold to a couple of memcpys
        let (checksum, raw_ptr) = if escaped {
            (0u64, self.raw_ptr_or_alloc(slot))
        } else {
            (
                quant::encode_slice_into(params, STORE_EPS, STORE_BITS, seed, payload_scratch),
                std::ptr::null_mut(),
            )
        };
        let s = self.seq[slot].load(Ordering::Relaxed);
        if s & 1 == 1
            || self.seq[slot]
                .compare_exchange(s, s + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            return false;
        }
        let p = self.rec_ptr(slot);
        // SAFETY: we hold the slot's seqlock (odd sequence); no other
        // writer can enter and readers discard copies torn by us
        unsafe {
            if let Some((rng_state, steps, last_loss)) = header {
                std::ptr::copy_nonoverlapping(
                    rng_state.to_le_bytes().as_ptr(),
                    p.add(OFF_RNG),
                    16,
                );
                std::ptr::copy_nonoverlapping(
                    steps.to_le_bytes().as_ptr(),
                    p.add(OFF_STEPS),
                    8,
                );
                std::ptr::copy_nonoverlapping(
                    last_loss.to_le_bytes().as_ptr(),
                    p.add(OFF_LOSS),
                    4,
                );
            }
            std::ptr::copy_nonoverlapping(
                checksum.to_le_bytes().as_ptr(),
                p.add(OFF_CHECKSUM),
                8,
            );
            std::ptr::copy_nonoverlapping(seed.to_le_bytes().as_ptr(), p.add(OFF_SEED), 4);
            *p.add(OFF_FLAG) = u8::from(escaped);
            if escaped {
                std::ptr::copy_nonoverlapping(params.as_ptr(), raw_ptr, self.dim);
            } else {
                std::ptr::copy_nonoverlapping(
                    payload_scratch.as_ptr(),
                    p.add(HEADER),
                    self.payload,
                );
            }
        }
        self.stamp[slot].store(stamp, Ordering::Relaxed);
        self.seq[slot].store(s + 2, Ordering::Release);
        true
    }

    fn raw_ptr_or_alloc(&self, slot: usize) -> *mut f32 {
        let cur = self.raw[slot].load(Ordering::Acquire);
        if !cur.is_null() {
            return cur;
        }
        let b: Box<[f32]> = vec![0.0f32; self.dim].into_boxed_slice();
        let p = Box::into_raw(b) as *mut f32;
        match self.raw[slot].compare_exchange(
            std::ptr::null_mut(),
            p,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                self.raw_nodes.fetch_add(1, Ordering::Relaxed);
                p
            }
            Err(existing) => {
                // lost the publish race: free ours, use the winner's
                // SAFETY: `p` is the box we just leaked and nobody else
                // has seen it
                unsafe {
                    drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(p, self.dim)));
                }
                existing
            }
        }
    }
}

impl Drop for NodeStore {
    fn drop(&mut self) {
        for r in self.raw.iter() {
            let p = r.load(Ordering::Acquire);
            if !p.is_null() {
                // SAFETY: published escape buffers are owned by the store
                // and freed exactly once, here
                unsafe {
                    drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(p, self.dim)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    fn store(dim: usize, cap: usize) -> NodeStore {
        let reference: Vec<f32> = (0..dim).map(|i| (i as f32) * 0.01).collect();
        NodeStore::new(cap, reference)
    }

    #[test]
    fn commit_then_read_roundtrips_within_eps() {
        let s = store(33, 4);
        let mut scratch = vec![0u8; s.payload_len()];
        let mut rng = Pcg64::seed(3);
        let params: Vec<f32> =
            s.reference().iter().map(|r| r + (rng.f32() - 0.5) * 2.0).collect();
        let retries = s.commit(1, &params, 0xDEAD_BEEF, 7, 0.25, 42, 99, &mut scratch);
        assert_eq!(retries, 0);
        let mut out = vec![0.0f32; 33];
        let meta = s.read_node(1, &mut out, &mut scratch);
        assert_eq!(meta.stamp, 42);
        assert_eq!(meta.rng_state, 0xDEAD_BEEF);
        assert_eq!(meta.steps, 7);
        assert_eq!(meta.last_loss, 0.25);
        for (o, p) in out.iter().zip(&params) {
            assert!((o - p).abs() <= STORE_EPS * 1.0001, "err {}", (o - p).abs());
        }
        assert_eq!(s.raw_nodes(), 0);
        assert_eq!(s.decode_failures(), 0);
    }

    #[test]
    fn try_push_preserves_the_owner_header() {
        let s = store(8, 2);
        let mut scratch = vec![0u8; s.payload_len()];
        let own: Vec<f32> = s.reference().to_vec();
        s.commit(0, &own, 111, 5, 1.5, 10, 1, &mut scratch);
        let pushed: Vec<f32> = s.reference().iter().map(|r| r + 0.5).collect();
        assert!(s.try_push(0, &pushed, 20, 2, &mut scratch));
        let mut out = vec![0.0f32; 8];
        let meta = s.read_node(0, &mut out, &mut scratch);
        // params took the push, the RNG/steps/loss header did not
        assert!((out[0] - pushed[0]).abs() <= STORE_EPS * 1.0001);
        assert_eq!(meta.rng_state, 111);
        assert_eq!(meta.steps, 5);
        assert_eq!(meta.last_loss, 1.5);
        assert_eq!(meta.stamp, 20);
    }

    #[test]
    fn far_models_escape_to_raw_and_stay_exact() {
        let s = store(16, 2);
        let mut scratch = vec![0u8; s.payload_len()];
        let far: Vec<f32> = s.reference().iter().map(|r| r + 100.0).collect();
        s.commit(0, &far, 1, 1, 0.0, 1, 3, &mut scratch);
        assert_eq!(s.raw_nodes(), 1);
        let mut out = vec![0.0f32; 16];
        s.read_node(0, &mut out, &mut scratch);
        assert_eq!(out, far, "raw escape must be exact");
        // sticky: a later in-range commit stays raw (and exact)
        let near: Vec<f32> = s.reference().to_vec();
        s.commit(0, &near, 2, 2, 0.0, 2, 4, &mut scratch);
        assert_eq!(s.raw_nodes(), 1);
        s.read_node(0, &mut out, &mut scratch);
        assert_eq!(out, near);
    }

    #[test]
    fn bytes_per_node_matches_the_layout() {
        let s = store(64, 10);
        // 48-byte header + ceil(64·16/8)=128 payload = 176, already 8-aligned
        assert_eq!(s.payload_len(), 128);
        assert_eq!(s.arena_bytes(), 10 * 176);
        assert_eq!(s.bytes_per_node(), 176 + 24);
    }

    #[test]
    fn concurrent_pushes_and_reads_never_tear() {
        let dim = 32;
        let s = store(dim, 1);
        let mut scratch = vec![0u8; s.payload_len()];
        let base: Vec<f32> = s.reference().to_vec();
        s.commit(0, &base, 0, 0, 0.0, 0, 0, &mut scratch);
        let writes = 2_000u64;
        std::thread::scope(|scope| {
            let sref = &s;
            scope.spawn(move || {
                let mut scratch = vec![0u8; sref.payload_len()];
                for v in 1..=writes {
                    // constant vectors: decoded coords must all agree
                    let val = (v % 30) as f32;
                    let data = vec![val; dim];
                    while !sref.try_push(0, &data, v, v as u32, &mut scratch) {
                        std::hint::spin_loop();
                    }
                }
            });
            for _ in 0..3 {
                scope.spawn(move || {
                    let mut scratch = vec![0u8; sref.payload_len()];
                    let mut out = vec![0.0f32; dim];
                    for _ in 0..2_000 {
                        sref.read_node(0, &mut out, &mut scratch);
                        let v = out[0];
                        assert!(
                            out.iter().all(|&x| (x - v).abs() <= 2.0 * STORE_EPS),
                            "torn read: {out:?}"
                        );
                    }
                });
            }
        });
        assert_eq!(s.decode_failures(), 0);
    }
}
