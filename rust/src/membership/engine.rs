//! The membership scale engine: a free-running executor over the compact
//! [`NodeStore`] and the live [`Roster`], for node counts the dense
//! executor cannot hold (n ∈ {10k, 100k, 1M} on one box).
//!
//! The dense freerun executor keeps every node's full [`NodeState`] (five
//! `dim`-wide vectors plus a double-buffered slot) resident — perfect at
//! thousands of nodes, impossible at a million. This engine inverts the
//! representation: node state *rests* lattice-encoded in the store
//! (~200 bytes/node at d=64) and is materialized into one per-worker
//! [`NodeState`] + [`MergeScratch`] only while an interaction touches it.
//! The executor protocol is freerun's, re-read through the store:
//!
//! 1. the worker claims a global event index and picks a live initiator
//!    from its own slot range (speed-class rejection sampling — no global
//!    RNG, no cross-shard contention);
//! 2. checkout: seqlock-read + decode the initiator's record, resume its
//!    private RNG via [`Pcg64::from_raw_state`];
//! 3. the policy's local phase, then a partner draw over the procedural
//!    graph ([`ProcGraph::sample_neighbor`], O(1)) retried past vacant
//!    slots, the partner's record snapshot-read, and the policy merge;
//! 4. commit: re-encode + write back the initiator's record (spinning out
//!    the rare cross-write race), best-effort cross-write the partner
//!    (dropped and counted on conflict or churn — nobody ever waits).
//!
//! **What is deliberately not persisted per node**: momentum (zeroed at
//! every checkout — the pairwise policies exchange models only, and the
//! paper's analysis carries no cross-interaction momentum) and the
//! simulated per-node clock (compute/comm charges are summed globally, so
//! throughput and totals survive; the per-node max — `sim_time` — does
//! not, and is reported as NaN). Both are the price of the ~200-byte
//! record, stated here and in the stats.
//!
//! **Churn** ([`ChurnSpec`]) runs as a per-event birth–death competition
//! in each worker: before each claimed event, one departure fires with
//! probability `leave · live_owned/owned` (death rate ∝ live population)
//! and one arrival with probability `join` (birth rate ∝ capacity, since
//! events are dealt ∝ owned slots). The stationary live count is therefore
//! `n · min(1, join/leave)`, mean-reverting — the band the membership
//! statistical test pins. Joiners take a recycled slot under a fresh
//! odd [`Roster`] generation, bootstrap their model from a live
//! neighbor's snapshot (falling back to the initial model), and derive a
//! fresh RNG stream from `(seed, slot, generation)` so no recycled slot
//! ever replays a departed node's randomness.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;
use std::time::Instant;

use crate::backend::Backend;
use crate::coordinator::{
    Algorithm, CurvePoint, FreerunStats, LrSchedule, MembershipStats, MergeScratch,
    MixPolicy, NodeState, PayloadKind, RunMetrics, RunSpec, StalenessHistogram, StepCtx,
    WorkerActivity,
};
use crate::netmodel::CostModel;
use crate::obs::metrics::append_snapshot;
use crate::obs::{MetricsRegistry, METRICS_CADENCE};
use crate::rngx::Pcg64;
use crate::scenario::{SpeedClass, STREAM_SCENARIO};
use crate::topology::{Graph, Topology};

use super::roster::{ChurnSpec, Roster};
use super::sampling::ProcGraph;
use super::store::NodeStore;

/// Worker RNG stream tags (`STREAM_SCALE_WORKER + worker_id`).
const STREAM_SCALE_WORKER: u64 = 0x5EED_3CA1_0000_0100;
/// Node RNG stream tags (`STREAM_SCALE_NODE + slot`); joiner incarnations
/// fold the roster generation into the root seed instead, so recycled
/// slots never replay a departed node's stream.
const STREAM_SCALE_NODE: u64 = 0x5EED_3CA1_0010_0000;

/// Staleness histogram capacity: exact buckets for lags up to 4096, one
/// overflow bucket above (the dense executor sizes by `n`, which would be
/// an 8M-bucket allocation per worker at n=1M).
const STALENESS_CAP: usize = 4096;

/// Partner re-draws past vacant (churned-out) slots before the event runs
/// as an isolated local phase.
const PARTNER_TRIES: usize = 8;

/// Initiator rejection-sampling tries before the event is skipped (only
/// reachable when a worker's entire range churned out or carries extreme
/// speed-class skew).
const INITIATOR_TRIES: usize = 64;

/// Knobs of one scale-engine run, beyond the shared [`RunSpec`].
#[derive(Clone, Debug)]
pub struct ScaleOptions {
    /// worker threads (0 = available parallelism)
    pub threads: usize,
    /// overlay family — must be procedural-capable above the materialize
    /// cutover (see [`ProcGraph::resolve`])
    pub topology: Topology,
    /// per-node speed classes (initiation-rate skew)
    pub speeds: SpeedClass,
    /// live churn spec (fixed roster when inactive)
    pub churn: ChurnSpec,
    /// resident bytes-per-node ceiling, enforced before allocation
    /// (0 = unenforced)
    pub node_budget: u64,
    /// live nodes sampled for the final consensus/loss evaluation
    /// (0 = min(n, 4096))
    pub eval_sample: usize,
    /// Prometheus-text metrics snapshots appended here at the obs cadence
    pub metrics_out: Option<String>,
}

impl Default for ScaleOptions {
    fn default() -> Self {
        Self {
            threads: 0,
            topology: Topology::Complete,
            speeds: SpeedClass::Uniform,
            churn: ChurnSpec::none(),
            node_budget: 0,
            eval_sample: 0,
            metrics_out: None,
        }
    }
}

/// Shared run state every scale worker sees.
struct ScaleShared<'a> {
    backend: &'a dyn Backend,
    cost: &'a CostModel,
    policy: &'a dyn MixPolicy,
    store: &'a NodeStore,
    roster: &'a Roster,
    graph: &'a ProcGraph,
    rates: &'a [f64],
    max_rate: f64,
    lr: LrSchedule,
    churn: ChurnSpec,
    seed: u64,
    dim: usize,
    n: usize,
    /// interactions completed (the staleness/lr clock, as in freerun)
    done: AtomicU64,
    /// global event indices (lr schedule only; never redistributes work)
    claimed: AtomicU64,
    bits: AtomicU64,
    fallbacks: AtomicU64,
    churn_misses: AtomicU64,
    skipped: AtomicU64,
    local_steps: AtomicU64,
    /// f64 totals flushed once per worker at exit (bit-stable join order
    /// is irrelevant: these are throughput aggregates, and this executor
    /// is non-replayable by contract anyway)
    compute_ns: AtomicU64,
    comm_ns: AtomicU64,
    /// placeholder for [`StepCtx::graph`]: the pairwise policies' local
    /// phase and merge never consult it (partner draws happen here, over
    /// the procedural graph) — asserted by the engine's policy gate
    pair_graph: Graph,
}

/// One worker's private tallies, merged at join.
struct WorkerOut {
    activity: WorkerActivity,
    staleness: StalenessHistogram,
    read_retries: u64,
    publish_retries: u64,
    push_conflicts: u64,
}

/// Run `algo` free-running over the compact store at roster capacity
/// `spec.n`. Requires a plain-model [`MixPolicy`] (the same gate as the
/// dense freerun path, narrowed: push-sum's weighted slots assume
/// cross-writes mutate canonical state, which the best-effort store
/// protocol does not guarantee under churn).
pub fn run_scale(
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    spec: &RunSpec,
    cost: &CostModel,
    opts: &ScaleOptions,
) -> Result<RunMetrics, String> {
    let n = spec.n;
    if n < 2 {
        return Err(format!(
            "the scale engine needs n >= 2 (got n={n}); pairwise gossip has \
             no partner to draw at n < 2"
        ));
    }
    let policy = algo.mix_policy().ok_or_else(|| {
        format!(
            "algorithm '{}' has no free-running mix policy, so it cannot run \
             on the scale engine: use swarm|poisson|adpsgd|dpsgd, or a replay \
             executor at small n",
            algo.name()
        )
    })?;
    if policy.payload() != PayloadKind::Plain {
        return Err(format!(
            "algorithm '{}' publishes weighted (push-sum) slot payloads, \
             which the compact store does not carry: use \
             swarm|poisson|adpsgd|dpsgd at scale, or the dense freerun \
             executor for sgp",
            algo.name()
        ));
    }
    let graph = ProcGraph::resolve(opts.topology, n, spec.seed)?;
    let (params0, _mom0) = backend.init();
    let dim = params0.len();

    // budget gate BEFORE the arena allocation: resident bytes per node =
    // store record + per-slot atomics + roster generation + speed rate
    let per_node = (NodeStore::record_bytes(dim) + 4 + 8) as u64;
    if opts.node_budget > 0 && per_node > opts.node_budget {
        return Err(format!(
            "node store needs {per_node} bytes/node at d={dim}, over the \
             node_budget={} ceiling; raise the budget, shrink the model, or \
             omit the key (or the --node-budget flag) to run unenforced",
            opts.node_budget
        ));
    }

    let threads = match opts.threads {
        0 => std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
        t => t,
    }
    .min(n);
    let store = NodeStore::new(n, params0.clone());
    let roster = Roster::new(n, n);
    let rates =
        opts.speeds.rates(n, &mut Pcg64::stream(spec.seed, STREAM_SCENARIO));
    let max_rate = rates.iter().cloned().fold(0.0, f64::max).max(1e-300);

    let sh = ScaleShared {
        backend,
        cost,
        policy: policy.as_ref(),
        store: &store,
        roster: &roster,
        graph: &graph,
        rates: &rates,
        max_rate,
        lr: spec.lr,
        churn: opts.churn,
        seed: spec.seed,
        dim,
        n,
        done: AtomicU64::new(0),
        claimed: AtomicU64::new(0),
        bits: AtomicU64::new(0),
        fallbacks: AtomicU64::new(0),
        churn_misses: AtomicU64::new(0),
        skipped: AtomicU64::new(0),
        local_steps: AtomicU64::new(0),
        compute_ns: AtomicU64::new(0),
        comm_ns: AtomicU64::new(0),
        pair_graph: Graph::complete(2),
    };
    let kernel = algo.kernel();
    let barrier = Barrier::new(threads);
    let stop = AtomicBool::new(false);

    let start = Instant::now();
    let mut outs: Vec<WorkerOut> = Vec::with_capacity(threads);
    std::thread::scope(|scope| -> Result<(), String> {
        let monitor = opts.metrics_out.as_deref().map(|path| {
            let f = std::fs::File::create(path)
                .map_err(|e| format!("cannot create metrics file {path}: {e}"))?;
            let shr = &sh;
            let stopr = &stop;
            Ok::<_, String>(scope.spawn(move || monitor_loop(shr, stopr, f, per_node)))
        });
        let monitor = match monitor {
            Some(Err(e)) => return Err(e),
            Some(Ok(h)) => Some(h),
            None => None,
        };
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let shr = &sh;
                let bar = &barrier;
                let lo = w * n / threads;
                let hi = (w + 1) * n / threads;
                let quota = spec.events * (hi as u64) / (n as u64)
                    - spec.events * (lo as u64) / (n as u64);
                scope.spawn(move || scale_worker(shr, kernel, w, lo..hi, quota, bar))
            })
            .collect();
        // join everything and set the stop flag BEFORE propagating any
        // worker panic, or the monitor loop would spin forever
        let mut worker_panicked = false;
        for h in handles {
            match h.join() {
                Ok(o) => outs.push(o),
                Err(_) => worker_panicked = true,
            }
        }
        stop.store(true, Ordering::Release);
        if let Some(h) = monitor {
            let _ = h.join();
        }
        if worker_panicked {
            return Err("scale worker panicked".to_string());
        }
        Ok(())
    })?;
    let wall_secs = start.elapsed().as_secs_f64();

    // merge per-worker tallies in worker order
    let mut staleness = StalenessHistogram::new(STALENESS_CAP);
    let (mut read_retries, mut publish_retries, mut push_conflicts) = (0u64, 0u64, 0u64);
    let mut workers = Vec::with_capacity(outs.len());
    for o in &outs {
        staleness.merge(&o.staleness);
        read_retries += o.read_retries;
        publish_retries += o.publish_retries;
        push_conflicts += o.push_conflicts;
        workers.push(o.activity);
    }

    // final evaluation over a strided sample of live slots (reading all
    // n records at 1M would dominate the run; the sample size is surfaced
    // in the stats so no truncation is silent)
    let eval_sample = match opts.eval_sample {
        0 => n.min(4096),
        k => n.min(k),
    };
    let mut acc = vec![0.0f64; dim];
    let mut buf = vec![0.0f32; dim];
    let mut payload = vec![0u8; store.payload_len()];
    let mut individual: Vec<f32> = Vec::new();
    let (mut sampled, mut loss_sum, mut loss_n, mut steps_sum) = (0usize, 0.0f64, 0u64, 0.0f64);
    let stride = (n / eval_sample).max(1);
    let mut slot = 0usize;
    while slot < n && sampled < eval_sample {
        if roster.is_live(slot) {
            let meta = store.read_node(slot, &mut buf, &mut payload);
            for (a, &v) in acc.iter_mut().zip(&buf) {
                *a += v as f64;
            }
            if individual.is_empty() {
                individual = buf.clone();
            }
            if (meta.last_loss as f64).is_finite() {
                loss_sum += meta.last_loss as f64;
                loss_n += 1;
            }
            steps_sum += backend.epochs(slot, meta.steps);
            sampled += 1;
        }
        slot += stride;
    }
    if sampled == 0 {
        // every sampled slot churned out: fall back to the initial model
        individual = params0.clone();
        acc.iter_mut().zip(&params0).for_each(|(a, &v)| *a = v as f64);
        sampled = 1;
    }
    let consensus: Vec<f32> = acc.into_iter().map(|v| (v / sampled as f64) as f32).collect();
    let ev = backend.eval(&consensus);
    let ind = backend.eval(&individual);
    let train_loss = if loss_n == 0 { f64::NAN } else { loss_sum / loss_n as f64 };
    let epochs = steps_sum / sampled as f64;

    let total_bits = sh.bits.into_inner();
    let quant_fallbacks = sh.fallbacks.into_inner();
    // completed interactions (claimed events minus skips) — the honest
    // throughput numerator
    let interactions = sh.done.into_inner();
    let mut m = RunMetrics::new(&spec.name);
    m.push(CurvePoint {
        t: spec.events,
        parallel_time: algo.parallel_time(spec.events, n),
        // per-node simulated clocks are not persisted in the compact
        // record (see module docs): the max-clock axis is undefined here
        sim_time: f64::NAN,
        epochs,
        train_loss,
        eval_loss: ev.loss,
        eval_acc: ev.accuracy,
        indiv_loss: ind.loss,
        gamma: f64::NAN,
        bits: total_bits,
    });
    m.interactions = interactions;
    m.local_steps = sh.local_steps.into_inner();
    m.total_bits = total_bits;
    m.quant_fallbacks = quant_fallbacks;
    m.sim_time = f64::NAN;
    m.compute_time_total = sh.compute_ns.into_inner() as f64 * 1e-9;
    m.comm_time_total = sh.comm_ns.into_inner() as f64 * 1e-9;
    m.final_eval_loss = ev.loss;
    m.final_eval_acc = ev.accuracy;
    m.final_model = consensus;
    m.epochs = epochs;
    m.executor = "freerun".to_string();
    m.threads = threads;
    m.kernel = kernel.name().to_string();
    m.freerun = Some(FreerunStats {
        threads,
        // sharding is the contiguous slot-range deal, one shard per worker
        shards: threads,
        wall_secs,
        interactions_per_sec: interactions as f64 / wall_secs.max(1e-9),
        codec: sh.policy.wire().name().to_string(),
        kernel: kernel.name().to_string(),
        wire_bits: total_bits,
        wire_fallbacks: quant_fallbacks,
        slot_read_retries: read_retries,
        slot_publish_retries: publish_retries,
        slot_push_conflicts: push_conflicts,
        staleness,
        workers,
        membership: Some(MembershipStats {
            capacity: n,
            live_start: n as u64,
            live_end: roster.live_count(),
            joins: roster.joins(),
            leaves: roster.leaves(),
            rejected_joins: roster.rejected_joins(),
            churn_misses: sh.churn_misses.into_inner(),
            skipped_events: sh.skipped.into_inner(),
            bytes_per_node: per_node,
            node_budget: opts.node_budget,
            raw_nodes: store.raw_nodes(),
            decode_failures: store.decode_failures(),
            eval_sample,
        }),
    });
    Ok(m)
}

/// One scale worker: seed the owned slot range in-thread (NUMA first
/// touch), then drain the event quota through the checkout → local phase →
/// partner merge → commit protocol, interleaving the churn competition.
fn scale_worker(
    sh: &ScaleShared<'_>,
    kernel: crate::kernels::Kernel,
    wid: usize,
    range: std::ops::Range<usize>,
    quota: u64,
    barrier: &Barrier,
) -> WorkerOut {
    let dim = sh.dim;
    let mut rng = Pcg64::stream(sh.seed, STREAM_SCALE_WORKER + wid as u64);
    let mut payload = vec![0u8; sh.store.payload_len()];
    let mut st = NodeState::new(vec![0.0; dim], vec![0.0; dim], Pcg64::seed(0));
    let mut scratch = MergeScratch::with_kernel(dim, kernel);
    let mut boot = vec![0.0f32; dim];

    // seed owned records in-thread: every node starts at the shared x0
    // with its private stream, so first-touch places each record's pages
    // on the seeding worker's NUMA node
    for slot in range.clone() {
        let node_rng = Pcg64::stream(sh.seed, STREAM_SCALE_NODE + slot as u64);
        sh.store.commit(
            slot,
            sh.store.reference(),
            node_rng.state_raw(),
            0,
            f32::NAN,
            0,
            rng.next_u32(),
            &mut payload,
        );
    }
    // owned-range worklists: uniform-index removal keeps both draws exact
    let mut live: Vec<u32> = range.clone().map(|s| s as u32).collect();
    let mut free: Vec<u32> = Vec::new();
    barrier.wait();

    let mut out = WorkerOut {
        activity: WorkerActivity::default(),
        staleness: StalenessHistogram::new(STALENESS_CAP),
        read_retries: 0,
        publish_retries: 0,
        push_conflicts: 0,
    };
    let owned = range.len().max(1) as f64;
    let (mut local_steps, mut bits, mut fallbacks) = (0u64, 0u64, 0u64);
    let (mut compute_secs, mut comm_secs) = (0.0f64, 0.0f64);
    let mut done_local = 0u64;
    let wall0 = Instant::now();
    let mut busy_mark = wall0;
    while done_local < quota {
        let t = sh.claimed.fetch_add(1, Ordering::Relaxed);
        done_local += 1;

        if sh.churn.active() {
            // birth–death competition (module docs): death ∝ live, birth ∝
            // capacity — stationary at live = n·min(1, join/leave)
            if !live.is_empty()
                && rng.bernoulli((sh.churn.leave * live.len() as f64 / owned).min(1.0))
            {
                let idx = rng.below_usize(live.len());
                let slot = live.swap_remove(idx) as usize;
                sh.roster.retire(slot);
                free.push(slot as u32);
            }
            if rng.bernoulli(sh.churn.join.min(1.0)) {
                match free.pop() {
                    Some(slot32) => {
                        let slot = slot32 as usize;
                        let gen = sh.roster.admit(slot);
                        // bootstrap from a live neighbor's snapshot, else x0
                        let mut src: &[f32] = sh.store.reference();
                        for _ in 0..PARTNER_TRIES {
                            let nb = sh.graph.sample_neighbor(slot, &mut rng);
                            if sh.roster.is_live(nb) && nb != slot {
                                sh.store.read_node(nb, &mut boot, &mut payload);
                                src = &boot;
                                break;
                            }
                            sh.churn_misses.fetch_add(1, Ordering::Relaxed);
                        }
                        let joiner = Pcg64::stream(
                            sh.seed ^ ((gen as u64) << 32),
                            STREAM_SCALE_NODE + slot as u64,
                        );
                        let boot_vec: Vec<f32> = src.to_vec();
                        sh.store.commit(
                            slot,
                            &boot_vec,
                            joiner.state_raw(),
                            0,
                            f32::NAN,
                            sh.done.load(Ordering::Relaxed),
                            rng.next_u32(),
                            &mut payload,
                        );
                        live.push(slot32);
                    }
                    None => sh.roster.reject_join(),
                }
            }
        }

        // initiator: uniform live owned slot, speed-class rejection sampling
        let mut initiator = None;
        for _ in 0..INITIATOR_TRIES {
            if live.is_empty() {
                break;
            }
            let slot = live[rng.below_usize(live.len())] as usize;
            if rng.f64() * sh.max_rate < sh.rates[slot] {
                initiator = Some(slot);
                break;
            }
        }
        let Some(slot) = initiator else {
            sh.skipped.fetch_add(1, Ordering::Relaxed);
            continue;
        };

        // checkout: decode the record, resume the node's private stream
        let sync0 = Instant::now();
        let meta = sh.store.read_node(slot, &mut st.params, &mut payload);
        out.read_retries += meta.retries;
        out.activity.wait_secs += sync0.elapsed().as_secs_f64();
        st.rng = Pcg64::from_raw_state(meta.rng_state);
        st.steps = meta.steps;
        st.last_loss = meta.last_loss as f64;
        st.mom.fill(0.0); // momentum is not persisted (module docs)
        st.time = 0.0;
        st.compute = 0.0;
        st.comm_time = 0.0;

        let h = sh.policy.draw_steps(&mut rng);
        let ctx = StepCtx {
            backend: sh.backend,
            cost: sh.cost,
            graph: &sh.pair_graph,
            lr: sh.lr.at(t + 1),
            dim,
            n: sh.n,
        };
        sh.policy.local_phase(&ctx, slot, &mut st, h);
        local_steps += h;

        // partner: O(1) procedural draw, retried past vacant slots
        let mut partner = None;
        for _ in 0..PARTNER_TRIES {
            let nb = sh.graph.sample_neighbor(slot, &mut rng);
            if sh.roster.is_live(nb) && nb != slot {
                partner = Some(nb);
                break;
            }
            sh.churn_misses.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(p) = partner {
            let pgen = sh.roster.generation(p);
            let sync1 = Instant::now();
            let pmeta = sh.store.read_node(p, &mut scratch.snapshot[..dim], &mut payload);
            out.read_retries += pmeta.retries;
            out.activity.wait_secs += sync1.elapsed().as_secs_f64();
            let now = sh.done.load(Ordering::Relaxed);
            out.staleness.record(now.saturating_sub(pmeta.stamp));
            let o = sh.policy.merge(&ctx, slot, &mut st, &mut scratch, &mut rng);
            bits += o.bits;
            fallbacks += o.fallbacks;

            let sync2 = Instant::now();
            let stamp = sh.done.load(Ordering::Relaxed);
            out.publish_retries += sh.store.commit(
                slot,
                &st.params,
                st.rng.state_raw(),
                st.steps,
                st.last_loss as f32,
                stamp,
                rng.next_u32(),
                &mut payload,
            );
            // cross-write the partner iff its incarnation survived the
            // merge (a recycled slot must not inherit a stale model)
            if sh.roster.generation(p) == pgen {
                if !sh.store.try_push(p, &scratch.cross[..dim], stamp, rng.next_u32(), &mut payload)
                {
                    out.push_conflicts += 1;
                }
            } else {
                sh.churn_misses.fetch_add(1, Ordering::Relaxed);
            }
            out.activity.wait_secs += sync2.elapsed().as_secs_f64();
        } else {
            // the whole neighborhood churned out: isolated local phase
            let sync2 = Instant::now();
            out.publish_retries += sh.store.commit(
                slot,
                &st.params,
                st.rng.state_raw(),
                st.steps,
                st.last_loss as f32,
                sh.done.load(Ordering::Relaxed),
                rng.next_u32(),
                &mut payload,
            );
            out.activity.wait_secs += sync2.elapsed().as_secs_f64();
        }
        compute_secs += st.compute;
        comm_secs += st.comm_time;
        sh.done.fetch_add(1, Ordering::Release);
        out.activity.interactions += 1;
        // flush hot-path tallies to the shared counters occasionally so
        // the metrics monitor sees live values without per-event traffic
        if done_local % 1024 == 0 {
            sh.bits.fetch_add(bits, Ordering::Relaxed);
            sh.fallbacks.fetch_add(fallbacks, Ordering::Relaxed);
            sh.local_steps.fetch_add(local_steps, Ordering::Relaxed);
            (bits, fallbacks, local_steps) = (0, 0, 0);
        }
        let now = Instant::now();
        out.activity.busy_secs += now.duration_since(busy_mark).as_secs_f64();
        busy_mark = now;
    }
    out.activity.busy_secs -= out.activity.wait_secs.min(out.activity.busy_secs);
    sh.bits.fetch_add(bits, Ordering::Relaxed);
    sh.fallbacks.fetch_add(fallbacks, Ordering::Relaxed);
    sh.local_steps.fetch_add(local_steps, Ordering::Relaxed);
    sh.compute_ns.fetch_add((compute_secs * 1e9) as u64, Ordering::Relaxed);
    sh.comm_ns.fetch_add((comm_secs * 1e9) as u64, Ordering::Relaxed);
    out
}

/// Metrics monitor: appends one Prometheus-text snapshot per cadence tick
/// while the workers run, then one final snapshot.
fn monitor_loop(
    sh: &ScaleShared<'_>,
    stop: &AtomicBool,
    mut f: std::fs::File,
    per_node: u64,
) {
    let reg = MetricsRegistry::new();
    let live = reg.gauge("swarm_live_nodes", "live roster slots");
    let joins = reg.gauge("swarm_joins_total", "admitted node arrivals");
    let leaves = reg.gauge("swarm_leaves_total", "node departures");
    let rejected = reg.gauge("swarm_rejected_joins_total", "arrivals with no vacant slot");
    let bpn = reg.gauge("swarm_bytes_per_node", "resident bytes per node");
    let raw = reg.gauge("swarm_store_raw_nodes", "nodes escaped to full precision");
    let ips = reg.gauge("swarm_interactions_per_sec", "wall-clock interaction rate");
    bpn.set(per_node as f64);
    let start = Instant::now();
    loop {
        let finished = stop.load(Ordering::Acquire);
        live.set(sh.roster.live_count() as f64);
        joins.set(sh.roster.joins() as f64);
        leaves.set(sh.roster.leaves() as f64);
        rejected.set(sh.roster.rejected_joins() as f64);
        raw.set(sh.store.raw_nodes() as f64);
        let secs = start.elapsed().as_secs_f64().max(1e-9);
        ips.set(sh.done.load(Ordering::Relaxed) as f64 / secs);
        let _ = append_snapshot(&mut f, &reg);
        if finished {
            return;
        }
        std::thread::sleep(METRICS_CADENCE);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{make_algorithm, AlgoOptions};

    fn quad(n: usize) -> crate::grad::QuadraticOracle {
        crate::grad::QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.2, 3)
    }

    fn spec(n: usize, events: u64) -> RunSpec {
        RunSpec {
            n,
            events,
            lr: LrSchedule::Constant(0.05),
            seed: 11,
            name: "scale-test".into(),
            eval_every: 0,
            track_gamma: false,
        }
    }

    #[test]
    fn scale_run_converges_on_the_quadratic() {
        let algo = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
        let n = 64;
        let backend = quad(n);
        let opts = ScaleOptions { threads: 2, ..ScaleOptions::default() };
        let m = run_scale(
            algo.as_ref(),
            &backend,
            &spec(n, 4000),
            &CostModel::deterministic(0.1),
            &opts,
        )
        .unwrap();
        let x0_loss = backend.eval(&backend.init().0).loss;
        assert!(
            m.final_eval_loss < 0.5 * x0_loss,
            "no progress: {} vs x0 {}",
            m.final_eval_loss,
            x0_loss
        );
        assert_eq!(m.interactions, 4000);
        assert!(m.local_steps > 0);
        assert_eq!(m.executor, "freerun");
        let fr = m.freerun.as_ref().unwrap();
        let ms = fr.membership.as_ref().unwrap();
        assert_eq!(ms.capacity, n);
        assert_eq!(ms.live_end, n as u64); // no churn configured
        assert_eq!(ms.joins + ms.leaves, 0);
        assert!(ms.bytes_per_node > 0);
        assert_eq!(ms.decode_failures, 0);
    }

    #[test]
    fn scale_engine_rejects_weighted_payloads_and_tiny_n() {
        let sgp = make_algorithm("sgp", &AlgoOptions::default()).unwrap();
        let backend = quad(4);
        let e = run_scale(
            sgp.as_ref(),
            &backend,
            &spec(4, 10),
            &CostModel::deterministic(0.1),
            &ScaleOptions::default(),
        )
        .unwrap_err();
        assert!(e.contains("dense freerun"), "{e}");
        let lsgd = make_algorithm("localsgd", &AlgoOptions::default()).unwrap();
        let e = run_scale(
            lsgd.as_ref(),
            &backend,
            &spec(4, 10),
            &CostModel::deterministic(0.1),
            &ScaleOptions::default(),
        )
        .unwrap_err();
        assert!(e.contains("no free-running mix policy"), "{e}");
        let swarm = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
        let one = quad(1);
        let e = run_scale(
            swarm.as_ref(),
            &one,
            &spec(1, 10),
            &CostModel::deterministic(0.1),
            &ScaleOptions::default(),
        )
        .unwrap_err();
        assert!(e.contains("n >= 2"), "{e}");
    }

    #[test]
    fn node_budget_gate_fires_before_allocation() {
        let algo = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
        let backend = quad(8);
        let opts = ScaleOptions { node_budget: 16, ..ScaleOptions::default() };
        let e = run_scale(
            algo.as_ref(),
            &backend,
            &spec(8, 10),
            &CostModel::deterministic(0.1),
            &opts,
        )
        .unwrap_err();
        assert!(e.contains("bytes/node"), "{e}");
        assert!(e.contains("node_budget=16"), "{e}");
    }

    #[test]
    fn churn_reaches_the_birth_death_equilibrium_band() {
        let algo = make_algorithm("swarm", &AlgoOptions::default()).unwrap();
        let n = 512;
        let backend = quad(n);
        // join/leave = 0.5 → stationary live ≈ n/2, mean-reverting
        let opts = ScaleOptions {
            threads: 2,
            churn: ChurnSpec { join: 0.25, leave: 0.5 },
            ..ScaleOptions::default()
        };
        let m = run_scale(
            algo.as_ref(),
            &backend,
            &spec(n, 20_000),
            &CostModel::deterministic(0.1),
            &opts,
        )
        .unwrap();
        let ms = m.freerun.as_ref().unwrap().membership.as_ref().unwrap();
        assert!(ms.joins > 0 && ms.leaves > 0, "churn never fired: {ms:?}");
        let live = ms.live_end as f64 / n as f64;
        assert!(
            (0.3..=0.7).contains(&live),
            "live fraction {live:.3} outside the n/2 equilibrium band ({ms:?})"
        );
        assert!(m.final_eval_loss.is_finite());
    }
}
